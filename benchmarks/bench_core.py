"""Core-engine benchmarks: vectorized kernels vs the per-touch references.

Four levels, mirroring the engine's layering:

* ``core.mattson.*``   — stack-distance kernel on one real touch stream;
* ``core.traffic.*``   — capacity-batched traffic kernel, Table-V capacities;
* ``core.fig11_sweep.*`` — the end-to-end Fig-11 design-space sweep
  (Table V x all four MLPerf suites): the batched ``SweepEngine`` vs the
  seed-style path (reference Fenwick Mattson + per-touch dirty-state
  recurrence, traffic simulated per (trace, capacity-set) as the old
  ``PerfModel._traffic_cache`` did). The ratio row is the PR-1 acceptance
  number (>= 10x).
* ``core.suite.*``     — the suite-level StreamBatch pass: the whole
  Fig-11 + Fig-12 + serve-grid evaluation (Table V x MLPerf suites x
  scale-out families x serve scenarios x {1,2,4} GPUs + every serve cost
  grid) through ONE ``SuiteAnalysis`` vs the per-trace loop it replaced
  (streams, analyses, traffic and time model all rebuilt per trace, as the
  pre-StreamBatch engine did). The ratio row is the suite-batching
  acceptance number (>= 3x); rows are asserted bit-identical.

All paths share the vectorized bottleneck time model (the seed's was
already per-op NumPy), so each comparison isolates one batching layer.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Csv, suite_scenarios, timed
from repro.core import copa
from repro.core.cachesim import (
    _STREAMS,
    _reference_traffic_below,
    build_stream,
    traffic_below,
)
from repro.core.stackdist import _mattson_pass, _reference_mattson_pass
from repro.core.sweep import (
    SweepEngine,
    TraceAnalysis,
    _as_spec,
    serve_cost_grids,
    suite_analysis_for,
)
from repro.core.sweep import _ANALYSES as _ANALYSIS_CACHE
from repro.core.sweep import _SUITES as _SUITE_CACHE
from repro.core.hw import MB
from repro.workloads import mlperf, registry
from repro.workloads.registry import scenario

TABLE_V_CAPS = [60 * MB, 60 * MB + 960 * MB, 60 * MB + 1920 * MB, float(1 << 50)]


def _fig11_scenarios() -> list[str]:
    return [n for lb in ("train_lb", "train_sb", "infer_lb", "infer_sb")
            for n in suite_scenarios(lb)]


def timed_min(fn, repeats: int = 3):
    """Best-of-N wall time (standard microbenchmark noise suppression);
    returns the last result + the minimum us."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        out, us = timed(fn)
        best = min(best, us)
    return out, best


def _seed_style_fig11(traces) -> dict[tuple[str, str], float]:
    """The pre-engine evaluation path: per-touch kernels, one traffic
    simulation per (trace, distinct capacity set), one analysis per trace."""
    out = {}
    base_spec = _as_spec(copa.GPU_N_BASE)
    specs = [(c.name, _as_spec(c)) for c in copa.TABLE_V]
    for trace in traces:
        stream = build_stream(trace, dist_fn=_reference_mattson_pass)
        ta = TraceAnalysis(trace, stream=stream)
        # Seed PerfModel cached traffic per (l2, l3) key and simulated each
        # key separately; replicate by filling the cache from the reference
        # kernel one capacity set at a time.
        seen: set[tuple[float, ...]] = set()
        for _, spec in [("base", base_spec)] + specs:
            caps = tuple(TraceAnalysis.capacities_for(spec))
            if caps in seen:
                continue
            seen.add(caps)
            for cap, lt in zip(caps, _reference_traffic_below(stream, list(caps))):
                ta._levels.setdefault(float(cap), lt)
        t_base = ta.time(base_spec)
        for name, spec in specs:
            out[(trace.name, name)] = t_base / ta.attribution(spec)[0]
    return out


def bench_core(csv: Csv):
    # --- kernel level: one real stream ---------------------------------------
    stream = build_stream(mlperf.training_trace("transformer", "large"))
    ids, sizes = stream.tensor_idx, stream.sizes

    _, us_vec = timed_min(lambda: _mattson_pass(ids, sizes))
    _, us_ref = timed_min(lambda: _reference_mattson_pass(ids, sizes))
    csv.add("core.mattson.vectorized", us_vec, f"{len(ids)} touches")
    csv.add("core.mattson.reference", us_ref,
            f"{us_ref / max(us_vec, 1e-9):.1f}x slower")

    _, us_vec = timed_min(lambda: traffic_below(stream, TABLE_V_CAPS))
    _, us_ref = timed_min(lambda: _reference_traffic_below(stream, TABLE_V_CAPS))
    csv.add("core.traffic.vectorized", us_vec, f"{len(TABLE_V_CAPS)} capacities")
    csv.add("core.traffic.reference", us_ref,
            f"{us_ref / max(us_vec, 1e-9):.1f}x slower")

    # --- end-to-end: the Fig-11 design space ---------------------------------
    traces = [scenario(n) for n in _fig11_scenarios()]

    def engine_run():
        return SweepEngine(traces, configs=copa.TABLE_V,
                           share_analyses=False).run()

    grid, us_engine = timed_min(engine_run)
    seed_out, us_seed = timed_min(lambda: _seed_style_fig11(traces))
    csv.add("core.fig11_sweep.engine", us_engine,
            f"{len(grid.rows)} (trace,config) cells")
    csv.add("core.fig11_sweep.reference_seed", us_seed,
            "per-touch kernels, per-config traffic")
    worst = max(
        abs(seed_out[(r.trace, r.config)] - r.speedup)
        / max(abs(seed_out[(r.trace, r.config)]), 1e-12)
        for r in grid.rows
    )
    csv.add("core.fig11_sweep.speedup", 0.0,
            f"{us_seed / max(us_engine, 1e-9):.1f}x faster "
            f"(acceptance >= 10x; max rel diff vs reference {worst:.2e})")


def bench_timemodel(csv: Csv):
    """(config x op) batched time model vs the per-spec reference loop.

    Both sides cost the full Table-V attribution (4 idealization terms per
    config) on one real trace from a warm traffic cache, so the comparison
    isolates exactly the matrix evaluation the engine now uses.
    """
    trace = mlperf.training_trace("transformer", "large")
    ta = TraceAnalysis(trace)
    specs = [_as_spec(c) for c in copa.TABLE_V]
    caps = {c for s in specs for c in TraceAnalysis.capacities_for(s)}
    ta.prefetch(caps)

    def reference():
        out = []
        for s in specs:
            t_act = ta._reference_time(s)
            t_nd = ta._reference_time(s, ideal_dram=True)
            t_nm = ta._reference_time(s, ideal_dram=True,
                                      ideal_mem_other=True)
            t_m = ta._reference_time(s, ideal_dram=True, ideal_mem_other=True,
                                     ideal_occupancy=True)
            out.append((t_act, {"Math": t_m,
                                "SM util": max(t_nm - t_m, 0.0),
                                "Memory others": max(t_nd - t_nm, 0.0),
                                "DRAM BW": max(t_act - t_nd, 0.0)}))
        return out

    got, us_vec = timed_min(lambda: ta.attribution_batch(specs))
    ref, us_ref = timed_min(reference)
    worst = max(abs(g[0] - r[0]) / r[0] for g, r in zip(got, ref))
    csv.add("core.timemodel.batched", us_vec,
            f"{len(specs)} configs x {len(ta.flops)} ops")
    csv.add("core.timemodel.reference", us_ref,
            f"{us_ref / max(us_vec, 1e-9):.1f}x slower; "
            f"max rel diff {worst:.1e}")


def _suite_works() -> list[str]:
    """The end-to-end benchmark suite: Fig 11 (all four MLPerf suites),
    Fig 12 (fixed-global-batch scale-out families), and the serve grid."""
    return (_fig11_scenarios()
            + registry.scaleout_names("scaleout.mlperf.train.")
            + registry.scenarios("serve.mlperf."))


def _per_trace_cost_grids(bench: str, configs) -> np.ndarray:
    """The pre-StreamBatch serve-grid pricing loop: one fresh analysis and
    one ``time_batch`` per batch scenario."""
    names = registry.scenarios(f"serve.mlperf.{bench}.b")
    by_batch = sorted((int(n.rsplit(".b", 1)[1]), n) for n in names)
    spec_objs = [_as_spec(c) for c in configs]
    base = np.empty((len(by_batch), len(spec_objs)))
    for k, (_, scen) in enumerate(by_batch):
        base[k] = TraceAnalysis(registry.scenario(scen)).time_batch(spec_objs)
    return base


def bench_core_suite(csv: Csv):
    """Suite-level batching: Fig-11 + Fig-12 + serve grids, one StreamBatch
    pass vs the per-trace loop. Acceptance: >= 3x, rows bit-identical."""
    works = _suite_works()
    kw = dict(configs=copa.TABLE_V, gpu_counts=(1, 2, 4))

    def batched():
        # The shipped path: one SuiteAnalysis pass per engine run; stream/
        # suite caches shared across runs (steady-state cost of repeated
        # full-suite sweeps — the first build is the core.suite.build row).
        grid = SweepEngine(works, **kw).run()
        for b in mlperf.INFER_BATCHES:
            serve_cost_grids(b, copa.TABLE_V)
        return grid

    def per_trace():
        # The pre-StreamBatch engine: no stream cache existed, every run
        # flattened + Mattson'd + simulated + costed one trace at a time.
        _STREAMS.clear()
        grid = SweepEngine(works, share_analyses=False, **kw).run(batched=False)
        for b in mlperf.INFER_BATCHES:
            _per_trace_cost_grids(b, copa.TABLE_V)
        return grid

    grid_b, us_b = timed_min(batched)
    grid_p, us_p = timed_min(per_trace)
    identical = len(grid_b.rows) == len(grid_p.rows) and all(
        dataclasses.asdict(rb) == dataclasses.asdict(rp)
        for rb, rp in zip(grid_b.rows, grid_p.rows)
    )
    csv.add("core.suite.batched", us_b,
            f"{len(grid_b.rows)} grid rows + {len(mlperf.INFER_BATCHES)} "
            f"serve grids, one SuiteAnalysis pass")
    csv.add("core.suite.per_trace", us_p,
            "pre-StreamBatch loop: per-trace streams/traffic/time")
    csv.add("core.suite.speedup", 0.0,
            f"{us_p / max(us_b, 1e-9):.1f}x faster (acceptance >= 3x; "
            f"rows bit-identical: {identical})")

    # One-time suite construction from cold: batched flatten + Mattson +
    # padding for every distinct trace the suite touches.
    traces = [t for w in SweepEngine(works, **kw).workloads
              for t in (w.trace_for(1), w.trace_for(2), w.trace_for(4))]
    uniq = list({id(t): t for t in traces}.values())

    def build_cold():
        _clear_suite_caches(uniq)
        return suite_analysis_for(uniq)

    _, us_build = timed(build_cold)
    csv.add("core.suite.build", us_build,
            f"cold batched stream+pad build, {len(uniq)} traces")

    # Full-registry one-call sweep: every scenario namespace at once.
    def registry_sweep():
        return SweepEngine(registry.scenarios(), configs=copa.TABLE_V).run()

    grid_r, us_reg = timed_min(registry_sweep)
    csv.add("core.suite.registry", us_reg,
            f"{len(grid_r.rows)} rows: all {len(registry.scenarios())} "
            f"registry scenarios x Table V in one pass")


def _clear_suite_caches(traces) -> None:
    """Drop every layer the suite build path can warm — streams (and their
    scan layouts), per-trace analyses, suite memos, and the traces' touch
    tables — so a 'cold' timing really pays the flatten."""
    _STREAMS.clear()
    _SUITE_CACHE.clear()
    _ANALYSIS_CACHE.clear()
    for t in traces:
        t.__dict__.pop("_touch_table", None)


def bench_core_suite_incremental(csv: Csv):
    """PR-10 incremental builds, with the CI speed floors asserted
    in-function (a violated floor raises, which turns the row into an
    ``.ERROR`` row and fails the harness run):

    * ``core.suite.warm_registry`` — a `suite_analysis_for` MISS over the
      already-analyzed full registry (the memo cleared, streams/layouts
      warm): padded-row assembly only, floor <= 15ms;
    * ``core.suite.incremental`` — `suite_append` of ONE new scenario onto
      a warm full-registry suite vs the cold rebuild of the grown
      membership, floor >= 5x faster.
    """
    from repro.core.sweep import SuiteAnalysis, suite_append

    traces = [scenario(n) for n in registry.scenarios()]
    caps = [60 * MB, 1020 * MB, float(1 << 50)]
    warm = suite_analysis_for(traces)
    warm.prefetch(caps)

    def warm_rebuild():
        _SUITE_CACHE.clear()
        return suite_analysis_for(traces)

    _, us_warm = timed_min(warm_rebuild)
    csv.add("core.suite.warm_registry", us_warm,
            f"{len(traces)}-trace memo miss, streams/layouts warm "
            f"(CI floor <= 15ms)")
    assert us_warm <= 15_000, \
        f"warm full-registry rebuild {us_warm:.0f}us > 15ms floor"

    base_traces, newcomer = traces[:-1], traces[-1]
    us_app = float("inf")
    for _ in range(3):
        _SUITE_CACHE.clear()
        base = suite_analysis_for(base_traces)
        base.prefetch(caps)
        _, us = timed(lambda: suite_append(base, [newcomer]))
        us_app = min(us_app, us)

    def rebuild_cold():
        _clear_suite_caches(traces)
        suite = SuiteAnalysis(traces)
        suite.prefetch(caps)
        return suite

    _, us_cold = timed_min(rebuild_cold)
    ratio = us_cold / max(us_app, 1e-9)
    csv.add("core.suite.incremental", us_app,
            f"append 1 of {len(traces)} scenarios + capacity union vs "
            f"{us_cold:.0f}us cold rebuild: {ratio:.1f}x "
            f"(CI floor >= 5x)")
    assert ratio >= 5.0, \
        f"single-scenario append only {ratio:.1f}x faster than cold rebuild"


def bench_check(csv: Csv):
    """Static analyzer: cold jaxpr trace + R1-R5 lint over the kernel
    catalog, and the kernel.* registry sweep those facts feed."""
    from repro.check import catalog
    from repro.check.rules import run_rules

    def cold_lint():
        catalog.trace_case.cache_clear()
        return run_rules(catalog.trace_all())

    findings, us_lint = timed(cold_lint)
    n_calls = sum(len(catalog.trace_case(n)) for n in catalog.case_names())
    csv.add("core.check.lint", us_lint,
            f"cold abstract-trace + lint: {n_calls} pallas_calls / "
            f"{len(catalog.case_names())} cases, "
            f"{sum(1 for f in findings if not f.waived)} unwaived")

    def kernel_sweep():
        return SweepEngine(["kernel.*"], configs=copa.TABLE_V).run()

    grid, us_k = timed_min(kernel_sweep)
    csv.add("core.check.sweep", us_k,
            f"{len(grid.rows)} rows: kernel.* catalog x Table V")


ALL = [bench_core, bench_timemodel, bench_core_suite,
       bench_core_suite_incremental, bench_check]
