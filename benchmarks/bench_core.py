"""Core-engine benchmarks: vectorized kernels vs the per-touch references.

Three levels, mirroring the engine's layering:

* ``core.mattson.*``   — stack-distance kernel on one real touch stream;
* ``core.traffic.*``   — capacity-batched traffic kernel, Table-V capacities;
* ``core.fig11_sweep.*`` — the end-to-end Fig-11 design-space sweep
  (Table V x all four MLPerf suites): the batched ``SweepEngine`` vs the
  seed-style path (reference Fenwick Mattson + per-touch dirty-state
  recurrence, traffic simulated per (trace, capacity-set) as the old
  ``PerfModel._traffic_cache`` did). The ratio row is the PR's acceptance
  number (>= 10x).

Both paths share the vectorized bottleneck time model (the seed's was
already per-op NumPy), so the comparison isolates exactly what this PR
vectorized.
"""
from __future__ import annotations

from benchmarks.common import Csv, suite_scenarios, timed
from repro.core import copa
from repro.core.cachesim import (
    _reference_traffic_below,
    build_stream,
    traffic_below,
)
from repro.core.stackdist import _mattson_pass, _reference_mattson_pass
from repro.core.sweep import SweepEngine, TraceAnalysis, _as_spec
from repro.core.hw import MB
from repro.workloads import mlperf
from repro.workloads.registry import scenario

TABLE_V_CAPS = [60 * MB, 60 * MB + 960 * MB, 60 * MB + 1920 * MB, float(1 << 50)]


def _fig11_scenarios() -> list[str]:
    return [n for lb in ("train_lb", "train_sb", "infer_lb", "infer_sb")
            for n in suite_scenarios(lb)]


def timed_min(fn, repeats: int = 3):
    """Best-of-N wall time (standard microbenchmark noise suppression);
    returns the last result + the minimum us."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        out, us = timed(fn)
        best = min(best, us)
    return out, best


def _seed_style_fig11(traces) -> dict[tuple[str, str], float]:
    """The pre-engine evaluation path: per-touch kernels, one traffic
    simulation per (trace, distinct capacity set), one analysis per trace."""
    out = {}
    base_spec = _as_spec(copa.GPU_N_BASE)
    specs = [(c.name, _as_spec(c)) for c in copa.TABLE_V]
    for trace in traces:
        stream = build_stream(trace, dist_fn=_reference_mattson_pass)
        ta = TraceAnalysis(trace, stream=stream)
        # Seed PerfModel cached traffic per (l2, l3) key and simulated each
        # key separately; replicate by filling the cache from the reference
        # kernel one capacity set at a time.
        seen: set[tuple[float, ...]] = set()
        for _, spec in [("base", base_spec)] + specs:
            caps = tuple(TraceAnalysis.capacities_for(spec))
            if caps in seen:
                continue
            seen.add(caps)
            for cap, lt in zip(caps, _reference_traffic_below(stream, list(caps))):
                ta._levels.setdefault(float(cap), lt)
        t_base = ta.time(base_spec)
        for name, spec in specs:
            out[(trace.name, name)] = t_base / ta.attribution(spec)[0]
    return out


def bench_core(csv: Csv):
    # --- kernel level: one real stream ---------------------------------------
    stream = build_stream(mlperf.training_trace("transformer", "large"))
    ids, sizes = stream.tensor_idx, stream.sizes

    _, us_vec = timed_min(lambda: _mattson_pass(ids, sizes))
    _, us_ref = timed_min(lambda: _reference_mattson_pass(ids, sizes))
    csv.add("core.mattson.vectorized", us_vec, f"{len(ids)} touches")
    csv.add("core.mattson.reference", us_ref,
            f"{us_ref / max(us_vec, 1e-9):.1f}x slower")

    _, us_vec = timed_min(lambda: traffic_below(stream, TABLE_V_CAPS))
    _, us_ref = timed_min(lambda: _reference_traffic_below(stream, TABLE_V_CAPS))
    csv.add("core.traffic.vectorized", us_vec, f"{len(TABLE_V_CAPS)} capacities")
    csv.add("core.traffic.reference", us_ref,
            f"{us_ref / max(us_vec, 1e-9):.1f}x slower")

    # --- end-to-end: the Fig-11 design space ---------------------------------
    traces = [scenario(n) for n in _fig11_scenarios()]

    def engine_run():
        return SweepEngine(traces, configs=copa.TABLE_V,
                           share_analyses=False).run()

    grid, us_engine = timed_min(engine_run)
    seed_out, us_seed = timed_min(lambda: _seed_style_fig11(traces))
    csv.add("core.fig11_sweep.engine", us_engine,
            f"{len(grid.rows)} (trace,config) cells")
    csv.add("core.fig11_sweep.reference_seed", us_seed,
            "per-touch kernels, per-config traffic")
    worst = max(
        abs(seed_out[(r.trace, r.config)] - r.speedup)
        / max(abs(seed_out[(r.trace, r.config)]), 1e-12)
        for r in grid.rows
    )
    csv.add("core.fig11_sweep.speedup", 0.0,
            f"{us_seed / max(us_engine, 1e-9):.1f}x faster "
            f"(acceptance >= 10x; max rel diff vs reference {worst:.2e})")


def bench_timemodel(csv: Csv):
    """(config x op) batched time model vs the per-spec reference loop.

    Both sides cost the full Table-V attribution (4 idealization terms per
    config) on one real trace from a warm traffic cache, so the comparison
    isolates exactly the matrix evaluation the engine now uses.
    """
    trace = mlperf.training_trace("transformer", "large")
    ta = TraceAnalysis(trace)
    specs = [_as_spec(c) for c in copa.TABLE_V]
    caps = {c for s in specs for c in TraceAnalysis.capacities_for(s)}
    ta.prefetch(caps)

    def reference():
        out = []
        for s in specs:
            t_act = ta._reference_time(s)
            t_nd = ta._reference_time(s, ideal_dram=True)
            t_nm = ta._reference_time(s, ideal_dram=True,
                                      ideal_mem_other=True)
            t_m = ta._reference_time(s, ideal_dram=True, ideal_mem_other=True,
                                     ideal_occupancy=True)
            out.append((t_act, {"Math": t_m,
                                "SM util": max(t_nm - t_m, 0.0),
                                "Memory others": max(t_nd - t_nm, 0.0),
                                "DRAM BW": max(t_act - t_nd, 0.0)}))
        return out

    got, us_vec = timed_min(lambda: ta.attribution_batch(specs))
    ref, us_ref = timed_min(reference)
    worst = max(abs(g[0] - r[0]) / r[0] for g, r in zip(got, ref))
    csv.add("core.timemodel.batched", us_vec,
            f"{len(specs)} configs x {len(ta.flops)} ops")
    csv.add("core.timemodel.reference", us_ref,
            f"{us_ref / max(us_vec, 1e-9):.1f}x slower; "
            f"max rel diff {worst:.1e}")


ALL = [bench_core, bench_timemodel]
