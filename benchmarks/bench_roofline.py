"""Roofline + arch-trace benchmarks (the TPU side of the study).

* roofline rows read the dry-run results JSON (written by
  ``repro.launch.dryrun``) and emit the three terms per cell;
* arch-COPA rows run the paper's cache/perf analysis over the assigned
  architectures (workloads.lm), tying the technique to our model zoo;
* kernel rows time the Pallas kernels in interpret mode (correctness-scale
  shapes; wall time on CPU is NOT TPU perf — the derived column carries the
  modelled HBM traffic instead, which is the quantity the kernels optimize).
"""
from __future__ import annotations

import json
import os


from benchmarks.common import Csv, timed
from repro.core.hw import MB
from repro.core.roofline import RooflineReport, useful_flops_cell
import repro.configs as configs

DRYRUN_JSON = os.environ.get("DRYRUN_JSON", "dryrun_results.json")


def load_reports(path: str = DRYRUN_JSON) -> list[RooflineReport]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        results = json.load(f)
    reports = []
    for key, r in results.items():
        if r.get("status") != "ok":
            continue
        cfg = configs.get(r["arch"])
        shape = configs.SHAPES[r["shape"]]
        reports.append(RooflineReport(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            chips=r["chips"],
            hlo_flops=r.get("flops_adjusted", r["flops_per_device"]),
            hlo_bytes=r.get("bytes_adjusted", r["bytes_per_device"]),
            collective_bytes=r.get("collective_adjusted",
                                   r["collective_bytes_per_device"]),
            model_flops=useful_flops_cell(cfg, shape),
            peak_memory_bytes=r.get("peak_memory_per_device", 0),
        ))
    return reports


def bench_roofline(csv: Csv):
    reports = load_reports()
    if not reports:
        csv.add("roofline.missing", 0.0, "run repro.launch.dryrun first")
        return
    for r in sorted(reports, key=lambda x: (x.arch, x.shape, x.mesh)):
        if r.mesh != "16x16":
            continue
        csv.add(f"roofline.{r.arch}.{r.shape}", 0.0,
                f"compute={r.compute_s:.3e}s memory={r.memory_s:.3e}s "
                f"collective={r.collective_s:.3e}s dominant={r.dominant} "
                f"roofline_frac={r.roofline_fraction:.3f}")


def bench_arch_copa(csv: Csv):
    """The paper's analysis applied to the assigned architectures — one
    engine grid over the lm registry scenarios to warm the shared caches,
    then each scenario's repricing timed on its own. (These rows used to
    split ONE wall time evenly across all scenarios, so every row recorded
    the identical number; now each row is its own measurement.)"""
    from repro.core import copa
    from repro.core.sweep import SweepEngine

    names = [f"lm.{arch}.{shape}" for arch in configs.ARCHS
             for shape in ("train_4k", "decode_32k")]
    kw = dict(configs=[copa.GPU_N_BASE],
              extra_llc_capacities=[60 * MB, 960 * MB])

    def run_one(name: str):
        grid = SweepEngine([name], **kw).run()
        t = grid.traces[0]
        r = grid.result(t, "GPU-N")
        sweep = grid.llc_traffic[t]
        red = sweep[float(60 * MB)] / max(sweep[float(960 * MB)], 1e-9)
        return t, r.time_s, r.bottleneck, min(red, 1e3)

    SweepEngine(names, **kw).run()  # warm streams/analyses/suite caches
    for name in names:
        (t, tsec, bn, red), us = timed(lambda n=name: run_one(n))
        csv.add(f"arch_copa.{t}", us,
                f"T={tsec*1e3:.2f}ms bottleneck={bn} "
                f"l3_960MB_traffic_reduction={red:.1f}x")


def bench_kernels(csv: Csv):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention_pallas

    def run():
        key = jax.random.PRNGKey(0)
        b, s, h, kvh, d = 1, 1024, 8, 2, 64
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
        o1 = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        o2 = ref.flash_attention_ref(q, k, v, causal=True)
        err = float(jnp.abs(o1 - o2).max())
        # modelled traffic: naive materializes S twice (fp32), flash doesn't
        naive_bytes = (q.size + k.size + v.size + o1.size) * 4 \
            + 2 * b * h * s * s * 4
        flash_bytes = (q.size + k.size + v.size + o1.size) * 4
        return err, naive_bytes / flash_bytes

    (err, ratio), us = timed(run)
    csv.add("kernels.flash_attention.allclose_err", us, f"{err:.2e}")
    csv.add("kernels.flash_attention.hbm_traffic_filter", 0.0,
            f"{ratio:.1f}x fewer HBM bytes vs naive (S=1024)")


ALL = [bench_roofline, bench_arch_copa, bench_kernels]
