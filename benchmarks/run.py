"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig11] [--json BENCH_core.json]

Prints ``name,us_per_call,derived`` CSV rows; ``--json OUT`` additionally
writes a ``{name: us_per_call}`` JSON snapshot (the perf-trajectory file —
CI and local runs write ``BENCH_core.json`` at the repo root).
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks.common import Csv


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings; run benches whose "
                         "function name matches any (e.g. fig11,core_suite)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write {name: us_per_call} JSON to OUT")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="diff this run against a saved BENCH_*.json "
                         "snapshot (informational unless "
                         "--fail-on-regression)")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="with --compare: new/old ratio above which a row "
                         "is REGRESSED (default 1.25)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="with --compare: exit 1 when any row regressed "
                         "past the threshold")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    from benchmarks import bench_core, bench_paper_figs, bench_roofline, \
        bench_serving

    benches = (bench_core.ALL + bench_paper_figs.ALL + bench_roofline.ALL
               + bench_serving.ALL)
    csv = Csv()
    print("name,us_per_call,derived")
    for fn in benches:
        if only and not any(tok in fn.__name__ for tok in only):
            continue
        try:
            fn(csv)
        except Exception as e:  # noqa: BLE001 — report, keep benching
            csv.add(f"{fn.__name__}.ERROR", 0.0, f"{type(e).__name__}: {e}")
    csv.emit()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(csv.as_json_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
    regressed = []
    if args.compare:
        from benchmarks.compare import compare_rows, format_table

        with open(args.compare) as f:
            baseline = json.load(f)
        rows = compare_rows(baseline, csv.as_json_dict(),
                            threshold=args.threshold)
        print(format_table(rows))
        regressed = [r["name"] for r in rows if r["status"] == "REGRESSED"]
    if csv.errors:
        print(f"{len(csv.errors)} benchmark(s) errored: {', '.join(csv.errors)}",
              file=sys.stderr)
        sys.exit(1)
    if regressed and args.fail_on_regression:
        print(f"{len(regressed)} row(s) regressed past "
              f"{args.threshold:.2f}x: {', '.join(regressed)}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
