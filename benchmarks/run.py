"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig11]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import Csv


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import bench_paper_figs, bench_roofline

    benches = bench_paper_figs.ALL + bench_roofline.ALL
    csv = Csv()
    print("name,us_per_call,derived")
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            fn(csv)
        except Exception as e:  # noqa: BLE001 — report, keep benching
            csv.add(f"{fn.__name__}.ERROR", 0.0, f"{type(e).__name__}: {e}")
    csv.emit()


if __name__ == '__main__':
    main()
