"""Serving-simulator benchmarks: fixed-seed, deterministic smoke rows.

``serving.smoke.*`` pins the request-level simulator's derived numbers (the
CI smoke step asserts nothing here — determinism means any drift shows up
as a diff against the recorded derived strings) and times the hot paths:
cost-grid export, the single-instance event loop at saturation, and the
fleet SLO scan. ``BENCH_serving.json`` records the us-per-call snapshot.
"""
from __future__ import annotations

from benchmarks.common import Csv, timed
from repro.core import copa
from repro.core.sweep import SweepEngine, serve_cost_grids
from repro.serve.fleet import instances_to_meet_slo
from repro.serve.sim import ArrivalSpec, Request, Slo, simulate

BENCH = "resnet"
CONFIGS = [copa.GPU_N_BASE, copa.HBM_L3]
SEED = 0


def bench_serving_smoke(csv: Csv):
    def build():
        return serve_cost_grids(BENCH, CONFIGS)

    grids, us = timed(build)
    csv.add("serving.smoke.cost_grid_export", us,
            f"{len(grids)}cfg x {len(grids['GPU-N'].batches)}batch")

    # closed-loop saturation: simulator vs the engine's serve row
    g = grids["GPU-N"]
    eng = SweepEngine([f"serve.mlperf.{BENCH}.b{g.max_batch}"],
                      configs=[copa.GPU_N_BASE]).run()
    row = eng.rows[0]

    def saturate():
        reqs = [Request(rid=i, t_arrival=0.0) for i in range(4 * g.max_batch)]
        return simulate(reqs, g).metrics

    m, us = timed(saturate)
    csv.add("serving.smoke.saturation_throughput", us,
            f"{m.throughput_rps:.1f}r/s (engine row {row.throughput:.1f})")

    # open-loop latency at 0.8x saturation, one instance per config
    rate = 0.8 * g.saturated_rps()
    arrivals = ArrivalSpec(name="bench.poisson", rate=rate, n_requests=512)

    def open_loop():
        out = {}
        for name, grid in grids.items():
            out[name] = simulate(arrivals.generate(SEED), grid).metrics
        return out

    metrics, us = timed(open_loop)
    for name, m in metrics.items():
        csv.add(f"serving.smoke.{name}.ttft_p99", us / len(metrics),
                f"{m.percentile('ttft', 99) * 1e3:.3f}ms")

    # SLO fleet sizing at 2.2x GPU-N saturation (long enough that an
    # undersized fleet's backlog blows the TTFT tail)
    slo = Slo(ttft_s=4 * g.step_time(g.max_batch), percentile=95)
    heavy = ArrivalSpec(name="bench.heavy", rate=2.2 * g.saturated_rps(),
                        n_requests=2048)

    def size():
        return {name: instances_to_meet_slo(grid, heavy, slo,
                                            max_instances=8, seed=SEED)
                for name, grid in grids.items()}

    sizes, us = timed(size)
    for name, n in sizes.items():
        csv.add(f"serving.smoke.{name}.instances_to_meet_slo",
                us / len(sizes), f"{n} @2.2x sat")


ALL = [bench_serving_smoke]
