"""Serving-simulator benchmarks: fixed-seed, deterministic smoke rows.

``serving.smoke.*`` pins the request-level simulator's derived numbers (the
CI smoke step asserts nothing here — determinism means any drift shows up
as a diff against the recorded derived strings) and times the hot paths:
cost-grid export, the single-instance event loop at saturation, and the
fleet SLO scan. ``serving.fleet.*`` times the vectorized fleet core
(`repro.serve.fleetbatch`) against the per-instance heap oracle on the
same stream — identical results, so the derived speedup is pure engine
cost; the 64x20k row ASSERTS speedup >= 5x (the CI floor; the recorded
number targets >= 10x). ``BENCH_serving.json`` records the us-per-call
snapshot.
"""
from __future__ import annotations

import gc
import time

import numpy as np

from benchmarks.common import Csv, timed
from repro.core import copa
from repro.core.sweep import CostGrid, SweepEngine, serve_cost_grids
from repro.serve.fleet import FleetSim, instances_to_meet_slo
from repro.serve.paged import PagedKvSpec
from repro.serve.sim import ArrivalSpec, LengthDist, Request, Slo, simulate

BENCH = "resnet"
CONFIGS = [copa.GPU_N_BASE, copa.HBM_L3]
SEED = 0


def bench_serving_smoke(csv: Csv):
    def build():
        return serve_cost_grids(BENCH, CONFIGS)

    grids, us = timed(build)
    csv.add("serving.smoke.cost_grid_export", us,
            f"{len(grids)}cfg x {len(grids['GPU-N'].batches)}batch")

    # closed-loop saturation: simulator vs the engine's serve row
    g = grids["GPU-N"]
    eng = SweepEngine([f"serve.mlperf.{BENCH}.b{g.max_batch}"],
                      configs=[copa.GPU_N_BASE]).run()
    row = eng.rows[0]

    def saturate():
        reqs = [Request(rid=i, t_arrival=0.0) for i in range(4 * g.max_batch)]
        return simulate(reqs, g).metrics

    m, us = timed(saturate)
    csv.add("serving.smoke.saturation_throughput", us,
            f"{m.throughput_rps:.1f}r/s (engine row {row.throughput:.1f})")

    # open-loop latency at 0.8x saturation, one instance per config
    rate = 0.8 * g.saturated_rps()
    arrivals = ArrivalSpec(name="bench.poisson", rate=rate, n_requests=512)

    def open_loop():
        out = {}
        for name, grid in grids.items():
            out[name] = simulate(arrivals.generate(SEED), grid).metrics
        return out

    metrics, us = timed(open_loop)
    for name, m in metrics.items():
        csv.add(f"serving.smoke.{name}.ttft_p99", us / len(metrics),
                f"{m.percentile('ttft', 99) * 1e3:.3f}ms")

    # SLO fleet sizing at 2.2x GPU-N saturation (long enough that an
    # undersized fleet's backlog blows the TTFT tail)
    slo = Slo(ttft_s=4 * g.step_time(g.max_batch), percentile=95)
    heavy = ArrivalSpec(name="bench.heavy", rate=2.2 * g.saturated_rps(),
                        n_requests=2048)

    def size():
        return {name: instances_to_meet_slo(grid, heavy, slo,
                                            max_instances=8, seed=SEED)
                for name, grid in grids.items()}

    sizes, us = timed(size)
    for name, n in sizes.items():
        csv.add(f"serving.smoke.{name}.instances_to_meet_slo",
                us / len(sizes), f"{n} @2.2x sat")


def _fleet_bench_grid(max_batch: int = 16) -> CostGrid:
    """Synthetic grid with batch- and KV-dependent step times — cheap to
    build, exercises every grid-pricing path of both fleet engines."""
    batches = tuple(2 ** k for k in range(max_batch.bit_length() - 1 + 1))
    edges = (2048.0, 8192.0, float("inf"))
    tab = np.asarray([[1e-3 * (1.0 + 0.02 * b + 0.05 * j)
                       for j in range(len(edges))] for b in batches])
    return CostGrid("fleet-bench", batches, edges, tab,
                    prefill_s_per_token=1e-6)


def _best_of(fn, reps: int = 3):
    # timeit-style: GC off while timing so collection pauses (seeded by
    # whatever the earlier benches left alive) don't land in one engine's
    # column
    best, out = float("inf"), None
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
    finally:
        if was_enabled:
            gc.enable()
    return out, best * 1e6


def bench_serving_fleet(csv: Csv):
    mb, out_mean = 16, 32.0
    grid = _fleet_bench_grid(mb)
    step = float(grid.step_time(mb, 4096.0))

    for n_inst, n_req in ((8, 5_000), (64, 20_000)):
        # 0.8x fleet saturation: queues form and drain, batches stay full.
        # LLM-decode-shaped outputs (mean 64 tokens) give every request a
        # long step chain — the regime the per-instance oracle is worst at
        # (O(batch) python work per step vs the batched core's O(1))
        rate = n_inst * 0.8 * mb / (step * 64.0)
        spec = ArrivalSpec("fleet.bench", rate, n_req,
                           prompt=LengthDist("fixed", 128),
                           output=LengthDist("uniform", low=32, high=96))
        kw = dict(max_batch=mb, kv_capacity_tokens=float("inf"))

        rb, us_b = _best_of(
            lambda: FleetSim(grid, n_inst, **kw).run(spec, seed=SEED))
        ro, us_o = _best_of(
            lambda: FleetSim(grid, n_inst, **kw).run(spec, seed=SEED,
                                                     batched=False))
        if not (np.array_equal(rb.batch.t_done, ro.batch.t_done)
                and np.array_equal(rb.batch.t_first_token,
                                   ro.batch.t_first_token)):
            raise AssertionError(
                f"fleet engines diverged at {n_inst}x{n_req}")
        speedup = us_o / us_b
        tag = f"{n_inst}x{n_req // 1000}k"
        csv.add(f"serving.fleet.batched_{tag}", us_b,
                f"{speedup:.1f}x vs oracle")
        csv.add(f"serving.fleet.oracle_{tag}", us_o,
                f"{len(rb.step_logs)} logs, identical results")
        if n_inst == 64:
            # CI floor: the vectorized core must hold at least 5x on the
            # flagship row (recorded speedups target >= 10x)
            assert speedup >= 5.0, \
                f"fleet speedup regressed to {speedup:.1f}x (< 5x floor)"

    # planet-scale sizing: bisect a 256-instance ladder (O(log N) batched
    # runs) for a mixed-rate bursty stream — the workflow the vectorized
    # core exists for
    heavy = ArrivalSpec("fleet.heavy", 180 * 0.8 * mb / (step * out_mean),
                        20_000, burst_factor=3.0, burst_fraction=0.25,
                        period_s=2.0, prompt=LengthDist("fixed", 128),
                        output=LengthDist("uniform", low=16, high=48))
    slo = Slo(ttft_s=50 * step, tpot_s=5 * step, percentile=95)

    def size():
        return instances_to_meet_slo(grid, heavy, slo, max_batch=mb,
                                     max_instances=256, seed=SEED,
                                     strategy="bisect")

    n, us = timed(size)
    csv.add("serving.fleet.size_256ladder", us, f"{n} instances @p95")


def bench_serving_paged(csv: Csv):
    """Block-table residency overhead in the vectorized fleet core: the
    paged fast path (page-occupancy columns + commit-budget prefix check)
    vs plain reservation on the flagship 64x20k row — ASSERTS <= 1.2x —
    plus the rich policy engine (oversubscription + LRU eviction) on a
    KV-pressured fleet for the us-per-call trajectory."""
    mb = 16
    grid = _fleet_bench_grid(mb)
    step = float(grid.step_time(mb, 4096.0))
    n_inst, n_req = 64, 20_000
    rate = n_inst * 0.8 * mb / (step * 64.0)
    spec = ArrivalSpec("fleet.bench", rate, n_req,
                       prompt=LengthDist("fixed", 128),
                       output=LengthDist("uniform", low=32, high=96))
    kw = dict(max_batch=mb, kv_capacity_tokens=float("inf"))
    tag = f"{n_inst}x{n_req // 1000}k"

    _, us_res = _best_of(
        lambda: FleetSim(grid, n_inst, **kw).run(spec, seed=SEED))
    rp, us_pag = _best_of(
        lambda: FleetSim(grid, n_inst, paged=PagedKvSpec(page_size=16),
                         **kw).run(spec, seed=SEED))
    overhead = us_pag / us_res
    csv.add(f"serving.paged.batched_{tag}", us_pag,
            f"{overhead:.2f}x vs reservation")
    csv.add(f"serving.paged.reservation_{tag}", us_res,
            f"{len(rp.step_logs)} logs")
    # CI floor: page bookkeeping must stay within 1.2x of the reservation
    # fast path on the flagship row
    assert overhead <= 1.2, \
        f"paged fleet overhead regressed to {overhead:.2f}x (> 1.2x floor)"

    # rich engine: oversubscribed pool under genuine KV pressure (evictions
    # fire), batched core vs the per-instance oracle
    tight = ArrivalSpec("fleet.paged", rate / 8, 4_000,
                        prompt=LengthDist("lognormal", mean=400, floor=8),
                        output=LengthDist("uniform", low=100, high=300))
    pg = PagedKvSpec(page_size=16, oversubscription=1.5, eviction="lru")
    kw8 = dict(max_batch=mb, kv_capacity_tokens=8_000.0, paged=pg)
    rb, us_b = _best_of(
        lambda: FleetSim(grid, 8, **kw8).run(tight, seed=SEED))
    ro, us_o = _best_of(
        lambda: FleetSim(grid, 8, **kw8).run(tight, seed=SEED,
                                             batched=False))
    if not (np.array_equal(rb.batch.t_done, ro.batch.t_done)
            and np.array_equal(rb.batch.evictions, ro.batch.evictions)):
        raise AssertionError("paged fleet engines diverged under eviction")
    csv.add("serving.paged.evict_8x4k", us_b,
            f"{us_o / us_b:.1f}x vs oracle, "
            f"{int(rb.batch.evictions.sum())} evictions")


def bench_serving_obs(csv: Csv):
    """Post-hoc observability priced against the engine it derives from:
    ``Timeline.derive`` + the windowed ``timeseries`` rollup on the
    flagship 64x20k fleet run — pure numpy over the run's own artifacts —
    ASSERTS <= 15% of the batched sim's cost (the CI floor). Building the
    Chrome-trace JSON event dicts is serialization, not derivation, so it
    gets its own un-floored row for the us-per-call trajectory."""
    from repro.obs.series import timeseries
    from repro.obs.timeline import Timeline, chrome_trace
    from repro.serve.sim import ObsConfig

    mb = 16
    grid = _fleet_bench_grid(mb)
    step = float(grid.step_time(mb, 4096.0))
    n_inst, n_req = 64, 20_000
    rate = n_inst * 0.8 * mb / (step * 64.0)
    spec = ArrivalSpec("fleet.bench", rate, n_req,
                       prompt=LengthDist("fixed", 128),
                       output=LengthDist("uniform", low=32, high=96))
    kw = dict(max_batch=mb, kv_capacity_tokens=float("inf"),
              obs=ObsConfig(level=1))
    tag = f"{n_inst}x{n_req // 1000}k"

    res, us_sim = _best_of(
        lambda: FleetSim(grid, n_inst, **kw).run(spec, seed=SEED))
    window = res.metrics.makespan_s / 50.0

    (tl, series), us_derive = _best_of(
        lambda: (Timeline.derive(res), timeseries(res, window)))
    frac = us_derive / us_sim
    csv.add(f"serving.obs.derive_{tag}", us_derive,
            f"{frac:.2f}x of batched sim ({tl.n_steps_total} steps, "
            f"{len(series)} windows)")
    # CI floor: deriving the timeline + the windowed rollup must stay a
    # rounding error next to the simulation itself
    assert frac <= 0.15, \
        f"obs derivation costs {frac:.2f}x of the batched sim (> 0.15 floor)"

    doc, us_ser = _best_of(
        lambda: chrome_trace(tl), reps=1)
    csv.add(f"serving.obs.chrome_trace_{tag}", us_ser,
            f"{len(doc['traceEvents'])} events (serialization, un-floored)")


ALL = [bench_serving_smoke, bench_serving_fleet, bench_serving_paged,
       bench_serving_obs]
