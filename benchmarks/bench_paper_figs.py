"""Benchmarks reproducing every COPA-GPU paper figure/table.

Each function emits ``name,us_per_call,derived`` rows; ``derived`` carries
the figure's headline metric next to the paper's reported value so the
reproduction gap is visible in raw CSV.

Every figure is one :class:`~repro.core.sweep.SweepEngine` grid: the engine
shares each trace's touch stream and batches all cache capacities a figure
needs into a single vectorized traffic pass, so the whole paper evaluation
is O(one trace walk per workload) instead of O(one per (workload, config)).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    Csv,
    geomean,
    suite_scenarios,
    suite_trace_names,
    timed,
)
from repro.core import copa, hw
from repro.core.hw import MB
from repro.core.sweep import SweepEngine
from repro.workloads import mlperf
from repro.workloads.registry import scaleout as registry_scaleout
from repro.workloads.registry import scenario
from repro.workloads.registry import suite as registry_suite


def bench_table1(csv: Csv):
    """Table I: memory-BW-to-math ratios across GPU generations."""
    def run():
        rows = []
        for g in (hw.P100, hw.V100, hw.A100, hw.GPU_N):
            r32 = g.dram_bandwidth / (g.fp32_tflops * 1e12) * 1e3
            r16 = g.dram_bandwidth / (g.fp16_tflops * 1e12) * 1e3
            rows.append((g.name, r32, r16))
        return rows

    rows, us = timed(run)
    for name, r32, r16 in rows:
        csv.add(f"table1.{name}.bw_per_fp32_tflop", us / len(rows),
                f"{r32:.1f}mB/F")
        csv.add(f"table1.{name}.bw_per_fp16_tflop", us / len(rows),
                f"{r16:.2f}mB/F (paper: P100 35 -> GPU-N 3.4)")


def bench_fig2(csv: Csv):
    """Fig 2: GPU-N bottleneck attribution."""
    def run():
        groups = {
            "train": suite_scenarios("train_lb") + suite_scenarios("train_sb"),
            "infer_lb": suite_scenarios("infer_lb"),
            "infer_sb": suite_scenarios("infer_sb"),
        }
        names = [n for g in groups.values() for n in g]
        grid = SweepEngine(names, configs=[copa.GPU_N_BASE]).run()
        out = {}
        for label, scen in groups.items():
            segs = {"DRAM BW": [], "SM util": [], "Memory others": [], "Math": []}
            for n in scen:
                r = grid.result(scenario(n).name, "GPU-N")
                for k in segs:
                    segs[k].append(r.segments[k] / r.time_s)
            out[label] = {k: float(np.mean(v)) for k, v in segs.items()}
        return out

    out, us = timed(run)
    csv.add("fig2.train.dram_frac", us, f"{out['train']['DRAM BW']:.3f} (paper 0.28)")
    csv.add("fig2.infer_lb.dram_frac", us, f"{out['infer_lb']['DRAM BW']:.3f} (paper 0.30)")
    csv.add("fig2.infer_sb.smutil_frac", us, f"{out['infer_sb']['SM util']:.3f} (paper 0.41)")


def bench_fig3(csv: Csv):
    """Fig 3: HPC DRAM-bandwidth insensitivity (130 workloads)."""
    def run():
        configs = [
            hw.GPU_N.with_(name=f"GPU-N@{label}",
                           dram_bandwidth=hw.GPU_N.dram_bandwidth * scale)
            for scale, label in ((1e6, "inf"), (1.5, "1.5x"),
                                 (0.75, "0.75x"), (0.5, "0.5x"))
        ]
        grid = SweepEngine(registry_suite("hpc"), configs=configs).run()
        return {c.name.split("@")[1]: grid.geomean_speedup(c.name)
                for c in configs}

    out, us = timed(run)
    csv.add("fig3.hpc.speedup_infBW", us, f"{out['inf']:.3f} (paper 1.05)")
    csv.add("fig3.hpc.speedup_0.75x", us, f"{out['0.75x']:.3f} (paper 0.96)")
    csv.add("fig3.hpc.speedup_0.5x", us, f"{out['0.5x']:.3f} (paper 0.86)")


CAPS_MB = (60, 120, 240, 480, 960, 1920, 3840)


def bench_fig4(csv: Csv):
    """Fig 4: DRAM traffic reduction vs LLC capacity."""
    def run():
        labels = ("train_lb", "infer_lb", "infer_sb")
        names = [n for lb in labels for n in suite_scenarios(lb)]
        caps = [c * MB for c in CAPS_MB]
        grid = SweepEngine(names, configs=[], extra_llc_capacities=caps).run()
        out = {}
        for lb in labels:
            reds = []
            for t in suite_trace_names(lb):
                sweep = grid.llc_traffic[t]
                base = sweep[float(60 * MB)]
                reds.append([min(base / max(sweep[float(c * MB)], 1e-9), 1e3)
                             for c in CAPS_MB])
            arr = np.array(reds)
            out[lb] = {"geo": np.exp(np.log(arr).mean(0)), "max": arr.max(0)}
        return out

    out, us = timed(run)
    g = out["train_lb"]
    csv.add("fig4.train_lb.reduction_960MB_max", us,
            f"{g['max'][4]:.1f}x (paper 'up to 5x')")
    csv.add("fig4.train_lb.reduction_120MB_max", us,
            f"{g['max'][1]:.2f}x (paper 'up to 2.1x')")
    csv.add("fig4.infer_lb.reduction_960MB_geo", us,
            f"{out['infer_lb']['geo'][4]:.1f}x (paper 16x)")
    csv.add("fig4.infer_sb.saturation_cap", us,
            f"{CAPS_MB[int(np.argmax(out['infer_sb']['geo'] >= out['infer_sb']['geo'][-1] * 0.99))]}MB (paper 240MB)")


def bench_fig8(csv: Csv):
    """Fig 8: DL perf vs DRAM bandwidth on the L3-less COPA-GPU."""
    def run():
        scales = (0.5, 1.5, 3.0, 1e6)
        configs = [hw.GPU_N.with_(name=f"GPU-N@{s}xBW",
                                  dram_bandwidth=hw.GPU_N.dram_bandwidth * s)
                   for s in scales]
        labels = ("train_lb", "infer_lb")
        names = [n for lb in labels for n in suite_scenarios(lb)]
        grid = SweepEngine(names, configs=configs).run()
        out = {}
        for lb in labels:
            traces = suite_trace_names(lb)
            for s, cfg in zip(scales, configs):
                sp = grid.speedups(cfg.name, traces)
                out[(lb, s)] = (geomean(sp), max(sp))
        return out

    out, us = timed(run)
    csv.add("fig8.train_lb.speedup_1.5xBW_geo", us,
            f"{out[('train_lb', 1.5)][0]:.3f} (paper 'up to 1.18')")
    csv.add("fig8.infer_lb.speedup_1.5xBW_geo", us,
            f"{out[('infer_lb', 1.5)][0]:.3f} (paper 'up to 1.21')")
    csv.add("fig8.train_lb.speedup_3xBW_geo", us,
            f"{out[('train_lb', 3.0)][0]:.3f} (diminishing past 3x per paper)")


def bench_fig9(csv: Csv):
    """Fig 9: DL perf vs LLC capacity (L2 sweep, no L3)."""
    def run():
        cap_configs = [hw.GPU_N.with_(name=f"GPU-N@{c}MB_L2",
                                      l2_capacity=c * MB)
                       for c in (60, 480, 960, 3840)]
        labels = ("train_lb", "train_sb", "infer_lb")
        names = [n for lb in labels for n in suite_scenarios(lb)]
        grid = SweepEngine(names, configs=cap_configs + [copa.PERFECT_L2]).run()
        out = {}
        for lb in labels:
            traces = suite_trace_names(lb)
            for c, cfg in zip((60, 480, 960, 3840), cap_configs):
                out[(lb, c)] = grid.geomean_speedup(cfg.name, traces)
        out[("train_lb", "perfect")] = grid.geomean_speedup(
            "PerfectL2", suite_trace_names("train_lb"))
        return out

    out, us = timed(run)
    csv.add("fig9.train_lb.speedup_960MB_L2", us,
            f"{out[('train_lb', 960)]:.3f} (paper: slightly < 2x-BW's 1.2x)")
    csv.add("fig9.train_lb.gap_3840MB_vs_perfect", us,
            f"{out[('train_lb', 'perfect')] / out[('train_lb', 3840)]:.3f}x (paper 1.08-1.13)")
    csv.add("fig9.infer_lb.speedup_960MB_L2", us,
            f"{out[('infer_lb', 960)]:.3f}")


def bench_fig10(csv: Csv):
    """Fig 10: UHB link bandwidth sensitivity for HBM+L3."""
    def run():
        base = copa.HBM_L3.build()
        configs = [
            base.with_(name=f"HBM+L3@{label}",
                       l3_bandwidth=hw.GPU_N.dram_bandwidth * scale)
            for scale, label in ((0.5, "0.5xRD+WR"), (1.0, "1x"), (2.0, "2x"),
                                 (4.0, "4x"), (1e6, "inf"))
        ]
        names = suite_scenarios("train_lb") + suite_scenarios("infer_lb")
        grid = SweepEngine(names, configs=configs).run()
        return {c.name.split("@")[1]: grid.geomean_speedup(c.name)
                for c in configs}

    out, us = timed(run)
    csv.add("fig10.uhb_2x_vs_inf", us,
            f"{out['2x'] / out['inf']:.3f} (paper within 3-6% of inf)")
    csv.add("fig10.uhb_0.5x_vs_inf", us, f"{out['0.5xRD+WR'] / out['inf']:.3f}")


def bench_fig11(csv: Csv):
    """Fig 11 / Table V: the COPA design space, one engine grid."""
    paper = {
        ("HBM+L3", "train_lb"): 1.21, ("HBM+L3", "train_sb"): 1.18,
        ("HBML+L3", "train_lb"): 1.31, ("HBML+L3", "train_sb"): 1.27,
        ("HBML+L3", "infer_lb"): 1.35, ("HBML+L3", "infer_sb"): 1.08,
        ("HBM+L3L", "infer_lb"): 1.40,
    }
    labels = ("train_lb", "train_sb", "infer_lb", "infer_sb")

    def run():
        names = [n for lb in labels for n in suite_scenarios(lb)]
        grid = SweepEngine(names, configs=copa.TABLE_V).run()
        return {
            (cfg.name, lb): grid.geomean_speedup(cfg.name, suite_trace_names(lb))
            for cfg in copa.TABLE_V
            for lb in labels
        }

    out, us = timed(run)
    for (name, label), v in sorted(out.items()):
        ref = paper.get((name, label))
        suffix = f" (paper {ref})" if ref else ""
        csv.add(f"fig11.{name}.{label}", us / len(out), f"{v:.3f}{suffix}")


def bench_fig12(csv: Csv):
    """Fig 12: HBML+L3 vs 2x/4x GPU-N scale-out at fixed global batch.

    One engine grid over (scale-out family x {GPU-N, HBML+L3} x {1,2,4}
    GPU instances): the registry's ``scaleout.mlperf.train.*`` families map
    each instance count to its per-GPU batch-override trace, and row
    speedups are throughput ratios against the 1-GPU GPU-N baseline —
    bit-identical to the seed's bespoke PerfModel loop (asserted in
    tests/test_sweep.py). A second grid prices the gradient all-reduce over
    a finite NVLink-class fabric, the projection the ideal-fabric paper
    methodology omits.
    """
    works = [f"scaleout.mlperf.train.{b}" for b in mlperf.TRAIN_BATCHES]
    names = [registry_scaleout(w).name for w in works]

    def run():
        grid = SweepEngine(works, configs=[copa.GPU_N_BASE, copa.HBML_L3],
                           gpu_counts=(1, 2, 4)).run()
        out = {
            "copa": grid.geomean_speedup("HBML+L3", names),
            "2x": geomean(grid.speedups("GPU-N", names, n_gpus=2)),
            "4x": geomean(grid.speedups("GPU-N", names, n_gpus=4)),
        }
        # Instances of baseline GPU-N needed to match 1 COPA GPU, per trace;
        # traces no swept count can match are reported, not averaged in.
        matched = grid.instances_to_match("GPU-N", "HBML+L3", names)
        reached = [n for n in matched.values() if n is not None]
        out["instances"] = float(np.mean(reached)) if reached else float("nan")
        out["reached"] = len(reached)
        ici = SweepEngine(works, configs=[copa.GPU_N_BASE],
                          gpu_counts=(2, 4), ici_bandwidth=600e9).run()
        out["2x_ici"] = geomean(ici.speedups("GPU-N", names, n_gpus=2))
        out["4x_ici"] = geomean(ici.speedups("GPU-N", names, n_gpus=4))
        return out

    out, us = timed(run)
    csv.add("fig12.HBML+L3.speedup", us, f"{out['copa']:.3f} (paper 1.27)")
    csv.add("fig12.2xGPU-N.speedup", us, f"{out['2x']:.3f} (paper 1.29)")
    csv.add("fig12.4xGPU-N.speedup", us, f"{out['4x']:.3f} (paper 1.43)")
    csv.add("fig12.copa_matches_2x", us,
            f"{out['copa'] / out['2x']:.3f} (paper ~1.0 -> 50% fewer GPUs)")
    csv.add("fig12.gpu_n_instances_per_copa", us,
            f"{out['instances']:.2f} over {out['reached']}/{len(names)} "
            f"matchable (paper 2.0 -> 50% fewer instances)")
    csv.add("fig12.2xGPU-N.speedup_ici600", us,
            f"{out['2x_ici']:.3f} (ring all-reduce @600GB/s)")
    csv.add("fig12.4xGPU-N.speedup_ici600", us,
            f"{out['4x_ici']:.3f} (ring all-reduce @600GB/s)")


def bench_serve_slo(csv: Csv):
    """Fleet-level analogue of Fig 12's instance-count claim: instances of
    converged GPU-N vs DL-COPA needed to serve a latency-bounded Poisson
    load (request-level simulator over the engine's serve cost grids).

    The paper's 50%-fewer-instances number is a steady-state throughput
    ratio; this row reports the SLO-percentile version — how many instances
    each config needs before p95 TTFT meets a fixed multiple of the
    full-batch step time, at an offered load of 2.5x one GPU-N's saturated
    throughput."""
    from repro.core.sweep import serve_cost_grids
    from repro.serve.fleet import instances_to_meet_slo
    from repro.serve.sim import ArrivalSpec, Slo

    def run():
        out = {}
        for bench in ("resnet", "gnmt"):
            grids = serve_cost_grids(bench, [copa.GPU_N_BASE, copa.HBML_L3])
            base = grids["GPU-N"]
            slo = Slo(ttft_s=4 * base.step_time(base.max_batch),
                      percentile=95)
            arrivals = ArrivalSpec(name=f"slo.{bench}",
                                   rate=2.5 * base.saturated_rps(),
                                   n_requests=2048)
            out[bench] = {
                name: instances_to_meet_slo(grid, arrivals, slo,
                                            max_instances=12, seed=0)
                for name, grid in grids.items()
            }
        return out

    out, us = timed(run)
    for bench, table in out.items():
        n_base, n_copa = table["GPU-N"], table["HBML+L3"]
        ratio = (n_base / n_copa) if (n_base and n_copa) else float("nan")
        csv.add(f"serve_slo.{bench}.instances_gpu_n", us / 4, f"{n_base}")
        csv.add(f"serve_slo.{bench}.instances_copa", us / 4,
                f"{n_copa} ({ratio:.2f}x fewer; paper's throughput-only "
                f"claim: 2x)")


def bench_energy(csv: Csv):
    """§III-D: HBM-related energy reduction with a 960MB L3."""
    def run():
        names = suite_scenarios("train_lb") + suite_scenarios("infer_lb")
        grid = SweepEngine(names, configs=[copa.GPU_N_BASE, copa.HBM_L3]).run()
        ratios = []
        for t in grid.traces:
            e_base = grid.result(t, "GPU-N").total_joules
            e_l3 = grid.result(t, "HBM+L3").total_joules
            ratios.append(e_base / max(e_l3, 1e-12))
        return geomean(ratios), max(ratios)

    (geo, mx), us = timed(run)
    csv.add("energy.hbm_reduction_geo", us, f"{geo:.2f}x")
    csv.add("energy.hbm_reduction_max", us, f"{mx:.2f}x (paper 'up to 3.4x')")


ALL = [bench_table1, bench_fig2, bench_fig3, bench_fig4, bench_fig8,
       bench_fig9, bench_fig10, bench_fig11, bench_fig12, bench_serve_slo,
       bench_energy]
