"""Shared benchmark helpers: suite iteration, CSV emission, model caching."""
from __future__ import annotations

import time
from functools import lru_cache

import numpy as np

from repro.core import hw, perfmodel
from repro.workloads import mlperf


@lru_cache(maxsize=256)
def model_for(suite: str, name: str, setting: str) -> perfmodel.PerfModel:
    if suite == "train":
        return perfmodel.PerfModel(mlperf.training_trace(name, setting))
    if suite == "infer":
        return perfmodel.PerfModel(mlperf.inference_trace(name, setting))
    raise KeyError(suite)


def train_models(setting: str):
    return [(n, model_for("train", n, setting)) for n in mlperf.TRAIN_BATCHES]


def infer_models(setting: str):
    return [(n, model_for("infer", n, setting)) for n in mlperf.INFER_BATCHES]


def geomean(xs):
    return perfmodel.geomean(xs)


class Csv:
    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived):
        self.rows.append((name, us_per_call, derived))

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6
