"""Shared benchmark helpers: suite naming, CSV emission, engine plumbing.

All figure benchmarks drive the batched sweep engine
(:class:`repro.core.sweep.SweepEngine`); the helpers here translate between
the paper's figure labels (``train_lb``, ``infer_sb``, ...) and registry
suites, and keep the per-process analysis cache warm across figures.
"""
from __future__ import annotations

import time

from repro.core.sweep import geomean as _geomean
from repro.workloads import registry

# Paper figure labels -> registry suites.
SUITE_LABELS = {
    "train_lb": "mlperf.train.large",
    "train_sb": "mlperf.train.small",
    "infer_lb": "mlperf.infer.large",
    "infer_sb": "mlperf.infer.small",
}


def suite_scenarios(label: str) -> list[str]:
    """Registry scenario names for a figure label."""
    return registry.suite(SUITE_LABELS[label])


def suite_trace_names(label: str) -> list[str]:
    """Trace names (SweepGrid row keys) for a figure label."""
    return [registry.scenario(n).name for n in suite_scenarios(label)]


def geomean(xs):
    return _geomean(xs)


class Csv:
    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived):
        self.rows.append((name, us_per_call, derived))

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")

    def as_json_dict(self) -> dict[str, float]:
        """Perf-trajectory snapshot: timed rows only — crashed benches
        (``*.ERROR``) and derived/sentinel rows (us == 0) would record a
        regression as a fake 0.0us data point."""
        return {name: round(us, 1) for name, us, _ in self.rows
                if us > 0 and not name.endswith(".ERROR")}

    @property
    def errors(self) -> list[str]:
        return [name for name, _, _ in self.rows if name.endswith(".ERROR")]


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6
