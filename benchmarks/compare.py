"""Diff two ``BENCH_*.json`` perf snapshots row by row.

    PYTHONPATH=src python -m benchmarks.compare BASELINE.json NEW.json \
        [--threshold 1.25] [--fail-on-regression]

Each snapshot is the ``{name: us_per_call}`` dict ``benchmarks.run --json``
writes. Rows are joined by name: the ratio column is new/old (>1 means
slower), regressions past ``--threshold`` are flagged ``REGRESSED`` and
rows only one side has are listed as added/removed rather than silently
dropped. ``benchmarks.run --compare BASELINE.json`` prints the same table
against the run it just timed.
"""
from __future__ import annotations

import argparse
import json
import sys


def compare_rows(old: dict, new: dict,
                 threshold: float = 1.25) -> list[dict]:
    """Join two snapshots into one row per benchmark name.

    Row status: ``ok`` / ``REGRESSED`` (ratio > threshold) / ``improved``
    (ratio < 1/threshold) for shared names; ``added`` / ``removed`` for
    one-sided names (their ratio is None)."""
    rows = []
    for name in sorted(set(old) | set(new)):
        o, n = old.get(name), new.get(name)
        if o is None or n is None:
            rows.append({"name": name, "old_us": o, "new_us": n,
                         "ratio": None,
                         "status": "added" if o is None else "removed"})
            continue
        # 0.0-valued rows are derived-only markers (speedup/ratio rows whose
        # payload lives in the derived column): identical zeros are a match,
        # not a div-by-zero regression.
        ratio = n / o if o > 0 else (1.0 if n == 0 else float("inf"))
        if ratio > threshold:
            status = "REGRESSED"
        elif ratio < 1.0 / threshold:
            status = "improved"
        else:
            status = "ok"
        rows.append({"name": name, "old_us": o, "new_us": n,
                     "ratio": ratio, "status": status})
    return rows


def format_table(rows: list[dict]) -> str:
    w = max((len(r["name"]) for r in rows), default=4)
    hdr = (f"{'name':<{w}s} {'old us':>12s} {'new us':>12s} "
           f"{'ratio':>7s}  status")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        o = f"{r['old_us']:12.1f}" if r["old_us"] is not None else " " * 12
        n = f"{r['new_us']:12.1f}" if r["new_us"] is not None else " " * 12
        rat = f"{r['ratio']:7.2f}" if r["ratio"] is not None else "      -"
        out.append(f"{r['name']:<{w}s} {o} {n} {rat}  {r['status']}")
    reg = sum(r["status"] == "REGRESSED" for r in rows)
    imp = sum(r["status"] == "improved" for r in rows)
    out.append(f"{len(rows)} rows: {reg} regressed, {imp} improved")
    return "\n".join(out)


def compare_files(old_path: str, new_path: str,
                  threshold: float = 1.25) -> list[dict]:
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    return compare_rows(old, new, threshold)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.compare",
        description="diff two BENCH_*.json perf snapshots")
    ap.add_argument("baseline", help="old {name: us} snapshot")
    ap.add_argument("new", help="new {name: us} snapshot")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="ratio above which a row is REGRESSED "
                         "(default 1.25)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 when any row regressed")
    ns = ap.parse_args(argv)
    rows = compare_files(ns.baseline, ns.new, ns.threshold)
    print(format_table(rows))
    if ns.fail_on_regression and any(r["status"] == "REGRESSED"
                                     for r in rows):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
