"""repro.check — static analysis for the Pallas kernels.

``trace_kernel`` abstract-evaluates a kernel to :class:`KernelFacts`
(grid, BlockSpecs, evaluated index maps, scratch, dots, store guards)
without executing it; ``run_rules`` lints the facts (R1-R5);
``compile_trace`` replays the block placements as an analytic touch
stream for the sweep engine. CLI: ``python -m repro.check``.

Heavy imports (jax) stay lazy: attributes resolve on first access.
"""
from __future__ import annotations

_EXPORTS = {
    "trace_kernel": ("repro.check.facts", "trace_kernel"),
    "KernelFacts": ("repro.check.facts", "KernelFacts"),
    "BlockFacts": ("repro.check.facts", "BlockFacts"),
    "Finding": ("repro.check.rules", "Finding"),
    "run_rules": ("repro.check.rules", "run_rules"),
    "RULES": ("repro.check.rules", "RULES"),
    "compile_trace": ("repro.check.streams", "compile_trace"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro.check' has no attribute "
                             f"{name!r}") from None
    import importlib
    return getattr(importlib.import_module(module), attr)
