"""KernelFacts: a declarative IR for Pallas kernels, extracted statically.

``trace_kernel`` abstract-evaluates a kernel wrapper over
``jax.ShapeDtypeStruct`` inputs (``jax.make_jaxpr`` — nothing executes, no
TPU required), finds every ``pallas_call`` equation, and records what the
analytic model and the rule engine need:

- the grid and its iteration order (last axis innermost, TPU semantics),
- every operand's BlockSpec: block shape, memory space, dtype, and the
  index_map *evaluated over the whole grid* (index maps are pure integer
  arithmetic, so the full block-visit table is computable at trace time),
- scratch shapes/spaces,
- every ``dot_general`` in the kernel body (dtypes, accumulator type,
  flops) and whether each store is guarded by ``pl.when`` (a ``cond``).

The visit tables drive R2/R3 and compile directly to touch streams in
``repro.check.streams``.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass
from functools import partial

import numpy as np

# Grids larger than this would make visit tables (and touch streams)
# unreasonably large for a static pass; the catalog stays well below.
MAX_GRID_STEPS = 1 << 18


def _dtype_name(dt) -> str:
    return np.dtype(dt).name if not hasattr(dt, "name") else dt.name


@dataclass(frozen=True)
class BlockFacts:
    """One pallas_call operand (input or output) and its block placement."""

    role: str                   # "in" | "out"
    index: int                  # position within its role
    name: str                   # kernel-ref name when recoverable, else in<i>
    array_shape: tuple[int, ...]
    dtype: str                  # numpy-style dtype name ("bfloat16", ...)
    block_shape: tuple[int, ...]
    memory_space: str           # "vmem" | "smem" | "any"
    # (n_steps, ndim) int64: index_map output for every grid step, in grid
    # iteration order (last grid axis fastest).
    block_indices: np.ndarray
    # Store counts into this ref from the kernel body (outputs only; inputs
    # keep zeros). "guarded" means inside a pl.when (cond) branch.
    unguarded_stores: int = 0
    guarded_stores: int = 0

    @property
    def itemsize(self) -> int:
        return int(np.dtype(self.dtype).itemsize)

    @property
    def block_bytes(self) -> int:
        return int(math.prod(self.block_shape)) * self.itemsize

    @property
    def array_bytes(self) -> int:
        return int(math.prod(self.array_shape)) * self.itemsize

    @property
    def nblocks(self) -> tuple[int, ...]:
        return tuple(-(-a // b) for a, b in
                     zip(self.array_shape, self.block_shape))

    def fetch_mask(self) -> np.ndarray:
        """True at grid steps where this operand's block differs from the
        previous step's — i.e. where the Pallas pipeline issues a DMA."""
        idx = self.block_indices
        mask = np.ones(len(idx), dtype=bool)
        if len(idx) > 1:
            mask[1:] = np.any(idx[1:] != idx[:-1], axis=1)
        return mask

    def flat_block_ids(self) -> np.ndarray:
        """Row-major flat id of the visited block at each grid step."""
        nb = np.asarray(self.nblocks, dtype=np.int64)
        strides = np.ones_like(nb)
        if len(nb) > 1:
            strides[:-1] = np.cumprod(nb[::-1])[::-1][1:]
        clipped = np.clip(self.block_indices, 0, nb - 1)
        return (clipped * strides).sum(axis=1)

    def runs(self) -> list[tuple[int, int, int]]:
        """Consecutive same-block runs as (flat_block_id, start, stop)."""
        ids = self.flat_block_ids()
        if not len(ids):
            return []
        cuts = np.flatnonzero(self.fetch_mask())
        bounds = np.append(cuts, len(ids))
        return [(int(ids[s]), int(s), int(e))
                for s, e in zip(bounds[:-1], bounds[1:])]


@dataclass(frozen=True)
class ScratchFacts:
    shape: tuple[int, ...]
    dtype: str
    memory_space: str           # "vmem" | "smem"

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.shape)) * int(np.dtype(self.dtype).itemsize)


@dataclass(frozen=True)
class DotFacts:
    """One dot_general in the kernel body."""

    lhs_dtype: str
    rhs_dtype: str
    out_dtype: str
    preferred_element_type: str | None
    out_shape: tuple[int, ...]
    contracted: tuple[int, ...]   # sizes of the contracted lhs dims
    guarded: bool                 # inside a pl.when branch

    @property
    def flops(self) -> float:
        return 2.0 * math.prod(self.out_shape) * math.prod(self.contracted)


@dataclass(frozen=True)
class KernelFacts:
    """Everything the rules and the stream compiler need about one
    pallas_call, anchored at the kernel function's def site."""

    kernel: str                 # kernel function name
    case: str                   # catalog case label (shape-matrix point)
    src_file: str
    src_line: int
    grid: tuple[int, ...]
    inputs: tuple[BlockFacts, ...]
    outputs: tuple[BlockFacts, ...]
    scratch: tuple[ScratchFacts, ...]
    dots: tuple[DotFacts, ...]

    @property
    def n_steps(self) -> int:
        return int(math.prod(self.grid))

    @property
    def blocks(self) -> tuple[BlockFacts, ...]:
        return self.inputs + self.outputs

    def flops_per_step(self) -> float:
        """Flops of the unconditional dots executed every grid step."""
        return sum(d.flops for d in self.dots if not d.guarded)

    def guarded_flops(self) -> float:
        return sum(d.flops for d in self.dots if d.guarded)


# --- jaxpr walking -----------------------------------------------------------

def _sub_closed_jaxprs(eqn):
    """(closed_jaxpr, eqn_invars_for_its_invars, enters_cond) children."""
    out = []
    params = eqn.params or {}
    if eqn.primitive.name == "cond":
        for br in params.get("branches", ()):
            out.append((br, list(eqn.invars[1:]), True))
        return out
    for key in ("jaxpr", "call_jaxpr"):
        sub = params.get(key)
        if sub is not None and hasattr(sub, "jaxpr"):
            out.append((sub, list(eqn.invars), False))
        elif sub is not None and hasattr(sub, "eqns"):
            class _Closed:  # open jaxpr: wrap for a uniform interface
                def __init__(self, j):
                    self.jaxpr, self.consts = j, []
            out.append((_Closed(sub), list(eqn.invars), False))
    return out


def _find_pallas_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            yield eqn
        else:
            for closed, _, _ in _sub_closed_jaxprs(eqn):
                yield from _find_pallas_eqns(closed.jaxpr)


def _eval_index_map(closed_jaxpr, grid: tuple[int, ...], ndim: int) -> np.ndarray:
    """Evaluate an index_map jaxpr over every grid step.

    Returns (n_steps, ndim) int64 in grid iteration order (last axis
    fastest — C-order flatten of the meshgrid matches TPU semantics).
    """
    import jax
    import jax.numpy as jnp
    from jax import core as jax_core

    n_steps = int(math.prod(grid))
    if n_steps > MAX_GRID_STEPS:
        raise ValueError(f"grid {grid} has {n_steps} steps "
                         f"(> {MAX_GRID_STEPS}); shrink the catalog case")
    mesh = np.meshgrid(*[np.arange(g, dtype=np.int64) for g in grid],
                       indexing="ij")
    steps = np.stack(mesh, axis=-1).reshape(-1, len(grid))

    def run(*idx):
        return jax_core.eval_jaxpr(closed_jaxpr.jaxpr, closed_jaxpr.consts,
                                   *idx)

    outs = jax.vmap(run)(*[jnp.asarray(steps[:, d], dtype=jnp.int32)
                           for d in range(len(grid))])
    cols = [np.asarray(o, dtype=np.int64).reshape(n_steps) for o in outs]
    if len(cols) != ndim:          # degenerate (rank-0 full-array) mapping
        cols = cols[:ndim] + [np.zeros(n_steps, np.int64)] * (ndim - len(cols))
    return np.stack(cols, axis=1) if cols else np.zeros((n_steps, 0), np.int64)


def _is_var(v) -> bool:
    return hasattr(v, "aval") and not hasattr(v, "val")


def _ref_stores(kjaxpr, ref_vars) -> dict:
    """Count guarded/unguarded stores per kernel ref var (recursively)."""
    counts = {v: [0, 0] for v in ref_vars}   # var -> [unguarded, guarded]

    def walk(jaxpr, mapping, in_cond):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in ("swap", "addupdate", "masked_swap"):
                tgt = mapping.get(eqn.invars[0]) if _is_var(eqn.invars[0]) \
                    else None
                if tgt is not None:
                    counts[tgt][1 if in_cond else 0] += 1
            for closed, invars, is_cond in _sub_closed_jaxprs(eqn):
                sub = closed.jaxpr
                m2 = {bv: mapping[ov]
                      for bv, ov in zip(sub.invars, invars)
                      if _is_var(ov) and ov in mapping}
                if m2:
                    walk(sub, m2, in_cond or is_cond)

    walk(kjaxpr, {v: v for v in ref_vars}, False)
    return counts


def _collect_dots(kjaxpr) -> list[DotFacts]:
    dots = []

    def walk(jaxpr, in_cond):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "dot_general":
                lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
                out = eqn.outvars[0].aval
                (lc, _), _ = eqn.params["dimension_numbers"]
                pref = eqn.params.get("preferred_element_type")
                dots.append(DotFacts(
                    lhs_dtype=_dtype_name(lhs.dtype),
                    rhs_dtype=_dtype_name(rhs.dtype),
                    out_dtype=_dtype_name(out.dtype),
                    preferred_element_type=(
                        _dtype_name(pref) if pref is not None else None),
                    out_shape=tuple(out.shape),
                    contracted=tuple(lhs.shape[d] for d in lc),
                    guarded=in_cond,
                ))
            for closed, _, is_cond in _sub_closed_jaxprs(eqn):
                walk(closed.jaxpr, in_cond or is_cond)

    walk(kjaxpr, False)
    return dots


_SRC_RE = re.compile(r"(\S+\.py):(\d+)")


def _src_of(name_and_src_info) -> tuple[str, str, int]:
    text = str(name_and_src_info)
    name = getattr(name_and_src_info, "name", None) or text.split(" ")[0]
    m = _SRC_RE.search(text)
    if m:
        return name, m.group(1), int(m.group(2))
    return name, "<unknown>", 0


def _memory_space_name(block_aval) -> str:
    space = getattr(block_aval, "memory_space", None)
    if space is None:
        return "vmem"
    s = str(space).lower()
    if "smem" in s:
        return "smem"
    if "any" in s:
        return "any"
    return "vmem"


def _facts_from_eqn(eqn, case: str) -> KernelFacts:
    gm = eqn.params["grid_mapping"]
    kernel_jaxpr = eqn.params["jaxpr"]
    name, src_file, src_line = _src_of(eqn.params.get("name_and_src_info"))
    grid = tuple(int(g) for g in gm.grid)

    n_index = int(getattr(gm, "num_index_operands", 0))
    n_in = int(gm.num_inputs)
    n_out = int(gm.num_outputs)
    # kernel invars: [index operands..., inputs..., outputs..., scratch...]
    invars = list(kernel_jaxpr.invars)
    in_vars = invars[n_index:n_index + n_in]
    out_vars = invars[n_index + n_in:n_index + n_in + n_out]
    scratch_vars = invars[n_index + n_in + n_out:]

    stores = _ref_stores(kernel_jaxpr, out_vars)
    mappings = list(gm.block_mappings)

    def block_facts(bm, role, i, var) -> BlockFacts:
        sds = bm.array_shape_dtype
        block_shape = tuple(
            int(b) if isinstance(b, (int, np.integer)) else 1
            for b in bm.block_shape)
        unguarded, guarded = stores.get(var, (0, 0)) if role == "out" \
            else (0, 0)
        return BlockFacts(
            role=role, index=i,
            name=f"{role}{i}",
            array_shape=tuple(int(s) for s in sds.shape),
            dtype=_dtype_name(sds.dtype),
            block_shape=block_shape,
            memory_space=_memory_space_name(bm.block_aval),
            block_indices=_eval_index_map(
                bm.index_map_jaxpr, grid, len(block_shape)),
            unguarded_stores=int(unguarded),
            guarded_stores=int(guarded),
        )

    inputs = tuple(block_facts(mappings[i], "in", i, in_vars[i])
                   for i in range(n_in))
    outputs = tuple(block_facts(mappings[n_in + i], "out", i, out_vars[i])
                    for i in range(n_out))
    scratch = tuple(
        ScratchFacts(
            shape=tuple(int(s) for s in v.aval.shape),
            dtype=_dtype_name(v.aval.dtype),
            memory_space=_memory_space_name(v.aval))
        for v in scratch_vars)

    return KernelFacts(
        kernel=name, case=case, src_file=src_file, src_line=src_line,
        grid=grid, inputs=inputs, outputs=outputs, scratch=scratch,
        dots=tuple(_collect_dots(kernel_jaxpr)),
    )


def trace_kernel(fn, *avals, case: str = "", **kwargs) -> list[KernelFacts]:
    """Abstract-eval ``fn(*avals)`` (ShapeDtypeStructs) and return one
    KernelFacts per pallas_call found, in program order. Nothing executes."""
    import jax

    wrapped = partial(fn, **kwargs) if kwargs else fn
    jaxpr = jax.make_jaxpr(wrapped)(*avals)
    facts = [_facts_from_eqn(eqn, case or getattr(fn, "__name__", "kernel"))
             for eqn in _find_pallas_eqns(jaxpr.jaxpr)]
    if not facts:
        raise ValueError(f"no pallas_call found tracing {fn!r}")
    return facts
