"""``python -m repro.check`` — lint the Pallas kernels statically.

Exit code = number of unwaived findings (0 means clean). Findings print as
``file:line: RULE [kernel @ case] message``; ``--json`` emits a machine-
readable list instead.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.check import catalog
from repro.check.rules import RULE_DESCRIPTIONS, RULES, run_rules


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="Static analyzer for the Pallas kernels in "
                    "src/repro/kernels/ (rules R1-R5).")
    p.add_argument("--rules", default=None,
                   help="comma-separated subset, e.g. --rules R1,R3 "
                        f"(default: all of {','.join(RULES)})")
    p.add_argument("--cases", default=None,
                   help="comma-separated catalog case subset "
                        "(see --list)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit findings as JSON")
    p.add_argument("--list", action="store_true", dest="list_cases",
                   help="list catalog cases and rules, then exit")
    p.add_argument("--no-waivers", action="store_true",
                   help="ignore '# check: waive[...]' comments")
    p.add_argument("--show-waived", action="store_true",
                   help="also print waived findings")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_cases:
        print("cases:")
        for name in catalog.case_names():
            print(f"  {name}")
        print("rules:")
        for rule in RULES:
            print(f"  {rule}  {RULE_DESCRIPTIONS[rule]}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip().upper() for r in args.rules.split(",") if r.strip()]
    names = catalog.case_names()
    if args.cases:
        wanted = [c.strip() for c in args.cases.split(",") if c.strip()]
        names = [n for n in names
                 if any(w == n or n.startswith(w) for w in wanted)]
        if not names:
            print(f"no catalog case matches {wanted}", file=sys.stderr)
            return 2

    facts = []
    for name in names:
        facts.extend(catalog.trace_case(name))
    findings = run_rules(facts, rules=rules, waivers=not args.no_waivers)
    unwaived = [f for f in findings if not f.waived]
    shown = findings if args.show_waived else unwaived

    if args.as_json:
        print(json.dumps([dataclasses.asdict(f) for f in shown], indent=2))
    else:
        for f in shown:
            print(f.format())
        waived_n = len(findings) - len(unwaived)
        print(f"repro.check: {len(facts)} pallas_call(s) across "
              f"{len(names)} case(s): {len(unwaived)} finding(s)"
              + (f", {waived_n} waived" if waived_n else ""))
    return len(unwaived)


if __name__ == "__main__":
    sys.exit(main())
