"""Compile KernelFacts into analytic touch streams (core.trace.Trace).

This is the kernel->registry bridge (ROADMAP direction 5): the same
statically-extracted block placements that the rules lint are replayed as
one touch per block *fetch* in grid-iteration order, so the sweep engine
prices measured-structure kernel traffic instead of hand-written per-tensor
streams.

Semantics (matching the Pallas pipeline):
- one Op per grid step;
- an input block is read when its index_map output changes from the
  previous step (the pipeline keeps the block resident otherwise);
- an output block is written once per consecutive same-block run, at the
  run's last step (the guarded-finalize idiom);
- per-step flops are the unconditional dot_generals, with pl.when-guarded
  dots charged on write steps;
- tensor names are per block (``<kernel>.<ref>[<flat_block_id>]``) so the
  cache model sees block-level reuse exactly.
"""
from __future__ import annotations

import numpy as np

from repro.core.trace import Trace, gemm_parallelism
from repro.check.facts import KernelFacts

_PRECISION = {
    "float32": "fp32", "float64": "fp32", "bfloat16": "bf16",
    "float16": "fp16", "int8": "int8", "uint8": "int8",
}


def _precision_of(facts: KernelFacts) -> str:
    for blk in facts.inputs:
        if blk.memory_space == "vmem":
            if blk.dtype.startswith("float8"):
                return "fp8"
            return _PRECISION.get(blk.dtype, "fp16")
    return "fp16"


def _parallelism_of(facts: KernelFacts) -> float:
    best = 0.0
    for dot in facts.dots:
        shape = dot.out_shape
        m = shape[-2] if len(shape) >= 2 else 1
        n = shape[-1] if shape else 1
        best = max(best, gemm_parallelism(int(m), int(n)))
    return best if best > 0 else float("inf")


def append_kernel_ops(trace: Trace, facts: KernelFacts) -> None:
    """Append one Op per grid step of ``facts`` to ``trace``."""
    n = facts.n_steps
    fetch = [blk.fetch_mask() for blk in facts.inputs]
    in_ids = [blk.flat_block_ids() for blk in facts.inputs]
    out_ids = [blk.flat_block_ids() for blk in facts.outputs]
    # A run's last step writes the block out.
    write_step = []
    for blk in facts.outputs:
        mask = np.zeros(n, dtype=bool)
        for _, _, stop in blk.runs():
            mask[stop - 1] = True
        write_step.append(mask)

    step_flops = facts.flops_per_step()
    fin_flops = facts.guarded_flops()
    precision = _precision_of(facts)
    parallelism = _parallelism_of(facts)
    kname = facts.kernel.lstrip("_")

    for step in range(n):
        reads = [
            (f"{kname}.{blk.name}[{int(in_ids[i][step])}]", blk.block_bytes)
            for i, blk in enumerate(facts.inputs) if fetch[i][step]]
        writes = [
            (f"{kname}.{blk.name}[{int(out_ids[i][step])}]", blk.block_bytes)
            for i, blk in enumerate(facts.outputs) if write_step[i][step]]
        flops = step_flops + (fin_flops if writes else 0.0)
        trace.emit(f"{kname}.s{step}", flops, reads=reads, writes=writes,
                   precision=precision, parallelism=parallelism)


def compile_trace(facts_list, name: str, kind: str = "inference") -> Trace:
    """One Trace for a kernel invocation (possibly several pallas_calls)."""
    if isinstance(facts_list, KernelFacts):
        facts_list = [facts_list]
    trace = Trace(name=name, kind=kind)
    for facts in facts_list:
        append_kernel_ops(trace, facts)
    return trace
