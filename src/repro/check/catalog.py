"""The kernel x shape matrix the analyzer runs over.

Import-light on purpose: jax and the kernel modules load lazily inside the
builders, so ``repro.workloads.registry`` can enumerate ``kernel.*``
scenario names without paying the jax import.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Callable


@dataclass(frozen=True)
class KernelCase:
    """One (kernel, shape) point: ``build()`` abstract-traces it."""

    kernel: str
    case: str
    build: Callable[[], list]        # -> list[KernelFacts]

    @property
    def name(self) -> str:
        return f"{self.kernel}.{self.case}"


def _sds(shape, dt: str):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, getattr(jnp, dt))


def _flash_attention(case, b, s, h, kvh, d, dt, causal, block):
    def build():
        from repro.check.facts import trace_kernel
        from repro.kernels.flash_attention import flash_attention_pallas
        q = _sds((b, s, h, d), dt)
        k = _sds((b, s, kvh, d), dt)
        v = _sds((b, s, kvh, d), dt)
        return trace_kernel(flash_attention_pallas, q, k, v, case=case,
                            causal=causal, block_q=block, block_kv=block)
    return KernelCase("flash_attention", case, build)


def _flash_attention_bwd(case, b, s, h, kvh, d, dt, causal, block):
    def build():
        from repro.check.facts import trace_kernel
        from repro.kernels.flash_attention_bwd import (
            flash_attention_bwd_pallas)
        q = _sds((b, s, h, d), dt)
        k = _sds((b, s, kvh, d), dt)
        v = _sds((b, s, kvh, d), dt)
        out = _sds((b, s, h, d), dt)
        lse = _sds((b, s, h), "float32")
        dout = _sds((b, s, h, d), dt)
        return trace_kernel(flash_attention_bwd_pallas, q, k, v, out, lse,
                            dout, case=case, causal=causal, block_q=block,
                            block_kv=block)
    return KernelCase("flash_attention_bwd", case, build)


def _flash_decode(case, b, s, h, kvh, d, dt, block_kv):
    def build():
        from repro.check.facts import trace_kernel
        from repro.kernels.flash_decode import flash_decode_pallas
        q = _sds((b, h, d), dt)
        k = _sds((b, s, kvh, d), dt)
        v = _sds((b, s, kvh, d), dt)
        return trace_kernel(flash_decode_pallas, q, k, v, s, case=case,
                            block_kv=block_kv)
    return KernelCase("flash_decode", case, build)


def _fused_ffn(case, t, d, f, dt, block_t, block_f):
    def build():
        from repro.check.facts import trace_kernel
        from repro.kernels.fused_ffn import fused_ffn_pallas
        x = _sds((t, d), dt)
        wg = _sds((d, f), dt)
        wu = _sds((d, f), dt)
        wd = _sds((f, d), dt)
        return trace_kernel(fused_ffn_pallas, x, wg, wu, wd, case=case,
                            block_t=block_t, block_f=block_f)
    return KernelCase("fused_ffn", case, build)


def _ssd_scan(case, b, s, h, p, n, dt, chunk):
    def build():
        from repro.check.facts import trace_kernel
        from repro.kernels.ssd_scan import ssd_scan_pallas
        x = _sds((b, s, h, p), dt)
        dtt = _sds((b, s, h), dt)
        a = _sds((h,), "float32")
        b_ = _sds((b, s, n), dt)
        c_ = _sds((b, s, n), dt)
        return trace_kernel(ssd_scan_pallas, x, dtt, a, b_, c_, case=case,
                            chunk=chunk)
    return KernelCase("ssd_scan", case, build)


CASES: tuple[KernelCase, ...] = (
    # GQA training-shape forward, bf16 + a single-head fp32 point.
    _flash_attention("b2s512", b=2, s=512, h=8, kvh=4, d=128, dt="bfloat16",
                     causal=True, block=256),
    _flash_attention("b1s1024f32", b=1, s=1024, h=4, kvh=4, d=128,
                     dt="float32", causal=False, block=256),
    _flash_attention_bwd("b2s512", b=2, s=512, h=8, kvh=4, d=128,
                         dt="bfloat16", causal=True, block=256),
    # Decode: long-KV bandwidth-bound cells (the serve pricing shape).
    _flash_decode("b2s2048", b=2, s=2048, h=8, kvh=4, d=128, dt="bfloat16",
                  block_kv=512),
    _flash_decode("b1s4096", b=1, s=4096, h=8, kvh=8, d=128, dt="bfloat16",
                  block_kv=512),
    _fused_ffn("t512d1024", t=512, d=1024, f=2048, dt="bfloat16",
               block_t=256, block_f=512),
    _fused_ffn("t256d512f32", t=256, d=512, f=1024, dt="float32",
               block_t=256, block_f=512),
    _ssd_scan("b2s1024", b=2, s=1024, h=4, p=64, n=128, dt="bfloat16",
              chunk=128),
)

_BY_NAME = {c.name: c for c in CASES}


def case_names() -> list[str]:
    return [c.name for c in CASES]


def get(name: str) -> KernelCase:
    try:
        return _BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown kernel case {name!r}; "
                       f"known: {case_names()}") from None


@lru_cache(maxsize=None)
def trace_case(name: str) -> tuple:
    """Build (and memoize) the KernelFacts for one catalog case."""
    return tuple(get(name).build())


def trace_all() -> list:
    """KernelFacts for every case in the matrix, in catalog order."""
    out = []
    for case in CASES:
        out.extend(trace_case(case.name))
    return out
