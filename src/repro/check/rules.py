"""Rule engine over KernelFacts: R1-R5 findings + inline waivers.

Rules (constants from ``repro.core.hw``):

  R1  tile alignment      — VMEM block lane/sublane dims are multiples of
                            the dtype's minimum tile, unless the block
                            covers the full array dim.
  R2  index_map bounds    — index maps evaluated over the whole grid stay
                            inside [0, cdiv(dim, block)); output placements
                            must cover every block.
  R3  write hazard        — an output block revisited across a
                            non-innermost grid axis, or revisited with an
                            unguarded store, races with the pipeline (the
                            guarded acc_scr init/finalize idiom is the fix).
  R4  accumulator dtype   — matmuls on sub-f32 operands must accumulate in
                            f32 (``preferred_element_type``).
  R5  footprint           — double-buffered blocks + scratch must fit the
                            per-core VMEM budget; SMEM operands the SMEM
                            budget.

Waivers: a ``# check: waive[R3]`` (or ``waive[R1,R5]``) comment inside a
function waives findings of those rules anchored inside that function's
body; at module top level it waives the whole file.
"""
from __future__ import annotations

import ast
import math
import re
from dataclasses import dataclass, replace
from functools import lru_cache

import numpy as np

from repro.core import hw
from repro.check.facts import KernelFacts

RULES = ("R1", "R2", "R3", "R4", "R5")

RULE_DESCRIPTIONS = {
    "R1": "block tile alignment vs MXU/VPU minimum tiles",
    "R2": "index_map bounds and output coverage over the grid",
    "R3": "write hazard on revisited output blocks",
    "R4": "f32 accumulation for low-precision matmuls",
    "R5": "VMEM/SMEM footprint per grid step vs per-core budget",
}

_F32 = "float32"
_LOW_PRECISION = re.compile(r"^(bfloat16|float16|float8_e\w+)$")


@dataclass(frozen=True)
class Finding:
    rule: str
    kernel: str
    case: str
    file: str
    line: int
    message: str
    waived: bool = False

    def format(self) -> str:
        tag = " (waived)" if self.waived else ""
        return (f"{self.file}:{self.line}: {self.rule} "
                f"[{self.kernel} @ {self.case}]{tag} {self.message}")


def _finding(facts: KernelFacts, rule: str, message: str) -> Finding:
    return Finding(rule=rule, kernel=facts.kernel, case=facts.case,
                   file=facts.src_file, line=facts.src_line, message=message)


# --- R1: tile alignment ------------------------------------------------------

def _check_tiles(facts: KernelFacts) -> list[Finding]:
    out = []
    for blk in facts.blocks:
        if blk.memory_space != "vmem" or not blk.block_shape:
            continue
        problems = []
        lane = blk.block_shape[-1]
        if lane % hw.TPU_LANE and lane != blk.array_shape[-1]:
            problems.append(f"lane dim {lane} is not a multiple of "
                            f"{hw.TPU_LANE}")
        if len(blk.block_shape) >= 2:
            sub = blk.block_shape[-2]
            want = hw.min_tile(blk.itemsize)[0]
            if sub % want and sub != blk.array_shape[-2]:
                problems.append(f"sublane dim {sub} is not a multiple of "
                                f"{want} for {blk.dtype}")
        if problems:
            out.append(_finding(
                facts, "R1",
                f"{blk.role}[{blk.index}] block {blk.block_shape} "
                f"({blk.dtype}): " + "; ".join(problems)))
    return out


# --- R2: index_map bounds + coverage -----------------------------------------

def _check_bounds(facts: KernelFacts) -> list[Finding]:
    out = []
    for blk in facts.blocks:
        if not blk.block_shape:
            continue
        idx = blk.block_indices
        nb = blk.nblocks
        oob = (idx < 0) | (idx >= np.asarray(nb, dtype=np.int64))
        oob_steps = oob.any(axis=1).nonzero()[0]
        if len(oob_steps):
            step = int(oob_steps[0])
            out.append(_finding(
                facts, "R2",
                f"{blk.role}[{blk.index}] index_map out of bounds at grid "
                f"step {step}: block index "
                f"{tuple(int(v) for v in idx[step])} outside "
                f"{tuple(nb)} (= cdiv(array {blk.array_shape}, "
                f"block {blk.block_shape}))"))
            continue   # coverage is meaningless once placements are OOB
        if blk.role == "out":
            visited = len(set(map(int, blk.flat_block_ids())))
            total = math.prod(nb)
            if visited < total:
                out.append(_finding(
                    facts, "R2",
                    f"out[{blk.index}] placements cover {visited}/{total} "
                    f"blocks — {total - visited} output block(s) never "
                    f"written"))
    return out


# --- R3: write hazard --------------------------------------------------------

def _check_write_hazard(facts: KernelFacts) -> list[Finding]:
    out = []
    for blk in facts.outputs:
        idx = blk.block_indices
        if bool(((idx < 0) |
                 (idx >= np.asarray(blk.nblocks, dtype=np.int64))).any()):
            continue   # OOB placements (R2's finding) make the visit
            # table meaningless — don't pile a phantom hazard on top
        runs = blk.runs()
        seen: dict[int, int] = {}
        split = False
        for bid, _, _ in runs:
            seen[bid] = seen.get(bid, 0) + 1
            if seen[bid] > 1:
                split = True
        if split:
            out.append(_finding(
                facts, "R3",
                f"out[{blk.index}] block revisited across a non-innermost "
                f"grid axis (same block in {max(seen.values())} separate "
                f"runs): the pipeline may flush a stale copy between "
                f"visits — reorder the grid so revisits are contiguous"))
            continue
        revisited = any(stop - start > 1 for _, start, stop in runs)
        if revisited and blk.unguarded_stores:
            out.append(_finding(
                facts, "R3",
                f"out[{blk.index}] block is revisited across "
                f"{max(stop - start for _, start, stop in runs)} grid steps "
                f"but has {blk.unguarded_stores} store(s) outside pl.when — "
                f"every store to a revisited block must be guarded "
                f"(init/accumulate in scratch, write once on the last "
                f"visit, as in flash_attention's acc_scr)"))
    return out


# --- R4: accumulator dtype ---------------------------------------------------

def _check_accumulators(facts: KernelFacts) -> list[Finding]:
    out = []
    for i, dot in enumerate(facts.dots):
        low = (_LOW_PRECISION.match(dot.lhs_dtype)
               or _LOW_PRECISION.match(dot.rhs_dtype))
        if not low:
            continue
        problems = []
        if dot.out_dtype != _F32:
            problems.append(f"accumulates in {dot.out_dtype}")
        if dot.preferred_element_type != _F32:
            problems.append(
                "preferred_element_type is "
                f"{dot.preferred_element_type or 'unset'}")
        if problems:
            out.append(_finding(
                facts, "R4",
                f"dot_general #{i} ({dot.lhs_dtype} x {dot.rhs_dtype}): "
                + "; ".join(problems)
                + " — pass preferred_element_type=jnp.float32"))
    return out


# --- R5: footprint -----------------------------------------------------------

def _check_footprint(facts: KernelFacts) -> list[Finding]:
    out = []
    vmem = sum(b.block_bytes for b in facts.blocks
               if b.memory_space == "vmem") * hw.PALLAS_PIPELINE_BUFFERS
    vmem += sum(s.nbytes for s in facts.scratch if s.memory_space == "vmem")
    if vmem > hw.PALLAS_VMEM_BUDGET:
        out.append(_finding(
            facts, "R5",
            f"VMEM footprint per grid step is {vmem / hw.MB:.1f} MB "
            f"({hw.PALLAS_PIPELINE_BUFFERS}x double-buffered blocks + "
            f"scratch) > budget {hw.PALLAS_VMEM_BUDGET / hw.MB:.0f} MB"))
    smem = sum(b.block_bytes for b in facts.blocks
               if b.memory_space == "smem")
    smem += sum(s.nbytes for s in facts.scratch if s.memory_space == "smem")
    if smem > hw.PALLAS_SMEM_BUDGET:
        out.append(_finding(
            facts, "R5",
            f"SMEM footprint is {smem / hw.KB:.1f} KB > budget "
            f"{hw.PALLAS_SMEM_BUDGET / hw.KB:.0f} KB"))
    return out


_RULE_FNS = {
    "R1": _check_tiles,
    "R2": _check_bounds,
    "R3": _check_write_hazard,
    "R4": _check_accumulators,
    "R5": _check_footprint,
}


# --- waivers -----------------------------------------------------------------

_WAIVE_RE = re.compile(r"#\s*check:\s*waive\[([A-Z0-9,\s]+)\]")


@dataclass(frozen=True)
class _Waiver:
    rules: tuple[str, ...]
    start: int       # first waived line (inclusive)
    stop: int        # last waived line (inclusive)


@lru_cache(maxsize=256)
def _waivers_for(path: str) -> tuple[_Waiver, ...]:
    try:
        with open(path) as f:
            source = f.read()
    except OSError:
        return ()
    spans = []     # function spans, innermost-last
    try:
        tree = ast.parse(source)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                spans.append((node.lineno, node.end_lineno))
    except SyntaxError:
        pass
    waivers = []
    for lineno, line in enumerate(source.splitlines(), start=1):
        m = _WAIVE_RE.search(line)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        enclosing = [s for s in spans if s[0] <= lineno <= s[1]]
        if enclosing:   # innermost function containing the comment
            start, stop = max(enclosing, key=lambda s: s[0])
        else:           # module level: waive the whole file
            start, stop = 1, len(source.splitlines()) + 1
        waivers.append(_Waiver(rules=rules, start=start, stop=stop))
    return tuple(waivers)


def apply_waivers(findings: list[Finding]) -> list[Finding]:
    """Mark findings covered by ``# check: waive[...]`` comments."""
    out = []
    for f in findings:
        waived = any(
            f.rule in w.rules and w.start <= f.line <= w.stop
            for w in _waivers_for(f.file))
        out.append(replace(f, waived=True) if waived and not f.waived else f)
    return out


# --- entry points ------------------------------------------------------------

def run_rules(facts, rules=None, waivers: bool = True) -> list[Finding]:
    """Run the selected rules over one KernelFacts or a list of them."""
    if isinstance(facts, KernelFacts):
        facts = [facts]
    selected = list(rules) if rules else list(RULES)
    unknown = [r for r in selected if r not in _RULE_FNS]
    if unknown:
        raise ValueError(f"unknown rules {unknown}; known: {list(RULES)}")
    findings = []
    for fct in facts:
        for rule in selected:
            findings.extend(_RULE_FNS[rule](fct))
    findings.sort(key=lambda f: (f.file, f.line, f.rule, f.message))
    return apply_waivers(findings) if waivers else findings
