"""Model assembly: specs, init, forward (scan over layers), loss, and the
prefill/decode paths with layer-stacked caches.

One entry point serves all 10 assigned architectures:

    model = LanguageModel(cfg)
    params = model.init(key)
    h = model.forward(params, batch)          # train/prefill hidden states
    loss = model.loss(params, batch)
    cache = model.init_cache(batch_size, max_len)
    logits, cache = model.decode_step(params, cache, tokens, pos)

Layer stacks are scanned (``lax.scan`` over stacked params) so the HLO stays
compact at 94 layers; heterogeneous stacks (DeepSeek first-k-dense, Zamba
shared block) mix one unrolled group with a scanned group.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.base import Specs, axes_tree, init_params, stack_specs
from repro.sharding.partition import sp_boundary
from repro.models.layers import (chunked_cross_entropy, embed, embedding_specs,
                                 logits_for_tokens, rmsnorm, rmsnorm_specs)

REMAT_POLICIES = {
    "none": None,
    "dots": lambda: jax.checkpoint_policies.checkpoint_dots,
    "full": lambda: jax.checkpoint_policies.nothing_saveable,
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    policy = REMAT_POLICIES[remat]()
    return jax.checkpoint(fn, policy=policy)


@dataclass
class LanguageModel:
    cfg: ModelConfig
    impl: str = "chunked"       # sdpa implementation
    remat: str = "none"

    # ------------------------------------------------------------------ specs --
    def specs(self) -> Specs:
        cfg = self.cfg
        s: Specs = {
            "emb": embedding_specs(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
            "ln_f": rmsnorm_specs(cfg.d_model),
        }
        if cfg.family in ("dense", "vlm"):
            s["layers"] = stack_specs(blocks.dense_block_specs(cfg), cfg.n_layers)
        elif cfg.family == "moe":
            kd = cfg.first_k_dense
            if kd:
                s["dense_layers"] = stack_specs(
                    blocks.moe_block_specs(cfg, dense_ffn=True), kd)
            s["layers"] = stack_specs(
                blocks.moe_block_specs(cfg, dense_ffn=False), cfg.n_layers - kd)
        elif cfg.family == "ssm":
            s["layers"] = stack_specs(blocks.mamba_block_specs(cfg), cfg.n_layers)
        elif cfg.family == "hybrid":
            s["layers"] = stack_specs(blocks.mamba_block_specs(cfg), cfg.n_layers)
            s["shared_attn"] = blocks.shared_attn_block_specs(cfg)
        elif cfg.family == "audio":
            s["enc_layers"] = stack_specs(
                blocks.encoder_block_specs(cfg), cfg.n_encoder_layers)
            s["layers"] = stack_specs(
                blocks.decoder_block_specs(cfg), cfg.n_layers)
            s["ln_enc"] = rmsnorm_specs(cfg.d_model)
        else:
            raise ValueError(cfg.family)
        return s

    def init(self, key, dtype=jnp.bfloat16):
        return init_params(self.specs(), key, dtype)

    def axes(self):
        return axes_tree(self.specs())

    # ------------------------------------------------------------- embeddings --
    def _embed_inputs(self, params, batch):
        """Handles token-only, VLM (patch embeds + tokens) and audio
        (encoder frames + decoder tokens) input conventions."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params["emb"], tokens)
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
        return x

    # ---------------------------------------------------------------- forward --
    def forward(self, params, batch):
        """Returns (hidden (B,S,d), aux_loss)."""
        cfg = self.cfg
        if cfg.family == "audio":
            return self._forward_audio(params, batch)
        x = self._embed_inputs(params, batch)
        b, s = x.shape[:2]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        aux = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "vlm"):
            body = _maybe_remat(
                lambda x_, p_: sp_boundary(
                    blocks.dense_block(p_, cfg, sp_boundary(x_), positions,
                                       impl=self.impl)), self.remat)
            x, _ = jax.lax.scan(lambda c, p: (body(c, p), None),
                                x, params["layers"])
        elif cfg.family == "moe":
            def _moe_block(x_, p_):
                y, a = blocks.moe_block(p_, cfg, sp_boundary(x_), positions,
                                        impl=self.impl)
                return sp_boundary(y), a

            block = _maybe_remat(_moe_block, self.remat)

            def moe_body(carry, p):
                x_, aux_ = carry
                y, a = block(x_, p)
                return (y, aux_ + a), None

            if cfg.first_k_dense:
                (x, aux), _ = jax.lax.scan(moe_body, (x, aux),
                                           params["dense_layers"])
            (x, aux), _ = jax.lax.scan(moe_body, (x, aux), params["layers"])
        elif cfg.family == "ssm":
            body = _maybe_remat(
                lambda x_, p_: sp_boundary(
                    blocks.mamba_block(p_, cfg, sp_boundary(x_))), self.remat)
            x, _ = jax.lax.scan(lambda c, p: (body(c, p), None),
                                x, params["layers"])
        elif cfg.family == "hybrid":
            shared = params["shared_attn"]
            period = cfg.attn_every

            def hybrid_body(carry, inp):
                x_, i = carry
                p_ = inp
                x_ = sp_boundary(blocks.mamba_block(p_, cfg, sp_boundary(x_)))
                x_ = jax.lax.cond(
                    (i + 1) % period == 0,
                    lambda v: sp_boundary(blocks.shared_attn_block(
                        shared, cfg, v, positions, impl=self.impl)),
                    lambda v: v,
                    x_,
                )
                return (x_, i + 1), None

            (x, _), _ = jax.lax.scan(hybrid_body, (x, jnp.int32(0)),
                                     params["layers"])
        h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        return h, aux

    def _forward_audio(self, params, batch):
        cfg = self.cfg
        frames = batch["frames"]  # (B, S_enc, d) — stubbed conv frontend output
        b, s_enc, _ = frames.shape
        enc_pos = jnp.broadcast_to(jnp.arange(s_enc, dtype=jnp.int32), (b, s_enc))
        x = frames.astype(jnp.bfloat16)

        enc_block = _maybe_remat(
            lambda c, p: sp_boundary(
                blocks.encoder_block(p, cfg, sp_boundary(c), enc_pos,
                                     impl=self.impl)), self.remat)
        x, _ = jax.lax.scan(lambda c, p: (enc_block(c, p), None),
                            x, params["enc_layers"])
        enc_out = rmsnorm(params["ln_enc"], x, cfg.norm_eps)

        tokens = batch["tokens"]
        s_dec = tokens.shape[1]
        dec_pos = jnp.broadcast_to(jnp.arange(s_dec, dtype=jnp.int32), (b, s_dec))
        y = embed(params["emb"], tokens)

        dec_block = _maybe_remat(
            lambda c, p: sp_boundary(
                blocks.decoder_block(p, cfg, sp_boundary(c), enc_out, dec_pos,
                                     enc_pos, impl=self.impl)), self.remat)
        y, _ = jax.lax.scan(lambda c, p: (dec_block(c, p), None),
                            y, params["layers"])
        h = rmsnorm(params["ln_f"], y, cfg.norm_eps)
        return h, jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------------- loss --
    def loss(self, params, batch, aux_weight: float = 0.01):
        h, aux = self.forward(params, batch)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        ce = chunked_cross_entropy(params["emb"], h, labels, mask=mask)
        return ce + aux_weight * aux

    # ------------------------------------------------------------------ cache --
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   enc_len: int = 0):
        cfg = self.cfg
        L = cfg.n_layers
        if cfg.family in ("dense", "vlm"):
            if cfg.use_mla:
                return {
                    "ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype),
                    "krope": jnp.zeros((L, batch, max_len, cfg.rope_head_dim), dtype),
                }
            kvh, hd = cfg.n_kv_heads, cfg.head_dim
            return {
                "k": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
                "v": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
            }
        if cfg.family == "moe":
            kd = cfg.first_k_dense
            base = {}
            if cfg.use_mla:
                base["ckv"] = jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype)
                base["krope"] = jnp.zeros((L, batch, max_len, cfg.rope_head_dim), dtype)
            else:
                kvh, hd = cfg.n_kv_heads, cfg.head_dim
                base["k"] = jnp.zeros((L, batch, max_len, kvh, hd), dtype)
                base["v"] = jnp.zeros((L, batch, max_len, kvh, hd), dtype)
            return base
        if cfg.family == "ssm":
            return self._ssm_cache(batch, dtype)
        if cfg.family == "hybrid":
            cache = self._ssm_cache(batch, dtype)
            n_inv = cfg.n_layers // cfg.attn_every
            kvh, hd = cfg.n_kv_heads, cfg.head_dim
            cache["shared_k"] = jnp.zeros((n_inv, batch, max_len, kvh, hd), dtype)
            cache["shared_v"] = jnp.zeros((n_inv, batch, max_len, kvh, hd), dtype)
            return cache
        if cfg.family == "audio":
            kvh, hd = cfg.n_kv_heads, cfg.head_dim
            return {
                "k": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
                "v": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
                "cross_k": jnp.zeros((L, batch, enc_len, kvh, hd), dtype),
                "cross_v": jnp.zeros((L, batch, enc_len, kvh, hd), dtype),
            }
        raise ValueError(cfg.family)

    def _ssm_cache(self, batch: int, dtype):
        cfg = self.cfg
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        return {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch), dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads,
                              cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        }

    # ------------------------------------------------------------ decode step --
    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B,1) int32; pos: scalar int32 (current length).
        Returns (logits (B,1,V), new_cache)."""
        cfg = self.cfg
        x = embed(params["emb"], tokens)
        b = x.shape[0]

        if cfg.family in ("dense", "vlm", "moe"):
            if cfg.use_mla:
                def body(x_, xs):
                    p_, ckv, krope = xs
                    from repro.models.attention import mla_decode
                    h = rmsnorm(p_["ln1"], x_, cfg.norm_eps)
                    o, ckv, krope = mla_decode(p_["attn"], cfg, h, ckv, krope, pos)
                    x_ = x_ + o
                    h = rmsnorm(p_["ln2"], x_, cfg.norm_eps)
                    if "ffn" in p_:
                        from repro.models.layers import ffn
                        x_ = x_ + ffn(p_["ffn"], h)
                    else:
                        from repro.models.moe import moe_ffn
                        y, _ = moe_ffn(p_["moe"], cfg, h)
                        x_ = x_ + y
                    return x_, (ckv, krope)

                groups = []
                if cfg.first_k_dense and "dense_layers" in params:
                    groups.append(("dense_layers", cfg.first_k_dense, 0))
                groups.append(("layers", cfg.n_layers - cfg.first_k_dense,
                               cfg.first_k_dense))
                new_ckv, new_krope = cache["ckv"], cache["krope"]
                for pkey, n_l, off in groups:
                    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, off, n_l, 0)
                    x, (ckv_g, krope_g) = jax.lax.scan(
                        body, x,
                        (params[pkey], sl(cache["ckv"]), sl(cache["krope"])))
                    new_ckv = jax.lax.dynamic_update_slice_in_dim(new_ckv, ckv_g, off, 0)
                    new_krope = jax.lax.dynamic_update_slice_in_dim(new_krope, krope_g, off, 0)
                cache = {"ckv": new_ckv, "krope": new_krope}
            else:
                from repro.models.attention import gqa_decode
                from repro.models.layers import ffn as ffn_fn

                def body(x_, xs):
                    p_, k_, v_ = xs
                    h = rmsnorm(p_["ln1"], x_, cfg.norm_eps)
                    o, k_, v_ = gqa_decode(p_["attn"], cfg, h, k_, v_, pos)
                    x_ = x_ + o
                    h = rmsnorm(p_["ln2"], x_, cfg.norm_eps)
                    if "ffn" in p_:
                        x_ = x_ + ffn_fn(p_["ffn"], h)
                    else:
                        from repro.models.moe import moe_ffn
                        y, _ = moe_ffn(p_["moe"], cfg, h)
                        x_ = x_ + y
                    return x_, (k_, v_)

                x, (k_new, v_new) = jax.lax.scan(
                    body, x, (params["layers"], cache["k"], cache["v"]))
                cache = {"k": k_new, "v": v_new}
        elif cfg.family == "ssm":
            def body(x_, xs):
                p_, cs, ss = xs
                x_, cs, ss = blocks.mamba_block_decode(p_, cfg, x_, cs, ss)
                return x_, (cs, ss)

            x, (conv_new, ssm_new) = jax.lax.scan(
                body, x, (params["layers"], cache["conv"], cache["ssm"]))
            cache = {"conv": conv_new, "ssm": ssm_new}
        elif cfg.family == "hybrid":
            shared = params["shared_attn"]
            period = cfg.attn_every
            sk, sv = cache["shared_k"], cache["shared_v"]

            def body(carry, xs):
                x_, i, sk_, sv_ = carry
                p_, cs, ss = xs
                x_, cs, ss = blocks.mamba_block_decode(p_, cfg, x_, cs, ss)

                def do_shared(args):
                    x_in, sk_in, sv_in = args
                    inv = i // period
                    from repro.models.attention import gqa_decode
                    from repro.models.layers import ffn as ffn_fn
                    k_i = jax.lax.dynamic_index_in_dim(sk_in, inv, 0, keepdims=False)
                    v_i = jax.lax.dynamic_index_in_dim(sv_in, inv, 0, keepdims=False)
                    h = rmsnorm(shared["ln1"], x_in, cfg.norm_eps)
                    o, k_i, v_i = gqa_decode(shared["attn"], cfg, h, k_i, v_i, pos)
                    x2 = x_in + o
                    h = rmsnorm(shared["ln2"], x2, cfg.norm_eps)
                    x2 = x2 + ffn_fn(shared["ffn"], h)
                    sk2 = jax.lax.dynamic_update_index_in_dim(sk_in, k_i, inv, 0)
                    sv2 = jax.lax.dynamic_update_index_in_dim(sv_in, v_i, inv, 0)
                    return x2, sk2, sv2

                x_, sk_, sv_ = jax.lax.cond(
                    (i + 1) % period == 0, do_shared,
                    lambda a: a, (x_, sk_, sv_))
                return (x_, i + 1, sk_, sv_), (cs, ss)

            (x, _, sk, sv), (conv_new, ssm_new) = jax.lax.scan(
                body, (x, jnp.int32(0), sk, sv),
                (params["layers"], cache["conv"], cache["ssm"]))
            cache = {"conv": conv_new, "ssm": ssm_new,
                     "shared_k": sk, "shared_v": sv}
        elif cfg.family == "audio":
            from repro.models.attention import decode_attention, gqa_decode
            from repro.models.layers import ffn as ffn_fn

            def body(x_, xs):
                p_, k_, v_, ck, cv = xs
                h = rmsnorm(p_["ln1"], x_, cfg.norm_eps)
                o, k_, v_ = gqa_decode(p_["attn"], cfg, h, k_, v_, pos)
                x_ = x_ + o
                h = rmsnorm(p_["ln_cross"], x_, cfg.norm_eps)
                q = jnp.einsum("bsd,de->bse", h, p_["cross"]["wq"]).reshape(
                    b, 1, cfg.n_heads, cfg.head_dim)
                o = decode_attention(q, ck, cv, kv_len=ck.shape[1])
                x_ = x_ + jnp.einsum("bse,ed->bsd", o.reshape(b, 1, -1),
                                     p_["cross"]["wo"])
                h = rmsnorm(p_["ln2"], x_, cfg.norm_eps)
                x_ = x_ + ffn_fn(p_["ffn"], h)
                return x_, (k_, v_)

            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"],
                          cache["cross_k"], cache["cross_v"]))
            cache = dict(cache, k=k_new, v=v_new)
        else:
            raise ValueError(cfg.family)

        h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        return logits_for_tokens(params["emb"], h), cache
