"""Per-family transformer blocks (pre-norm residual structure)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.base import Specs
from repro.models.layers import ffn, ffn_specs, rmsnorm, rmsnorm_specs


# ---- dense / GQA -----------------------------------------------------------------

def dense_block_specs(cfg: ModelConfig) -> Specs:
    a = attn.mla_specs(cfg) if cfg.use_mla else attn.gqa_specs(cfg)
    return {
        "ln1": rmsnorm_specs(cfg.d_model),
        "attn": a,
        "ln2": rmsnorm_specs(cfg.d_model),
        "ffn": ffn_specs(cfg.d_model, cfg.d_ff),
    }


def dense_block(params, cfg: ModelConfig, x, positions, impl="chunked",
                causal=True):
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h = attn.mla_attention(params["attn"], cfg, h, positions, causal=causal,
                               impl=impl)
    else:
        h = attn.gqa_attention(params["attn"], cfg, h, positions, causal=causal,
                               impl=impl)
    x = x + h
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    return x + ffn(params["ffn"], h)


# ---- MoE -------------------------------------------------------------------------

def moe_block_specs(cfg: ModelConfig, dense_ffn: bool) -> Specs:
    a = attn.mla_specs(cfg) if cfg.use_mla else attn.gqa_specs(cfg)
    s: Specs = {
        "ln1": rmsnorm_specs(cfg.d_model),
        "attn": a,
        "ln2": rmsnorm_specs(cfg.d_model),
    }
    if dense_ffn:
        s["ffn"] = ffn_specs(cfg.d_model, cfg.dense_d_ff or cfg.d_ff)
    else:
        s["moe"] = moe_mod.moe_specs(cfg)
    return s


def moe_block(params, cfg: ModelConfig, x, positions, impl="chunked"):
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h = attn.mla_attention(params["attn"], cfg, h, positions, impl=impl)
    else:
        h = attn.gqa_attention(params["attn"], cfg, h, positions, impl=impl)
    x = x + h
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if "ffn" in params:
        return x + ffn(params["ffn"], h), jnp.zeros((), jnp.float32)
    y, aux = moe_mod.moe_ffn(params["moe"], cfg, h)
    return x + y, aux


# ---- SSM (Mamba-2) -----------------------------------------------------------------

def mamba_block_specs(cfg: ModelConfig) -> Specs:
    return {"ln": rmsnorm_specs(cfg.d_model), "mixer": ssm_mod.ssm_specs(cfg)}


def mamba_block(params, cfg: ModelConfig, x):
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    y, _ = ssm_mod.mamba2_forward(params["mixer"], cfg, h)
    return x + y


def mamba_block_decode(params, cfg: ModelConfig, x, conv_state, ssm_state):
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    y, (cs, ss) = ssm_mod.mamba2_decode(params["mixer"], cfg, h, conv_state,
                                        ssm_state)
    return x + y, cs, ss


# ---- Zamba-style shared attention block ----------------------------------------------

def shared_attn_block_specs(cfg: ModelConfig) -> Specs:
    return {
        "ln1": rmsnorm_specs(cfg.d_model),
        "attn": attn.gqa_specs(cfg),
        "ln2": rmsnorm_specs(cfg.d_model),
        "ffn": ffn_specs(cfg.d_model, cfg.d_ff),
    }


def shared_attn_block(params, cfg: ModelConfig, x, positions, impl="chunked"):
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    h = attn.gqa_attention(params["attn"], cfg, h, positions, impl=impl)
    x = x + h
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    return x + ffn(params["ffn"], h)


# ---- encoder/decoder (Whisper backbone) ------------------------------------------------

def encoder_block_specs(cfg: ModelConfig) -> Specs:
    return dense_block_specs(cfg)


def encoder_block(params, cfg: ModelConfig, x, positions, impl="chunked"):
    return dense_block(params, cfg, x, positions, impl=impl, causal=False)


def decoder_block_specs(cfg: ModelConfig) -> Specs:
    s = dense_block_specs(cfg)
    s["ln_cross"] = rmsnorm_specs(cfg.d_model)
    s["cross"] = attn.gqa_specs(cfg)
    return s


def decoder_block(params, cfg: ModelConfig, x, enc_out, positions,
                  enc_positions, impl="chunked"):
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    h = attn.gqa_attention(params["attn"], cfg, h, positions, causal=True,
                           impl=impl)
    x = x + h
    # cross attention: queries from decoder, keys/values from encoder output
    h = rmsnorm(params["ln_cross"], x, cfg.norm_eps)
    b, s, _ = h.shape
    q = jnp.einsum("bsd,de->bse", h, params["cross"]["wq"]).reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    k = jnp.einsum("bsd,de->bse", enc_out, params["cross"]["wk"]).reshape(
        b, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,de->bse", enc_out, params["cross"]["wv"]).reshape(
        b, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
    o = attn.sdpa(q, k, v, causal=False, impl=impl)
    x = x + jnp.einsum("bse,ed->bsd", o.reshape(b, s, -1),
                       params["cross"]["wo"])
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    return x + ffn(params["ffn"], h)
