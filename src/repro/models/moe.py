"""Mixture-of-Experts: top-k routing with sort-based grouped dispatch.

Design (TPU-native, compile-friendly at 128-160 experts):

* Router: softmax top-k over expert logits, optional shared experts
  (DeepSeek-style) always active.
* Dispatch: tokens are *sorted by expert id* and packed into a fixed
  ``(E, capacity)`` grid (GShard-style capacity factor; overflow drops with
  renormalized combine weights). The grouped tensor carries logical axes
  ``("experts", "expert_cap", "embed")`` so expert parallelism shards the
  leading axis over the ``model`` mesh axis; XLA SPMD materializes the
  all-to-all around the gather/scatter.
* Expert FFN: one einsum over the expert axis (SwiGLU), weights
  ``(E, d, ff)`` sharded on E.

The auxiliary load-balance loss (Switch-style) is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import P, Specs
from repro.models.layers import ffn, ffn_specs


def moe_specs(cfg: ModelConfig) -> Specs:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    s: Specs = {
        "router": P((d, e), ("embed", "experts"), init="small"),
        "w_gate": P((e, d, f), ("experts", "embed", "ff")),
        "w_up": P((e, d, f), ("experts", "embed", "ff")),
        "w_down": P((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.n_shared_experts:
        s["shared"] = ffn_specs(d, cfg.moe_d_ff * cfg.n_shared_experts)
    return s


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    from repro.sharding.optflags import opt

    cf = 1.0 if opt("moe_cf1") else cfg.capacity_factor
    cap = int(n_tokens * cfg.top_k * cf / cfg.n_experts)
    return max(8, ((cap + 7) // 8) * 8)


def route(params, cfg: ModelConfig, x2d):
    """x2d: (T, d) -> (weights (T,k), experts (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x2d, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss.
    e = cfg.n_experts
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[experts.reshape(-1)].add(
        1.0 / (experts.size)
    )
    aux = e * jnp.sum(me * ce)
    return weights.astype(x2d.dtype), experts, aux


def moe_ffn(params, cfg: ModelConfig, x):
    """x: (B, S, d) -> (B, S, d), aux_loss."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    weights, experts, aux = route(params, cfg, x2d)
    k, e = cfg.top_k, cfg.n_experts
    cap = _capacity(t, cfg)

    # ---- sort-based packing into (E, cap) ----
    flat_expert = experts.reshape(-1)                      # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)              # (T*k,)
    flat_weight = weights.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sw = flat_expert[order], flat_token[order], flat_weight[order]
    # position within its expert group
    ones = jnp.ones_like(se)
    pos_in_e = jnp.cumsum(ones) - 1
    group_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    slot = pos_in_e - group_start[se]                      # 0-based within expert
    keep = slot < cap
    # scatter token ids into the (E, cap) grid; empty slots point at T (zeros
    # row); overflow entries scatter out-of-bounds and are dropped.
    slot_or_oob = jnp.where(keep, slot, cap).astype(jnp.int32)
    grid_tok = jnp.full((e, cap), t, jnp.int32)
    grid_w = jnp.zeros((e, cap), flat_weight.dtype)
    grid_tok = grid_tok.at[se, slot_or_oob].set(st.astype(jnp.int32), mode="drop")
    grid_w = grid_w.at[se, slot_or_oob].set(sw, mode="drop")

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    xg = x_pad[grid_tok]                                   # (E, cap, d)
    # Pin the grouped layout: experts over the model axis (EP), capacity over
    # data — the SPMD partitioner otherwise materializes (E, cap, d) fully
    # replicated (tens of GiB at 160 experts).
    from repro.sharding.partition import constrain

    xg = constrain(xg, "model", "data", None)

    # ---- expert SwiGLU over the expert axis ----
    g = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xg, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    h = constrain(h, "model", "data", None)
    yg = jnp.einsum("ecf,efd->ecd", h, params["w_down"])   # (E, cap, d)
    yg = constrain(yg, "model", "data", None)

    # ---- combine: weighted scatter back to tokens ----
    yw = yg * grid_w[..., None].astype(yg.dtype)
    y2d = jnp.zeros((t + 1, d), yg.dtype).at[grid_tok.reshape(-1)].add(
        yw.reshape(-1, d), mode="drop")[:t]
    y2d = constrain(y2d, "data", None)

    if cfg.n_shared_experts:
        y2d = y2d + ffn(params["shared"], x2d)
    return y2d.reshape(b, s, d), aux
