"""Shared layer primitives: RMSNorm, RoPE, SwiGLU FFN, embeddings, chunked
cross-entropy. Pure functions over param dicts (see ``models.base``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import P, Specs


# --------------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------------

def rmsnorm_specs(d: int) -> Specs:
    return {"scale": P((d,), ("embed",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)            # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]                  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------------
# SwiGLU FFN
# --------------------------------------------------------------------------------

def ffn_specs(d: int, d_ff: int) -> Specs:
    return {
        "w_gate": P((d, d_ff), ("embed", "ff")),
        "w_up": P((d, d_ff), ("embed", "ff")),
        "w_down": P((d_ff, d), ("ff", "embed")),
    }


def ffn(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# --------------------------------------------------------------------------------
# Embedding + LM head
# --------------------------------------------------------------------------------

def embedding_specs(vocab: int, d: int, tied: bool) -> Specs:
    s: Specs = {"embedding": P((vocab, d), ("vocab", "embed"), init="small")}
    if not tied:
        s["lm_head"] = P((d, vocab), ("embed", "vocab"))
    return s


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed_weight(params):
    if "lm_head" in params:
        return params["lm_head"]
    return params["embedding"].T


def chunked_cross_entropy(params, h, labels, chunk: int = 512,
                          mask=None) -> jax.Array:
    """Vocab projection + softmax-xent without materializing full logits.

    h: (B, S, D); labels: (B, S). Scans over S in chunks; each chunk's
    logits are (B, chunk, V) and are rematerialized in the backward pass.
    """
    w = unembed_weight(params)
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask_full = jnp.pad(
            jnp.ones((b, s), jnp.float32) if mask is None else mask,
            ((0, 0), (0, pad)),
        )
    else:
        mask_full = jnp.ones((b, s), jnp.float32) if mask is None else mask
    nc = h.shape[1] // chunk
    h = h.reshape(b, nc, chunk, d).swapaxes(0, 1)            # (nc, B, c, D)
    labels = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    mask_full = mask_full.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        hx, lx, mx = xs
        logits = jnp.einsum("bcd,dv->bcv", hx, w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        loss = ((lse - gold) * mx).sum()
        return carry + loss, None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (h, labels, mask_full))
    return total / jnp.maximum(mask_full.sum(), 1.0)


def logits_for_tokens(params, h):
    """Full logits (decode path: S is 1)."""
    return jnp.einsum("...d,dv->...v", h, unembed_weight(params))
