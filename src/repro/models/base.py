"""Functional parameter machinery (no flax): specs -> init -> pytrees.

Every module describes its parameters as a dict of :class:`P` specs carrying
shape, *logical axis names* and an initializer. ``init_params`` materializes
a pytree of arrays; ``axes_tree`` yields the parallel pytree of logical-axis
tuples the sharding layer maps onto the mesh. Layer stacks get a leading
``layers`` axis so the forward pass can ``lax.scan`` over them.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | small (0.006) | identity
    scale: float | None = None  # override stddev

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Specs = dict  # nested dict[str, P | Specs]


def stack_specs(specs: Specs, n: int, axis_name: str = "layers") -> Specs:
    """Add a leading stacked-layer dimension to every spec."""
    out = {}
    for k, v in specs.items():
        if isinstance(v, P):
            out[k] = replace(v, shape=(n,) + v.shape, axes=(axis_name,) + v.axes)
        else:
            out[k] = stack_specs(v, n, axis_name)
    return out


def _init_one(key, p: P, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = p.scale if p.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    if p.init == "small":
        std = 0.006
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)


def init_params(specs: Specs, key: jax.Array, dtype=jnp.bfloat16):
    flat: list[tuple[tuple, P]] = []

    def walk(s, path):
        for k, v in sorted(s.items()):
            if isinstance(v, P):
                flat.append((path + (k,), v))
            else:
                walk(v, path + (k,))

    walk(specs, ())
    keys = jax.random.split(key, max(len(flat), 1))
    out: dict = {}
    for (path, p), k in zip(flat, keys):
        node = out
        for seg in path[:-1]:
            node = node.setdefault(seg, {})
        node[path[-1]] = _init_one(k, p, dtype)
    return out


def abstract_params(specs: Specs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree (for dry-run lowering without allocation)."""

    def walk(s):
        return {
            k: (jax.ShapeDtypeStruct(v.shape, dtype) if isinstance(v, P) else walk(v))
            for k, v in s.items()
        }

    return walk(specs)


def axes_tree(specs: Specs):
    def walk(s):
        return {k: (v.axes if isinstance(v, P) else walk(v)) for k, v in s.items()}

    return walk(specs)


def count_params(specs: Specs) -> int:
    total = 0

    def walk(s):
        nonlocal total
        for v in s.values():
            if isinstance(v, P):
                total += int(np.prod(v.shape))
            else:
                walk(v)

    walk(specs)
    return total
