"""Attention: GQA and MLA (DeepSeek-V2), with three SDPA implementations.

* ``naive``   — materializes scores; tiny shapes / oracles only.
* ``chunked`` — flash-style online-softmax over KV blocks expressed in pure
  jnp ``lax.scan`` (O(block) memory, compiles at 32k+ without materializing
  S). This is the default compile path on CPU and the reference the Pallas
  kernel is validated against. Each block step is ``jax.checkpoint``-ed so
  the backward pass recomputes block scores (flash-backward behaviour).
* ``pallas``  — the TPU kernel in ``repro.kernels`` (selected via MSM policy
  on real hardware).

Decode paths take a KV cache (or MLA latent cache) and a scalar position.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import P, Specs
from repro.models.layers import apply_rope

NEG_INF = -1e30


# --------------------------------------------------------------------------------
# SDPA implementations (q: B,Sq,H,D; k/v: B,Skv,KVH,D)
# --------------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None,
                    scale: float | None = None):
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, sq, kvh, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    idx_q = jnp.arange(sq) + q_offset
    idx_k = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= idx_k[None, :] <= idx_q[:, None]
    if kv_len is not None:
        mask &= idx_k[None, :] < kv_len
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(b, sq, h, v.shape[-1])


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int = 512,
                      kv_chunk: int = 1024, scale: float | None = None):
    """Flash-style attention in pure jnp: scan over q chunks; inner scan over
    kv chunks with online softmax. Memory is O(q_chunk x kv_chunk)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    dv = v.shape[-1]
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    pad_q = (-sq) % q_chunk
    pad_kv = (-skv) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // q_chunk, k.shape[1] // kv_chunk
    qc = q.reshape(b, nq, q_chunk, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(b, nk, kv_chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, kv_chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    valid_kv = skv

    @jax.checkpoint
    def kv_step(carry, inputs):
        m, l, acc, q_blk, q_start = carry
        k_blk, v_blk, ki = inputs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk).astype(jnp.float32)
        s = s * scale
        iq = jnp.arange(q_chunk)[:, None]
        ik = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = ik < valid_kv
        if causal:
            mask = mask & (ik <= (q_start + iq))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new, q_blk, q_start), None

    def q_block(carry, inputs):
        qi, q_blk = inputs
        m0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, dv), jnp.float32)
        (m, l, acc, _, _), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0, q_blk, qi * q_chunk),
            (kc, vc, jnp.arange(nk)),
        )
        out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        return carry, out

    _, results = jax.lax.scan(q_block, 0, (jnp.arange(nq), qc))
    # (nq, b, kvh, g, q_chunk, dv) -> (b, nq*q_chunk, h, dv)
    out = results.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * q_chunk, h, dv)
    return out[:, :sq]


# --------------------------------------------------------------------------------
# custom-VJP flash attention: O(block) memory in fwd AND bwd.
# The forward saves only (q, k, v, out, lse); the backward recomputes score
# blocks — the flash-attention-2 recipe (arXiv:2307.08691) expressed in jnp.
# This is the training default: autodiff-through-scan would stack per-step
# online-softmax carries (multi-GiB per layer at 4k+ sequence lengths).
# --------------------------------------------------------------------------------

def _blockify(x, n, c):
    """(B,S,...) -> (n, B, c, ...)"""
    b = x.shape[0]
    return x.reshape(b, n, c, *x.shape[2:]).swapaxes(0, 1)


def _flash_fwd_impl(q, k, v, pos_q, pos_k, causal, scale, q_chunk, kv_chunk):
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    dv = v.shape[-1]
    g = h // kvh
    nq, nk = sq // q_chunk, skv // kv_chunk
    qc = _blockify(q.reshape(b, sq, kvh, g, d), nq, q_chunk)
    kc = _blockify(k, nk, kv_chunk)
    vc = _blockify(v, nk, kv_chunk)
    pqc = _blockify(pos_q, nq, q_chunk)     # (nq, B, qc)
    pkc = _blockify(pos_k, nk, kv_chunk)

    def q_block(_, inputs):
        qi, q_blk, pq_blk = inputs
        m0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, dv), jnp.float32)

        def kv_step(carry, kv_inputs):
            m, l, acc = carry
            k_blk, v_blk, pk_blk, ki = kv_inputs
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk).astype(jnp.float32) * scale
            if causal:
                # runtime positions (supports packing; also keeps XLA from
                # constant-folding full-score-shaped masks)
                msk = pk_blk[:, None, :] <= pq_blk[:, :, None]   # (B,qc,kc)
                s = jnp.where(msk[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kc, vc, pkc, jnp.arange(nk)))
        out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), qc, pqc))
    # outs: (nq,B,kvh,g,qc,dv); lses: (nq,B,kvh,g,qc)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, dv)
    lse = lses.transpose(1, 0, 4, 2, 3).reshape(b, sq, h)
    return out, lse


def _flash_bwd_impl(q, k, v, pos_q, pos_k, out, lse, dout, causal, scale,
                    q_chunk, kv_chunk):
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    dv_dim = v.shape[-1]
    g = h // kvh
    nq, nk = sq // q_chunk, skv // kv_chunk
    qg = q.reshape(b, sq, kvh, g, d)
    og = out.reshape(b, sq, kvh, g, dv_dim)
    dog = dout.reshape(b, sq, kvh, g, dv_dim)
    lseg = lse.reshape(b, sq, kvh, g)
    delta = jnp.sum(og.astype(jnp.float32) * dog.astype(jnp.float32), -1)
    qc = _blockify(qg, nq, q_chunk)
    doc = _blockify(dog, nq, q_chunk)
    lsec = _blockify(lseg, nq, q_chunk)
    dc = _blockify(delta, nq, q_chunk)
    kc = _blockify(k, nk, kv_chunk)
    vc = _blockify(v, nk, kv_chunk)
    pqc = _blockify(pos_q, nq, q_chunk)
    pkc = _blockify(pos_k, nk, kv_chunk)

    def p_block(pq_blk, pk_blk, q_blk, k_blk, lse_blk):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk).astype(jnp.float32) * scale
        if causal:
            msk = pk_blk[:, None, :] <= pq_blk[:, :, None]
            s = jnp.where(msk[:, None, None], s, NEG_INF)
        # lse_blk: (B,qc,kvh,g) -> (B,kvh,g,qc)
        lse_t = lse_blk.transpose(0, 2, 3, 1)
        return jnp.exp(s - lse_t[..., None])

    # ---- dq: scan q blocks, inner scan kv ----
    def dq_block(_, inputs):
        pq_blk, q_blk, do_blk, lse_blk, d_blk = inputs
        do_t = do_blk.transpose(0, 2, 3, 1, 4).astype(jnp.float32)
        d_t = d_blk.transpose(0, 2, 3, 1)

        def kv_step(acc, kv_inputs):
            k_blk, v_blk, pk_blk = kv_inputs
            p = p_block(pq_blk, pk_blk, q_blk, k_blk, lse_blk)
            dp = jnp.einsum("bhgqe,bkhe->bhgqk", do_t,
                            v_blk.astype(jnp.float32))
            ds = p * (dp - d_t[..., None]) * scale
            return acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                    k_blk.astype(jnp.float32)), None

        acc0 = jnp.zeros((b, q_chunk, kvh, g, d), jnp.float32)
        dq_blk, _ = jax.lax.scan(kv_step, acc0, (kc, vc, pkc))
        return None, dq_blk

    _, dq_blocks = jax.lax.scan(dq_block, None,
                                (pqc, qc, doc, lsec, dc))
    dq = dq_blocks.swapaxes(0, 1).reshape(b, sq, h, d).astype(q.dtype)

    # ---- dk, dv: scan kv blocks, inner scan q ----
    def dkv_block(_, inputs):
        pk_blk, k_blk, v_blk = inputs

        def q_step(carry, q_inputs):
            dk_acc, dv_acc = carry
            pq_blk, q_blk, do_blk, lse_blk, d_blk = q_inputs
            p = p_block(pq_blk, pk_blk, q_blk, k_blk, lse_blk)
            do_t = do_blk.transpose(0, 2, 3, 1, 4).astype(jnp.float32)
            d_t = d_blk.transpose(0, 2, 3, 1)
            dv_acc = dv_acc + jnp.einsum("bhgqk,bhgqe->bkhe", p, do_t)
            dp = jnp.einsum("bhgqe,bkhe->bhgqk", do_t, v_blk.astype(jnp.float32))
            ds = p * (dp - d_t[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                         q_blk.astype(jnp.float32))
            return (dk_acc, dv_acc), None

        dk0 = jnp.zeros((b, kv_chunk, kvh, d), jnp.float32)
        dv0 = jnp.zeros((b, kv_chunk, kvh, dv_dim), jnp.float32)
        (dk_blk, dv_blk), _ = jax.lax.scan(
            q_step, (dk0, dv0), (pqc, qc, doc, lsec, dc))
        return None, (dk_blk, dv_blk)

    _, (dk_blocks, dv_blocks) = jax.lax.scan(dkv_block, None,
                                             (pkc, kc, vc))
    dk = dk_blocks.swapaxes(0, 1).reshape(b, skv, kvh, d).astype(k.dtype)
    dv = dv_blocks.swapaxes(0, 1).reshape(b, skv, kvh, dv_dim).astype(v.dtype)
    return dq, dk, dv


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def flash_attention_jnp(q, k, v, pos_q, pos_k, causal, scale, q_chunk,
                        kv_chunk):
    out, _ = _flash_fwd_impl(q, k, v, pos_q, pos_k, causal, scale, q_chunk,
                             kv_chunk)
    return out


def _flash_vjp_fwd(q, k, v, pos_q, pos_k, causal, scale, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, pos_q, pos_k, causal, scale, q_chunk,
                               kv_chunk)
    return out, (q, k, v, pos_q, pos_k, out, lse)


def _flash_vjp_bwd(causal, scale, q_chunk, kv_chunk, saved, dout):
    q, k, v, pos_q, pos_k, out, lse = saved
    dq, dk, dv = _flash_bwd_impl(q, k, v, pos_q, pos_k, out, lse, dout,
                                 causal, scale, q_chunk, kv_chunk)
    return dq, dk, dv, jnp.zeros_like(pos_q), jnp.zeros_like(pos_k)


flash_attention_jnp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, *, causal: bool, scale: float | None = None,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    positions=None, kv_positions=None):
    """Shape-normalizing wrapper: pads S to chunk multiples, handles dv != d.
    ``positions``/``kv_positions``: (B,S) int32 runtime positions (sequence
    packing; also prevents the mask from being constant-folded at score
    shape)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32), (b, sq))
    if kv_positions is None:
        kv_positions = (positions if sq == skv else jnp.broadcast_to(
            jnp.arange(skv, dtype=jnp.int32), (b, skv)))
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    pad_q = (-sq) % q_chunk
    pad_kv = (-skv) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad_q)))
    if pad_kv:
        if causal and sq == skv + pad_kv - pad_q:
            k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
            # padded keys get position INT32_MAX -> masked for every query
            kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad_kv)),
                                   constant_values=jnp.iinfo(jnp.int32).max)
        else:
            kv_chunk = next(c for c in range(kv_chunk, 0, -1) if skv % c == 0)
    out = flash_attention_jnp(q, k, v, positions, kv_positions, causal, scale,
                              q_chunk, kv_chunk)
    return out[:, :sq]


def decode_attention(q, k_cache, v_cache, kv_len, scale: float | None = None):
    """Single-token attention against a (possibly sequence-sharded) cache.

    q: (B,1,H,D); caches: (B,S,KVH,D); kv_len: number of valid entries.
    Score/softmax reductions over the cache axis lower to psum-style
    collectives when S is sharded (context-parallel flash-decode).
    """
    b, _, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kvh, g, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32)
    scores = scores * scale
    mask = jnp.arange(s)[None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, NEG_INF)
    # int8-quantized caches: compute the weighted sum in bf16 (dequant is a
    # scale-fold upstream; the cast here keeps softmax weights non-integer)
    acc_dtype = jnp.bfloat16 if v_cache.dtype == jnp.int8 else v_cache.dtype
    p = jax.nn.softmax(scores, axis=-1).astype(acc_dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(acc_dtype))
    return out.reshape(b, 1, h, v_cache.shape[-1])


def sdpa(q, k, v, *, causal: bool, impl: str = "chunked",
         q_chunk: int = 512, kv_chunk: int = 1024, scale=None,
         positions=None):
    if impl == "naive" or q.shape[1] <= 256:
        return naive_attention(q, k, v, causal=causal, scale=scale)
    if impl == "pallas":
        from repro.kernels import ops as kops

        return kops.flash_attention_op(q, k, v, causal=causal, scale=scale)
    return flash_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                           kv_chunk=kv_chunk, scale=scale,
                           positions=positions)


# --------------------------------------------------------------------------------
# GQA attention module
# --------------------------------------------------------------------------------

def gqa_specs(cfg: ModelConfig) -> Specs:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": P((d, h * hd), ("embed", "heads")),
        "wk": P((d, kvh * hd), ("embed", "kv_heads")),
        "wv": P((d, kvh * hd), ("embed", "kv_heads")),
        "wo": P((h * hd, d), ("heads", "embed")),
    }


def gqa_project_qkv(params, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(b, s, kvh, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(b, s, kvh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(params, cfg: ModelConfig, x, positions, *, causal=True,
                  impl="chunked"):
    q, k, v = gqa_project_qkv(params, cfg, x, positions)
    from repro.sharding.optflags import opt
    from repro.sharding.partition import constrain

    if opt("gqa_expand_kv") and cfg.n_kv_heads < cfg.n_heads:
        g = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if opt("attn_gather_once"):
        # settle the attention layout once, outside the block scans
        q = constrain(q, ("pod", "data"), None, "model", None)
        k = constrain(k, ("pod", "data"), None, "model", None)
        v = constrain(v, ("pod", "data"), None, "model", None)
    out = sdpa(q, k, v, causal=causal, impl=impl, positions=positions)
    b, s = x.shape[:2]
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), params["wo"])


def gqa_decode(params, cfg: ModelConfig, x, cache_k, cache_v, pos, impl="chunked"):
    """One-token decode. cache_[kv]: (B, S, KVH, D); pos: scalar index of the
    new token. Returns (out, new_k, new_v)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = gqa_project_qkv(params, cfg, x, positions)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    out = decode_attention(q, cache_k, cache_v, kv_len=pos + 1)
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), params["wo"])
    return out, cache_k, cache_v


# --------------------------------------------------------------------------------
# MLA attention (DeepSeek-V2): latent-compressed KV cache
# --------------------------------------------------------------------------------

def mla_specs(cfg: ModelConfig) -> Specs:
    d, h = cfg.d_model, cfg.n_heads
    hd, r, vd = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "wq_a": P((d, ql), ("embed", "lora")),
        "wq_b": P((ql, h * (hd + r)), ("lora", "heads")),
        "wkv_a": P((d, kvl + r), ("embed", "lora")),
        "wk_b": P((kvl, h * hd), ("lora", "heads")),
        "wv_b": P((kvl, h * vd), ("lora", "heads")),
        "wo": P((h * vd, d), ("heads", "embed")),
    }


def _mla_qkv(params, cfg: ModelConfig, x, positions, c_kv, k_rope):
    """Expand latent cache into per-head K/V and build rope-augmented Q/K."""
    b, s_kv = c_kv.shape[0], c_kv.shape[1]
    s_q = x.shape[1]
    h, hd, r, vd = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q = jnp.einsum("bsd,dl->bsl", x, params["wq_a"])
    q = jnp.einsum("bsl,le->bse", q, params["wq_b"]).reshape(b, s_q, h, hd + r)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsl,le->bse", c_kv, params["wk_b"]).reshape(b, s_kv, h, hd)
    v = jnp.einsum("bsl,le->bse", c_kv, params["wv_b"]).reshape(b, s_kv, h, vd)
    # shared rope key broadcast across heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s_kv, h, r))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    return q_full, k, v


def mla_attention(params, cfg: ModelConfig, x, positions, *, causal=True,
                  impl="chunked"):
    b, s, _ = x.shape
    kvl, r = cfg.kv_lora_rank, cfg.rope_head_dim
    ckv_full = jnp.einsum("bsd,dl->bsl", x, params["wkv_a"])
    c_kv, k_rope = ckv_full[..., :kvl], ckv_full[..., kvl:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    q, k, v = _mla_qkv(params, cfg, x, positions, c_kv, k_rope)
    scale = (cfg.head_dim + r) ** -0.5
    out = sdpa(q, k, v, causal=causal, impl=impl, scale=scale,
               positions=positions)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), params["wo"])


def mla_decode(params, cfg: ModelConfig, x, cache_ckv, cache_krope, pos):
    """One-token MLA decode in the ABSORBED form: scores are computed against
    the latent cache directly (wk_b folded into q, wv_b applied after the
    weighted latent sum), so per-head K/V are never expanded over the cache.
    The cache stores only (kv_lora + rope) per token — the compressed cache
    is itself a DRAM-traffic filter, exactly the paper's L3 argument."""
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    kvl, r, vd = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.v_head_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    ckv_full = jnp.einsum("bsd,dl->bsl", x, params["wkv_a"])
    c_new, krope_new = ckv_full[..., :kvl], ckv_full[..., kvl:]
    krope_new = apply_rope(krope_new[:, :, None, :], positions,
                           cfg.rope_theta)[:, :, 0]
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_new.astype(cache_ckv.dtype), (0, pos, 0))
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, krope_new.astype(cache_krope.dtype), (0, pos, 0))

    q = jnp.einsum("bsd,dl->bsl", x, params["wq_a"])
    q = jnp.einsum("bsl,le->bse", q, params["wq_b"]).reshape(b, 1, h, hd + r)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    wk_b = params["wk_b"].reshape(kvl, h, hd)
    wv_b = params["wv_b"].reshape(kvl, h, vd)
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, wk_b)
    s_nope = jnp.einsum("bqhl,bkl->bhqk", q_abs.astype(jnp.float32),
                        cache_ckv.astype(jnp.float32))
    s_rope = jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(jnp.float32),
                        cache_krope.astype(jnp.float32))
    scores = (s_nope + s_rope) * ((hd + r) ** -0.5)
    mask = jnp.arange(cache_ckv.shape[1])[None, None, None, :] < pos + 1
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkl->bqhl", p.astype(cache_ckv.dtype), cache_ckv)
    out = jnp.einsum("bqhl,lhv->bqhv", ctx, wv_b)
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), params["wo"])
    return out, cache_ckv, cache_krope
