"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD algorithm: within a chunk the token mixing is the quadratic
"attention-like" form; across chunks a linear recurrence carries the
(heads, head_dim, state) SSM state. Both forms never materialize anything
larger than (chunk x chunk) per head — the same VMEM-filtering structure the
paper's L3 provides in hardware, which is why this layer is also one of our
Pallas kernel targets.

Decode keeps O(1)-in-sequence state: (conv window, SSM state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import P, Specs


def ssm_specs(cfg: ModelConfig) -> Specs:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    return {
        "in_proj": P((d, 2 * di + 2 * n + h), ("embed", "ff")),
        "conv_w": P((cfg.ssm_conv, conv_ch), (None, "ff"), init="small"),
        "conv_b": P((conv_ch,), ("ff",), init="zeros"),
        "A_log": P((h,), ("heads",), init="zeros"),
        "D": P((h,), ("heads",), init="ones"),
        "dt_bias": P((h,), ("heads",), init="zeros"),
        "norm": P((di,), ("ff",), init="ones"),
        "out_proj": P((di, d), ("ff", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    b_ = zxbcdt[..., 2 * di:2 * di + n]
    c_ = zxbcdt[..., 2 * di + n:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, x, b_, c_, dt


def _causal_conv(params, xbc, conv_state=None):
    """Depthwise causal conv over (B,S,C). Returns (out, new_state)."""
    kw = params["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], kw - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(kw):
        out = out + xp[:, i:i + xbc.shape[1]] * params["conv_w"][i]
    out = jax.nn.silu((out + params["conv_b"]).astype(jnp.float32)).astype(xbc.dtype)
    new_state = xp[:, xp.shape[1] - (kw - 1):]
    return out, new_state


def ssd_chunked(x, dt, A, b_, c_, chunk: int, initial_state=None,
                head_block: int = 8):
    """SSD scan. x: (B,S,H,P); dt: (B,S,H); A: (H,) (negative);
    b_/c_: (B,S,N). Returns (y (B,S,H,P), final_state (B,H,P,N)).

    Structure: ``lax.scan`` over chunks carries the SSM state; within a
    chunk the quadratic intra-chunk term is evaluated per head-block
    (sequential ``lax.map``) so the largest transient is
    (B, L, L, head_block) — the compile-memory analogue of the Pallas
    kernel's VMEM tiling.
    """
    bsz, s, h, p = x.shape
    n = b_.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0)))
        c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    hb = min(head_block, h)
    nhb = h // hb if h % hb == 0 else 1
    if h % hb != 0:
        hb = h
    xc = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(bsz, nc, chunk, h).transpose(1, 0, 2, 3)
    bc = b_.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = c_.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def chunk_step(state, inp):
        xz, dtz, bz, cz = inp          # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N)
        dA = dtz.astype(jnp.float32) * A[None, None, :]
        seg = jnp.cumsum(dA, axis=1)                      # (B,L,H)
        total = seg[:, -1]                                # (B,H)
        cb = jnp.einsum("bin,bjn->bij", cz.astype(jnp.float32),
                        bz.astype(jnp.float32))           # (B,L,L)
        xdt = xz.astype(jnp.float32) * dtz.astype(jnp.float32)[..., None]

        def hb_fn(args):
            seg_h, xdt_h = args        # (B,L,hb), (B,L,hb,P)
            decay = jnp.exp(seg_h[:, :, None, :] - seg_h[:, None, :, :])
            decay = jnp.where(tril[None, :, :, None], decay, 0.0)
            att = cb[..., None] * decay                   # (B,L,L,hb)
            return jnp.einsum("bijh,bjhp->bihp", att, xdt_h)

        seg_b = jnp.moveaxis(seg.reshape(bsz, chunk, nhb, hb), 2, 0)
        xdt_b = jnp.moveaxis(xdt.reshape(bsz, chunk, nhb, hb, p), 2, 0)
        y_diag = jax.lax.map(hb_fn, (seg_b, xdt_b))       # (nhb,B,L,hb,P)
        y_diag = jnp.moveaxis(y_diag, 0, 2).reshape(bsz, chunk, h, p)

        # inter-chunk output from the carried state at chunk start
        y_off = jnp.einsum("bin,bih,bhpn->bihp", cz.astype(jnp.float32),
                           jnp.exp(seg), state)
        # state update
        decay_out = jnp.exp(total[:, None, :] - seg)      # (B,L,H)
        states_z = jnp.einsum("bjn,bjh,bjhp->bhpn", bz.astype(jnp.float32),
                              dtz.astype(jnp.float32) * decay_out,
                              xz.astype(jnp.float32))
        new_state = states_z + jnp.exp(total)[:, :, None, None] * state
        return new_state, (y_diag + y_off).astype(x.dtype)

    init = (jnp.zeros((bsz, h, p, n), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))
    final_state, ys = jax.lax.scan(chunk_step, init, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * chunk, h, p)
    return y[:, :s], final_state


def mamba2_forward(params, cfg: ModelConfig, x, chunk: int | None = None):
    """Full Mamba-2 mixer over (B,S,d). Returns (y, (conv_state, ssm_state))."""
    di, h, p = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xin, b_, c_, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xin, b_, c_], axis=-1)
    xbc, conv_state = _causal_conv(params, xbc)
    xin, b_, c_ = xbc[..., :di], xbc[..., di:di + cfg.ssm_state], xbc[..., di + cfg.ssm_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xin.reshape(*xin.shape[:2], h, p)
    y, ssm_state = ssd_chunked(xh, dt, A, b_, c_, chunk or cfg.ssm_chunk)
    y = y + xh * params["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(*x.shape[:2], di)
    # gated RMSNorm then out-projection (Mamba-2 block structure)
    yz = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    var = jnp.mean(jnp.square(yz.astype(jnp.float32)), -1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
          * params["norm"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", yz, params["out_proj"]), (conv_state, ssm_state)


def mamba2_decode(params, cfg: ModelConfig, x, conv_state, ssm_state):
    """Single-token step. x: (B,1,d); conv_state: (B,kw-1,C);
    ssm_state: (B,H,P,N)."""
    di, h, p, n = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xin, b_, c_, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xin, b_, c_], axis=-1)
    xbc, conv_state = _causal_conv(params, xbc, conv_state)
    xin, b_, c_ = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xin.reshape(x.shape[0], h, p)
    dt1 = dt[:, 0]                                        # (B,H)
    dA = jnp.exp(dt1 * A[None, :])                        # (B,H)
    dbx = jnp.einsum("bn,bh,bhp->bhpn", b_[:, 0].astype(jnp.float32),
                     dt1, xh.astype(jnp.float32))
    ssm_state = ssm_state * dA[:, :, None, None] + dbx
    y = jnp.einsum("bn,bhpn->bhp", c_[:, 0].astype(jnp.float32), ssm_state)
    y = y.astype(x.dtype) + xh * params["D"][None, :, None].astype(x.dtype)
    y = y.reshape(x.shape[0], 1, di)
    yz = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    var = jnp.mean(jnp.square(yz.astype(jnp.float32)), -1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
          * params["norm"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", yz, params["out_proj"]), (conv_state, ssm_state)
