"""Model zoo: one composable LanguageModel over all assigned families."""
from repro.models.lm import LanguageModel

__all__ = ["LanguageModel"]
