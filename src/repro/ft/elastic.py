"""Elastic run control: checkpoint/restart across mesh-shape changes.

``ElasticRunner`` owns the restart loop around a train function:

    runner = ElasticRunner(ckpt_dir, build_state, train_segment)
    runner.run(max_steps)

* ``build_state(mesh, restore_step)`` constructs (params, opt_state, step)
  — restoring and RESHARDING from the latest checkpoint when one exists
  (the checkpoint layer stores arrays by name, so any mesh shape whose
  shardings the caller provides will do: scale 16 hosts -> 12 hosts and the
  same checkpoint restores onto the smaller mesh).
* ``train_segment(state, steps)`` runs until it returns (completed) or
  raises (hang/preemption) — the runner saves, rebuilds the mesh with
  whatever devices are now healthy, and resumes.

On real fleets mesh health comes from the cluster scheduler; here
``mesh_factory`` abstracts it (tests inject shrinking device sets).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step


@dataclass
class RunState:
    params: object
    opt_state: object
    step: int
    mesh: object = None
    restarts: int = 0


@dataclass
class QueueDepthAutoscaler:
    """Queue-depth-driven fleet sizing for the serving simulator.

    The serving-side face of elastic run control: where :class:`ElasticRunner`
    resizes a training mesh across restarts, this policy resizes a serving
    fleet (``repro.serve.fleet.FleetSim``) at a fixed cadence from what a
    real autoscaler can observe — queue depth and running batch occupancy.

    Thresholds are in units of FULL BATCHES per instance — a loaded-but-
    stable instance naturally runs with a batch or two waiting, so absolute
    request counts would flap at the correct size:

    * scale UP by one when more than ``high_batches`` full batches per
      instance are waiting AND the backlog is not already draining (an
      undersized fleet has an ever-growing queue; a recovering one should
      not keep adding instances);
    * scale DOWN by one when the queue is near-empty (< ``low_batches``)
      and the running work would fit ``n - 1`` instances at ``down_util``
      batch utilization.

    Under stationary load this converges to the smallest stable fleet —
    within one instance of ``instances_to_meet_slo`` for any SLO loose
    enough to be queue-stability-bound (asserted in tests).
    """

    high_batches: float = 2.0
    low_batches: float = 0.25
    down_util: float = 0.7
    min_instances: int = 1
    max_instances: int = 64
    _last_queued: float = field(default=-1.0, init=False, repr=False)

    def decide(self, n_active: int, queued: int, running: int,
               max_batch: int) -> int:
        # Both fleet engines (the per-instance oracle and the vectorized
        # core in ``repro.serve.fleetbatch``) call this at autoscale ticks;
        # coerce observations so numpy scalars from the batched engine and
        # plain ints from the oracle drive bit-identical decisions.
        n_active, queued, running = int(n_active), int(queued), int(running)
        capacity = max(n_active, 1) * max_batch
        growing = self._last_queued < 0 or queued >= self._last_queued
        self._last_queued = float(queued)
        if queued > self.high_batches * capacity and growing:
            return min(n_active + 1, self.max_instances)
        if (queued < self.low_batches * capacity
                and n_active > self.min_instances
                and running <= (n_active - 1) * max_batch * self.down_util):
            return max(n_active - 1, self.min_instances)
        return n_active


class ElasticRunner:
    def __init__(self, ckpt_dir: str, mesh_factory: Callable[[], object],
                 build_state: Callable, train_segment: Callable,
                 max_restarts: int = 10, save_every: int = 100):
        self.ckpt_dir = ckpt_dir
        self.mesh_factory = mesh_factory
        self.build_state = build_state
        self.train_segment = train_segment
        self.max_restarts = max_restarts
        self.save_every = save_every
        self.ckpt = AsyncCheckpointer(ckpt_dir)

    def run(self, max_steps: int) -> RunState:
        restarts = 0
        while True:
            mesh = self.mesh_factory()
            start = latest_step(self.ckpt_dir)
            state = self.build_state(mesh, start)
            state.mesh = mesh
            state.restarts = restarts
            try:
                state = self.train_segment(self, state, max_steps)
                self.ckpt.wait()
                return state
            except Exception as e:  # noqa: BLE001 — restart-able failure
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                print(f"[elastic] segment failed ({type(e).__name__}: {e}); "
                      f"restart {restarts}/{self.max_restarts}")
                time.sleep(0.1)

    def maybe_save(self, state: RunState, force: bool = False):
        if force or (state.step > 0 and state.step % self.save_every == 0):
            self.ckpt.save_async(
                state.step,
                {"params": state.params, "opt": state.opt_state},
                extra={"step": state.step})
