from repro.ft.watchdog import StepWatchdog, StragglerStats
from repro.ft.elastic import ElasticRunner, RunState

__all__ = ["StepWatchdog", "StragglerStats", "ElasticRunner", "RunState"]
