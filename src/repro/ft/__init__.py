from repro.ft.watchdog import StepWatchdog, StragglerStats
from repro.ft.elastic import ElasticRunner, QueueDepthAutoscaler, RunState

__all__ = ["StepWatchdog", "StragglerStats", "ElasticRunner",
           "QueueDepthAutoscaler", "RunState"]
