"""Step watchdog: hang detection + straggler statistics.

At thousand-node scale the common failure is not a clean crash but a
*silent stall* (one chip wedged inside a collective) or a persistent
straggler (one host at 70% step rate dragging every synchronous step). The
watchdog runs host-side:

* ``deadline``: if no step completes within ``deadline_s``, the registered
  ``on_hang`` callback fires (default: raise in the main thread's next
  check — the launcher turns that into kill+restart-from-checkpoint).
* straggler stats: an EWMA of step time and a robust z-score of the last
  step; sustained outliers trip ``on_straggler`` (the launcher's policy is
  to demote the slow host / shrink the mesh via the elastic runner).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass


@dataclass
class StragglerStats:
    ewma_s: float = 0.0
    var_ewma: float = 0.0
    n: int = 0
    slow_streak: int = 0
    threshold: float = 2.0        # step considered slow if > threshold x ewma
    streak_to_flag: int = 3

    def observe(self, dt: float) -> bool:
        """Returns True when a sustained straggler pattern is detected."""
        if self.n == 0:
            self.ewma_s = dt
        alpha = 0.1
        slow = self.n > 3 and dt > self.threshold * self.ewma_s
        self.slow_streak = self.slow_streak + 1 if slow else 0
        # slow steps damp the mean update so one straggler doesn't poison it
        beta = alpha * (0.25 if slow else 1.0)
        self.ewma_s = (1 - beta) * self.ewma_s + beta * dt
        self.var_ewma = (1 - alpha) * self.var_ewma + alpha * (dt - self.ewma_s) ** 2
        self.n += 1
        return self.slow_streak >= self.streak_to_flag


class StepWatchdog:
    """Context-managed heartbeat around the training loop."""

    def __init__(self, deadline_s: float = 600.0, on_hang=None,
                 on_straggler=None, poll_s: float = 1.0):
        self.deadline_s = deadline_s
        self.on_hang = on_hang
        self.on_straggler = on_straggler
        self.poll_s = poll_s
        self.stats = StragglerStats()
        self._last_beat = time.monotonic()
        self._last_step_start = time.monotonic()
        self._stop = threading.Event()
        self.hang_detected = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)

    def __enter__(self):
        self._thread.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._thread.join(timeout=5)
        return False

    def step_started(self):
        self._last_step_start = time.monotonic()
        self._last_beat = self._last_step_start

    def step_finished(self) -> float:
        now = time.monotonic()
        dt = now - self._last_step_start
        self._last_beat = now
        if self.stats.observe(dt) and self.on_straggler:
            self.on_straggler(self.stats)
        return dt

    def _watch(self):
        while not self._stop.is_set():
            time.sleep(self.poll_s)
            if time.monotonic() - self._last_beat > self.deadline_s:
                self.hang_detected.set()
                if self.on_hang:
                    self.on_hang()
                return

    def check(self):
        """Call from the main loop; raises if the watcher flagged a hang."""
        if self.hang_detected.is_set():
            raise TimeoutError(
                f"no step heartbeat for > {self.deadline_s}s — assuming a "
                "wedged collective; restart from the last checkpoint")
