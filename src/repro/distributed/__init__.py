from repro.distributed.pipeline import bubble_fraction, pipeline_apply

__all__ = ["bubble_fraction", "pipeline_apply"]
