"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

The production meshes expose a natural stage axis: ``pod`` (2 stages at
2x16x16) — pipelining across pods converts the slow cross-pod gradient
all-reduce into point-to-point boundary ppermutes, the standard move when
inter-pod bandwidth is the binding constraint (DP/PP trade-off at 1000+
chips).

Implementation: layers are split into ``n_stages`` contiguous groups whose
parameters are sharded over the stage axis (each device holds only its
stage's layers). ``pipeline_apply`` runs the classic GPipe schedule inside
``shard_map``: with M microbatches and S stages, the loop runs M+S-1 ticks;
each tick every stage applies its block to its current microbatch and the
activations rotate one stage forward via ``jax.lax.ppermute``. Bubble
fraction = (S-1)/(M+S-1), as reported by :func:`bubble_fraction`.

Works under jit, differentiates (jax.grad through shard_map+ppermute), and
is validated against the unpipelined reference in
``tests/test_pipeline.py`` on 8 fake devices.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def stage_params_sharding(mesh: Mesh, axis: str = "pipe"):
    """Stacked per-stage params: leading dim = stage, sharded over the axis."""
    return NamedSharding(mesh, P(axis))


def pipeline_apply(block_fn, stage_params, x, *, mesh: Mesh,
                   axis: str = "pipe", n_microbatches: int | None = None):
    """Run a pipelined stack of stages.

    block_fn(params_stage, x_mb) -> y_mb — one stage's computation (itself
    typically a scan over that stage's layers).
    stage_params: pytree with leading dim = n_stages, sharded over ``axis``.
    x: (M, mb, ...) microbatched input, replicated over ``axis``.

    Returns y with the same (M, mb, ...) layout.
    """
    n_stages = mesh.shape[axis]
    m = x.shape[0]
    n_microbatches = n_microbatches or m
    assert m == n_microbatches

    def run(params_local, x_all):
        # params_local: (1, ...) this stage's slice; x_all: full (M, mb, ...)
        params_me = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        n_ticks = m + n_stages - 1
        buf = jnp.zeros_like(x_all[0])
        outputs = jnp.zeros_like(x_all)

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, m - 1)
            fresh = jax.lax.dynamic_index_in_dim(x_all, mb_idx, 0,
                                                 keepdims=False)
            cur = jnp.where(stage == 0, fresh, buf)
            # is this stage holding a real microbatch at tick t?
            my_mb = t - stage
            active = (my_mb >= 0) & (my_mb < m)
            y = block_fn(params_me, cur)
            y = jnp.where(active, y, cur)
            # last stage writes its finished microbatch
            out_idx = jnp.clip(my_mb, 0, m - 1)
            write = active & (stage == n_stages - 1)
            prev = jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                                keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(write, y, prev), out_idx, 0)
            # rotate activations forward one stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outputs), None

        (_, outputs), _ = jax.lax.scan(tick, (buf, outputs),
                                       jnp.arange(n_ticks))
        # every stage computed an `outputs` buffer; only the last stage's is
        # real — mask-and-psum broadcasts it back (replicated over the axis)
        outputs = jnp.where(stage == n_stages - 1, outputs, 0.0)
        return jax.lax.psum(outputs, axis)

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(run, mesh=mesh,
                   in_specs=(spec_p, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x)
