"""Zamba2-1.2B: Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,        # shared block uses full MHA
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,         # shared attention+MLP block after every 6th mamba block
    tie_embeddings=True,
)
