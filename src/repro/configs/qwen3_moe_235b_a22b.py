"""Qwen3-MoE 235B-A22B-class: 128 experts, top-8, GQA kv=4
[hf:Qwen/Qwen3-30B-A3B family; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,            # unused for MoE layers; kept per assignment sheet
    moe_d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    n_experts=128,
    top_k=8,
    rope_theta=1_000_000.0,
)
