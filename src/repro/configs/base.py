"""Architecture configuration schema + the workload shape grid.

Every assigned architecture is a :class:`ModelConfig`; ``smoke()`` returns
the reduced same-family variant used by the CPU smoke tests. The full
configs are only ever lowered via the dry-run (ShapeDtypeStruct — no
allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim
    first_k_dense: int = 0      # leading dense layers (DeepSeek)
    dense_d_ff: int = 0         # hidden dim of those dense layers
    capacity_factor: float = 1.25

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0         # 0 -> head_dim

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    attn_every: int = 0         # hybrid: shared attention block every N layers

    # --- encoder-decoder (Whisper) ---
    n_encoder_layers: int = 0
    cross_attention: bool = False

    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    frontend: str | None = None  # "audio" | "vision" (stub: embeddings provided)
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.use_mla and self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)

    # ---- derived sizes --------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing: SSM and hybrid families only."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def attn_params_per_layer(self) -> int:
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        if self.use_mla:
            r = self.rope_head_dim
            q = self.q_lora_rank * d + self.q_lora_rank * h * (hd + r) if self.q_lora_rank else d * h * (hd + r)
            kvp = d * (self.kv_lora_rank + r) + self.kv_lora_rank * h * (hd + self.v_head_dim)
            o = h * self.v_head_dim * d
            return q + kvp + o
        return d * h * hd + 2 * d * kv * hd + h * hd * d

    def ffn_params(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff  # SwiGLU: gate, up, down

    def ssm_params_per_layer(self) -> int:
        d, di, s = self.d_model, self.d_inner, self.ssm_state
        in_proj = d * (2 * di + 2 * s + self.ssm_heads)  # z, x, B, C, dt
        conv = (di + 2 * s) * self.ssm_conv
        out = di * d
        return in_proj + conv + out + 2 * self.ssm_heads  # + A, D

    def n_params(self) -> float:
        """Total parameters (embeddings included once; +lm head if untied)."""
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        total = float(emb)
        enc = self.n_encoder_layers
        dec = self.n_layers
        if self.family == "ssm":
            total += dec * (self.ssm_params_per_layer() + 2 * self.d_model)
            return total
        if self.family == "hybrid":
            total += dec * (self.ssm_params_per_layer() + 2 * self.d_model)
            # one SHARED attention+MLP block (Zamba-style)
            total += self.attn_params_per_layer() + self.ffn_params(self.d_ff)
            return total
        per_layer_attn = self.attn_params_per_layer() + 2 * self.d_model
        if self.n_experts:
            moe_layers = dec - self.first_k_dense
            dense_layers = self.first_k_dense
            expert_p = (self.n_experts + self.n_shared_experts) * self.ffn_params(self.moe_d_ff)
            router_p = self.d_model * self.n_experts
            total += dec * per_layer_attn
            total += moe_layers * (expert_p + router_p)
            total += dense_layers * self.ffn_params(self.dense_d_ff or self.d_ff)
            return total
        total += (dec + enc) * (per_layer_attn + self.ffn_params(self.d_ff))
        if self.cross_attention:
            total += dec * self.attn_params_per_layer()
        return total

    def n_active_params(self) -> float:
        """Per-token activated parameters (MoE: only routed top-k + shared)."""
        if not self.n_experts:
            return self.n_params()
        dec = self.n_layers
        moe_layers = dec - self.first_k_dense
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        active = float(emb) + dec * (self.attn_params_per_layer() + 2 * self.d_model)
        active += moe_layers * (
            (self.top_k + self.n_shared_experts) * self.ffn_params(self.moe_d_ff)
            + self.d_model * self.n_experts
        )
        active += self.first_k_dense * self.ffn_params(self.dense_d_ff or self.d_ff)
        return active

    # ---- reduced variant for CPU smoke tests -----------------------------------
    def smoke(self) -> "ModelConfig":
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if (self.attn_every or self.first_k_dense) else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=2, moe_d_ff=64,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      first_k_dense=min(self.first_k_dense, 1), dense_d_ff=128)
        if self.use_mla:
            kw.update(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8, v_head_dim=16)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
        if self.attn_every:
            kw.update(attn_every=2, n_kv_heads=4)
        if self.n_encoder_layers:
            kw.update(n_encoder_layers=2)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: str                    # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell, with skip reason."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "long_500k needs sub-quadratic attention (pure full-attention arch)"
    return True, ""
