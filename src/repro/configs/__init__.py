"""Architecture registry: ``get(arch_id)`` resolves ``--arch`` flags."""
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, cell_is_runnable

from repro.configs.tinyllama_1_1b import CONFIG as TINYLLAMA
from repro.configs.yi_6b import CONFIG as YI_6B
from repro.configs.mistral_nemo_12b import CONFIG as MISTRAL_NEMO
from repro.configs.granite_3_2b import CONFIG as GRANITE
from repro.configs.qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE
from repro.configs.deepseek_v2_236b import CONFIG as DEEPSEEK_V2
from repro.configs.mamba2_1_3b import CONFIG as MAMBA2
from repro.configs.zamba2_1_2b import CONFIG as ZAMBA2
from repro.configs.internvl2_26b import CONFIG as INTERNVL2
from repro.configs.whisper_base import CONFIG as WHISPER

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        TINYLLAMA, YI_6B, MISTRAL_NEMO, GRANITE, QWEN3_MOE,
        DEEPSEEK_V2, MAMBA2, ZAMBA2, INTERNVL2, WHISPER,
    )
}


def get(arch_id: str) -> ModelConfig:
    if arch_id.endswith("-smoke"):
        return ARCHS[arch_id[: -len("-smoke")]].smoke()
    return ARCHS[arch_id]


__all__ = [
    "ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "cell_is_runnable", "get",
]
