"""Whisper-base backbone: 6L enc + 6L dec, d=512; conv frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356;
unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    cross_attention=True,
    frontend="audio",
    tie_embeddings=True,
)
