"""InternVL2-26B backbone: InternLM2-20B LM; InternViT frontend is a STUB
(input_specs provides precomputed patch embeddings) [arXiv:2404.16821; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    frontend="vision",
    rope_theta=1_000_000.0,
)
