"""DeepSeek-V2 236B: MLA (kv_lora=512) + 2 shared / 160 routed top-6 MoE
[arXiv:2405.04434; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,       # MLA: per-head K/V reconstructed from the latent
    d_ff=1536,
    moe_d_ff=1536,
    dense_d_ff=12288,
    first_k_dense=1,
    vocab_size=102400,
    head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
)
