"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b-smoke \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the serving substrate: KV-cache allocation + sharding,
prefill-via-decode warmup, batched greedy/sampled decode with per-request
stop handling, and simple continuous-batching slot reuse.

``--sim`` switches to the analytic request-level simulator instead of the
jax model: Poisson arrivals against one simulated instance per COPA config
of an MLPerf serving scenario (``--bench``), reporting latency percentiles
and SLO goodput (see ``repro.serve.sim`` / ``repro.serve.fleet``):

    PYTHONPATH=src python -m repro.launch.serve --sim --bench resnet
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.launch.mesh import make_host_mesh, set_default_mesh
from repro.models import LanguageModel
from repro.serve.step import make_decode_step


class ServingEngine:
    """Minimal continuous-batching engine over the decode step."""

    def __init__(self, model: LanguageModel, params, batch: int,
                 max_len: int, enc_len: int = 64):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache = model.init_cache(batch, max_len, enc_len=enc_len)
        self.decode = jax.jit(make_decode_step(model), donate_argnums=(1,))
        self.lengths = np.zeros(batch, np.int32)

    def prefill(self, prompts: np.ndarray):
        """Teacher-forced prefill via the decode step (token at a time —
        simple and exact; production prefill uses the chunked forward)."""
        b, plen = prompts.shape
        toks = None
        for t in range(plen):
            toks, self.cache = self.decode(
                self.params, self.cache, prompts[:, t:t + 1],
                jnp.int32(t), jax.random.PRNGKey(t))
        self.lengths[:] = plen
        return toks

    def generate(self, prompts: np.ndarray, steps: int):
        next_tok = self.prefill(prompts)
        out = [np.asarray(next_tok)]
        pos = prompts.shape[1]
        for i in range(steps - 1):
            next_tok, self.cache = self.decode(
                self.params, self.cache, next_tok, jnp.int32(pos + i),
                jax.random.PRNGKey(1000 + i))
            out.append(np.asarray(next_tok))
        self.lengths += steps
        return np.concatenate(out, axis=1)


def sim_main(args):
    """Analytic serving simulation of one MLPerf bench across COPA configs."""
    from repro.core import copa
    from repro.core.sweep import serve_cost_grids
    from repro.serve.fleet import latency_goodput_rows
    from repro.serve.sim import ArrivalSpec, Slo

    cfgs = [copa.TABLE_V_BY_NAME[n] for n in args.sim_configs.split(",")]
    grids = serve_cost_grids(args.bench, cfgs)
    base = next(iter(grids.values()))
    sat = base.saturated_rps()
    rates = [f * sat for f in (0.5, 0.8, 1.1)]
    arrivals = ArrivalSpec(name=f"launch.{args.bench}", rate=sat,
                           n_requests=args.requests)
    slo = Slo(ttft_s=4 * base.step_time(base.max_batch), percentile=95)
    rows = latency_goodput_rows(grids, arrivals, rates, slo,
                                n_instances=args.instances, seed=0)
    print(f"{args.bench}: {args.instances} instance(s)/config, "
          f"SLO p95 TTFT<={slo.ttft_s*1e3:.2f}ms")
    for r in rows:
        print(f"{r['config']:<12} rate={r['rate_rps']:>9.1f}/s "
              f"ttft p50/p99 {r['ttft_p50_ms']:.2f}/{r['ttft_p99_ms']:.2f}ms "
              f"goodput {r['goodput_rps']:.1f}/s "
              f"{'ok' if r['slo_met'] else 'SLO MISS'}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--sim", action="store_true",
                    help="run the analytic request-level simulator instead "
                         "of the jax model")
    ap.add_argument("--bench", default="resnet",
                    help="[--sim] MLPerf serving bench (serve.mlperf.<bench>)")
    ap.add_argument("--sim-configs", default="GPU-N,HBM+L3",
                    help="[--sim] comma-separated Table-V config names")
    ap.add_argument("--instances", type=int, default=1,
                    help="[--sim] fleet size per config")
    ap.add_argument("--requests", type=int, default=2000)
    args = ap.parse_args(argv)

    if args.sim:
        return sim_main(args)

    cfg = configs.get(args.arch)
    mesh = make_host_mesh()
    set_default_mesh(mesh)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, args.batch, args.max_len)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    toks = engine.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", toks[0][:12].tolist())
    return toks


if __name__ == "__main__":
    main()
