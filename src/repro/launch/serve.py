"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b-smoke \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the serving substrate: KV-cache allocation + sharding,
prefill-via-decode warmup, batched greedy/sampled decode with per-request
stop handling, and simple continuous-batching slot reuse.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.launch.mesh import make_host_mesh, set_default_mesh
from repro.models import LanguageModel
from repro.serve.step import make_decode_step


class ServingEngine:
    """Minimal continuous-batching engine over the decode step."""

    def __init__(self, model: LanguageModel, params, batch: int,
                 max_len: int, enc_len: int = 64):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache = model.init_cache(batch, max_len, enc_len=enc_len)
        self.decode = jax.jit(make_decode_step(model), donate_argnums=(1,))
        self.lengths = np.zeros(batch, np.int32)

    def prefill(self, prompts: np.ndarray):
        """Teacher-forced prefill via the decode step (token at a time —
        simple and exact; production prefill uses the chunked forward)."""
        b, plen = prompts.shape
        toks = None
        for t in range(plen):
            toks, self.cache = self.decode(
                self.params, self.cache, prompts[:, t:t + 1],
                jnp.int32(t), jax.random.PRNGKey(t))
        self.lengths[:] = plen
        return toks

    def generate(self, prompts: np.ndarray, steps: int):
        next_tok = self.prefill(prompts)
        out = [np.asarray(next_tok)]
        pos = prompts.shape[1]
        for i in range(steps - 1):
            next_tok, self.cache = self.decode(
                self.params, self.cache, next_tok, jnp.int32(pos + i),
                jax.random.PRNGKey(1000 + i))
            out.append(np.asarray(next_tok))
        self.lengths += steps
        return np.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    mesh = make_host_mesh()
    set_default_mesh(mesh)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, args.batch, args.max_len)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    toks = engine.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", toks[0][:12].tolist())
    return toks


if __name__ == "__main__":
    main()
