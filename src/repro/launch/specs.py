"""Abstract input construction for the dry-run: ShapeDtypeStructs with
shardings attached — weak-type-correct, shardable, zero allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

import repro.configs as configs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import msm
from repro.models import LanguageModel
from repro.models.base import abstract_params
from repro.sharding.partition import (batch_spec, cache_shardings,
                                      param_shardings)
from repro.train import OptimConfig, init_opt_state

VLM_PATCHES = 256
WHISPER_ENC_LEN = 1500


def sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def model_for(cfg: ModelConfig, shape: ShapeConfig, policy=None) -> LanguageModel:
    policy = policy or msm.recommend(shape.name, cfg.n_params())
    return LanguageModel(cfg, impl=policy.attention_impl, remat=policy.remat)


def abstract_model_params(model: LanguageModel, mesh: Mesh, fsdp: bool = True):
    specs = model.specs()
    aparams = abstract_params(specs)
    shardings = param_shardings(model.axes(), aparams, mesh, fsdp=fsdp)

    def attach(a, s):
        if isinstance(a, dict):
            return {k: attach(a[k], s[k]) for k in a}
        return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)

    return attach(aparams, shardings), shardings


def optim_config_for(policy) -> OptimConfig:
    return OptimConfig(
        moment_dtype="bfloat16" if policy.optimizer_dtype == "bfloat16" else "float32",
        master_weights=policy.master_weights,
        # RTN updates in the capacity-specialized recipe: the SR path costs a
        # params-sized u32/u64 RNG temp per step (~7 GiB/device at 236B).
        stochastic_rounding=False,
    )


def abstract_opt_state(model, aparams, opt_cfg: OptimConfig, mesh,
                       grad_compression=None):
    """eval_shape through the real initializer, then attach shardings that
    mirror the parameter shardings."""
    astate = jax.eval_shape(
        lambda p: init_opt_state(p, opt_cfg, grad_compression), aparams)

    def mirror(a, template):
        if isinstance(a, dict):
            return {k: mirror(a[k], template) for k in a}
        # scalars replicate; tensors inherit the matching param sharding by path
        return a

    # attach: walk astate alongside a params-shaped template where possible
    def attach(node, params_node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in ("mu", "nu", "master", "ef"):
                    out[k] = attach_tree_like_params(v, params_node)
                elif k == "step":
                    out[k] = jax.ShapeDtypeStruct(
                        v.shape, v.dtype,
                        sharding=NamedSharding(mesh, PartitionSpec()))
                else:
                    out[k] = attach(v, params_node)
            return out
        return node

    def attach_tree_like_params(node, params_node):
        if isinstance(node, dict):
            return {k: attach_tree_like_params(node[k], params_node[k])
                    for k in node}
        return jax.ShapeDtypeStruct(node.shape, node.dtype,
                                    sharding=params_node.sharding)

    return attach(astate, aparams)


def _sharding_of(tree):
    return jax.tree.map(lambda a: a.sharding, tree)


def input_specs(arch: str, shape_name: str, mesh: Mesh, policy=None):
    """Returns (step_kind, model, abstract_args, out_shardings) for the cell.

    out_shardings pin the step outputs (new params / opt state / cache) to
    the input shardings — without this XLA is free to materialize the
    optimizer math unsharded (observed: 26 GiB/device of fp32 temporaries on
    a 1.1B model) and donation cannot alias."""
    cfg = configs.get(arch)
    shape = configs.SHAPES[shape_name]
    policy = policy or msm.recommend(shape.name, cfg.n_params())
    model = model_for(cfg, shape, policy)
    gb, seq = shape.global_batch, shape.seq_len
    bspec = batch_spec(mesh)
    tok_dtype = jnp.int32
    repl = NamedSharding(mesh, PartitionSpec())

    fsdp = policy.serve_fsdp if shape.step != "train" else True
    aparams, _ = abstract_model_params(model, mesh, fsdp=fsdp)

    if shape.step == "train":
        batch = {
            "tokens": sds((gb, seq), tok_dtype, mesh, bspec),
            "labels": sds((gb, seq), tok_dtype, mesh, bspec),
            # runtime positions: sequence packing support + keeps causal
            # masks from being constant-folded at score shape
            "positions": sds((gb, seq), tok_dtype, mesh, bspec),
        }
        if cfg.frontend == "vision":
            batch["patch_embeds"] = sds((gb, VLM_PATCHES, cfg.d_model),
                                        jnp.bfloat16, mesh, bspec)
        if cfg.family == "audio":
            batch["frames"] = sds((gb, seq, cfg.d_model), jnp.bfloat16, mesh,
                                  bspec)
            batch["tokens"] = sds((gb, seq // 4), tok_dtype, mesh, bspec)
            batch["labels"] = sds((gb, seq // 4), tok_dtype, mesh, bspec)
        opt_cfg = optim_config_for(policy)
        aopt = abstract_opt_state(model, aparams, opt_cfg, mesh,
                                  policy.grad_compression)
        rng = sds((2,), jnp.uint32, mesh, PartitionSpec())
        metrics_sh = {"lr": repl, "grad_norm": repl, "loss": repl}
        out_sh = (_sharding_of(aparams), _sharding_of(aopt), metrics_sh)
        return "train", model, (aparams, aopt, batch, rng), out_sh

    if shape.step == "prefill":
        batch = {"tokens": sds((gb, seq), tok_dtype, mesh, bspec),
                 "positions": sds((gb, seq), tok_dtype, mesh, bspec)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = sds((gb, VLM_PATCHES, cfg.d_model),
                                        jnp.bfloat16, mesh, bspec)
        if cfg.family == "audio":
            batch["frames"] = sds((gb, seq, cfg.d_model), jnp.bfloat16, mesh,
                                  bspec)
            batch["tokens"] = sds((gb, seq // 4), tok_dtype, mesh, bspec)
        out_sh = NamedSharding(mesh, bspec)
        return "prefill", model, (aparams, batch), out_sh

    # decode: one new token against a seq_len cache
    shard_seq = policy.kv_shard_axis == "data" or gb == 1
    kv_dtype = jnp.int8 if policy.kv_cache_dtype == "int8" else jnp.bfloat16
    acache = jax.eval_shape(
        lambda: model.init_cache(gb, seq, dtype=kv_dtype,
                                 enc_len=WHISPER_ENC_LEN))
    cshard = cache_shardings(acache, mesh, shard_seq=shard_seq)
    acache = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=cshard[k])
              for k, v in acache.items()}
    tokens = sds((gb, 1), tok_dtype, mesh,
                 bspec if gb > 1 else PartitionSpec())
    pos = sds((), jnp.int32, mesh, PartitionSpec())
    rng = sds((2,), jnp.uint32, mesh, PartitionSpec())
    out_sh = (tokens.sharding, _sharding_of(acache))
    return "decode", model, (aparams, acache, tokens, pos, rng), out_sh
