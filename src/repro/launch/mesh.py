"""Production mesh construction (assignment-specified shapes).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
