"""Production mesh construction (assignment-specified shapes).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py forces 512).

Also the jax version-compat seam: ``jax.sharding.AxisType`` /
``jax.make_mesh(..., axis_types=...)`` and ``jax.sharding.set_mesh`` only
exist on newer jax releases. Everything in this repo (and the subprocess
test scripts) builds meshes through :func:`make_compat_mesh` and installs
them through :func:`set_default_mesh`, which degrade gracefully on older
jax: meshes are built without explicit axis types (the old default), and
the ambient-mesh install becomes a no-op (all shardings in this codebase
are passed explicitly as NamedShardings; the only implicit-mesh consumer,
``sharding.partition.constrain``, already no-ops without an abstract mesh).
"""
from __future__ import annotations

import jax


def _axis_types_kw(n_axes: int) -> dict:
    """``axis_types=(Auto,) * n`` where the installed jax supports it."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_compat_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types on jax that has them."""
    try:
        return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))
    except TypeError:
        # AxisType exists but make_mesh predates the axis_types kwarg.
        return jax.make_mesh(shape, axes)


def set_default_mesh(mesh) -> None:
    """``jax.sharding.set_mesh`` where available; no-op on older jax."""
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is None:
        return
    setter(mesh)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return make_compat_mesh((data, model), ("data", "model"))
