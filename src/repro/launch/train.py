"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b-smoke \
        --steps 50 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/run1

Wires every substrate together: config -> model -> sharded train step ->
deterministic data pipeline -> watchdog -> async checkpointing -> elastic
restart. On this CPU container it trains reduced configs; on a TPU fleet the
same driver runs the full ones (mesh via ``--mesh data,model``).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import repro.configs as configs
from repro.core import msm
from repro.data.pipeline import DataConfig, DataLoader
from repro.ft import ElasticRunner, RunState, StepWatchdog
from repro.checkpoint.ckpt import restore
from repro.launch.mesh import make_host_mesh, set_default_mesh
from repro.models import LanguageModel
from repro.models.base import abstract_params
from repro.sharding.partition import batch_spec, param_shardings
from repro.train import OptimConfig, init_opt_state, make_train_step
from repro.train.optim import state_shardings
from jax.sharding import NamedSharding


def build(args, mesh, restore_step=None):
    cfg = configs.get(args.arch)
    policy = msm.recommend("train_4k", cfg.n_params())
    model = LanguageModel(cfg, impl=policy.attention_impl,
                          remat=args.remat or policy.remat)
    opt_cfg = OptimConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps)
    aparams = abstract_params(model.specs())
    shardings = param_shardings(model.axes(), aparams, mesh)
    set_default_mesh(mesh)
    if restore_step is not None:
        _, tree, extra = restore(
            args.ckpt_dir, restore_step,
            shardings={"params": shardings,
                       "opt": state_shardings(shardings, opt_cfg, mesh)})
        params, opt_state = tree["params"], tree["opt"]
        start = int(extra.get("step", restore_step))
        print(f"[train] restored step {start} from {args.ckpt_dir}")
    else:
        params = jax.device_put(model.init(jax.random.PRNGKey(args.seed)),
                                shardings)
        opt_state = jax.device_put(
            init_opt_state(params, opt_cfg),
            state_shardings(shardings, opt_cfg, mesh))
        start = 0
    step_fn = make_train_step(model, opt_cfg, microbatches=args.microbatches,
                              grad_shardings=shardings)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    return model, cfg, params, opt_state, jitted, start


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args(argv)

    def mesh_factory():
        return make_host_mesh(model=args.mesh_model)

    def build_state(mesh, restore_step):
        model, cfg, params, opt, jitted, start = build(args, mesh, restore_step)
        st = RunState(params=params, opt_state=opt, step=start, mesh=mesh)
        st.model, st.cfg, st.jitted = model, cfg, jitted
        return st

    def train_segment(runner: ElasticRunner, st: RunState, max_steps: int):
        cfg = st.cfg
        data = DataLoader(
            DataConfig(cfg.vocab_size, args.seq_len, args.global_batch,
                       seed=args.seed),
            start_step=st.step, process_index=0, process_count=1)
        bspec = NamedSharding(st.mesh, batch_spec(st.mesh))
        losses = []
        with StepWatchdog(deadline_s=300.0) as wd:
            try:
                for step, batch in data:
                    if step >= max_steps:
                        break
                    wd.check()
                    wd.step_started()
                    batch = {k: jax.device_put(v, bspec) for k, v in batch.items()}
                    rng = jax.random.PRNGKey(step)
                    st.params, st.opt_state, metrics = st.jitted(
                        st.params, st.opt_state, batch, rng)
                    dt = wd.step_finished()
                    st.step = step + 1
                    runner.maybe_save(st)
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    if step % args.log_every == 0:
                        print(f"step {step:5d} loss {loss:8.4f} "
                              f"gnorm {float(metrics['grad_norm']):7.3f} "
                              f"dt {dt*1e3:7.1f}ms", flush=True)
            finally:
                data.close()
        runner.maybe_save(st, force=True)
        st.final_losses = losses
        return st

    runner = ElasticRunner(args.ckpt_dir, mesh_factory, build_state,
                           train_segment, save_every=args.save_every)
    st = runner.run(args.steps)
    print(f"done at step {st.step}; final loss "
          f"{np.mean(st.final_losses[-10:]):.4f}")
    return st


if __name__ == "__main__":
    main()
