import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
)

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
``jax.jit(step).lower(**abstract_inputs).compile()`` must succeed on the
single-pod 16x16 mesh AND the 2x16x16 multi-pod mesh for every cell, and the
per-device memory/cost analyses feed EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

Results are cached per cell in the output JSON (incremental; safe to re-run).
"""

import argparse
import json
import time
import traceback

import jax

import repro.configs as configs
from repro.configs.base import cell_is_runnable
from repro.core.hloparse import parse_collectives
from repro.core.hlo_cost import analyze_hlo_cost, raw_cost_analysis
from repro.core.roofline import model_flops_lm
from repro.launch.mesh import make_production_mesh, set_default_mesh
from repro.launch.specs import input_specs, optim_config_for
from repro.core import msm
from repro.train import make_train_step
from repro.serve.step import make_decode_step, make_prefill_step


def _clamp_microbatches(policy_mb: int, gb: int, mesh) -> int:
    """Largest mb <= policy that leaves an integer per-shard batch."""
    shards = 1
    for a, n in zip(mesh.axis_names, mesh.devices.shape):
        if a in ("pod", "data"):
            shards *= n
    per_shard = max(gb // shards, 1)
    mb = min(policy_mb, per_shard)
    while per_shard % mb:
        mb -= 1
    return max(mb, 1)


def build_step(kind: str, model, policy, abstract_args=None, mesh=None,
               global_batch=None):
    if kind == "train":
        opt_cfg = optim_config_for(policy)
        mb = policy.microbatches
        if mesh is not None and global_batch:
            mb = _clamp_microbatches(policy.microbatches, global_batch, mesh)
        grad_sh = batch_sh = None
        if abstract_args is not None:
            import jax as _jax
            from jax.sharding import NamedSharding, PartitionSpec
            aparams, _, abatch, _ = abstract_args
            grad_sh = _jax.tree.map(lambda a: a.sharding, aparams)
            def mb_shard(a):
                spec = a.sharding.spec
                return NamedSharding(a.sharding.mesh,
                                     PartitionSpec(None, *spec))
            batch_sh = _jax.tree.map(mb_shard, abatch)
        step = make_train_step(model, opt_cfg, policy.grad_compression,
                               microbatches=mb,
                               grad_shardings=grad_sh,
                               batch_shardings=batch_sh)

        def train(params, opt_state, batch, rng):
            return step(params, opt_state, batch, rng)

        return train, dict(donate_argnums=(0, 1))
    if kind == "prefill":
        prefill = make_prefill_step(model)
        return prefill, {}
    decode = make_decode_step(model)

    def dec(params, cache, tokens, pos, rng):
        return decode(params, cache, tokens, pos, rng)

    return dec, dict(donate_argnums=(1,))


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = configs.get(arch)
    shape = configs.SHAPES[shape_name]
    ok, reason = cell_is_runnable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        return dict(base, status="skipped", reason=reason)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = msm.recommend(shape.name, cfg.n_params())
    kind, model, abstract_args, out_sh = input_specs(arch, shape_name, mesh,
                                                     policy)
    step_fn, jit_kw = build_step(kind, model, policy, abstract_args,
                                 mesh=mesh, global_batch=shape.global_batch)

    set_default_mesh(mesh)
    lowered = jax.jit(step_fn, out_shardings=out_sh,
                      **jit_kw).lower(*abstract_args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = raw_cost_analysis(compiled)
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)
    # trip-count-expanded accounting (XLA counts while bodies once)
    adj = analyze_hlo_cost(hlo_text)

    chips = mesh.devices.size
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    n_active = cfg.n_active_params()
    result = dict(
        base,
        status="ok",
        step=kind,
        policy=policy.name,
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops_per_device=float(cost.get("flops", 0.0)) if cost else 0.0,
        bytes_per_device=float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        collective_bytes_per_device=coll.total_bytes,
        collectives=coll.as_dict(),
        flops_adjusted=adj.dot_flops,
        bytes_adjusted=adj.bytes_accessed,
        collective_adjusted=adj.collective_bytes,
        collective_adjusted_by_kind={k: float(v) for k, v in
                                     adj.collective_by_kind.items()},
        model_flops=model_flops_lm(n_active, tokens, training=(kind == "train")),
    )
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            result[attr] = int(getattr(mem, attr, 0) or 0)
        result["peak_memory_per_device"] = (
            result.get("temp_size_in_bytes", 0)
            + result.get("argument_size_in_bytes", 0)
            - result.get("alias_size_in_bytes", 0)
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    cells = []
    archs = list(configs.ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(configs.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    for arch, shape, mp in cells:
        key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
        if key in results and results[key].get("status") in ("ok", "skipped") \
                and not args.force:
            print(f"[cached] {key}: {results[key]['status']}")
            continue
        print(f"[run] {key} ...", flush=True)
        try:
            res = run_cell(arch, shape, mp)
        except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results[key] = res
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if res["status"] == "ok":
            print(f"  ok: compile={res['compile_s']}s "
                  f"flops/dev={res['flops_per_device']:.3e} "
                  f"bytes/dev={res['bytes_per_device']:.3e} "
                  f"coll/dev={res['collective_bytes_per_device']:.3e} "
                  f"peakmem/dev={res.get('peak_memory_per_device', 0)/2**30:.2f}GiB",
                  flush=True)
        else:
            print(f"  {res['status']}: {res.get('reason') or res.get('error')}",
                  flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"\nSummary: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
