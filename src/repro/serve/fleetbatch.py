"""Vectorized fleet core: the whole fleet as struct-of-arrays event state.

``repro.serve.fleet.FleetSim`` answers the paper's scale-out question at the
request level, but its per-instance loop walks Python ``Request`` objects —
O(batch) attribute churn per engine iteration — which caps it at tens of
instances. This module re-runs the SAME discrete-event semantics with fleet
state as arrays (the batched-scan-over-rows move ``StreamBatch`` made for
traces): requests are the columns of a :class:`~repro.serve.sim.RequestBatch`
and instances are rows of scalar event state, so a 500-instance
100k-request diurnal run prices in seconds instead of minutes.

What makes it fast — and still bit-identical to the oracle:

* **Arrivals are a sorted array + pointer, not heap entries.** Only step
  completions and autoscale ticks live in the heap; arrival events always
  outrank same-timestamp heap events (their sequence numbers are smaller,
  exactly as the oracle pushes them), so wave ordering is preserved.
* **O(1) step state via admission-step aggregates.** A request admitted at
  instance step ``k`` has emitted ``step - k`` tokens ever after, so the
  resident-KV sum the cost model needs is the closed form
  ``sum_prompt + batch * step - sum_admit_step`` — three counters updated
  only at admission/completion, never a per-request sweep per iteration.
* **Completions are pre-bucketed by step index.** Admission at step ``k``
  of a request with ``o`` output tokens schedules its completion at step
  ``k + o - 1``; each step-finish pops one bucket (ids + aggregate sums)
  instead of scanning the running batch.
* **Waves batch the pricing.** All events at one timestamp drain first
  (simultaneous arrivals share batches, as in the oracle); every instance
  the wave kicked then prices its next iteration through ONE vectorized
  :meth:`~repro.core.sweep.CostGrid.step_time` call, with a bisect-based
  scalar fast path when the wave touched a single instance.
* **FIFO admission uses a vectorized KV-reservation prefix check** — a
  cumulative-sum + ``searchsorted`` over the waiting head region — when the
  candidate window is wide, and an amortized-O(1) scalar walk otherwise.

``repro.serve.fleet.FleetSim.run`` dispatches here by default; the
per-instance ``Instance``/heap loop survives behind ``run(batched=False)``
as the parity oracle, asserted request-for-request bit-identical (timings,
step logs, scale events) in ``tests/test_fleet_batch.py``.
"""
from __future__ import annotations

import heapq
import math
from bisect import bisect_left

import numpy as np

from repro.serve.sim import RequestBatch, SimMetrics, StepLog

# Below this many candidates/completions the scalar path beats numpy-call
# overhead; both paths are exact, so the cutover is pure perf.
_VEC_CUTOVER = 8


def _scalar_pricer(cost):
    """(step_time, prefill_time, grid_like, per_tok) with a pure-Python
    bisect fast path for ``CostGrid``-shaped costs — identical table
    lookups, no per-step numpy call overhead. ``per_tok`` is the grid's
    prefill seconds/token (None for non-grid costs), so hot loops can
    inline the multiply instead of calling ``prefill_time``."""
    grid_like = (hasattr(cost, "step_time_s") and hasattr(cost, "batches")
                 and hasattr(cost, "seq_edges"))
    if not grid_like:
        return cost.step_time, cost.prefill_time, False, None
    batches = list(cost.batches)
    edges = list(cost.seq_edges)
    table = np.asarray(cost.step_time_s).tolist()   # exact float64 values
    max_b, last_j = batches[-1], len(edges) - 1

    def step_time(batch, resident):
        if batch < 1 or batch > max_b:
            raise ValueError(
                f"batch outside priced range [1, {max_b}]: {batch!r}")
        j = bisect_left(edges, resident)
        return table[bisect_left(batches, batch)][
            j if j < last_j else last_j]

    per_tok = float(getattr(cost, "prefill_s_per_token", 0.0))

    def prefill_time(prompt_tokens):
        return prompt_tokens * per_tok

    return step_time, prefill_time, True, per_tok


def run_fleet(cost, batch: RequestBatch, *, n_instances: int = 1,
              router: str = "least_loaded", max_batch: int | None = None,
              kv_capacity_tokens: float = float("inf"),
              autoscaler=None, autoscale_interval_s: float = 0.0):
    """One batched fleet run over ``batch`` (consumed via a fresh copy).

    Semantics are exactly ``FleetSim.run(batched=False)``; see the module
    docstring for the vectorization strategy. Returns a
    :class:`~repro.serve.fleet.FleetResult`.
    """
    from repro.serve.fleet import ROUTERS, FleetResult, ScaleEvent

    if router not in ROUTERS:
        raise ValueError(f"unknown router {router!r}; one of {ROUTERS}")
    if n_instances < 1:
        raise ValueError("n_instances must be >= 1")
    if autoscaler is not None and autoscale_interval_s <= 0:
        raise ValueError("autoscaler needs autoscale_interval_s > 0")
    mb = int(max_batch if max_batch is not None else cost.max_batch)
    if mb < 1:
        raise ValueError("max_batch must be >= 1")
    cap = float(kv_capacity_tokens)
    interval = float(autoscale_interval_s)
    round_robin = router == "round_robin"

    b = batch.fresh()
    n = len(b)
    t_admitted, t_first, t_done = b.t_admitted, b.t_first_token, b.t_done
    tokens_emitted = b.tokens_emitted
    outputs = b.output_tokens
    # python lists: ~30ns scalar reads in the hot loop vs numpy item access
    t_arr_l = b.t_arrival.tolist()
    rid_l = b.rid.tolist()
    prompt_l = b.prompt_tokens.tolist()
    out_l = outputs.tolist()
    kv_arr = b.kv_tokens
    kv_l = kv_arr.tolist()

    step_scalar, prefill_scalar, grid_like, per_tok = _scalar_pricer(cost)
    if grid_like:      # hot loops inline the table lookup (no call overhead)
        g_batches = list(cost.batches)
        g_edges = list(cost.seq_edges)
        g_table = np.asarray(cost.step_time_s).tolist()
        g_maxb, g_lastj = g_batches[-1], len(g_edges) - 1
        # validate once so grid-priced steps skip the per-step dt check
        # (a grid cell + non-negative finite prefill is always a valid dt)
        for row_ in g_table:
            for v in row_:
                if not (v > 0 and math.isfinite(v)):
                    raise ValueError(
                        f"non-positive/non-finite step time {v!r}")
        if not (per_tok >= 0 and math.isfinite(per_tok)):
            raise ValueError(
                f"non-finite/negative prefill_s_per_token {per_tok!r}")
        # direct batch-size -> table-row map (batch rounds UP to the next
        # priced size) so the per-step lookup is one list index + one bisect
        g_row = [None] + [g_table[bisect_left(g_batches, bb)]
                          for bb in range(1, g_maxb + 1)]

    # -- per-instance event state (index = instance id, rows of the fleet) -----
    busy: list[bool] = []
    kvres: list[float] = []          # reserved KV tokens (int-valued float)
    nrun: list[int] = []             # running batch size
    sum_p: list[int] = []            # sum of running prompts
    sum_as: list[int] = []           # sum of running admission step indices
    kstep: list[int] = []            # steps started
    wait_q: list[list[int]] = []     # FIFO waiting rows...
    wait_h: list[int] = []           # ...consumed from a head pointer
    buckets: list[dict[int, list]] = []  # finish step -> [rows, cnt, Σp, Σk, Σkv]
    logs: list[list[tuple]] = []
    load: list[int] = []                 # waiting + running, per instance id

    active: list[int] = []
    draining: list[int] = []
    draining_set: set[int] = set()
    retire_records: list[tuple[float, int]] = []   # (t_retired, instance)
    # routing state: loads of ACTIVE instances, compact and position-aligned
    # with `active` so least-loaded is one argmin (no fancy indexing);
    # posl[i] is instance i's position in `active` (-1 when not active)
    load_act = np.zeros(0, dtype=np.int64)
    posl: list[int] = []

    def rebuild_active() -> None:
        nonlocal load_act
        load_act = np.asarray([load[i] for i in active], dtype=np.int64)
        for idx in range(len(posl)):
            posl[idx] = -1
        for p, i in enumerate(active):
            posl[i] = p

    def spawn() -> None:
        i = len(busy)
        busy.append(False); kvres.append(0.0); nrun.append(0)
        sum_p.append(0); sum_as.append(0); kstep.append(0)
        wait_q.append([]); wait_h.append(0)
        buckets.append({}); logs.append([])
        load.append(0)
        posl.append(-1)
        active.append(i)

    def drain_one(now: float) -> None:
        if len(active) <= 1:
            return
        i = active.pop(int(load_act.argmin()))
        rebuild_active()
        if not busy[i] and load[i] == 0:
            retire_records.append((now, i))
        else:
            draining.append(i)
            draining_set.add(i)

    for _ in range(n_instances):
        spawn()
    rebuild_active()

    def admit(i: int, now: float) -> tuple[list[int], float]:
        """FIFO admission bounded by batch slots and the KV-reservation
        prefix (no skipping past a blocked head) — the oracle's ``_admit``.
        Returns (admitted rows, their summed prefill time)."""
        h, w = wait_h[i], wait_q[i]
        lim = len(w) - h
        slots = mb - nrun[i]
        if slots < lim:
            lim = slots
        if lim <= 0:
            return (), 0.0
        cap_left = cap - kvres[i]
        if lim <= _VEC_CUTOVER:
            m, acc = 0, 0
            while m < lim:
                kv = kv_l[w[h + m]]
                if acc + kv > cap_left:
                    break
                acc += kv
                m += 1
        else:
            # vectorized prefix check: largest m with cumsum(kv) <= budget
            csum = np.cumsum(kv_arr[w[h:h + lim]])
            m = int(np.searchsorted(csum, cap_left, side="right"))
        if m == 0:
            return (), 0.0
        rows = w[h:h + m]
        wait_h[i] = h + m
        if h + m > 512 and (h + m) * 2 >= len(w):
            del w[:h + m]
            wait_h[i] = 0
        if m <= _VEC_CUTOVER:
            for r in rows:
                t_admitted[r] = now
        else:
            t_admitted[rows] = now
        k = kstep[i]
        tot_kv = tot_p = 0
        prefill = 0.0
        bks = buckets[i]
        for r in rows:
            fk = k + out_l[r] - 1          # the step whose end completes r
            bkt = bks.get(fk)
            if bkt is None:
                bks[fk] = bkt = [[], 0, 0, 0, 0]
            bkt[0].append(r)
            bkt[1] += 1
            p = prompt_l[r]
            bkt[2] += p
            bkt[3] += k
            bkt[4] += kv_l[r]
            tot_kv += kv_l[r]
            tot_p += p
            # oracle order: per-request prefill times summed left-to-right
            prefill += p * per_tok if per_tok is not None \
                else prefill_scalar(p)
        kvres[i] += tot_kv
        nrun[i] += m
        sum_p[i] += tot_p
        sum_as[i] += m * k
        return rows, prefill

    # -- the global event loop -------------------------------------------------
    # Steps live in the heap as (t_end, seq, instance); arrivals stay a
    # sorted array + pointer and the (single) pending autoscale tick is a
    # scalar. At equal timestamps arrivals outrank everything (seqs 0..n-1,
    # exactly the order the oracle pushed them) and step/tick events
    # interleave by seq — the oracle's heap order.
    INF = float("inf")
    heap: list[tuple[float, int, int]] = []
    seq = n          # arrivals implicitly hold seqs 0..n-1 (array order)
    arr_ptr = 0
    done = 0
    clock = 0.0
    rr = 0
    scale_events: list[ScaleEvent] = []
    tick_pending = False
    next_tick, tick_seq = INF, -1
    if autoscaler is not None and n:
        tick_pending, next_tick, tick_seq = True, t_arr_l[0] + interval, seq
        seq += 1

    while (arr_ptr < n or heap or tick_pending) and done < n:
        Ta = t_arr_l[arr_ptr] if arr_ptr < n else INF
        Tt = next_tick if tick_pending else INF
        T = Ta if Ta <= Tt else Tt
        # Fast-forward: between interaction points (arrivals / autoscale
        # ticks) instances are independent, so run each popped instance's
        # finish->admit->start chain privately until it crosses T or goes
        # idle — no heap churn or wave scaffolding per step. Steps landing
        # exactly ON T stay in the heap for the wave below, preserving the
        # oracle's ordering against same-timestamp arrivals and ticks.
        while heap and heap[0][0] < T:
            tcur, _, i = heapq.heappop(heap)
            # Chain-local scalars (written back after the chain): between
            # interaction points no other instance can observe this state,
            # and the chain was popped busy so ``busy[i]`` stays True
            # unless the instance retires or idles out.
            bks = buckets[i]
            logs_i = logs[i]
            w = wait_q[i]
            k_i = kstep[i]
            nr = nrun[i]
            sp_i = sum_p[i]
            sa_i = sum_as[i]
            kvr = kvres[i]
            h = wait_h[i]
            ld = load[i]
            pp = posl[i]
            drn = i in draining_set
            while True:
                bkt = bks.pop(k_i - 1, None)
                if bkt is not None:
                    rows, cnt, sp, sa, skv = bkt
                    if cnt <= _VEC_CUTOVER:
                        for r in rows:
                            t_done[r] = tcur
                            tokens_emitted[r] = out_l[r]
                    else:
                        t_done[rows] = tcur
                        tokens_emitted[rows] = outputs[rows]
                    nr -= cnt
                    sp_i -= sp
                    sa_i -= sa
                    kvr -= skv
                    ld -= cnt
                    if pp >= 0:
                        load_act[pp] -= cnt
                    done += cnt
                if drn and ld == 0:
                    draining.remove(i)
                    draining_set.discard(i)
                    retire_records.append((tcur, i))
                    busy[i] = False
                    break
                # admit(), inlined — this is the engine's hottest block
                lim = len(w) - h
                slots = mb - nr
                if slots < lim:
                    lim = slots
                m = 0
                if lim > 0:
                    cap_left = cap - kvr
                    if lim <= _VEC_CUTOVER:
                        acc = 0
                        while m < lim:
                            kv = kv_l[w[h + m]]
                            if acc + kv > cap_left:
                                break
                            acc += kv
                            m += 1
                    else:
                        csum = np.cumsum(kv_arr[w[h:h + lim]])
                        m = int(np.searchsorted(csum, cap_left,
                                                side="right"))
                prefill = 0.0
                if m:
                    rows = w[h:h + m]
                    h += m
                    if h > 512 and h * 2 >= len(w):
                        del w[:h]
                        h = 0
                    if m <= _VEC_CUTOVER:
                        for r in rows:
                            t_admitted[r] = tcur
                    else:
                        t_admitted[rows] = tcur
                    tot_kv = tot_p = 0
                    for r in rows:
                        fk = k_i + out_l[r] - 1
                        bkt = bks.get(fk)
                        if bkt is None:
                            bks[fk] = bkt = [[], 0, 0, 0, 0]
                        bkt[0].append(r)
                        bkt[1] += 1
                        p = prompt_l[r]
                        bkt[2] += p
                        bkt[3] += k_i
                        bkt[4] += kv_l[r]
                        tot_kv += kv_l[r]
                        tot_p += p
                        prefill += p * per_tok if per_tok is not None \
                            else prefill_scalar(p)
                    kvr += tot_kv
                    nr += m
                    sp_i += tot_p
                    sa_i += m * k_i
                else:
                    rows = ()
                if nr == 0:
                    busy[i] = False
                    break
                resident = sp_i + nr * k_i - sa_i
                if grid_like:
                    if nr > g_maxb:
                        raise ValueError(
                            f"batch outside priced range [1, {g_maxb}]: "
                            f"{nr!r}")
                    j = bisect_left(g_edges, resident)
                    dt = g_row[nr][j if j < g_lastj else g_lastj] + prefill
                else:
                    dt = step_scalar(nr, resident) + prefill
                    if not (dt > 0 and math.isfinite(dt)):
                        raise ValueError(
                            f"non-positive/non-finite step time {dt!r}")
                t_end = tcur + dt
                logs_i.append((tcur, t_end, nr, kvr, len(w) - h, m))
                if m:
                    if m <= _VEC_CUTOVER:
                        for r in rows:
                            t_first[r] = t_end
                    else:
                        t_first[rows] = t_end
                k_i += 1
                sq = seq
                seq += 1
                if t_end >= T:
                    heapq.heappush(heap, (t_end, sq, i))
                    break
                tcur = t_end
            kstep[i] = k_i
            nrun[i] = nr
            sum_p[i] = sp_i
            sum_as[i] = sa_i
            kvres[i] = kvr
            wait_h[i] = h
            load[i] = ld
        if T == INF or done >= n:
            break      # oracle exits before a pending tick once all done
        assert T >= clock, "fleet clock went backwards"
        clock = T
        # Lone arrival (the common wave) — route + submit + start inline.
        if (Ta < Tt and (not heap or heap[0][0] != Ta)
                and (arr_ptr + 1 == n or t_arr_l[arr_ptr + 1] != Ta)):
            row = arr_ptr
            if kv_l[row] > cap:
                raise ValueError(
                    f"request {rid_l[row]} needs {kv_l[row]} KV tokens; "
                    f"instance capacity is {cap:.0f} — it can never be "
                    f"admitted")
            if round_robin:
                i = active[rr % len(active)]
                rr += 1
                p = posl[i]
            elif len(active) == 1:
                i = active[0]
                p = 0
            else:
                p = load_act.argmin()
                i = active[p]
            wait_q[i].append(row)
            load[i] += 1
            load_act[p] += 1
            arr_ptr += 1
            if busy[i]:
                continue
            rows, prefill = admit(i, Ta)
            bsz = nrun[i]
            if bsz == 0:
                continue
            resident = sum_p[i] + bsz * kstep[i] - sum_as[i]
            if grid_like:
                if bsz > g_maxb:
                    raise ValueError(
                        f"batch outside priced range [1, {g_maxb}]: {bsz!r}")
                j = bisect_left(g_edges, resident)
                dt = g_row[bsz][j if j < g_lastj else g_lastj] + prefill
            else:
                dt = step_scalar(bsz, resident) + prefill
            if not (dt > 0 and math.isfinite(dt)):
                raise ValueError(f"non-positive/non-finite step time {dt!r}")
            t_end = Ta + dt
            logs[i].append((Ta, t_end, bsz, kvres[i],
                            len(wait_q[i]) - wait_h[i], len(rows)))
            if rows:
                # the iteration that prefills a request emits its first token
                if len(rows) <= _VEC_CUTOVER:
                    for r in rows:
                        t_first[r] = t_end
                else:
                    t_first[rows] = t_end
            busy[i] = True
            kstep[i] += 1
            heapq.heappush(heap, (t_end, seq, i))
            seq += 1
            continue
        # General wave at T: drain every same-timestamp event before
        # starting iterations (simultaneous arrivals share a batch — see
        # repro.serve.sim), arrivals first, then steps/ticks by seq.
        kick: dict[int, None] = {}
        while arr_ptr < n and t_arr_l[arr_ptr] == T:
            row = arr_ptr
            if kv_l[row] > cap:
                raise ValueError(
                    f"request {rid_l[row]} needs {kv_l[row]} KV tokens; "
                    f"instance capacity is {cap:.0f} — it can never be "
                    f"admitted")
            if round_robin:
                i = active[rr % len(active)]
                rr += 1
                p = posl[i]
            elif len(active) == 1:
                i = active[0]
                p = 0
            else:
                p = load_act.argmin()
                i = active[p]
            wait_q[i].append(row)
            load[i] += 1
            load_act[p] += 1
            kick[i] = None
            arr_ptr += 1
        while True:
            has_step = bool(heap) and heap[0][0] == T
            has_tick = tick_pending and next_tick == T
            if has_step and (not has_tick or heap[0][1] < tick_seq):
                _, _, i = heapq.heappop(heap)
                busy[i] = False
                bkt = buckets[i].pop(kstep[i] - 1, None)
                if bkt is not None:
                    rows, cnt, sp, sa, skv = bkt
                    if cnt <= _VEC_CUTOVER:
                        for r in rows:
                            t_done[r] = T
                            tokens_emitted[r] = out_l[r]
                    else:
                        t_done[rows] = T
                        tokens_emitted[rows] = outputs[rows]
                    nrun[i] -= cnt
                    sum_p[i] -= sp
                    sum_as[i] -= sa
                    kvres[i] -= skv
                    load[i] -= cnt
                    p = posl[i]
                    if p >= 0:
                        load_act[p] -= cnt
                    done += cnt
                if i in draining_set and load[i] == 0:
                    draining.remove(i)
                    draining_set.discard(i)
                    retire_records.append((T, i))
                else:
                    kick[i] = None
            elif has_tick:
                tick_pending = False
                queued = running = 0
                for i in active:
                    queued += len(wait_q[i]) - wait_h[i]
                    running += nrun[i]
                target = autoscaler.decide(len(active), queued, running, mb)
                if target > len(active):
                    while len(active) < target:
                        spawn()
                    rebuild_active()
                while len(active) > max(target, 1):
                    drain_one(T)
                scale_events.append(ScaleEvent(T, len(active), queued,
                                               running))
                if done < n:
                    next_tick, tick_seq = T + interval, seq
                    seq += 1
                    tick_pending = True
            else:
                break
        # Admit + size every kicked instance first, then price the whole
        # wave's next steps through one batched CostGrid lookup.
        starters = []
        for i in kick:
            if busy[i]:
                continue
            rows, prefill = admit(i, T)
            bsz = nrun[i]
            if bsz == 0:
                continue
            resident = sum_p[i] + bsz * kstep[i] - sum_as[i]
            starters.append((i, bsz, resident, prefill, rows))
        if len(starters) > 1 and grid_like:
            times = cost.step_time(
                np.array([s[1] for s in starters]),
                np.array([s[2] for s in starters])).tolist()
        else:
            times = [step_scalar(s[1], s[2]) for s in starters]
        for (i, bsz, _, prefill, rows), st in zip(starters, times):
            dt = st + prefill
            if not (dt > 0 and math.isfinite(dt)):
                raise ValueError(f"non-positive/non-finite step time {dt!r}")
            t_end = T + dt
            logs[i].append((T, t_end, bsz, kvres[i],
                            len(wait_q[i]) - wait_h[i], len(rows)))
            if rows:
                # the iteration that prefills a request emits its first token
                if len(rows) <= _VEC_CUTOVER:
                    for r in rows:
                        t_first[r] = t_end
                else:
                    t_first[rows] = t_end
            busy[i] = True
            kstep[i] += 1
            heapq.heappush(heap, (t_end, seq, i))
            seq += 1

    leftovers = sum(load)
    assert done == n and leftovers == 0, "requests left in system"
    # Retirements sort by time (stable within a wave), matching the order
    # the oracle appended them while events were globally time-ordered.
    retire_records.sort(key=lambda rec: rec[0])
    retired = [i for _, i in retire_records]
    order = active + draining + retired
    return FleetResult(
        batch=b,
        metrics=SimMetrics.from_batch(b),
        step_logs=[StepLog.from_rows(logs[i]) for i in order],
        n_instances_final=len(active),
        scale_events=scale_events,
    )
