"""Vectorized fleet core: the whole fleet as struct-of-arrays event state.

``repro.serve.fleet.FleetSim`` answers the paper's scale-out question at the
request level, but its per-instance loop walks Python ``Request`` objects —
O(batch) attribute churn per engine iteration — which caps it at tens of
instances. This module re-runs the SAME discrete-event semantics with fleet
state as arrays (the batched-scan-over-rows move ``StreamBatch`` made for
traces): requests are the columns of a :class:`~repro.serve.sim.RequestBatch`
and instances are rows of scalar event state, so a 500-instance
100k-request diurnal run prices in seconds instead of minutes.

What makes it fast — and still bit-identical to the oracle:

* **Arrivals are a sorted array + pointer, not heap entries.** Only step
  completions and autoscale ticks live in the heap; arrival events always
  outrank same-timestamp heap events (their sequence numbers are smaller,
  exactly as the oracle pushes them), so wave ordering is preserved.
* **O(1) step state via admission-step aggregates.** A request admitted at
  instance step ``k`` has emitted ``step - k`` tokens ever after, so the
  resident-KV sum the cost model needs is the closed form
  ``sum_prompt + batch * step - sum_admit_step`` — three counters updated
  only at admission/completion, never a per-request sweep per iteration.
* **Completions are pre-bucketed by step index.** Admission at step ``k``
  of a request with ``o`` output tokens schedules its completion at step
  ``k + o - 1``; each step-finish pops one bucket (ids + aggregate sums)
  instead of scanning the running batch.
* **Waves batch the pricing.** All events at one timestamp drain first
  (simultaneous arrivals share batches, as in the oracle); every instance
  the wave kicked then prices its next iteration through ONE vectorized
  :meth:`~repro.core.sweep.CostGrid.step_time` call, with a bisect-based
  scalar fast path when the wave touched a single instance.
* **FIFO admission uses a vectorized prefix check over the commit budget**
  — a cumulative-sum + ``searchsorted`` over the waiting head region (KV
  tokens under full reservation, committed pages under paged KV) — when
  the candidate window is wide, and an amortized-O(1) scalar walk
  otherwise.
* **Paged KV occupancy is O(1) per step via page-crossing buckets.** A
  request admitted at step ``k`` with ``prompt`` context maps a new page
  exactly at the steps ``s > k`` with ``s ≡ k + 1 - prompt (mod
  page_size)``, so one ``page_size``-slot increment array per instance
  (plus per-completion-bucket removal lists) carries the mapped-page sum
  the pricing and the step log need — no per-request page walk.

Two cores share this file. The fast path above covers full reservation and
paged KV with ``oversubscription <= 1`` under default scheduling — the
regimes where admission order fully determines residency. Eviction,
chunked prefill and decode-priority break the O(1) aggregates (occupancy
stops being a pure function of admission step), so those dispatch to
:func:`_run_fleet_rich`: the same event skeleton with O(batch) per-step
state transitions over int-list residency columns — still array-backed
and allocation-free, and still bit-identical to the oracle.

``repro.serve.fleet.FleetSim.run`` dispatches here by default; the
per-instance ``Instance``/heap loop survives behind ``run(batched=False)``
as the parity oracle, asserted request-for-request bit-identical (timings,
step logs, scale events) in ``tests/test_fleet_batch.py`` and
``tests/test_paged_kv.py``.
"""
from __future__ import annotations

import heapq
import math
from bisect import bisect_left
from collections import deque

import numpy as np

from repro.serve.paged import PagedKvSpec, SchedPolicy
from repro.serve.sim import ObsConfig, RequestBatch, SimMetrics, StepLog
from repro.serve.sim import _obs_phases as _obs_on

# Below this many candidates/completions the scalar path beats numpy-call
# overhead; both paths are exact, so the cutover is pure perf.
_VEC_CUTOVER = 8


def _scalar_pricer(cost):
    """(step_time, prefill_time, grid_like, per_tok) with a pure-Python
    bisect fast path for ``CostGrid``-shaped costs — identical table
    lookups, no per-step numpy call overhead. ``per_tok`` is the grid's
    prefill seconds/token (None for non-grid costs), so hot loops can
    inline the multiply instead of calling ``prefill_time``."""
    grid_like = (hasattr(cost, "step_time_s") and hasattr(cost, "batches")
                 and hasattr(cost, "seq_edges"))
    if not grid_like:
        return cost.step_time, cost.prefill_time, False, None
    batches = list(cost.batches)
    edges = list(cost.seq_edges)
    table = np.asarray(cost.step_time_s).tolist()   # exact float64 values
    max_b, last_j = batches[-1], len(edges) - 1

    def step_time(batch, resident):
        if batch < 1 or batch > max_b:
            raise ValueError(
                f"batch outside priced range [1, {max_b}]: {batch!r}")
        j = bisect_left(edges, resident)
        return table[bisect_left(batches, batch)][
            j if j < last_j else last_j]

    per_tok = float(getattr(cost, "prefill_s_per_token", 0.0))

    def prefill_time(prompt_tokens):
        return prompt_tokens * per_tok

    return step_time, prefill_time, True, per_tok


def run_fleet(cost, batch: RequestBatch, *, n_instances: int = 1,
              router: str = "least_loaded", max_batch: int | None = None,
              kv_capacity_tokens: float = float("inf"),
              paged: PagedKvSpec | None = None,
              sched: SchedPolicy | None = None,
              autoscaler=None, autoscale_interval_s: float = 0.0,
              obs: ObsConfig | None = None):
    """One batched fleet run over ``batch`` (consumed via a fresh copy).

    Semantics are exactly ``FleetSim.run(batched=False)``; see the module
    docstring for the vectorization strategy and the fast/rich dispatch.
    Returns a :class:`~repro.serve.fleet.FleetResult`.
    """
    from repro.serve.fleet import ROUTERS, FleetResult, ScaleEvent

    if router not in ROUTERS:
        raise ValueError(f"unknown router {router!r}; one of {ROUTERS}")
    if n_instances < 1:
        raise ValueError("n_instances must be >= 1")
    if autoscaler is not None and autoscale_interval_s <= 0:
        raise ValueError("autoscaler needs autoscale_interval_s > 0")
    mb = int(max_batch if max_batch is not None else cost.max_batch)
    if mb < 1:
        raise ValueError("max_batch must be >= 1")
    cap = float(kv_capacity_tokens)
    interval = float(autoscale_interval_s)
    if sched is None:
        sched = SchedPolicy()
    # Eviction / chunked prefill / decode-priority make page occupancy
    # history-dependent — the O(1) aggregates below no longer apply, so
    # those policies run on the rich per-request core instead.
    if not sched.is_default or (paged is not None
                                and paged.oversubscription > 1.0):
        return _run_fleet_rich(cost, batch, n_instances=n_instances,
                               router=router, mb=mb, cap=cap, paged=paged,
                               sched=sched, autoscaler=autoscaler,
                               interval=interval, obs=obs)
    round_robin = router == "round_robin"
    # ObsConfig level 1: step-log rows carry an 8th column (prefill tokens
    # consumed by the iteration) — a value the admission loops already sum,
    # so the extra work is one tuple concat per logged step.
    OBS = _obs_on(obs)

    b = batch.fresh()
    n = len(b)
    t_admitted, t_first, t_done = b.t_admitted, b.t_first_token, b.t_done
    tokens_emitted = b.tokens_emitted
    outputs = b.output_tokens
    # python lists: ~30ns scalar reads in the hot loop vs numpy item access
    t_arr_l = b.t_arrival.tolist()
    rid_l = b.rid.tolist()
    prompt_l = b.prompt_tokens.tolist()
    out_l = outputs.tolist()
    kv_arr = b.kv_tokens
    kv_l = kv_arr.tolist()

    # Paged fast path (oversubscription <= 1, default scheduling): commit
    # accounting runs in page units against the oversubscribable budget;
    # mapped-page occupancy is carried by O(1) crossing buckets (see the
    # module docstring). ``cu_*`` are the commit units the admission
    # prefix check sums — KV tokens under reservation, peak pages when
    # paged — so one code path serves both.
    PF = paged is not None
    if PF:
        P = paged.page_size
        cap_pages = float("inf") if math.isinf(cap) else int(cap // P)
        budget = cap_pages * paged.oversubscription
        cu_l = [(kv + P - 1) // P for kv in kv_l]
        cu_arr = np.asarray(cu_l, dtype=np.int64)
        fit_limit = cap_pages
    else:
        P = 1
        budget = cap
        cu_l = kv_l
        cu_arr = kv_arr
        fit_limit = cap

    def _never_admissible(row: int) -> ValueError:
        if PF:
            return ValueError(
                f"request {rid_l[row]} needs {cu_l[row]} KV pages; "
                f"instance capacity is {cap_pages} — it can never be "
                f"admitted")
        return ValueError(
            f"request {rid_l[row]} needs {kv_l[row]} KV tokens; "
            f"instance capacity is {cap:.0f} — it can never be "
            f"admitted")

    step_scalar, prefill_scalar, grid_like, per_tok = _scalar_pricer(cost)
    if grid_like:      # hot loops inline the table lookup (no call overhead)
        g_batches = list(cost.batches)
        g_edges = list(cost.seq_edges)
        g_table = np.asarray(cost.step_time_s).tolist()
        g_maxb, g_lastj = g_batches[-1], len(g_edges) - 1
        # validate once so grid-priced steps skip the per-step dt check
        # (a grid cell + non-negative finite prefill is always a valid dt)
        for row_ in g_table:
            for v in row_:
                if not (v > 0 and math.isfinite(v)):
                    raise ValueError(
                        f"non-positive/non-finite step time {v!r}")
        if not (per_tok >= 0 and math.isfinite(per_tok)):
            raise ValueError(
                f"non-finite/negative prefill_s_per_token {per_tok!r}")
        # direct batch-size -> table-row map (batch rounds UP to the next
        # priced size) so the per-step lookup is one list index + one bisect
        g_row = [None] + [g_table[bisect_left(g_batches, bb)]
                          for bb in range(1, g_maxb + 1)]

    # -- per-instance event state (index = instance id, rows of the fleet) -----
    busy: list[bool] = []
    kvres: list = []                 # committed units (KV tokens / pages)
    nrun: list[int] = []             # running batch size
    sum_p: list[int] = []            # sum of running prompts
    sum_as: list[int] = []           # sum of running admission step indices
    kstep: list[int] = []            # steps started
    wait_q: list[list[int]] = []     # FIFO waiting rows...
    wait_h: list[int] = []           # ...consumed from a head pointer
    # finish step -> [rows, cnt, Σp, Σk, Σcu, Σd_last, crossing slots]
    buckets: list[dict[int, list]] = []
    logs: list[list[tuple]] = []
    load: list[int] = []                 # waiting + running, per instance id
    mapped: list[int] = []           # paged: mapped pages this step
    pinc: list[list[int]] = []       # paged: page crossings per step mod P

    active: list[int] = []
    draining: list[int] = []
    draining_set: set[int] = set()
    retire_records: list[tuple[float, int]] = []   # (t_retired, instance)
    # routing state: loads of ACTIVE instances, compact and position-aligned
    # with `active` so least-loaded is one argmin (no fancy indexing);
    # posl[i] is instance i's position in `active` (-1 when not active)
    load_act = np.zeros(0, dtype=np.int64)
    posl: list[int] = []

    def rebuild_active() -> None:
        nonlocal load_act
        load_act = np.asarray([load[i] for i in active], dtype=np.int64)
        for idx in range(len(posl)):
            posl[idx] = -1
        for p, i in enumerate(active):
            posl[i] = p

    def spawn() -> None:
        i = len(busy)
        busy.append(False); kvres.append(0 if PF else 0.0); nrun.append(0)
        sum_p.append(0); sum_as.append(0); kstep.append(0)
        wait_q.append([]); wait_h.append(0)
        buckets.append({}); logs.append([])
        load.append(0)
        mapped.append(0); pinc.append([0] * P if PF else None)
        posl.append(-1)
        active.append(i)

    def drain_one(now: float) -> None:
        if len(active) <= 1:
            return
        i = active.pop(int(load_act.argmin()))
        rebuild_active()
        if not busy[i] and load[i] == 0:
            retire_records.append((now, i))
        else:
            draining.append(i)
            draining_set.add(i)

    for _ in range(n_instances):
        spawn()
    rebuild_active()

    def admit(i: int, now: float) -> tuple[list[int], float, int]:
        """FIFO admission bounded by batch slots and the committed-unit
        prefix (no skipping past a blocked head) — the oracle's admission
        loop. Returns (admitted rows, their summed prefill time, their
        summed prompt tokens — the fast path prefills whole prompts at
        admission, so that sum IS the iteration's prefill-token count)."""
        h, w = wait_h[i], wait_q[i]
        lim = len(w) - h
        slots = mb - nrun[i]
        if slots < lim:
            lim = slots
        if lim <= 0:
            return (), 0.0, 0
        cap_left = budget - kvres[i]
        if lim <= _VEC_CUTOVER:
            m, acc = 0, 0
            while m < lim:
                cu = cu_l[w[h + m]]
                if acc + cu > cap_left:
                    break
                acc += cu
                m += 1
        else:
            # vectorized prefix check: largest m with cumsum(cu) <= budget
            csum = np.cumsum(cu_arr[w[h:h + lim]])
            m = int(np.searchsorted(csum, cap_left, side="right"))
        if m == 0:
            return (), 0.0, 0
        rows = w[h:h + m]
        wait_h[i] = h + m
        if h + m > 512 and (h + m) * 2 >= len(w):
            del w[:h + m]
            wait_h[i] = 0
        if m <= _VEC_CUTOVER:
            for r in rows:
                t_admitted[r] = now
        else:
            t_admitted[rows] = now
        k = kstep[i]
        tot_cu = tot_p = 0
        prefill = 0.0
        bks = buckets[i]
        if PF:
            mp_i = mapped[i]
            pinc_i = pinc[i]
        for r in rows:
            fk = k + out_l[r] - 1          # the step whose end completes r
            bkt = bks.get(fk)
            if bkt is None:
                bks[fk] = bkt = [[], 0, 0, 0, 0, 0, []]
            bkt[0].append(r)
            bkt[1] += 1
            p = prompt_l[r]
            bkt[2] += p
            bkt[3] += k
            bkt[4] += cu_l[r]
            tot_cu += cu_l[r]
            tot_p += p
            if PF:
                # first-step demand: the prompt being prefilled this step
                mp_i += (p + P - 1) // P
                jr = (k + 1 - p) % P       # page-crossing residue class
                pinc_i[jr] += 1
                bkt[5] += (p + out_l[r] - 1 + P - 1) // P   # d_last
                bkt[6].append(jr)
            # oracle order: per-request prefill times summed left-to-right
            prefill += p * per_tok if per_tok is not None \
                else prefill_scalar(p)
        if PF:
            mapped[i] = mp_i
        kvres[i] += tot_cu
        nrun[i] += m
        sum_p[i] += tot_p
        sum_as[i] += m * k
        return rows, prefill, tot_p

    # -- the global event loop -------------------------------------------------
    # Steps live in the heap as (t_end, seq, instance); arrivals stay a
    # sorted array + pointer and the (single) pending autoscale tick is a
    # scalar. At equal timestamps arrivals outrank everything (seqs 0..n-1,
    # exactly the order the oracle pushed them) and step/tick events
    # interleave by seq — the oracle's heap order.
    INF = float("inf")
    heap: list[tuple[float, int, int]] = []
    seq = n          # arrivals implicitly hold seqs 0..n-1 (array order)
    arr_ptr = 0
    done = 0
    clock = 0.0
    rr = 0
    scale_events: list[ScaleEvent] = []
    tick_pending = False
    next_tick, tick_seq = INF, -1
    if autoscaler is not None and n:
        tick_pending, next_tick, tick_seq = True, t_arr_l[0] + interval, seq
        seq += 1

    while (arr_ptr < n or heap or tick_pending) and done < n:
        Ta = t_arr_l[arr_ptr] if arr_ptr < n else INF
        Tt = next_tick if tick_pending else INF
        T = Ta if Ta <= Tt else Tt
        # Fast-forward: between interaction points (arrivals / autoscale
        # ticks) instances are independent, so run each popped instance's
        # finish->admit->start chain privately until it crosses T or goes
        # idle — no heap churn or wave scaffolding per step. Steps landing
        # exactly ON T stay in the heap for the wave below, preserving the
        # oracle's ordering against same-timestamp arrivals and ticks.
        while heap and heap[0][0] < T:
            tcur, _, i = heapq.heappop(heap)
            # Chain-local scalars (written back after the chain): between
            # interaction points no other instance can observe this state,
            # and the chain was popped busy so ``busy[i]`` stays True
            # unless the instance retires or idles out.
            bks = buckets[i]
            logs_i = logs[i]
            w = wait_q[i]
            k_i = kstep[i]
            nr = nrun[i]
            sp_i = sum_p[i]
            sa_i = sum_as[i]
            kvr = kvres[i]
            h = wait_h[i]
            ld = load[i]
            pp = posl[i]
            mp_i = mapped[i]
            pinc_i = pinc[i]
            drn = i in draining_set
            while True:
                bkt = bks.pop(k_i - 1, None)
                if bkt is not None:
                    rows, cnt, sp, sa, scu, sdl, jl = bkt
                    if cnt <= _VEC_CUTOVER:
                        for r in rows:
                            t_done[r] = tcur
                            tokens_emitted[r] = out_l[r]
                    else:
                        t_done[rows] = tcur
                        tokens_emitted[rows] = outputs[rows]
                    nr -= cnt
                    sp_i -= sp
                    sa_i -= sa
                    kvr -= scu
                    ld -= cnt
                    if pp >= 0:
                        load_act[pp] -= cnt
                    done += cnt
                    if PF:
                        mp_i -= sdl
                        for jr in jl:
                            pinc_i[jr] -= 1
                if drn and ld == 0:
                    draining.remove(i)
                    draining_set.discard(i)
                    retire_records.append((tcur, i))
                    busy[i] = False
                    break
                if PF:
                    # carried-over requests crossing into a new page at
                    # step k_i (admissions below register AFTER this, so
                    # their first-step demand is never double-counted)
                    mp_i += pinc_i[k_i % P]
                # admit(), inlined — this is the engine's hottest block
                lim = len(w) - h
                slots = mb - nr
                if slots < lim:
                    lim = slots
                m = 0
                if lim > 0:
                    cap_left = budget - kvr
                    if lim <= _VEC_CUTOVER:
                        acc = 0
                        while m < lim:
                            cu = cu_l[w[h + m]]
                            if acc + cu > cap_left:
                                break
                            acc += cu
                            m += 1
                    else:
                        csum = np.cumsum(cu_arr[w[h:h + lim]])
                        m = int(np.searchsorted(csum, cap_left,
                                                side="right"))
                prefill = 0.0
                if m:
                    rows = w[h:h + m]
                    h += m
                    if h > 512 and h * 2 >= len(w):
                        del w[:h]
                        h = 0
                    if m <= _VEC_CUTOVER:
                        for r in rows:
                            t_admitted[r] = tcur
                    else:
                        t_admitted[rows] = tcur
                    tot_cu = tot_p = 0
                    for r in rows:
                        fk = k_i + out_l[r] - 1
                        bkt = bks.get(fk)
                        if bkt is None:
                            bks[fk] = bkt = [[], 0, 0, 0, 0, 0, []]
                        bkt[0].append(r)
                        bkt[1] += 1
                        p = prompt_l[r]
                        bkt[2] += p
                        bkt[3] += k_i
                        bkt[4] += cu_l[r]
                        tot_cu += cu_l[r]
                        tot_p += p
                        if PF:
                            mp_i += (p + P - 1) // P
                            jr = (k_i + 1 - p) % P
                            pinc_i[jr] += 1
                            bkt[5] += (p + out_l[r] - 1 + P - 1) // P
                            bkt[6].append(jr)
                        prefill += p * per_tok if per_tok is not None \
                            else prefill_scalar(p)
                    kvr += tot_cu
                    nr += m
                    sp_i += tot_p
                    sa_i += m * k_i
                else:
                    rows = ()
                    tot_p = 0   # no admissions -> no prefill this iteration
                if nr == 0:
                    busy[i] = False
                    break
                resident = mp_i * P if PF else sp_i + nr * k_i - sa_i
                if grid_like:
                    if nr > g_maxb:
                        raise ValueError(
                            f"batch outside priced range [1, {g_maxb}]: "
                            f"{nr!r}")
                    j = bisect_left(g_edges, resident)
                    dt = g_row[nr][j if j < g_lastj else g_lastj] + prefill
                else:
                    dt = step_scalar(nr, resident) + prefill
                    if not (dt > 0 and math.isfinite(dt)):
                        raise ValueError(
                            f"non-positive/non-finite step time {dt!r}")
                t_end = tcur + dt
                if PF:
                    lrow = (tcur, t_end, nr, kvr * P, len(w) - h, m, mp_i)
                else:
                    lrow = (tcur, t_end, nr, kvr, len(w) - h, m, 0.0)
                logs_i.append(lrow + (tot_p,) if OBS else lrow)
                if m:
                    if m <= _VEC_CUTOVER:
                        for r in rows:
                            t_first[r] = t_end
                    else:
                        t_first[rows] = t_end
                k_i += 1
                sq = seq
                seq += 1
                if t_end >= T:
                    heapq.heappush(heap, (t_end, sq, i))
                    break
                tcur = t_end
            kstep[i] = k_i
            nrun[i] = nr
            sum_p[i] = sp_i
            sum_as[i] = sa_i
            kvres[i] = kvr
            wait_h[i] = h
            load[i] = ld
            mapped[i] = mp_i
        if T == INF or done >= n:
            break      # oracle exits before a pending tick once all done
        assert T >= clock, "fleet clock went backwards"
        clock = T
        # Lone arrival (the common wave) — route + submit + start inline.
        if (Ta < Tt and (not heap or heap[0][0] != Ta)
                and (arr_ptr + 1 == n or t_arr_l[arr_ptr + 1] != Ta)):
            row = arr_ptr
            if cu_l[row] > fit_limit:
                raise _never_admissible(row)
            if round_robin:
                i = active[rr % len(active)]
                rr += 1
                p = posl[i]
            elif len(active) == 1:
                i = active[0]
                p = 0
            else:
                p = load_act.argmin()
                i = active[p]
            wait_q[i].append(row)
            load[i] += 1
            load_act[p] += 1
            arr_ptr += 1
            if busy[i]:
                continue
            rows, prefill, ptoks = admit(i, Ta)
            bsz = nrun[i]
            if bsz == 0:
                continue
            resident = mapped[i] * P if PF \
                else sum_p[i] + bsz * kstep[i] - sum_as[i]
            if grid_like:
                if bsz > g_maxb:
                    raise ValueError(
                        f"batch outside priced range [1, {g_maxb}]: {bsz!r}")
                j = bisect_left(g_edges, resident)
                dt = g_row[bsz][j if j < g_lastj else g_lastj] + prefill
            else:
                dt = step_scalar(bsz, resident) + prefill
            if not (dt > 0 and math.isfinite(dt)):
                raise ValueError(f"non-positive/non-finite step time {dt!r}")
            t_end = Ta + dt
            lrow = (Ta, t_end, bsz, kvres[i] * P if PF else kvres[i],
                    len(wait_q[i]) - wait_h[i], len(rows),
                    float(mapped[i]) if PF else 0.0)
            logs[i].append(lrow + (ptoks,) if OBS else lrow)
            if rows:
                # the iteration that prefills a request emits its first token
                if len(rows) <= _VEC_CUTOVER:
                    for r in rows:
                        t_first[r] = t_end
                else:
                    t_first[rows] = t_end
            busy[i] = True
            kstep[i] += 1
            heapq.heappush(heap, (t_end, seq, i))
            seq += 1
            continue
        # General wave at T: drain every same-timestamp event before
        # starting iterations (simultaneous arrivals share a batch — see
        # repro.serve.sim), arrivals first, then steps/ticks by seq.
        kick: dict[int, None] = {}
        while arr_ptr < n and t_arr_l[arr_ptr] == T:
            row = arr_ptr
            if cu_l[row] > fit_limit:
                raise _never_admissible(row)
            if round_robin:
                i = active[rr % len(active)]
                rr += 1
                p = posl[i]
            elif len(active) == 1:
                i = active[0]
                p = 0
            else:
                p = load_act.argmin()
                i = active[p]
            wait_q[i].append(row)
            load[i] += 1
            load_act[p] += 1
            kick[i] = None
            arr_ptr += 1
        while True:
            has_step = bool(heap) and heap[0][0] == T
            has_tick = tick_pending and next_tick == T
            if has_step and (not has_tick or heap[0][1] < tick_seq):
                _, _, i = heapq.heappop(heap)
                busy[i] = False
                bkt = buckets[i].pop(kstep[i] - 1, None)
                if bkt is not None:
                    rows, cnt, sp, sa, scu, sdl, jl = bkt
                    if cnt <= _VEC_CUTOVER:
                        for r in rows:
                            t_done[r] = T
                            tokens_emitted[r] = out_l[r]
                    else:
                        t_done[rows] = T
                        tokens_emitted[rows] = outputs[rows]
                    nrun[i] -= cnt
                    sum_p[i] -= sp
                    sum_as[i] -= sa
                    kvres[i] -= scu
                    load[i] -= cnt
                    if PF:
                        mapped[i] -= sdl
                        pinc_i = pinc[i]
                        for jr in jl:
                            pinc_i[jr] -= 1
                    p = posl[i]
                    if p >= 0:
                        load_act[p] -= cnt
                    done += cnt
                if i in draining_set and load[i] == 0:
                    draining.remove(i)
                    draining_set.discard(i)
                    retire_records.append((T, i))
                else:
                    kick[i] = None
            elif has_tick:
                tick_pending = False
                queued = running = 0
                for i in active:
                    queued += len(wait_q[i]) - wait_h[i]
                    running += nrun[i]
                target = autoscaler.decide(len(active), queued, running, mb)
                if target > len(active):
                    while len(active) < target:
                        spawn()
                    rebuild_active()
                while len(active) > max(target, 1):
                    drain_one(T)
                scale_events.append(ScaleEvent(T, len(active), queued,
                                               running))
                if done < n:
                    next_tick, tick_seq = T + interval, seq
                    seq += 1
                    tick_pending = True
            else:
                break
        # Admit + size every kicked instance first, then price the whole
        # wave's next steps through one batched CostGrid lookup.
        starters = []
        for i in kick:
            if busy[i]:
                continue
            if PF and nrun[i]:
                # page crossings of the carried-over batch at this step
                # (before admission registers its first-step demand)
                mapped[i] += pinc[i][kstep[i] % P]
            rows, prefill, ptoks = admit(i, T)
            bsz = nrun[i]
            if bsz == 0:
                continue
            resident = mapped[i] * P if PF \
                else sum_p[i] + bsz * kstep[i] - sum_as[i]
            starters.append((i, bsz, resident, prefill, rows, ptoks))
        if len(starters) > 1 and grid_like:
            times = cost.step_time(
                np.array([s[1] for s in starters]),
                np.array([s[2] for s in starters])).tolist()
        else:
            times = [step_scalar(s[1], s[2]) for s in starters]
        for (i, bsz, _, prefill, rows, ptoks), st in zip(starters, times):
            dt = st + prefill
            if not (dt > 0 and math.isfinite(dt)):
                raise ValueError(f"non-positive/non-finite step time {dt!r}")
            t_end = T + dt
            lrow = (T, t_end, bsz, kvres[i] * P if PF else kvres[i],
                    len(wait_q[i]) - wait_h[i], len(rows),
                    float(mapped[i]) if PF else 0.0)
            logs[i].append(lrow + (ptoks,) if OBS else lrow)
            if rows:
                # the iteration that prefills a request emits its first token
                if len(rows) <= _VEC_CUTOVER:
                    for r in rows:
                        t_first[r] = t_end
                else:
                    t_first[rows] = t_end
            busy[i] = True
            kstep[i] += 1
            heapq.heappush(heap, (t_end, seq, i))
            seq += 1

    leftovers = sum(load)
    assert done == n and leftovers == 0, "requests left in system"
    # Retirements sort by time (stable within a wave), matching the order
    # the oracle appended them while events were globally time-ordered.
    retire_records.sort(key=lambda rec: rec[0])
    retired = [i for _, i in retire_records]
    order = active + draining + retired
    return FleetResult(
        batch=b,
        metrics=SimMetrics.from_batch(b),
        step_logs=[StepLog.from_rows(logs[i]) for i in order],
        n_instances_final=len(active),
        scale_events=scale_events,
        n_instances_initial=n_instances,
    )


def _run_fleet_rich(cost, batch: RequestBatch, *, n_instances: int,
                    router: str, mb: int, cap: float,
                    paged: PagedKvSpec | None, sched: SchedPolicy,
                    autoscaler, interval: float,
                    obs: ObsConfig | None = None):
    """The rich fleet core: eviction, chunked prefill, decode-priority.

    Same event skeleton as the fast path (arrivals as sorted array +
    pointer, steps in the heap, waves draining same-timestamp events), but
    per-step state transitions are O(batch) over int-list residency
    columns — ``ctx``/``consumed``/``res_emitted`` per request, a running
    row list per instance — because these policies make occupancy depend
    on scheduling history, not just the admission step. Bit-identical to
    the ``Instance`` oracle (same plan/evict/admit/price order per
    iteration), asserted in ``tests/test_paged_kv.py``."""
    from repro.serve.fleet import FleetResult, ScaleEvent

    round_robin = router == "round_robin"
    OBS = _obs_on(obs)
    b = batch.fresh()
    n = len(b)
    t_admitted, t_first, t_done = b.t_admitted, b.t_first_token, b.t_done
    tokens_emitted = b.tokens_emitted
    evict_col = b.evictions
    t_arr_l = b.t_arrival.tolist()
    rid_l = b.rid.tolist()
    prompt_l = b.prompt_tokens.tolist()
    out_l = b.output_tokens.tolist()
    kv_l = b.kv_tokens.tolist()

    step_scalar, prefill_scalar, _, per_tok = _scalar_pricer(cost)

    PF = paged is not None
    if PF:
        P = paged.page_size
        cap_pages = float("inf") if math.isinf(cap) else int(cap // P)
        budget = cap_pages * paged.oversubscription
        evict_lru = paged.eviction == "lru"
        cu_l = [(kv + P - 1) // P for kv in kv_l]
        fit_limit = cap_pages
    else:
        P = 1
        budget = cap
        evict_lru = False
        cu_l = kv_l
        fit_limit = cap
    chunk_cap = sched.prefill_chunk
    decode_pri = sched.decode_priority

    # -- per-request residency state (reset at each (re-)admission) ------------
    ctx = [0] * n        # KV tokens to (re)build: prompt + emitted-at-admit
    con = [0] * n        # prefill progress this residency
    resem = [0] * n      # tokens emitted this residency
    em = [0] * n         # tokens emitted ever (the oracle's tokens_emitted)

    # -- per-instance state ----------------------------------------------------
    busy: list[bool] = []
    committed: list = []             # commit units (pages / float tokens)
    runl: list[list[int]] = []       # running rows, admission order
    waitq: list[deque] = []          # FIFO waiting (evictees re-enter LEFT)
    planc: list[list[int]] = []      # stashed chunks of the step in flight
    plane: list[list[bool]] = []     # stashed emit flags
    logs: list[list[tuple]] = []
    load: list[int] = []

    active: list[int] = []
    draining: list[int] = []
    draining_set: set[int] = set()
    retire_records: list[tuple[float, int]] = []
    load_act = np.zeros(0, dtype=np.int64)
    posl: list[int] = []

    def rebuild_active() -> None:
        nonlocal load_act
        load_act = np.asarray([load[i] for i in active], dtype=np.int64)
        for idx in range(len(posl)):
            posl[idx] = -1
        for p, i in enumerate(active):
            posl[i] = p

    def spawn() -> None:
        i = len(busy)
        busy.append(False); committed.append(0 if PF else 0.0)
        runl.append([]); waitq.append(deque())
        planc.append([]); plane.append([])
        logs.append([]); load.append(0)
        posl.append(-1)
        active.append(i)

    def drain_one(now: float) -> None:
        if len(active) <= 1:
            return
        i = active.pop(int(load_act.argmin()))
        rebuild_active()
        if not busy[i] and load[i] == 0:
            retire_records.append((now, i))
        else:
            draining.append(i)
            draining_set.add(i)

    for _ in range(n_instances):
        spawn()
    rebuild_active()

    def start(i: int, now: float) -> float | None:
        """Plan + evict + admit + price one iteration — the oracle's
        ``start_step``, over SoA residency columns."""
        rl = runl[i]
        wq = waitq[i]
        ch: list[int] = []
        ef: list[bool] = []
        dem: list[int] = []
        D = 0
        for r in rl:
            rem_p = ctx[r] - con[r]
            c = 0 if rem_p <= 0 else \
                (rem_p if chunk_cap is None or chunk_cap >= rem_p
                 else chunk_cap)
            ch.append(c)
            ef.append(c >= rem_p)
            if PF:
                d = (con[r] + c + resem[r] + P - 1) // P
                dem.append(d)
                D += d
        ci = committed[i]
        if evict_lru and D > cap_pages:
            victims: list[int] = []
            while D > cap_pages:
                v = rl.pop(0)
                D -= dem.pop(0)
                ch.pop(0)
                ef.pop(0)
                ci -= cu_l[v]
                evict_col[v] += 1
                victims.append(v)
            for v in reversed(victims):
                wq.appendleft(v)
        nadm = 0
        mid_prefill = False
        for e in ef:
            if not e:
                mid_prefill = True
                break
        while wq and len(rl) < mb:
            if decode_pri and rl and (mid_prefill or nadm):
                break
            r = wq[0]
            if ci + cu_l[r] > budget:
                break  # FIFO: no skipping past the blocked head
            base = prompt_l[r] + em[r]
            c = base if chunk_cap is None or chunk_cap >= base else chunk_cap
            if PF:
                d = (c + P - 1) // P
                if D + d > cap_pages:
                    break  # admission must never trigger eviction
                dem.append(d)
                D += d
            wq.popleft()
            ta = t_admitted[r]
            if ta != ta:                   # NaN: first admission only
                t_admitted[r] = now
            ctx[r] = base
            con[r] = 0
            resem[r] = 0
            ci += cu_l[r]
            rl.append(r)
            ch.append(c)
            ef.append(c >= base)
            nadm += 1
        committed[i] = ci
        if not rl:
            return None
        prefill = 0.0
        resident = 0
        ptoks = 0
        for idx, r in enumerate(rl):
            c = ch[idx]
            if not PF:
                resident += con[r] + c + resem[r]
            if c:
                ptoks += c
                prefill += c * per_tok if per_tok is not None \
                    else prefill_scalar(c)
        if PF:
            resident = D * P
        dt = step_scalar(len(rl), resident) + prefill
        if not (dt > 0 and math.isfinite(dt)):
            raise ValueError(f"non-positive/non-finite step time {dt!r}")
        t_end = now + dt
        lrow = (now, t_end, len(rl), float(ci * P) if PF else ci,
                len(wq), nadm, float(D) if PF else 0.0)
        logs[i].append(lrow + (ptoks,) if OBS else lrow)
        planc[i] = ch
        plane[i] = ef
        return t_end

    def finish(i: int, now: float) -> int:
        """Replay the stashed plan — the oracle's ``finish_step``."""
        rl = runl[i]
        ch = planc[i]
        ef = plane[i]
        ci = committed[i]
        still: list[int] = []
        ndone = 0
        for idx, r in enumerate(rl):
            con[r] += ch[idx]
            if ef[idx]:
                e = em[r] + 1
                em[r] = e
                resem[r] += 1
                if e == 1:
                    t_first[r] = now
                if e >= out_l[r]:
                    t_done[r] = now
                    tokens_emitted[r] = e
                    ci -= cu_l[r]
                    ndone += 1
                    continue
            still.append(r)
        runl[i] = still
        committed[i] = ci
        return ndone

    # -- the global event loop (the fast path's skeleton, scalar calls) --------
    INF = float("inf")
    heap: list[tuple[float, int, int]] = []
    seq = n
    arr_ptr = 0
    done = 0
    clock = 0.0
    rr = 0
    scale_events: list[ScaleEvent] = []
    tick_pending = False
    next_tick, tick_seq = INF, -1
    if autoscaler is not None and n:
        tick_pending, next_tick, tick_seq = True, t_arr_l[0] + interval, seq
        seq += 1

    def _never_admissible(row: int) -> ValueError:
        if PF:
            return ValueError(
                f"request {rid_l[row]} needs {cu_l[row]} KV pages; "
                f"instance capacity is {cap_pages} — it can never be "
                f"admitted")
        return ValueError(
            f"request {rid_l[row]} needs {kv_l[row]} KV tokens; "
            f"instance capacity is {cap:.0f} — it can never be "
            f"admitted")

    while (arr_ptr < n or heap or tick_pending) and done < n:
        Ta = t_arr_l[arr_ptr] if arr_ptr < n else INF
        Tt = next_tick if tick_pending else INF
        T = Ta if Ta <= Tt else Tt
        # Fast-forward chain, as in the fast path: between interaction
        # points a popped instance runs finish->start privately.
        while heap and heap[0][0] < T:
            tcur, _, i = heapq.heappop(heap)
            pp = posl[i]
            drn = i in draining_set
            while True:
                nd = finish(i, tcur)
                if nd:
                    done += nd
                    load[i] -= nd
                    if pp >= 0:
                        load_act[pp] -= nd
                if drn and load[i] == 0:
                    draining.remove(i)
                    draining_set.discard(i)
                    retire_records.append((tcur, i))
                    busy[i] = False
                    break
                t_end = start(i, tcur)
                if t_end is None:
                    busy[i] = False
                    break
                sq = seq
                seq += 1
                if t_end >= T:
                    heapq.heappush(heap, (t_end, sq, i))
                    break
                tcur = t_end
        if T == INF or done >= n:
            break
        assert T >= clock, "fleet clock went backwards"
        clock = T
        # General wave at T (no lone-arrival shortcut here — policy steps
        # are O(batch) anyway): arrivals first, then steps/ticks by seq.
        kick: dict[int, None] = {}
        while arr_ptr < n and t_arr_l[arr_ptr] == T:
            row = arr_ptr
            if cu_l[row] > fit_limit:
                raise _never_admissible(row)
            if round_robin:
                i = active[rr % len(active)]
                rr += 1
                p = posl[i]
            elif len(active) == 1:
                i = active[0]
                p = 0
            else:
                p = load_act.argmin()
                i = active[p]
            waitq[i].append(row)
            load[i] += 1
            load_act[p] += 1
            kick[i] = None
            arr_ptr += 1
        while True:
            has_step = bool(heap) and heap[0][0] == T
            has_tick = tick_pending and next_tick == T
            if has_step and (not has_tick or heap[0][1] < tick_seq):
                _, _, i = heapq.heappop(heap)
                busy[i] = False
                nd = finish(i, T)
                if nd:
                    done += nd
                    load[i] -= nd
                    p = posl[i]
                    if p >= 0:
                        load_act[p] -= nd
                if i in draining_set and load[i] == 0:
                    draining.remove(i)
                    draining_set.discard(i)
                    retire_records.append((T, i))
                else:
                    kick[i] = None
            elif has_tick:
                tick_pending = False
                queued = running = 0
                for i in active:
                    queued += len(waitq[i])
                    running += len(runl[i])
                target = autoscaler.decide(len(active), queued, running, mb)
                if target > len(active):
                    while len(active) < target:
                        spawn()
                    rebuild_active()
                while len(active) > max(target, 1):
                    drain_one(T)
                scale_events.append(ScaleEvent(T, len(active), queued,
                                               running))
                if done < n:
                    next_tick, tick_seq = T + interval, seq
                    seq += 1
                    tick_pending = True
            else:
                break
        for i in kick:
            if busy[i]:
                continue
            t_end = start(i, T)
            if t_end is None:
                continue
            busy[i] = True
            heapq.heappush(heap, (t_end, seq, i))
            seq += 1

    leftovers = sum(load)
    assert done == n and leftovers == 0, "requests left in system"
    retire_records.sort(key=lambda rec: rec[0])
    retired = [i for _, i in retire_records]
    order = active + draining + retired
    return FleetResult(
        batch=b,
        metrics=SimMetrics.from_batch(b),
        step_logs=[StepLog.from_rows(logs[i]) for i in order],
        n_instances_final=len(active),
        scale_events=scale_events,
        n_instances_initial=n_instances,
    )
