"""Serving: jax prefill/decode steps + the request-level simulator.

``repro.serve.sim`` / ``repro.serve.fleet`` are pure-NumPy and import
cheaply; the jax step builders load lazily so simulator users never pay the
jax import.
"""

__all__ = ["make_decode_step", "make_prefill_step"]


def __getattr__(name):
    if name in __all__:
        from repro.serve import step

        return getattr(step, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
