"""Paged KV residency: block-table allocation behind the serving scheduler.

The COPA paper prices its serving configs under the assumption that a
request's peak KV footprint is resident for its whole lifetime — which is
exactly what ``repro.serve.sim`` did by reserving ``prompt + output`` tokens
at admission. Production engines (vLLM-style block tables) allocate KV in
fixed-size *pages* as the sequence grows, which changes what the MSM's
DRAM capacity knob buys: the same DRAM holds more in-flight requests, and
an oversubscribed pool trades occasional eviction + prefill recompute for
admission headroom. This module is the allocator layer of that model:

* :class:`PagedKvSpec` — the residency policy (``page_size``,
  ``oversubscription``, eviction policy) threaded through
  :class:`~repro.serve.sim.Instance`, ``repro.serve.fleet`` and the batched
  fleet core. ``paged=None`` at the API layer keeps the original
  full-reservation behavior (the parity oracle).
* :class:`ReservedKv` — the scalar reservation allocator (the old
  ``kv_reserved`` counter behind the shared allocator interface).
* :class:`PagedKv` — a real block table: free list of page ids,
  per-request page lists, a *commit* ledger (peak pages per admitted
  request, bounded by ``capacity_pages * oversubscription``) and a *mapped*
  ledger (pages actually backing resident KV, bounded by
  ``capacity_pages``).
* :class:`SchedPolicy` — the scheduler hook that rides on the allocator
  interface: chunked prefill (``prefill_chunk`` tokens per request per
  iteration) and decode-priority admission (at most one admission per
  iteration, and none while a prefill is mid-flight).

Residency model (shared by the heap oracle and both batched engines, and
what the parity tests pin down):

* a request's *committed* footprint is its peak ``ceil((prompt + output) /
  page_size)`` pages, checked against the oversubscribable commit budget at
  admission — with ``oversubscription == 1.0`` this is exactly the old
  conservative reservation, page-granular;
* its *mapped* footprint at a step is ``ceil(kv_read / page_size)`` where
  ``kv_read`` is the KV the step must read (prefilled context + previously
  emitted tokens). The token a step writes lands in the page mapped at its
  next step's start (write-allocate at the step boundary), so a request's
  final token never needs a resident page — pages exist to serve future
  reads. With ``page_size=1`` and oversubscription disabled the mapped sum
  equals the reservation path's resident-KV sum bit-for-bit;
* when mapped demand would exceed physical pages (only possible with
  ``oversubscription > 1``), the LRU policy evicts the least-recently-
  admitted running request(s) back to the *front* of the waiting queue;
  their pages are freed and their KV is recomputed (prompt + already-
  emitted tokens re-prefilled) at re-admission — emitted tokens are never
  lost, only residency. Extreme oversubscription can recompute-thrash,
  exactly as on real engines; admission never triggers eviction (a
  candidate must fit the *physical* pool on top of current demand).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

EVICTION_POLICIES = ("none", "lru")


def pages_for(tokens: int, page_size: int) -> int:
    """Pages holding ``tokens`` KV tokens (ceil division; 0 tokens -> 0)."""
    return -(-tokens // page_size)


@dataclass(frozen=True)
class PagedKvSpec:
    """Block-table residency policy for one serving instance.

    ``oversubscription`` scales the commit budget: 1.0 admits only what is
    guaranteed to fit physically (eviction can never fire); > 1.0 admits
    more and requires an eviction policy to resolve page-pool pressure."""

    page_size: int = 16
    oversubscription: float = 1.0
    eviction: str = "none"

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if not (self.oversubscription > 0
                and math.isfinite(self.oversubscription)):
            raise ValueError("oversubscription must be finite and > 0")
        if self.eviction not in EVICTION_POLICIES:
            raise ValueError(f"unknown eviction policy {self.eviction!r}; "
                             f"one of {EVICTION_POLICIES}")
        if self.eviction == "none" and self.oversubscription > 1.0:
            raise ValueError(
                "oversubscription > 1 needs an eviction policy (mapped "
                "demand may exceed physical pages)")


@dataclass(frozen=True)
class SchedPolicy:
    """Continuous-batching scheduler variants on the allocator hook.

    ``prefill_chunk`` caps the prompt tokens one request prefills per
    iteration (None: whole prompt in its admission iteration — the
    original semantics); the iteration that consumes the last chunk also
    emits the first token. ``decode_priority`` admits at most ONE request
    per iteration into a non-empty batch and defers admission entirely
    while any running request is still mid-prefill, bounding the prefill
    stall a decode step can absorb."""

    prefill_chunk: int | None = None
    decode_priority: bool = False

    def __post_init__(self):
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1 (or None)")

    @property
    def is_default(self) -> bool:
        return self.prefill_chunk is None and not self.decode_priority


class ReservedKv:
    """Scalar full-reservation allocator — the ``page_size=None`` oracle.

    Commits a request's whole ``prompt + output`` token footprint at
    admission (the original ``kv_reserved`` counter); nothing is paged, so
    ``pages_mapped`` is always 0 and eviction never applies."""

    page_size = None

    def __init__(self, capacity_tokens: float):
        self.capacity_tokens = float(capacity_tokens)
        self.reserved = 0.0
        self.pages_mapped = 0

    def fits(self, kv_tokens: int) -> bool:
        """Could this request EVER be admitted on an empty instance?"""
        return kv_tokens <= self.capacity_tokens

    def can_admit(self, kv_tokens: int) -> bool:
        return self.reserved + kv_tokens <= self.capacity_tokens

    def admit(self, rid: int, kv_tokens: int) -> None:
        self.reserved += kv_tokens

    def ensure(self, rid: int, demand_pages: int) -> None:
        pass

    def release(self, rid: int, kv_tokens: int) -> None:
        self.reserved -= kv_tokens

    @property
    def committed_tokens(self) -> float:
        return self.reserved


class PagedKv:
    """Block-table KV allocator: free list + per-request page lists.

    Two ledgers guard two different limits. The *commit* ledger holds each
    admitted request's peak page count against ``commit_budget =
    capacity_pages * oversubscription`` — the admission bound. The *mapped*
    ledger holds the pages actually wired to requests against the physical
    ``capacity_pages`` — the eviction bound. Page ids are handed out
    deterministically (ascending from the free list) so engine parity is
    exact; with infinite capacity the free list is virtual (a counter)."""

    def __init__(self, capacity_tokens: float, spec: PagedKvSpec):
        self.spec = spec
        self.page_size = spec.page_size
        self.capacity_tokens = float(capacity_tokens)
        if math.isinf(self.capacity_tokens):
            self.capacity_pages: float = float("inf")
            self._free: list[int] | None = None    # virtual free list
            self._next_page = 0
        else:
            self.capacity_pages = int(self.capacity_tokens
                                      // self.page_size)
            # pop() from the tail yields pages 0, 1, 2, ...
            self._free = list(range(self.capacity_pages - 1, -1, -1))
            self._next_page = -1
        self.commit_budget = self.capacity_pages * spec.oversubscription
        self.page_table: dict[int, list[int]] = {}
        self._committed: dict[int, int] = {}       # rid -> peak pages
        self.committed_pages = 0
        self.pages_mapped = 0

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def fits(self, kv_tokens: int) -> bool:
        """Peak footprint fits the PHYSICAL pool (else never admissible)."""
        return self.pages_for(kv_tokens) <= self.capacity_pages

    def can_admit(self, kv_tokens: int) -> bool:
        return (self.committed_pages + self.pages_for(kv_tokens)
                <= self.commit_budget)

    def admit(self, rid: int, kv_tokens: int) -> None:
        if rid in self._committed:
            raise RuntimeError(f"request {rid} already admitted")
        peak = self.pages_for(kv_tokens)
        self._committed[rid] = peak
        self.committed_pages += peak
        self.page_table[rid] = []

    def ensure(self, rid: int, demand_pages: int) -> None:
        """Grow ``rid``'s page list to ``demand_pages`` (never shrinks —
        a residency's KV only grows until release/eviction)."""
        pages = self.page_table[rid]
        grow = demand_pages - len(pages)
        if grow <= 0:
            return
        if self._free is None:
            nxt = self._next_page
            pages.extend(range(nxt, nxt + grow))
            self._next_page = nxt + grow
        else:
            if grow > len(self._free):
                raise RuntimeError(
                    "page pool exhausted — eviction should have run")
            for _ in range(grow):
                pages.append(self._free.pop())
        self.pages_mapped += grow

    def release(self, rid: int, kv_tokens: int | None = None) -> None:
        """Unmap + uncommit ``rid`` (completion or eviction)."""
        pages = self.page_table.pop(rid)
        self.pages_mapped -= len(pages)
        if self._free is not None:
            self._free.extend(reversed(pages))
        self.committed_pages -= self._committed.pop(rid)

    @property
    def committed_tokens(self) -> float:
        """Committed footprint in token units (what the step log records —
        with ``page_size=1`` this equals the reservation path's counter)."""
        return float(self.committed_pages * self.page_size)


def make_allocator(capacity_tokens: float,
                   spec: PagedKvSpec | None):
    """The allocator behind an :class:`~repro.serve.sim.Instance`."""
    if spec is None:
        return ReservedKv(capacity_tokens)
    return PagedKv(capacity_tokens, spec)
