"""Serving steps: prefill (builds the KV cache) and decode (one token).

``serve_step`` for the dry-run grid is the decode step: one new token
against a ``seq_len``-deep cache. Sampling is greedy/temperature/top-k on
fp32 logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill_step(model):
    cfg = model.cfg

    def prefill(params, batch):
        """Runs the full-sequence forward and returns (last_logits, hidden).
        Cache population for the generic path is handled by running the
        chunked forward; serving engines that need the cache use
        ``decode_from_scratch`` below or keep prompt-parallel caches."""
        h, _ = model.forward(params, batch)
        from repro.models.layers import logits_for_tokens

        return logits_for_tokens(params["emb"], h[:, -1:, :])

    return prefill


def make_decode_step(model, sample: str = "greedy", temperature: float = 1.0,
                     top_k: int = 0):
    def decode_step(params, cache, tokens, pos, rng):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        logits = logits[:, -1, :].astype(jnp.float32)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1)
        else:
            logits = logits / jnp.maximum(temperature, 1e-6)
            if top_k:
                vals, _ = jax.lax.top_k(logits, top_k)
                logits = jnp.where(logits < vals[:, -1:], -1e30, logits)
            nxt = jax.random.categorical(rng, logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return decode_step
