"""Fleet-level serving: N simulated instances behind a router.

Answers the paper's scale-out question at the request level: how many
instances of a COPA config does a latency-bounded service need?
:class:`FleetSim` runs one global discrete-event loop over N instances —
arrivals are dispatched by a router (``round_robin`` or ``least_loaded``),
each instance schedules its own continuous-batching iterations, and an
optional autoscaler (queue-depth policy from ``repro.ft.elastic``) resizes
the fleet at a fixed cadence.

Two engines share these semantics: the default is the vectorized
struct-of-arrays core in ``repro.serve.fleetbatch`` (requests as
:class:`~repro.serve.sim.RequestBatch` columns, instances as rows of one
event state — planet-scale fleets price in seconds); ``run(batched=False)``
keeps the original per-instance :class:`~repro.serve.sim.Instance`/heap
loop as the parity oracle, asserted bit-identical in tests.

:func:`instances_to_meet_slo` is the SLO-percentile analogue of
``SweepGrid.instances_to_target``: the smallest fleet whose simulated
latency percentiles meet the :class:`~repro.serve.sim.Slo`.
:func:`scan_fleet` finds it by doubling + bisection — each probe is one
batched run over the SAME generated request stream, so a 200+-instance
answer costs ~log2(N) simulations instead of N.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Sequence

from repro.serve.sim import (
    ArrivalSpec,
    Instance,
    ObsConfig,
    Request,
    RequestBatch,
    SimMetrics,
    Slo,
    StepLog,
    fresh_requests,
)

ROUTERS = ("round_robin", "least_loaded")


@dataclass
class ScaleEvent:
    t: float
    n_active: int
    queued: int
    running: int


@dataclass
class FleetResult:
    batch: RequestBatch               # per-request timings, SoA, arrival-sorted
    metrics: SimMetrics
    step_logs: list[StepLog]          # one per instance ever active
    n_instances_final: int            # active (non-draining) at completion
    scale_events: list[ScaleEvent] = field(default_factory=list)
    n_instances_initial: int | None = None   # fleet size before any autoscale

    @property
    def requests(self) -> list[Request]:
        """Per-request objects, materialized from the SoA batch on demand
        (the batched core never builds them)."""
        if getattr(self, "_requests", None) is None:
            self._requests = self.batch.to_requests()
        return self._requests

    @property
    def n_instances_peak(self) -> int:
        return max((e.n_active for e in self.scale_events),
                   default=self.n_instances_final)

    def timeseries(self, window_s: float, *, slo: Slo | None = None):
        """Windowed :class:`repro.obs.series.MetricSeries` rollup — the
        per-window goodput/percentile/occupancy view of this run."""
        from repro.obs.series import timeseries
        return timeseries(self, window_s, slo=slo)


_ARRIVAL, _STEP_DONE, _TICK = 0, 1, 2


class FleetSim:
    """N serving instances of one config behind a router.

    All instances share one cost model (``CostGrid``-like) and per-instance
    ``max_batch`` / ``kv_capacity_tokens`` limits. With an ``autoscaler``
    (see :class:`repro.ft.elastic.QueueDepthAutoscaler`) the fleet is
    resized every ``autoscale_interval_s``: scale-up adds a fresh instance;
    scale-down drains the least-loaded one (it stops receiving arrivals,
    finishes its queue, then leaves the fleet)."""

    def __init__(self, cost, n_instances: int = 1, *,
                 router: str = "least_loaded",
                 max_batch: int | None = None,
                 kv_capacity_tokens: float = float("inf"),
                 paged=None, sched=None,
                 autoscaler=None, autoscale_interval_s: float = 0.0,
                 obs: ObsConfig | None = None):
        if router not in ROUTERS:
            raise ValueError(f"unknown router {router!r}; one of {ROUTERS}")
        if n_instances < 1:
            raise ValueError("n_instances must be >= 1")
        if autoscaler is not None and autoscale_interval_s <= 0:
            raise ValueError("autoscaler needs autoscale_interval_s > 0")
        self.cost = cost
        self.router = router
        self.max_batch = max_batch
        self.kv_capacity_tokens = kv_capacity_tokens
        self.paged = paged
        self.sched = sched
        self.autoscaler = autoscaler
        self.autoscale_interval_s = float(autoscale_interval_s)
        self.obs = obs
        self._n_initial = int(n_instances)
        self._active: list[Instance] = []
        self._draining: list[Instance] = []
        self._retired: list[Instance] = []
        for _ in range(n_instances):
            self._spawn()
        self._rr = 0

    # -- fleet membership ------------------------------------------------------
    def _spawn(self) -> Instance:
        inst = Instance(self.cost, max_batch=self.max_batch,
                        kv_capacity_tokens=self.kv_capacity_tokens,
                        paged=self.paged, sched=self.sched, obs=self.obs)
        self._active.append(inst)
        return inst

    def _drain_one(self) -> None:
        if len(self._active) <= 1:
            return
        inst = min(self._active, key=lambda i: i.load)
        self._active.remove(inst)
        (self._retired if inst.idle else self._draining).append(inst)

    def _route(self, req: Request) -> Instance:
        if self.router == "round_robin":
            inst = self._active[self._rr % len(self._active)]
            self._rr += 1
            return inst
        return min(self._active, key=lambda i: i.load)

    # -- the global event loop -------------------------------------------------
    def run(self, requests: Sequence[Request] | ArrivalSpec | RequestBatch,
            seed: int = 0, *, batched: bool = True) -> FleetResult:
        if batched:
            from repro.serve import fleetbatch  # lazy: avoids import cycle

            if isinstance(requests, ArrivalSpec):
                rb = requests.generate_batch(seed)
            elif isinstance(requests, RequestBatch):
                rb = requests
            else:
                rb = RequestBatch.from_requests(requests)
            return fleetbatch.run_fleet(
                self.cost, rb, n_instances=len(self._active),
                router=self.router, max_batch=self.max_batch,
                kv_capacity_tokens=self.kv_capacity_tokens,
                paged=self.paged, sched=self.sched,
                autoscaler=self.autoscaler,
                autoscale_interval_s=self.autoscale_interval_s,
                obs=self.obs)
        if isinstance(requests, ArrivalSpec):
            requests = requests.generate(seed)
        elif isinstance(requests, RequestBatch):
            requests = requests.to_requests()
        # copy: a shared request list (replayed trace) must not carry one
        # run's timing state into the next (scan_fleet reuses the list)
        reqs = fresh_requests(requests)
        events: list[tuple[float, int, int, object]] = []
        seq = 0
        for r in reqs:
            heapq.heappush(events, (r.t_arrival, seq, _ARRIVAL, r))
            seq += 1
        scale_events: list[ScaleEvent] = []
        if self.autoscaler is not None and reqs:
            heapq.heappush(events, (reqs[0].t_arrival
                                    + self.autoscale_interval_s, seq, _TICK,
                                    None))
            seq += 1
        done = 0
        clock = 0.0
        while events and done < len(reqs):
            t, _, kind, payload = heapq.heappop(events)
            assert t >= clock, "fleet clock went backwards"
            clock = t
            # Drain every event at this timestamp before starting iterations
            # (simultaneous arrivals share a batch — see repro.serve.sim).
            kick: dict[int, Instance] = {}
            while True:
                if kind == _ARRIVAL:
                    inst = self._route(payload)
                    inst.submit(payload)
                    kick[id(inst)] = inst
                elif kind == _STEP_DONE:
                    inst = payload
                    done += len(inst.finish_step(t))
                    if inst in self._draining and inst.idle:
                        self._draining.remove(inst)
                        self._retired.append(inst)
                    else:
                        kick[id(inst)] = inst
                else:  # autoscale tick
                    queued = sum(len(i.waiting) for i in self._active)
                    running = sum(len(i.running) for i in self._active)
                    target = self.autoscaler.decide(
                        len(self._active), queued, running,
                        self.max_batch or self.cost.max_batch)
                    while len(self._active) < target:
                        self._spawn()
                    while len(self._active) > max(target, 1):
                        self._drain_one()
                    scale_events.append(ScaleEvent(t, len(self._active),
                                                   queued, running))
                    if done < len(reqs):
                        heapq.heappush(events, (t + self.autoscale_interval_s,
                                                seq, _TICK, None))
                        seq += 1
                if not (events and events[0][0] == t):
                    break
                _, _, kind, payload = heapq.heappop(events)
            for inst in kick.values():
                if not inst.busy:
                    t_end = inst.start_step(t)
                    if t_end is not None:
                        heapq.heappush(events, (t_end, seq, _STEP_DONE, inst))
                        seq += 1
        leftovers = sum(i.load for i in
                        self._active + self._draining + self._retired)
        assert done == len(reqs) and leftovers == 0, "requests left in system"
        logs = [i.step_log() for i in
                self._active + self._draining + self._retired]
        out = FleetResult(
            batch=RequestBatch.from_completed(reqs),
            metrics=SimMetrics.from_requests(reqs),
            step_logs=logs,
            n_instances_final=len(self._active),
            scale_events=scale_events,
            n_instances_initial=self._n_initial,
        )
        out._requests = reqs
        return out


def scan_fleet(cost, arrivals: ArrivalSpec | Sequence[Request] | RequestBatch,
               slo: Slo, *,
               router: str = "least_loaded", max_batch: int | None = None,
               kv_capacity_tokens: float = float("inf"),
               paged=None, sched=None, obs: ObsConfig | None = None,
               max_instances: int = 64, seed: int = 0,
               batched: bool = True, strategy: str = "bisect"
               ) -> dict[int, SimMetrics]:
    """Probe fleet sizes until the smallest SLO-meeting size is bracketed;
    returns metrics for every size probed.

    The request stream is generated ONCE and re-run fresh per probe, so
    every probed size sees the identical arrival trace. ``strategy`` picks
    the probe schedule: ``"bisect"`` (default) doubles 1, 2, 4, ... to the
    first SLO-meeting size then bisects the bracket — O(log N) batched runs,
    which is what makes 200+-instance sizing cheap; ``"linear"`` is the
    original 1..N scan (kept for parity tests — both schedules land on the
    same :func:`instances_to_meet_slo` answer whenever SLO attainment is
    monotone in fleet size, asserted in tests)."""
    if strategy not in ("bisect", "linear"):
        raise ValueError(f"unknown strategy {strategy!r}")
    if isinstance(arrivals, ArrivalSpec):
        base = arrivals.generate_batch(seed) if batched \
            else arrivals.generate(seed)
    else:
        base = arrivals   # FleetSim.run re-materializes fresh copies

    def probe(k: int) -> SimMetrics:
        sim = FleetSim(cost, k, router=router, max_batch=max_batch,
                       kv_capacity_tokens=kv_capacity_tokens,
                       paged=paged, sched=sched, obs=obs)
        return sim.run(base, seed=seed, batched=batched).metrics

    out: dict[int, SimMetrics] = {}
    if strategy == "linear":
        for k in range(1, max_instances + 1):
            out[k] = probe(k)
            if slo.met(out[k]):
                break
        return out
    k, lo = 1, 0
    while True:                       # doubling: find the first met size
        out[k] = probe(k)
        if slo.met(out[k]):
            break
        if k >= max_instances:
            return out                # even the cap falls short
        lo, k = k, min(2 * k, max_instances)
    hi = k
    while hi - lo > 1:                # bisect the (fail, met] bracket
        mid = (lo + hi) // 2
        out[mid] = probe(mid)
        if slo.met(out[mid]):
            hi = mid
        else:
            lo = mid
    return out


def instances_to_meet_slo(cost,
                          arrivals: ArrivalSpec | Sequence[Request]
                          | RequestBatch,
                          slo: Slo, **kw) -> int | None:
    """Smallest fleet size whose simulated percentiles meet ``slo`` (None
    when even ``max_instances`` falls short) — the SLO analogue of
    ``SweepGrid.instances_to_target``."""
    scanned = scan_fleet(cost, arrivals, slo, **kw)
    met = [k for k, m in scanned.items() if slo.met(m)]
    return min(met) if met else None


def latency_goodput_rows(grids: dict[str, "object"], arrivals: ArrivalSpec,
                         rates: Sequence[float], slo: Slo, *,
                         n_instances: int = 1, router: str = "least_loaded",
                         kv_capacity_tokens: float = float("inf"),
                         paged=None, sched=None,
                         seed: int = 0) -> list[dict]:
    """Comparison-table rows (config x arrival rate): latency percentiles +
    SLO goodput, shared by the examples / launch drivers / benchmarks."""
    rows = []
    for rate in rates:
        spec = arrivals.with_rate(rate)
        for name, grid in grids.items():
            m = FleetSim(grid, n_instances, router=router,
                         kv_capacity_tokens=kv_capacity_tokens,
                         paged=paged, sched=sched).run(
                             spec, seed=seed).metrics
            rows.append({
                "config": name,
                "rate_rps": rate,
                "ttft_p50_ms": 1e3 * m.percentile("ttft", 50),
                "ttft_p99_ms": 1e3 * m.percentile("ttft", 99),
                "tpot_p99_ms": 1e3 * m.percentile("tpot", 99),
                "e2e_p99_ms": 1e3 * m.percentile("e2e", 99),
                "goodput_rps": m.goodput_rps(slo),
                "slo_met": slo.met(m),
            })
    return rows
