"""Request-level serving simulator: continuous batching over analytic costs.

The sweep engine prices one *step* of a serving workload (a batched decode
iteration of a ``serve.*`` scenario) per COPA config. This module turns those
step costs into what a latency-bounded service actually sees: open-loop
arrivals queue at an instance, a continuous-batching scheduler admits them
into the running batch at step boundaries (bounded by ``max_batch`` and KV
residency), and every completed request carries TTFT / TPOT / E2E timings.

Layering:

* :class:`Request` / :class:`ArrivalSpec` — open-loop arrival processes
  (Poisson, deterministically-modulated bursts, replayed traces) with
  configurable prompt/output length distributions. Everything is seeded and
  deterministic.
* :class:`Instance` — ONE serving instance's scheduler state (FIFO waiting
  queue, running batch, KV residency via a ``repro.serve.paged`` allocator:
  scalar full reservation by default, a block-table :class:`~repro.serve.
  paged.PagedKv` when a :class:`~repro.serve.paged.PagedKvSpec` is given).
  Step costs come from any object with the
  :class:`~repro.core.sweep.CostGrid` interface: ``max_batch``,
  ``step_time(batch, resident_tokens)``, ``prefill_time(prompt_tokens)``.
* :func:`simulate` — the single-instance discrete-event loop (heap of
  arrival/step-completion events). ``repro.serve.fleet`` layers N instances
  behind a router on the same :class:`Instance` mechanics.
* :func:`_reference_sim` — closed-form single-request oracle the event loop
  is tested against, matching the codebase's engine/oracle pattern.

Scheduling model (one engine iteration):

* at a step boundary the instance first resolves page pressure (paged KV
  with ``oversubscription > 1`` may evict the least-recently-admitted
  running request back to the FRONT of the waiting queue — its KV is
  recomputed at re-admission), then admits waiting requests FIFO while the
  batch has a slot and the allocator accepts the request's committed
  footprint (full ``prompt + output`` reservation by default; peak *pages*
  against an oversubscribable commit budget when paged);
* the iteration interleaves prefill and decode: its duration is the decode
  step cost at the (batch, resident-KV) grid cell plus the prefill cost of
  every prompt chunk consumed this step (whole prompts at admission by
  default; bounded by ``SchedPolicy.prefill_chunk`` when chunked);
* every running request that is past its prompt emits one token per
  iteration; the first token of a request is produced by the iteration
  that consumed its last prompt chunk (TTFT = queue wait + prefill + one
  decode step).

Residency/scheduling policies live in ``repro.serve.paged`` — see its
docstring for the paged-KV model and the parity contract (``page_size=1``
with oversubscription disabled reproduces the reservation path
bit-for-bit).
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass, replace
from typing import Iterable, Sequence

import numpy as np

from repro.serve.paged import PagedKvSpec, SchedPolicy, make_allocator

NAN = float("nan")


@dataclass
class Request:
    """One serving request. ``output_tokens`` engine iterations complete it;
    the paper-style one-shot scenarios (an MLPerf inference sample) are the
    ``prompt_tokens=0, output_tokens=1`` special case."""

    rid: int
    t_arrival: float
    prompt_tokens: int = 0
    output_tokens: int = 1
    # -- filled in by the simulator -------------------------------------------
    t_admitted: float = NAN
    t_first_token: float = NAN
    t_done: float = NAN
    tokens_emitted: int = 0
    evictions: int = 0          # paged KV: times evicted (recompute count)

    def __post_init__(self):
        if self.output_tokens < 1:
            raise ValueError("output_tokens must be >= 1")
        if self.prompt_tokens < 0 or self.t_arrival < 0:
            raise ValueError("prompt_tokens/t_arrival must be >= 0")

    @property
    def kv_tokens(self) -> int:
        """Peak KV residency this request reserves at admission."""
        return self.prompt_tokens + self.output_tokens


# -- length distributions ------------------------------------------------------

@dataclass(frozen=True)
class LengthDist:
    """Token-length distribution: ``fixed`` (mean), ``uniform`` [low, high],
    or ``lognormal`` (mean, sigma of the underlying normal). Samples are
    clipped to >= ``floor``."""

    kind: str = "fixed"
    mean: float = 1.0
    low: int = 1
    high: int = 1
    sigma: float = 0.5
    floor: int = 0

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.kind == "fixed":
            out = np.full(n, int(round(self.mean)))
        elif self.kind == "uniform":
            out = rng.integers(self.low, self.high + 1, n)
        elif self.kind == "lognormal":
            mu = math.log(max(self.mean, 1e-9)) - 0.5 * self.sigma ** 2
            out = np.rint(rng.lognormal(mu, self.sigma, n)).astype(np.int64)
        else:
            raise ValueError(f"unknown length distribution {self.kind!r}")
        return np.maximum(out.astype(np.int64), self.floor)


ONE_SHOT_PROMPT = LengthDist("fixed", mean=0, floor=0)
ONE_SHOT_OUTPUT = LengthDist("fixed", mean=1, floor=1)


@dataclass(frozen=True)
class ArrivalSpec:
    """An open-loop arrival process: ``generate(seed)`` materializes a
    deterministic request list.

    ``burst_factor``/``burst_fraction``/``period_s`` modulate a Poisson
    process: within each period the first ``burst_fraction`` runs at
    ``burst_factor`` x the off-phase rate, with the off-phase rate chosen so
    the long-run mean stays ``rate``. ``profile`` generalizes that to any
    piecewise-constant shape: a tuple of relative rate multipliers spread
    evenly over ``period_s`` (normalized so the long-run mean stays
    ``rate``) — a recorded diurnal load curve, say. The default is a plain
    (homogeneous) Poisson process."""

    name: str
    rate: float                       # mean requests/s
    n_requests: int
    prompt: LengthDist = ONE_SHOT_PROMPT
    output: LengthDist = ONE_SHOT_OUTPUT
    burst_factor: float = 1.0
    burst_fraction: float = 0.0
    period_s: float = 0.0
    profile: tuple[float, ...] = ()   # piecewise-constant relative rates

    def __post_init__(self):
        if self.profile:
            prof = np.asarray(self.profile, dtype=float)
            if (prof < 0).any() or prof.max() <= 0:
                raise ValueError(
                    "profile multipliers must be >= 0 with at least one > 0")
            if self.period_s <= 0:
                raise ValueError("profile needs period_s > 0")

    def with_rate(self, rate: float) -> "ArrivalSpec":
        return replace(self, rate=float(rate))

    def _thin_keep(self, t: np.ndarray, peak: float) -> np.ndarray:
        """Instantaneous rate at time ``t`` as a fraction of ``peak``."""
        phase = np.mod(t, self.period_s) / self.period_s
        if self.profile:
            prof = np.asarray(self.profile, dtype=float)
            idx = np.minimum((phase * len(prof)).astype(np.int64),
                             len(prof) - 1)
            return (self.rate * prof[idx] / prof.mean()) / peak
        frac, bf = self.burst_fraction, self.burst_factor
        # off-phase rate keeping the long-run mean at self.rate
        r_off = self.rate / (frac * bf + (1.0 - frac))
        r_on = bf * r_off
        return np.where(phase < frac, r_on, r_off) / peak

    def _sample_arrays(self, seed: int) -> tuple[np.ndarray, np.ndarray,
                                                 np.ndarray]:
        rng = np.random.default_rng(seed)
        n = self.n_requests
        bursty = bool(self.profile) or (
            self.burst_fraction > 0 and self.burst_factor != 1.0
            and self.period_s > 0)
        if not bursty:
            times = np.cumsum(rng.exponential(1.0 / self.rate, n))
        else:
            # Thinning (Lewis-Shedler): draw at the peak rate, keep with
            # probability rate(t)/peak — exact for piecewise-constant rates.
            if self.profile:
                prof = np.asarray(self.profile, dtype=float)
                peak = self.rate * prof.max() / prof.mean()
            else:
                frac, bf = self.burst_fraction, self.burst_factor
                peak = bf * self.rate / (frac * bf + (1.0 - frac))
            times_l, t, kept = [], 0.0, 0
            while kept < n:
                block = max(n - kept, 64) * 2
                gaps = rng.exponential(1.0 / peak, block)
                cand = t + np.cumsum(gaps)
                keep = rng.random(block) < self._thin_keep(cand, peak)
                sel = cand[keep][: n - kept]
                times_l.append(sel)
                kept += len(sel)
                t = float(cand[-1])
            times = np.concatenate(times_l)
        prompts = self.prompt.sample(rng, n)
        outputs = self.output.sample(rng, n)
        return times, prompts, outputs

    def generate(self, seed: int = 0) -> list[Request]:
        times, prompts, outputs = self._sample_arrays(seed)
        return [Request(rid=i, t_arrival=float(times[i]),
                        prompt_tokens=int(prompts[i]),
                        output_tokens=int(outputs[i]))
                for i in range(self.n_requests)]

    def generate_batch(self, seed: int = 0) -> "RequestBatch":
        """Materialize the same request stream as :meth:`generate` (identical
        RNG draws) straight into struct-of-arrays form — no per-request
        Python objects, which is what lets planet-scale fleet runs price
        100k-request traces cheaply."""
        times, prompts, outputs = self._sample_arrays(seed)
        return RequestBatch.from_arrays(times, prompts, outputs)


def replay(times: Sequence[float], prompts: Sequence[int] | int = 0,
           outputs: Sequence[int] | int = 1) -> list[Request]:
    """Requests from an explicit arrival-time trace (replayed workload)."""
    n = len(times)
    p = [prompts] * n if isinstance(prompts, int) else list(prompts)
    o = [outputs] * n if isinstance(outputs, int) else list(outputs)
    order = np.argsort(np.asarray(times, dtype=float), kind="stable")
    return [Request(rid=int(i), t_arrival=float(times[i]),
                    prompt_tokens=int(p[i]), output_tokens=int(o[i]))
            for i in order]


# -- struct-of-arrays requests -------------------------------------------------

@dataclass
class RequestBatch:
    """A request stream as struct-of-arrays — rows are requests, sorted by
    ``(t_arrival, rid)`` exactly like :func:`fresh_requests` orders object
    lists. The batched fleet core (``repro.serve.fleetbatch``) reads the
    static columns and fills the timing columns in place; :meth:`fresh`
    hands out a pristine copy so one generated stream can drive every probe
    of a fleet-size scan arrival-identically."""

    rid: np.ndarray             # int64
    t_arrival: np.ndarray       # float64, ascending (rid tie-break)
    prompt_tokens: np.ndarray   # int64
    output_tokens: np.ndarray   # int64
    # -- filled in by the simulator -------------------------------------------
    t_admitted: np.ndarray = None
    t_first_token: np.ndarray = None
    t_done: np.ndarray = None
    tokens_emitted: np.ndarray = None
    evictions: np.ndarray = None

    def __post_init__(self):
        n = len(self.rid)
        if self.t_admitted is None:
            self.t_admitted = np.full(n, NAN)
        if self.t_first_token is None:
            self.t_first_token = np.full(n, NAN)
        if self.t_done is None:
            self.t_done = np.full(n, NAN)
        if self.tokens_emitted is None:
            self.tokens_emitted = np.zeros(n, dtype=np.int64)
        if self.evictions is None:
            self.evictions = np.zeros(n, dtype=np.int64)
        if np.any(self.output_tokens < 1):
            raise ValueError("output_tokens must be >= 1")
        if np.any(self.prompt_tokens < 0) or np.any(self.t_arrival < 0):
            raise ValueError("prompt_tokens/t_arrival must be >= 0")

    def __len__(self) -> int:
        return len(self.rid)

    @property
    def kv_tokens(self) -> np.ndarray:
        """Peak KV residency each request reserves at admission."""
        return self.prompt_tokens + self.output_tokens

    @classmethod
    def from_arrays(cls, times, prompts, outputs,
                    rids=None) -> "RequestBatch":
        t = np.asarray(times, dtype=np.float64)
        rid = np.arange(len(t), dtype=np.int64) if rids is None \
            else np.asarray(rids, dtype=np.int64)
        order = np.lexsort((rid, t))
        return cls(rid=rid[order], t_arrival=t[order],
                   prompt_tokens=np.asarray(prompts, np.int64)[order],
                   output_tokens=np.asarray(outputs, np.int64)[order])

    @classmethod
    def from_requests(cls, requests: Iterable[Request]) -> "RequestBatch":
        reqs = list(requests)
        return cls.from_arrays([r.t_arrival for r in reqs],
                               [r.prompt_tokens for r in reqs],
                               [r.output_tokens for r in reqs],
                               rids=[r.rid for r in reqs])

    @classmethod
    def from_completed(cls, reqs: Sequence[Request]) -> "RequestBatch":
        """SoA snapshot of an already-simulated request list. ``reqs`` must
        be arrival-sorted (:func:`fresh_requests` order), so columns line up
        positionally."""
        rb = cls.from_requests(reqs)
        rb.t_admitted = np.array([r.t_admitted for r in reqs])
        rb.t_first_token = np.array([r.t_first_token for r in reqs])
        rb.t_done = np.array([r.t_done for r in reqs])
        rb.tokens_emitted = np.array([r.tokens_emitted for r in reqs],
                                     dtype=np.int64)
        rb.evictions = np.array([r.evictions for r in reqs], dtype=np.int64)
        return rb

    def fresh(self) -> "RequestBatch":
        """Pristine copy (timing columns reset) — the SoA analogue of
        :func:`fresh_requests`."""
        return RequestBatch(rid=self.rid, t_arrival=self.t_arrival,
                            prompt_tokens=self.prompt_tokens,
                            output_tokens=self.output_tokens)

    def to_requests(self) -> list[Request]:
        """Materialize per-request objects (compat with the oracle API)."""
        out = []
        for i in range(len(self.rid)):
            r = Request(rid=int(self.rid[i]),
                        t_arrival=float(self.t_arrival[i]),
                        prompt_tokens=int(self.prompt_tokens[i]),
                        output_tokens=int(self.output_tokens[i]))
            r.t_admitted = float(self.t_admitted[i])
            r.t_first_token = float(self.t_first_token[i])
            r.t_done = float(self.t_done[i])
            r.tokens_emitted = int(self.tokens_emitted[i])
            r.evictions = int(self.evictions[i])
            out.append(r)
        return out


# -- instance mechanics --------------------------------------------------------

@dataclass(frozen=True)
class ObsConfig:
    """Observability level threaded through the serving engines.

    * level 0 (default): engines record the base 7-column :class:`StepLog`.
    * level 1: each step-log row carries one extra column,
      ``prefill_tokens`` — the prompt-chunk tokens the iteration consumed —
      which is what ``repro.obs.timeline`` needs to split instance lanes
      into prefill-heavy vs pure-decode spans.

    Every level produces bit-identical timing results (parity-asserted both
    ways in tests): the column is derived from values the schedulers already
    compute, never from extra work on the hot path.
    """

    level: int = 0

    def __post_init__(self):
        if self.level not in (0, 1):
            raise ValueError(f"ObsConfig.level must be 0 or 1, "
                             f"got {self.level!r}")

    @property
    def step_phases(self) -> bool:
        """Whether step logs carry the ``prefill_tokens`` column."""
        return self.level >= 1


def _obs_phases(obs: ObsConfig | None) -> bool:
    return obs is not None and obs.step_phases


@dataclass
class StepLog:
    """Per-iteration schedule record (numpy views over the run).

    ``kv_reserved`` is the committed KV footprint in token units (paged:
    committed pages x page_size); ``pages`` is the mapped-page demand of
    the iteration (0 under full reservation, which maps nothing).
    ``prefill_tokens`` (prompt-chunk tokens consumed by the iteration) is
    only recorded at ``ObsConfig(level=1)`` and is ``None`` otherwise."""

    t_start: np.ndarray
    t_end: np.ndarray
    batch: np.ndarray
    kv_reserved: np.ndarray
    queued: np.ndarray       # waiting-queue depth after admission
    admitted: np.ndarray
    pages: np.ndarray        # mapped KV pages during the iteration
    prefill_tokens: np.ndarray | None = None   # ObsConfig(level>=1) only

    @classmethod
    def from_rows(cls, rows: list[tuple]) -> "StepLog":
        if not rows:
            cols = np.empty((7, 0), dtype=float)
        else:
            # zip(*rows) transposes at C speed — much faster than
            # np.array() introspecting a list of tuples row by row
            cols = [np.asarray(c, dtype=float) for c in zip(*rows)]
        return cls(t_start=cols[0], t_end=cols[1],
                   batch=cols[2].astype(int), kv_reserved=cols[3],
                   queued=cols[4].astype(int), admitted=cols[5].astype(int),
                   pages=cols[6].astype(int),
                   prefill_tokens=(cols[7].astype(int) if len(cols) > 7
                                   else None))


class Instance:
    """One serving instance: FIFO admission into a continuous batch.

    The event loop (here or in ``repro.serve.fleet``) drives it with
    ``submit`` at arrival events and ``finish_step`` at step completions;
    ``start_step`` returns the completion time to schedule (or None when
    idle). ``load`` is what routers and the autoscaler observe.

    KV residency goes through a ``repro.serve.paged`` allocator: the
    default is the scalar full-reservation :class:`~repro.serve.paged.
    ReservedKv` (the pre-paging behavior, bit-for-bit); a
    :class:`~repro.serve.paged.PagedKvSpec` swaps in the block-table
    :class:`~repro.serve.paged.PagedKv`. A :class:`~repro.serve.paged.
    SchedPolicy` selects chunked-prefill / decode-priority scheduling on
    the same hook. Each iteration is planned at ``start_step`` as
    ``(request, prompt chunk consumed, emits-a-token)`` triples; the plan
    is replayed by ``finish_step`` so both phases agree on what the
    iteration did."""

    def __init__(self, cost, max_batch: int | None = None,
                 kv_capacity_tokens: float = float("inf"),
                 paged: PagedKvSpec | None = None,
                 sched: SchedPolicy | None = None,
                 obs: ObsConfig | None = None):
        self.cost = cost
        self.max_batch = int(max_batch if max_batch is not None
                             else cost.max_batch)
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.kv_capacity_tokens = float(kv_capacity_tokens)
        self.paged = paged
        self.sched = sched if sched is not None else SchedPolicy()
        self.alloc = make_allocator(self.kv_capacity_tokens, paged)
        self._obs_phases = _obs_phases(obs)
        self.waiting: deque[Request] = deque()
        self.running: list[Request] = []
        self.busy = False
        self._plan: list[tuple[Request, int, bool]] = []
        self._log_rows: list[tuple] = []

    @property
    def kv_reserved(self) -> float:
        """Committed KV footprint in token units (allocator-backed)."""
        return self.alloc.committed_tokens

    @property
    def load(self) -> int:
        return len(self.waiting) + len(self.running)

    @property
    def idle(self) -> bool:
        return not self.busy and self.load == 0

    def submit(self, req: Request) -> None:
        if not self.alloc.fits(req.kv_tokens):
            if self.paged is None:
                raise ValueError(
                    f"request {req.rid} needs {req.kv_tokens} KV tokens; "
                    f"instance capacity is {self.kv_capacity_tokens:.0f} — "
                    f"it can never be admitted")
            raise ValueError(
                f"request {req.rid} needs "
                f"{self.alloc.pages_for(req.kv_tokens)} KV pages; instance "
                f"capacity is {self.alloc.capacity_pages} — it can never "
                f"be admitted")
        self.waiting.append(req)

    def start_step(self, now: float) -> float | None:
        """Evict (paged, over-pressure) + admit + begin one iteration;
        returns its completion time, or None when there is nothing to
        run."""
        if self.busy:
            raise RuntimeError("instance already mid-step")
        paged = self.alloc.page_size is not None
        chunk_cap = self.sched.prefill_chunk
        # -- plan the iteration for the carried-over running batch ------------
        plan: list[tuple[Request, int, bool]] = []
        demands: list[int] = []
        demand = 0
        for r in self.running:
            rem = r._ctx - r._consumed
            chunk = 0 if rem <= 0 else \
                (rem if chunk_cap is None or chunk_cap >= rem else chunk_cap)
            emits = chunk >= rem
            plan.append((r, chunk, emits))
            if paged:
                d = self.alloc.pages_for(r._consumed + chunk + r._res_em)
                demands.append(d)
                demand += d
        # -- evict LRU (least-recently-admitted) under page pressure ----------
        if paged and demand > self.alloc.capacity_pages:
            victims: list[Request] = []
            while demand > self.alloc.capacity_pages:
                r, _, _ = plan.pop(0)
                self.running.pop(0)
                demand -= demands.pop(0)
                self.alloc.release(r.rid)
                r.evictions += 1
                victims.append(r)
            # back to the FRONT of the queue, mutual order preserved; their
            # KV (prompt + emitted so far) is recomputed at re-admission
            self.waiting.extendleft(reversed(victims))
        # -- FIFO admission ---------------------------------------------------
        admitted = 0
        mid_prefill = any(not emits for _, _, emits in plan)
        while self.waiting and len(self.running) < self.max_batch:
            if self.sched.decode_priority and self.running \
                    and (mid_prefill or admitted):
                break
            req = self.waiting[0]
            if not self.alloc.can_admit(req.kv_tokens):
                break  # FIFO: no skipping past the blocked head
            base = req.prompt_tokens + req.tokens_emitted
            chunk = base if chunk_cap is None or chunk_cap >= base \
                else chunk_cap
            emits = chunk >= base
            if paged:
                d = self.alloc.pages_for(chunk)
                if demand + d > self.alloc.capacity_pages:
                    break  # admission must never trigger eviction
                demands.append(d)
                demand += d
            self.waiting.popleft()
            if math.isnan(req.t_admitted):
                req.t_admitted = now
            req._ctx = base
            req._consumed = 0
            req._res_em = 0
            self.alloc.admit(req.rid, req.kv_tokens)
            self.running.append(req)
            plan.append((req, chunk, emits))
            admitted += 1
        if not self.running:
            return None
        # -- map pages + price the iteration ----------------------------------
        prefill = 0.0
        resident = 0
        ptoks = 0
        for idx, (r, chunk, _) in enumerate(plan):
            if paged:
                self.alloc.ensure(r.rid, demands[idx])
            else:
                resident += r._consumed + chunk + r._res_em
            if chunk:
                ptoks += chunk
                prefill += self.cost.prefill_time(chunk)
        if paged:
            # priced at page granularity: mapped pages x page_size tokens
            resident = demand * self.alloc.page_size
        dt = self.cost.step_time(len(self.running), resident) + prefill
        if not (dt > 0 and math.isfinite(dt)):
            raise ValueError(f"non-positive/non-finite step time {dt!r}")
        t_end = now + dt
        row = (now, t_end, len(self.running), self.alloc.committed_tokens,
               len(self.waiting), admitted, float(demand))
        self._log_rows.append(row + (ptoks,) if self._obs_phases else row)
        self._plan = plan
        self.busy = True
        return t_end

    def finish_step(self, now: float) -> list[Request]:
        """Replay the iteration's plan: advance prefill progress, emit one
        token per decoding request, complete + release finished ones.
        Returns the completions."""
        if not self.busy:
            raise RuntimeError("no step in flight")
        self.busy = False
        done: list[Request] = []
        still: list[Request] = []
        for r, chunk, emits in self._plan:
            r._consumed += chunk
            if emits:
                r.tokens_emitted += 1
                r._res_em += 1
                if r.tokens_emitted == 1:
                    r.t_first_token = now
                if r.tokens_emitted >= r.output_tokens:
                    r.t_done = now
                    self.alloc.release(r.rid, r.kv_tokens)
                    done.append(r)
                    continue
            still.append(r)
        self.running = still
        self._plan = []
        return done

    def step_log(self) -> StepLog:
        return StepLog.from_rows(self._log_rows)


# -- metrics / SLO -------------------------------------------------------------

@dataclass(frozen=True)
class Slo:
    """A latency SLO: the ``percentile`` of each finite target must be met.
    Per-request, TPOT is ignored for single-token requests (no inter-token
    gap exists)."""

    ttft_s: float = float("inf")
    tpot_s: float = float("inf")
    e2e_s: float = float("inf")
    percentile: float = 99.0

    def met(self, m: "SimMetrics") -> bool:
        if len(m.ttft) == 0:
            return True
        p = self.percentile
        # TPOT percentile over multi-token requests ONLY — single-token
        # requests have no inter-token gap (tpot recorded as 0.0) and would
        # dilute the percentile, under-sizing fleets on short-output
        # workloads (the ok_mask divergence fixed per ROADMAP direction 3).
        tpot = m.tpot[m.output_tokens > 1]
        tpot_ok = len(tpot) == 0 or np.percentile(tpot, p) <= self.tpot_s
        return (np.percentile(m.ttft, p) <= self.ttft_s
                and tpot_ok
                and np.percentile(m.e2e, p) <= self.e2e_s)

    def ok_mask(self, m: "SimMetrics") -> np.ndarray:
        multi = m.output_tokens > 1
        return ((m.ttft <= self.ttft_s)
                & (np.where(multi, m.tpot, 0.0) <= self.tpot_s)
                & (m.e2e <= self.e2e_s))


@dataclass
class SimMetrics:
    """Vectorized per-request timings for one simulation."""

    ttft: np.ndarray
    tpot: np.ndarray            # 0 for single-token requests
    e2e: np.ndarray
    output_tokens: np.ndarray
    t_first_arrival: float
    t_last_done: float
    evictions: np.ndarray = None   # per-request paged-KV recompute count

    def __post_init__(self):
        if self.evictions is None:
            self.evictions = np.zeros(len(self.ttft), dtype=np.int64)

    @classmethod
    def from_arrays(cls, t_arr, t_first, t_done, out,
                    evictions=None) -> "SimMetrics":
        """Metrics straight from timing columns (a :class:`RequestBatch`) —
        no per-request objects in the loop."""
        if len(t_arr) == 0:
            z = np.zeros(0)
            return cls(z, z, z, z.astype(int), 0.0, 0.0)
        t_arr, t_first, t_done, out = (np.asarray(t_arr, dtype=np.float64),
                                       np.asarray(t_first, dtype=np.float64),
                                       np.asarray(t_done, dtype=np.float64),
                                       np.asarray(out, dtype=np.float64))
        if np.isnan(t_done).any():
            raise ValueError("metrics over an incomplete simulation")
        gaps = np.maximum(out - 1, 1)
        return cls(
            ttft=t_first - t_arr,
            tpot=np.where(out > 1, (t_done - t_first) / gaps, 0.0),
            e2e=t_done - t_arr,
            output_tokens=out.astype(int),
            t_first_arrival=float(t_arr.min()),
            t_last_done=float(t_done.max()),
            evictions=(None if evictions is None
                       else np.asarray(evictions, dtype=np.int64)),
        )

    @classmethod
    def from_requests(cls, requests: Sequence[Request]) -> "SimMetrics":
        if not requests:
            z = np.zeros(0)
            return cls(z, z, z, z.astype(int), 0.0, 0.0)
        arr = np.array([(r.t_arrival, r.t_first_token, r.t_done,
                         r.output_tokens) for r in requests])
        t_arr, t_first, t_done, out = arr.T
        return cls.from_arrays(t_arr, t_first, t_done, out,
                               evictions=[r.evictions for r in requests])

    @classmethod
    def from_batch(cls, batch: "RequestBatch") -> "SimMetrics":
        return cls.from_arrays(batch.t_arrival, batch.t_first_token,
                               batch.t_done, batch.output_tokens,
                               evictions=batch.evictions)

    @property
    def makespan_s(self) -> float:
        return max(self.t_last_done - self.t_first_arrival, 1e-12)

    @property
    def total_evictions(self) -> int:
        """Paged-KV evictions (KV recomputes) across all requests."""
        return int(self.evictions.sum())

    @property
    def eviction_rate_rps(self) -> float:
        """Evictions per second of makespan."""
        return self.total_evictions / self.makespan_s

    @property
    def evicted_frac(self) -> float:
        """Fraction of requests evicted at least once."""
        if len(self.evictions) == 0:
            return 0.0
        return float((self.evictions > 0).mean())

    @property
    def throughput_rps(self) -> float:
        return len(self.ttft) / self.makespan_s

    @property
    def throughput_tokens(self) -> float:
        return float(self.output_tokens.sum()) / self.makespan_s

    def percentile(self, metric: str, p: float) -> float:
        xs = getattr(self, metric)
        return float(np.percentile(xs, p)) if len(xs) else 0.0

    def goodput_rps(self, slo: Slo) -> float:
        """SLO-constrained goodput: requests/s whose individual TTFT/TPOT/E2E
        all met the targets."""
        if len(self.ttft) == 0:
            return 0.0
        return float(slo.ok_mask(self).sum()) / self.makespan_s


@dataclass
class SimResult:
    requests: list[Request]
    metrics: SimMetrics
    step_log: StepLog

    def timeseries(self, window_s: float, *, slo: Slo | None = None):
        """Windowed :class:`repro.obs.series.MetricSeries` rollup."""
        from repro.obs.series import timeseries
        return timeseries(self, window_s, slo=slo)


# -- the single-instance event loop --------------------------------------------

_ARRIVAL, _STEP_DONE = 0, 1


def fresh_requests(requests: Iterable[Request]) -> list[Request]:
    """Pristine copies of a request list, arrival-sorted. Simulations fill
    timing state into their requests, so a shared list (a replayed trace
    scanned over several fleet sizes) must be re-materialized per run —
    without this, run 2 would see run 1's tokens as already emitted."""
    return sorted((replace(r, t_admitted=NAN, t_first_token=NAN, t_done=NAN,
                           tokens_emitted=0, evictions=0) for r in requests),
                  key=lambda r: (r.t_arrival, r.rid))


def simulate(requests: Iterable[Request], cost, *,
             max_batch: int | None = None,
             kv_capacity_tokens: float = float("inf"),
             paged: PagedKvSpec | None = None,
             sched: SchedPolicy | None = None,
             obs: ObsConfig | None = None) -> SimResult:
    """Run one instance over an open-loop arrival stream to completion.

    A heap-ordered discrete-event loop: arrival events enqueue into the
    instance; step-completion events emit tokens and immediately start the
    next iteration while work remains. Deterministic given the request list
    (which is copied, so one list can drive many runs). ``paged``/``sched``
    select the KV residency and scheduling policies (see
    ``repro.serve.paged``); the defaults preserve the full-reservation
    behavior exactly.
    """
    reqs = fresh_requests(requests)
    inst = Instance(cost, max_batch=max_batch,
                    kv_capacity_tokens=kv_capacity_tokens,
                    paged=paged, sched=sched, obs=obs)
    events: list[tuple[float, int, int]] = []  # (time, seq, kind)
    seq = 0
    for r in reqs:
        heapq.heappush(events, (r.t_arrival, seq, _ARRIVAL))
        seq += 1
    next_arrival = 0  # index into reqs, in heap-push order
    clock = 0.0
    while events:
        t, _, kind = heapq.heappop(events)
        assert t >= clock, "simulation clock went backwards"
        clock = t
        # Drain EVERY event at this timestamp before starting an iteration:
        # simultaneous arrivals must all be admissible into the same batch
        # (saturation at arrival-rate -> inf fills whole batches).
        while True:
            if kind == _ARRIVAL:
                inst.submit(reqs[next_arrival])
                next_arrival += 1
            else:
                inst.finish_step(t)
            if not (events and events[0][0] == t):
                break
            _, _, kind = heapq.heappop(events)
        if not inst.busy:
            t_end = inst.start_step(t)
            if t_end is not None:
                heapq.heappush(events, (t_end, seq, _STEP_DONE))
                seq += 1
    assert not inst.waiting and not inst.running, "requests left in system"
    return SimResult(requests=reqs,
                     metrics=SimMetrics.from_requests(reqs),
                     step_log=inst.step_log())


def _reference_sim(req: Request, cost) -> tuple[float, float]:
    """Closed-form (t_first_token, t_done) for ONE request on an idle
    instance — the oracle the event loop must reproduce exactly.

    The request is admitted at arrival; iteration k (0-based) runs at batch 1
    with ``prompt + k`` resident tokens; the first iteration also pays the
    prefill."""
    t = req.t_arrival + cost.prefill_time(req.prompt_tokens)
    t_first = NAN
    for k in range(req.output_tokens):
        t += cost.step_time(1, req.prompt_tokens + k)
        if k == 0:
            t_first = t
    return t_first, t
