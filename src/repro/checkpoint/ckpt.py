"""Atomic, async, resharding-on-restore checkpointing (no orbax).

Layout:
    <dir>/step_000123/
        manifest.msgpack      # tree structure, shapes, dtypes, meta
        arrays.npz            # flattened leaves (addressable shards gathered)
    <dir>/LATEST              # atomic pointer, written last

Guarantees:
* atomic commit — LATEST is renamed into place only after a full write, so a
  crash mid-write never corrupts the restore path;
* async — ``save_async`` snapshots device arrays to host then writes on a
  background thread (training continues);
* elastic restore — arrays are loaded by *name* and resharded onto whatever
  mesh/sharding the restorer provides, so a job can resume on a different
  topology (the elasticity contract in ``repro.ft``).
"""
from __future__ import annotations

import os
import shutil
import threading
import time

import jax
import msgpack
import numpy as np

_DTYPES_SAFE = {"bfloat16"}


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    flat = _flatten(tree)
    host = {}
    meta = {}
    for name, arr in flat.items():
        np_arr = np.asarray(jax.device_get(arr))
        if np_arr.dtype.name in _DTYPES_SAFE:
            meta[name] = {"dtype": np_arr.dtype.name}
            np_arr = np_arr.view(np.uint16)
        host[name] = np_arr
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:09d}_{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb({
            "step": step,
            "time": time.time(),
            "meta": meta,
            "extra": extra or {},
            "names": list(host),
        }))
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k.replace("/", "__"): v for k, v in host.items()})
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest_tmp = os.path.join(ckpt_dir, ".LATEST_tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


class AsyncCheckpointer:
    """Snapshot-on-call, write-in-background. One in-flight save at a time
    (a second save waits — backpressure instead of unbounded host memory)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save_async(self, step: int, tree, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def _run():
            try:
                save(self.dir, step, host_tree, extra)
                self._gc()
            except Exception as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, step: int | None = None, shardings=None,
            template=None):
    """Load a checkpoint; reshard onto ``shardings`` (tree matching the saved
    structure) if given. Returns (step, tree, extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(d, "arrays.npz"))
    flat = {}
    for name in manifest["names"]:
        arr = data[name.replace("/", "__")]
        if manifest["meta"].get(name, {}).get("dtype") == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        flat[name] = arr
    tree = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        flat_out = {}
        for name, arr in flat.items():
            sh = flat_sh.get(name)
            flat_out[name] = jax.device_put(arr, sh) if sh is not None else arr
        tree = _unflatten(flat_out)
    return manifest["step"], tree, manifest.get("extra", {})
