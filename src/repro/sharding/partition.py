"""Logical-axis -> mesh-axis resolution (DP/FSDP/TP/EP/SP).

Parameters carry logical axis names (see ``models.base.P``); this module
maps them onto the production mesh:

    experts  -> "model"   (expert parallelism for MoE)
    heads / kv_heads / ff / vocab -> "model"  (megatron-style TP)
    embed    -> "data"    (FSDP weight sharding over the data axis)
    layers / lora / None  -> replicated

Divisibility-aware: a logical axis whose dimension does not divide the mesh
axis (e.g. 4 KV heads over model=16, or an odd vocab) silently degrades to
replication for that axis — the standard fallback (KV-head replication under
GQA-TP) — so every architecture maps onto the fixed production mesh without
per-arch special cases. When multiple logical axes in one tensor want the
same mesh axis, the first (leftmost priority order below) wins.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# Priority-ordered: earlier entries claim their mesh axis first within a tensor.
LOGICAL_RULES: list[tuple[str, tuple[str, ...]]] = [
    ("experts", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("ff", ("model",)),
    ("vocab", ("model",)),
    ("embed", ("data",)),       # FSDP: weights gathered just-in-time
    ("expert_cap", ("data",)),
    ("layers", ()),
    ("lora", ()),
]
_RULES = dict(LOGICAL_RULES)
_PRIORITY = {name: i for i, (name, _) in enumerate(LOGICAL_RULES)}


def resolve_spec(shape: tuple[int, ...], axes: tuple[str | None, ...],
                 mesh: Mesh, fsdp: bool = True) -> PartitionSpec:
    """Build a PartitionSpec for one tensor, enforcing divisibility and
    one-mesh-axis-per-tensor-dim / one-dim-per-mesh-axis."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    taken: set[str] = set()
    entries: list[str | None] = [None] * len(axes)
    # Resolve in priority order so e.g. "experts" claims "model" before "ff".
    order = sorted(range(len(axes)),
                   key=lambda i: _PRIORITY.get(axes[i] or "", 99))
    for i in order:
        name = axes[i]
        if name is None or name not in _RULES:
            continue
        if not fsdp and name == "embed":
            continue
        for mesh_axis in _RULES[name]:
            if mesh_axis not in mesh_sizes or mesh_axis in taken:
                continue
            if shape[i] % mesh_sizes[mesh_axis] != 0:
                continue  # degrade to replication (e.g. 4 kv-heads over 16)
            entries[i] = mesh_axis
            taken.add(mesh_axis)
            break
    return PartitionSpec(*entries)


def param_shardings(axes_tree, shapes_tree, mesh: Mesh, fsdp: bool = True):
    """Tree of NamedShardings parallel to the params tree."""

    def walk(ax, shp):
        if isinstance(ax, dict):
            return {k: walk(ax[k], shp[k]) for k in ax}
        return NamedSharding(mesh, resolve_spec(tuple(shp.shape), ax, mesh,
                                                fsdp=fsdp))

    return walk(axes_tree, shapes_tree)


def batch_spec(mesh: Mesh, seq_sharded: bool = False) -> PartitionSpec:
    """Token batches: batch over (pod, data); optionally sequence over data
    (context/sequence parallelism for the gb=1 long-context cells)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if seq_sharded:
        return PartitionSpec(None, ("data",))
    return PartitionSpec(batch_axes if len(batch_axes) > 1 else batch_axes[0])


def cache_shardings(cache_tree, mesh: Mesh, shard_seq: bool = False):
    """KV-cache shardings. Layout per family (leading dim = layers):

    attention k/v (L, B, S, KVH, D): batch over (pod,data); kv-heads over
    model when divisible, otherwise the SEQUENCE dim shards over model —
    decode is bandwidth-bound, so spreading the cache across chips buys
    aggregate HBM bandwidth (the COPA 'compose more memory system around
    fixed compute' move); XLA turns the softmax reductions into psums.
    MLA latent caches (no head dim) always sequence-shard. ``shard_seq``
    (gb=1 long-context) shards S over data instead. SSM conv/ssm states:
    batch over (pod,data)."""
    dims = {a: s for a, s in zip(mesh.axis_names, mesh.devices.shape)}
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    nbatch = max(_flat(dims, batch_axes), 1)

    def spec_for(name: str, arr) -> PartitionSpec:
        shape = arr.shape
        if name in ("k", "v", "shared_k", "shared_v", "cross_k", "cross_v",
                    "ckv", "krope"):
            seq_ok_model = shape[2] % dims.get("model", 1) == 0
            if shard_seq and shape[2] % dims.get("data", 1) == 0:
                return PartitionSpec(None, None, "data")
            if shape[1] % nbatch == 0 and shape[1] > 1:
                entries = [None, bspec, None]
                has_kvh = name not in ("ckv", "krope") and len(shape) >= 4
                if has_kvh and shape[3] % dims.get("model", 1) == 0:
                    entries += ["model"]
                elif seq_ok_model:
                    entries[2] = "model"   # context-parallel over TP axis
                return PartitionSpec(*entries)
            if seq_ok_model:
                return PartitionSpec(None, None, "model")
            return PartitionSpec()
        # ssm conv/ssm states: (L, B, ...)
        if shape[1] % nbatch == 0 and shape[1] > 1:
            return PartitionSpec(None, bspec)
        return PartitionSpec()

    return {k: NamedSharding(mesh, spec_for(k, v)) for k, v in cache_tree.items()}


def _flat(dims: dict, axes: tuple) -> int:
    n = 1
    for a in axes:
        n *= dims.get(a, 1)
    return n


def constrain(x, *entries):
    """Best-effort ``with_sharding_constraint`` inside model code.

    ``entries`` are mesh-axis names, tuples of names, or None per dim. Axes
    not present in the ambient mesh, or not dividing the dim, degrade to
    None; with no mesh at all (CPU unit tests) this is a no-op. This is how
    model internals (e.g. MoE grouped tensors, sequence-parallel residual
    boundaries) pin their layout without plumbing shardings everywhere."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.axis_names:
            return x
        sizes = dict(zip(am.axis_names, am.axis_sizes))
    except Exception:  # noqa: BLE001
        return x
    resolved = []
    for dim, e in zip(x.shape, entries):
        if e is None:
            resolved.append(None)
            continue
        axes = (e,) if isinstance(e, str) else tuple(e)
        axes = tuple(a for a in axes if a in sizes)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if not axes or dim % prod != 0:
            resolved.append(None)
        else:
            resolved.append(axes[0] if len(axes) == 1 else axes)
    if all(e is None for e in resolved):
        return x
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*resolved))


def sp_boundary(x):
    """Sequence-parallel residual boundary: (B, S, D) activations sharded
    batch->(pod,data), seq->model. Keeps the per-layer remat stash and all
    norm/elementwise work fully sharded (Megatron-SP, arXiv:2205.05198);
    the SPMD partitioner inserts the all-gather at QKV/FFN entry and the
    reduce-scatter after the output projections."""
    return constrain(x, ("pod", "data"), "model", None)
