from repro.sharding.partition import (LOGICAL_RULES, batch_spec,
                                      cache_shardings, param_shardings,
                                      resolve_spec)

__all__ = [
    "LOGICAL_RULES", "batch_spec", "cache_shardings", "param_shardings",
    "resolve_spec",
]
