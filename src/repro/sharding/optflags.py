"""Beyond-paper optimization flags (the §Perf hillclimb knobs).

Enabled via ``REPRO_OPTS=gqa_expand_kv,serve_nofsdp,kv_int8`` so every
hillclimb change can be measured against the untouched baseline with the
same code tree.

* ``gqa_expand_kv`` — replicate KV heads to the full query-head count before
  flash attention. The grouped (kvh, g) reshape defeats SPMD propagation
  when kvh doesn't divide the model axis: XLA replicates the whole attention
  computation across TP shards (observed 16-33x dot-FLOP inflation at 32k
  prefill). Expanded KV keeps the head dim = n_heads, which shards cleanly.
* ``serve_nofsdp`` — serving weights TP-shard only (replicated over data):
  removes the per-step FSDP weight all-gather, which dominates the decode
  collective term with no optimizer state to justify it.
* ``kv_int8`` — int8 KV cache: halves the decode memory term (decode AI ~1).
* ``attn_gather_once`` — pin q/k/v to their attention layout (batch over
  data, heads over model, full sequence) BEFORE the flash block scans. With
  sequence-parallel residuals, leaving the reshard to SPMD propagation makes
  XLA re-gather the sequence inside every (q-block x kv-block) scan step —
  observed ~60x collective-byte inflation on dense train cells.
"""
from __future__ import annotations

import os

ENABLED = frozenset(
    x.strip() for x in (os.environ.get("REPRO_OPTS") or "").split(",") if x)


def opt(name: str) -> bool:
    return name in ENABLED
