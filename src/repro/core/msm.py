"""Software Memory-System Modules — the COPA idea, TPU-native.

The paper composes one reusable compute module (GPM) with domain-specialized
memory-system modules (MSM). On a TPU fleet the compute module is the model's
math graph; the composable memory system is *policy*: which attention
implementation, which remat policy, which optimizer-state dtype, how the KV
cache is laid out and sharded, which Pallas kernels filter HBM traffic.

``compose(domain, ...)`` returns the policy bundle for a workload domain the
same way a COPA SKU pairs a GPM with an MSM; ``recommend()`` derives the
domain from an (arch, shape) cell, and ``analyze()`` runs the paper's cache
model over the cell's trace to quantify how much traffic each policy filters
(the software analogue of Fig 4).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.hw import MB, TPU_V5E
from repro.core.sweep import analysis_for, suite_analysis_for
from repro.core.trace import Trace


@dataclass(frozen=True)
class MemoryPolicy:
    """One composed software-MSM: everything that shapes HBM traffic."""

    name: str
    attention_impl: str = "chunked"      # naive | chunked | pallas
    attention_block_q: int = 512
    attention_block_kv: int = 1024
    remat: str = "none"                  # none | dots | full
    optimizer_dtype: str = "float32"     # float32 | bfloat16 moments
    master_weights: bool = True
    kv_cache_dtype: str = "bfloat16"
    kv_shard_axis: str | None = None     # e.g. "data" for context-parallel decode
    fused_ffn: bool = False              # Pallas fused SwiGLU
    donate_state: bool = True
    grad_compression: str | None = None  # None | bf16 | int8_ef
    microbatches: int = 1                # gradient-accumulation depth
    serve_fsdp: bool = True              # False: replicate weights over data
                                         # (kills per-step weight all-gathers)
    # Buddy-Compression-style KV residency knob (arXiv 1903.02596): the KV
    # cache is stored compressed in DRAM, multiplying effective capacity by
    # ``kv_compression_ratio`` at the cost of a fractional bandwidth tax on
    # every KV byte moved (compress/decompress traffic over the link).
    kv_compression_ratio: float = 1.0    # >= 1; 1.0 = off
    kv_compression_bw_tax: float = 0.0   # extra fraction of KV bytes moved

    def __post_init__(self):
        if self.kv_compression_ratio < 1.0:
            raise ValueError("kv_compression_ratio must be >= 1")
        if self.kv_compression_bw_tax < 0.0:
            raise ValueError("kv_compression_bw_tax must be >= 0")

    def describe(self) -> str:
        bits = [
            f"attn={self.attention_impl}(q{self.attention_block_q}/kv{self.attention_block_kv})",
            f"remat={self.remat}",
            f"opt={self.optimizer_dtype}" + ("+master" if self.master_weights else ""),
            f"kv={self.kv_cache_dtype}" + (f"@{self.kv_shard_axis}" if self.kv_shard_axis else ""),
        ]
        if self.fused_ffn:
            bits.append("fused_ffn")
        if self.grad_compression:
            bits.append(f"gradcomp={self.grad_compression}")
        if self.kv_compression_ratio != 1.0:
            bits.append(f"kvcomp={self.kv_compression_ratio:g}x"
                        f"(+{self.kv_compression_bw_tax:.0%}bw)")
        return " ".join(bits)


# The domain-specialized SKUs — same model "GPM", different memory systems.
TRAIN_MSM = MemoryPolicy(
    name="msm_train",
    attention_impl="chunked",
    remat="full",          # per-block full remat: only block boundaries saved
    optimizer_dtype="float32",
    grad_compression=None,
    microbatches=4,
)
TRAIN_LARGE_MSM = replace(
    TRAIN_MSM,
    name="msm_train_large",
    remat="full",
    optimizer_dtype="bfloat16",
    master_weights=False,   # stochastic-rounding updates: 6 bytes/param total
    grad_compression="bf16",
    microbatches=16,
)
PREFILL_MSM = MemoryPolicy(
    name="msm_prefill",
    attention_impl="chunked",
    attention_block_q=1024,
    attention_block_kv=1024,
    remat="none",
    master_weights=False,
)
DECODE_MSM = MemoryPolicy(
    name="msm_decode",
    attention_impl="chunked",
    attention_block_kv=2048,
    remat="none",
    master_weights=False,
)
LONG_CONTEXT_MSM = replace(
    DECODE_MSM,
    name="msm_long_context",
    kv_shard_axis="data",     # context-parallel flash-decode
)

_BY_NAME = {
    p.name: p
    for p in (TRAIN_MSM, TRAIN_LARGE_MSM, PREFILL_MSM, DECODE_MSM, LONG_CONTEXT_MSM)
}


def compose(name: str, **overrides) -> MemoryPolicy:
    base = _BY_NAME[name]
    return replace(base, **overrides) if overrides else base


def recommend(shape_name: str, n_params: float) -> MemoryPolicy:
    """Pick the software-MSM for a workload cell, like choosing a COPA SKU."""
    from repro.sharding.optflags import opt

    def finish(p: MemoryPolicy) -> MemoryPolicy:
        if not shape_name.startswith("train"):
            if opt("serve_nofsdp"):
                p = replace(p, serve_fsdp=False)
            if opt("kv_int8"):
                p = replace(p, kv_cache_dtype="int8")
        return p

    if shape_name.startswith("train"):
        # Models too large for fp32 optimizer residency get the large-model MSM
        # (bf16 moments + full remat), exactly the capacity-driven
        # specialization argument of the paper.
        big = n_params * 14 > 0.70 * TPU_V5E.hbm_capacity * 256
        return finish(TRAIN_LARGE_MSM if big else TRAIN_MSM)
    if shape_name.startswith("prefill"):
        return finish(PREFILL_MSM)
    if shape_name.startswith("long"):
        return finish(LONG_CONTEXT_MSM)
    return finish(DECODE_MSM)


KV_BYTES_PER_ELEM = {"float32": 4, "bfloat16": 2, "float16": 2,
                     "fp8": 1, "int8": 1}

# Fraction of DRAM held back for activations / workspace on top of the
# resident weights when the reserve is derived from a model config.
_ACTIVATION_MARGIN = 0.05


def kv_reserve_frac(spec, model_config=None) -> float:
    """The DRAM fraction set aside for weights + activations.

    With a :class:`~repro.configs.base.ModelConfig` the reserve is the
    model's actual resident weight bytes (``n_params`` at the config's
    param dtype) plus a small activation margin; without one, the
    historical conservative 0.30 stands in. Raises when the weights alone
    leave no room for KV — that config can't serve on this MSM at all."""
    if model_config is None:
        return 0.30
    bytes_per_param = KV_BYTES_PER_ELEM.get(model_config.dtype, 2)
    frac = (model_config.n_params() * bytes_per_param / spec.dram_capacity
            + _ACTIVATION_MARGIN)
    if frac >= 1.0:
        raise ValueError(
            f"model {model_config.name} needs {frac:.0%} of DRAM for "
            f"weights + activations — no capacity left for KV")
    return frac


def kv_token_capacity(spec, policy: MemoryPolicy, elems_per_token: int,
                      reserve_frac: float | None = None, *,
                      model_config=None) -> int:
    """Resident KV tokens one serving instance can hold — the admission
    bound of the request-level simulator (``repro.serve.sim``).

    Usable DRAM (capacity minus the reserve set aside for weights and
    activations — derived via :func:`kv_reserve_frac` when ``reserve_frac``
    is None) over the per-token KV bytes; the element width comes from the
    policy's ``kv_cache_dtype``, so an int8-KV MSM holds 2x the tokens of a
    bf16 one, and a COPA MSM with ``dram_capacity_scale`` > 1 holds
    proportionally more — capacity-driven specialization at the serving
    layer. The policy's ``kv_compression_ratio`` multiplies the effective
    capacity (Buddy-Compression residency; the bandwidth tax is priced by
    the serving cost grids, not here)."""
    if reserve_frac is None:
        reserve_frac = kv_reserve_frac(spec, model_config)
    if not 0.0 <= reserve_frac < 1.0:
        raise ValueError("reserve_frac must be in [0, 1)")
    if elems_per_token < 1:
        raise ValueError("elems_per_token must be >= 1")
    per_token = elems_per_token * KV_BYTES_PER_ELEM[policy.kv_cache_dtype]
    usable = (1.0 - reserve_frac) * spec.dram_capacity \
        * policy.kv_compression_ratio
    return int(usable // per_token)


def kv_page_capacity(spec, policy: MemoryPolicy, elems_per_token: int,
                     page_size: int, reserve_frac: float | None = None, *,
                     model_config=None) -> int:
    """:func:`kv_token_capacity` in block-table pages: the physical page
    pool one instance's ``PagedKv`` allocator manages (its oversubscribable
    commit budget is this times the spec's oversubscription factor)."""
    if page_size < 1:
        raise ValueError("page_size must be >= 1")
    return kv_token_capacity(spec, policy, elems_per_token, reserve_frac,
                             model_config=model_config) // page_size


@dataclass
class TrafficAnalysis:
    """Fig-4-style sweep for a cell: traffic filtered per on-chip capacity."""

    trace_name: str
    baseline_traffic: float
    sweep: dict[float, float]

    def reduction_at(self, capacity: float) -> float:
        return self.baseline_traffic / max(self.sweep[capacity], 1.0)


DEFAULT_CAPACITIES_MB = (60, 120, 240, 480, 960, 1920, 3840)


def analyze(trace: Trace, capacities_mb: tuple[int, ...] = DEFAULT_CAPACITIES_MB) -> TrafficAnalysis:
    caps = [c * MB for c in capacities_mb]
    sweep = analysis_for(trace).dram_traffic(caps)
    return TrafficAnalysis(
        trace_name=trace.name,
        baseline_traffic=sweep[caps[0]],
        sweep=sweep,
    )


def analyze_suite(
    traces: list[Trace],
    capacities_mb: tuple[int, ...] = DEFAULT_CAPACITIES_MB,
) -> list[TrafficAnalysis]:
    """Suite-level :func:`analyze`: one padded
    :class:`~repro.core.sweep.SuiteAnalysis` pass prices the Fig-4 sweep
    for every cell at once (bit-identical per trace to :func:`analyze` —
    the per-trace caches are shared, so mixing the two stays consistent)."""
    caps = [c * MB for c in capacities_mb]
    mat = suite_analysis_for(list(traces)).dram_traffic(caps)
    return [
        TrafficAnalysis(
            trace_name=t.name,
            baseline_traffic=float(row[0]),
            sweep={c: float(v) for c, v in zip(caps, row)},
        )
        for t, row in zip(traces, mat)
    ]
