"""Tensor-granular access-trace IR.

The paper evaluates one *end-to-end iteration* of each workload through a
trace-driven memory-hierarchy simulator, explicitly to capture inter-kernel
reuse (§IV-A). We reproduce that with a deterministic, analytic trace: a
sequence of :class:`Op` records, each reading/writing named logical tensors.

Granularity: one Op ≈ one GPU kernel (a GEMM, a conv, a fused elementwise
group). DL traffic streams over large tensors, so tensor-level touches (with
fractional residency inside the cache model) are the natural unit — the
cache model in ``cachesim.py`` is calibrated against an exact block-level LRU
in the tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Sequence

BYTES = {"fp32": 4, "tf32": 4, "fp16": 2, "bf16": 2, "int8": 1, "fp8": 1}


@dataclass(frozen=True)
class Op:
    """One kernel launch: FLOPs plus the tensors it touches.

    ``reads``/``writes`` are tuples of ``(tensor_name, nbytes)``. A tensor
    that is accumulated in place (e.g. a weight-gradient buffer) appears in
    both. ``parallelism`` is the number of concurrent scalar lanes the kernel
    can fill; the perf model turns it into an SM-occupancy factor.
    """

    name: str
    flops: float
    reads: tuple[tuple[str, int], ...] = ()
    writes: tuple[tuple[str, int], ...] = ()
    precision: str = "fp16"
    parallelism: float = float("inf")

    @property
    def read_bytes(self) -> int:
        return sum(b for _, b in self.reads)

    @property
    def write_bytes(self) -> int:
        return sum(b for _, b in self.writes)

    @property
    def touch_bytes(self) -> int:
        return self.read_bytes + self.write_bytes


@dataclass
class Trace:
    """One end-to-end iteration of a workload."""

    name: str
    ops: list[Op] = field(default_factory=list)
    # Metadata for reporting; not used by the simulator itself.
    batch_size: int = 0
    kind: str = "training"  # "training" | "inference"

    # -- builders -------------------------------------------------------------
    def emit(
        self,
        name: str,
        flops: float,
        reads: Sequence[tuple[str, int]] = (),
        writes: Sequence[tuple[str, int]] = (),
        precision: str = "fp16",
        parallelism: float | None = None,
    ) -> Op:
        if parallelism is None:
            # Default: one lane per output element (elementwise-ish kernels);
            # matmul/conv builders pass an explicit tile-level parallelism.
            elems = sum(b for _, b in writes) / max(BYTES.get(precision, 2), 1)
            parallelism = max(elems, 1.0)
        op = Op(
            name=name,
            flops=float(flops),
            reads=tuple((t, int(b)) for t, b in reads if b > 0),
            writes=tuple((t, int(b)) for t, b in writes if b > 0),
            precision=precision,
            parallelism=float(parallelism),
        )
        self.ops.append(op)
        return op

    # -- aggregate properties --------------------------------------------------
    @property
    def total_flops(self) -> float:
        return sum(op.flops for op in self.ops)

    @property
    def total_touch_bytes(self) -> int:
        return sum(op.touch_bytes for op in self.ops)

    def footprint_bytes(self) -> int:
        """Unique bytes across all tensors (upper bound, no buffer reuse)."""
        seen: dict[str, int] = {}
        for op in self.ops:
            for t, b in op.reads + op.writes:
                seen[t] = max(seen.get(t, 0), b)
        return sum(seen.values())

    def peak_live_bytes(self) -> int:
        """Allocator-peak proxy: a tensor is live from its first to its last
        touch; persistent tensors (weights, optimizer state — anything both
        read and written, or read before written) are live throughout. This
        matches how the paper reports per-GPU 'memory footprint' (Table III).
        """
        first: dict[str, int] = {}
        last: dict[str, int] = {}
        size: dict[str, int] = {}
        persistent: set[str] = set()
        written: set[str] = set()
        for i, op in enumerate(self.ops):
            for t, b in op.reads:
                first.setdefault(t, i)
                last[t] = i
                size[t] = max(size.get(t, 0), b)
                if t not in written:
                    persistent.add(t)  # read before ever written: lives across iters
            for t, b in op.writes:
                first.setdefault(t, i)
                last[t] = i
                size[t] = max(size.get(t, 0), b)
                written.add(t)
        n = len(self.ops)
        delta = [0] * (n + 1)
        base = 0
        for t, s in size.items():
            if t in persistent:
                base += s
            else:
                delta[first[t]] += s
                delta[last[t] + 1] -= s
        peak, cur = 0, 0
        for i in range(n):
            cur += delta[i]
            peak = max(peak, cur)
        return base + peak

    def touches(self) -> Iterable[tuple[int, str, int, bool]]:
        """Flatten to (op_index, tensor, nbytes, is_write), reads first."""
        for i, op in enumerate(self.ops):
            for t, b in op.reads:
                yield i, t, b, False
            for t, b in op.writes:
                yield i, t, b, True

    def scaled(self, name: str, flop_scale: float, byte_scale: float) -> "Trace":
        """Uniformly scaled copy (used for projection sensitivity tests)."""
        out = Trace(name=name, batch_size=self.batch_size, kind=self.kind)
        for op in self.ops:
            out.ops.append(
                Op(
                    name=op.name,
                    flops=op.flops * flop_scale,
                    reads=tuple((t, int(b * byte_scale)) for t, b in op.reads),
                    writes=tuple((t, int(b * byte_scale)) for t, b in op.writes),
                    precision=op.precision,
                    parallelism=op.parallelism,
                )
            )
        return out


def gemm_parallelism(m: int, n: int) -> float:
    """Concurrency exposed by an (m,n) output GEMM tiled 128x128 per CTA.

    Each 128x128 output tile occupies one CTA of ~256 threads on the modeled
    machine; the returned number is in scalar-lane units comparable to
    ``GpuSpec.concurrency``.
    """
    tiles = math.ceil(m / 128) * math.ceil(n / 128)
    return float(tiles * 256)
