"""Tensor-granular access-trace IR.

The paper evaluates one *end-to-end iteration* of each workload through a
trace-driven memory-hierarchy simulator, explicitly to capture inter-kernel
reuse (§IV-A). We reproduce that with a deterministic, analytic trace: a
sequence of :class:`Op` records, each reading/writing named logical tensors.

Granularity: one Op ≈ one GPU kernel (a GEMM, a conv, a fused elementwise
group). DL traffic streams over large tensors, so tensor-level touches (with
fractional residency inside the cache model) are the natural unit — the
cache model in ``cachesim.py`` is calibrated against an exact block-level LRU
in the tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

BYTES = {"fp32": 4, "tf32": 4, "fp16": 2, "bf16": 2, "int8": 1, "fp8": 1}


@dataclass(frozen=True)
class TouchTable:
    """:meth:`Trace.touch_table`: the trace's touches as flat arrays.

    One slim Python pass builds the raw columns; every per-tensor statistic
    the cache model needs (first/last touch, max size, first-is-write) is
    derived vectorized. ``name_id`` interns tensor names in first-appearance
    order — the dense-id convention the flatten/recycling passes in
    ``repro.core.cachesim`` build on.
    """

    op_idx: np.ndarray        # (n,) int32 op index per touch
    name_id: np.ndarray       # (n,) int64 first-appearance interned name id
    sizes: np.ndarray         # (n,) float64 touch bytes
    is_write: np.ndarray      # (n,) bool
    names: list[str]          # id -> tensor name (first-appearance order)
    stream_flag: np.ndarray   # (K,) bool: name starts with "in."
    first: np.ndarray         # (K,) int64 first touch position
    last: np.ndarray          # (K,) int64 last touch position
    max_size: np.ndarray      # (K,) float64 max touch bytes of the tensor
    first_is_write: np.ndarray  # (K,) bool
    has_buf_names: bool       # any real tensor named like a recycled buffer

    @property
    def n_touches(self) -> int:
        return len(self.op_idx)

    @property
    def n_names(self) -> int:
        return len(self.names)


@dataclass(frozen=True)
class Op:
    """One kernel launch: FLOPs plus the tensors it touches.

    ``reads``/``writes`` are tuples of ``(tensor_name, nbytes)``. A tensor
    that is accumulated in place (e.g. a weight-gradient buffer) appears in
    both. ``parallelism`` is the number of concurrent scalar lanes the kernel
    can fill; the perf model turns it into an SM-occupancy factor.
    """

    name: str
    flops: float
    reads: tuple[tuple[str, int], ...] = ()
    writes: tuple[tuple[str, int], ...] = ()
    precision: str = "fp16"
    parallelism: float = float("inf")

    @property
    def read_bytes(self) -> int:
        return sum(b for _, b in self.reads)

    @property
    def write_bytes(self) -> int:
        return sum(b for _, b in self.writes)

    @property
    def touch_bytes(self) -> int:
        return self.read_bytes + self.write_bytes


@dataclass
class Trace:
    """One end-to-end iteration of a workload."""

    name: str
    ops: list[Op] = field(default_factory=list)
    # Metadata for reporting; not used by the simulator itself.
    batch_size: int = 0
    kind: str = "training"  # "training" | "inference"

    # -- builders -------------------------------------------------------------
    def emit(
        self,
        name: str,
        flops: float,
        reads: Sequence[tuple[str, int]] = (),
        writes: Sequence[tuple[str, int]] = (),
        precision: str = "fp16",
        parallelism: float | None = None,
    ) -> Op:
        if parallelism is None:
            # Default: one lane per output element (elementwise-ish kernels);
            # matmul/conv builders pass an explicit tile-level parallelism.
            elems = sum(b for _, b in writes) / max(BYTES.get(precision, 2), 1)
            parallelism = max(elems, 1.0)
        op = Op(
            name=name,
            flops=float(flops),
            reads=tuple((t, int(b)) for t, b in reads if b > 0),
            writes=tuple((t, int(b)) for t, b in writes if b > 0),
            precision=precision,
            parallelism=float(parallelism),
        )
        self.ops.append(op)
        return op

    # -- aggregate properties --------------------------------------------------
    @property
    def total_flops(self) -> float:
        return sum(op.flops for op in self.ops)

    @property
    def total_touch_bytes(self) -> int:
        return sum(op.touch_bytes for op in self.ops)

    def footprint_bytes(self) -> int:
        """Unique bytes across all tensors (upper bound, no buffer reuse)."""
        seen: dict[str, int] = {}
        for op in self.ops:
            for t, b in op.reads + op.writes:
                seen[t] = max(seen.get(t, 0), b)
        return sum(seen.values())

    def peak_live_bytes(self) -> int:
        """Allocator-peak proxy: a tensor is live from its first to its last
        touch; persistent tensors (weights, optimizer state — anything both
        read and written, or read before written) are live throughout. This
        matches how the paper reports per-GPU 'memory footprint' (Table III).
        """
        first: dict[str, int] = {}
        last: dict[str, int] = {}
        size: dict[str, int] = {}
        persistent: set[str] = set()
        written: set[str] = set()
        for i, op in enumerate(self.ops):
            for t, b in op.reads:
                first.setdefault(t, i)
                last[t] = i
                size[t] = max(size.get(t, 0), b)
                if t not in written:
                    persistent.add(t)  # read before ever written: lives across iters
            for t, b in op.writes:
                first.setdefault(t, i)
                last[t] = i
                size[t] = max(size.get(t, 0), b)
                written.add(t)
        n = len(self.ops)
        delta = [0] * (n + 1)
        base = 0
        for t, s in size.items():
            if t in persistent:
                base += s
            else:
                delta[first[t]] += s
                delta[last[t] + 1] -= s
        peak, cur = 0, 0
        for i in range(n):
            cur += delta[i]
            peak = max(peak, cur)
        return base + peak

    def touches(self) -> Iterable[tuple[int, str, int, bool]]:
        """Flatten to (op_index, tensor, nbytes, is_write), reads first."""
        for i, op in enumerate(self.ops):
            for t, b in op.reads:
                yield i, t, b, False
            for t, b in op.writes:
                yield i, t, b, True

    def touch_table(self) -> TouchTable:
        """Flat touch arrays + per-tensor stats, cached on the trace.

        Same touch order as :meth:`touches` (reads before writes per op).
        Keyed by op count like the analysis caches: a trace that grows via
        :meth:`emit` gets a fresh table; in-place edits of existing ops are
        on the caller.
        """
        cached = self.__dict__.get("_touch_table")
        if cached is not None and cached[0] == len(self.ops):
            return cached[1]
        rw = [op.reads + op.writes for op in self.ops]
        counts = np.fromiter((len(x) for x in rw), dtype=np.int64,
                             count=len(rw))
        n = int(counts.sum())
        intern: dict[str, int] = {}
        name_id = np.fromiter(
            (intern.setdefault(t, len(intern)) for x in rw for t, _ in x),
            dtype=np.int64, count=n)
        sizes = np.fromiter((b for x in rw for _, b in x),
                            dtype=np.float64, count=n)
        op_idx = np.repeat(np.arange(len(rw), dtype=np.int32), counts)
        n_reads = np.fromiter((len(op.reads) for op in self.ops),
                              dtype=np.int64, count=len(rw))
        op_start = np.cumsum(counts) - counts
        pos = np.arange(n, dtype=np.int64)
        is_write = pos - np.repeat(op_start, counts) >= np.repeat(n_reads,
                                                                  counts)
        K = len(intern)
        if n:
            # name_id is first-appearance interned, so np.unique's sorted
            # uniques are exactly 0..K-1 and return_index gives first touches.
            first = np.unique(name_id, return_index=True)[1]
            last = (n - 1) - np.unique(name_id[::-1], return_index=True)[1]
        else:
            first = np.zeros(0, dtype=np.int64)
            last = np.zeros(0, dtype=np.int64)
        max_size = np.zeros(K)
        np.maximum.at(max_size, name_id, sizes)
        table = TouchTable(
            op_idx=op_idx,
            name_id=name_id,
            sizes=sizes,
            is_write=is_write,
            names=list(intern),
            stream_flag=np.fromiter((t.startswith("in.") for t in intern),
                                    dtype=bool, count=K),
            first=first,
            last=last,
            max_size=max_size,
            first_is_write=is_write[first] if n else np.zeros(0, dtype=bool),
            has_buf_names=any(t.startswith("__buf") for t in intern),
        )
        self.__dict__["_touch_table"] = (len(self.ops), table)
        return table

    def scaled(self, name: str, flop_scale: float, byte_scale: float) -> "Trace":
        """Uniformly scaled copy (used for projection sensitivity tests)."""
        out = Trace(name=name, batch_size=self.batch_size, kind=self.kind)
        for op in self.ops:
            out.ops.append(
                Op(
                    name=op.name,
                    flops=op.flops * flop_scale,
                    reads=tuple((t, int(b * byte_scale)) for t, b in op.reads),
                    writes=tuple((t, int(b * byte_scale)) for t, b in op.writes),
                    precision=op.precision,
                    parallelism=op.parallelism,
                )
            )
        return out


def gemm_parallelism(m: int, n: int) -> float:
    """Concurrency exposed by an (m,n) output GEMM tiled 128x128 per CTA.

    Each 128x128 output tile occupies one CTA of ~256 threads on the modeled
    machine; the returned number is in scalar-lane units comparable to
    ``GpuSpec.concurrency``.
    """
    tiles = math.ceil(m / 128) * math.ceil(n / 128)
    return float(tiles * 256)
