"""LRU stack-distance machinery (Mattson et al. 1970).

Two implementations:

* :func:`reuse_distances` — tensor-granular, bytes-weighted Mattson using a
  Fenwick tree: for every touch it returns the number of *unique other bytes*
  touched since the previous touch of the same tensor. O(T log T) for a
  trace of T touches. This feeds the fractional-residency cache model in
  ``cachesim.py``.

* :class:`BlockLRU` — an exact block-granular LRU simulator (slow, small
  traces only). Used by the property tests to validate the fractional model.
"""
from __future__ import annotations

import numpy as np

INF = float("inf")


class Fenwick:
    """Fenwick tree over float weights."""

    def __init__(self, n: int):
        self.n = n
        self.tree = np.zeros(n + 1, dtype=np.float64)

    def add(self, i: int, delta: float) -> None:
        i += 1
        while i <= self.n:
            self.tree[i] += delta
            i += i & (-i)

    def prefix(self, i: int) -> float:
        """Sum over [0, i] inclusive."""
        i += 1
        s = 0.0
        while i > 0:
            s += self.tree[i]
            i -= i & (-i)
        return s

    def range(self, lo: int, hi: int) -> float:
        """Sum over [lo, hi] inclusive; 0 when empty."""
        if lo > hi:
            return 0.0
        return self.prefix(hi) - (self.prefix(lo - 1) if lo > 0 else 0.0)


def _mattson_pass(tensor_ids: np.ndarray, sizes: np.ndarray) -> np.ndarray:
    """dist[t] = unique other bytes touched strictly between the previous
    touch of tensor_ids[t] and t; +inf for first touches."""
    n = len(tensor_ids)
    fen = Fenwick(n)
    pos: dict[int, int] = {}
    dist = np.full(n, INF)
    for t in range(n):
        x = int(tensor_ids[t])
        s = float(sizes[t])
        p = pos.get(x)
        if p is not None:
            dist[t] = fen.range(p + 1, t - 1)
            fen.add(p, -s)
        fen.add(t, s)
        pos[x] = t
    return dist


def reuse_distances(
    tensor_ids: np.ndarray,
    sizes: np.ndarray,
    cyclic: bool = True,
) -> np.ndarray:
    """Bytes-weighted unique-reuse distance per touch.

    ``tensor_ids[t]`` identifies the tensor touched at step t; ``sizes[t]``
    its size in bytes. First touches are cold (+inf) unless ``cyclic``: then
    the trace is treated as a steady-state loop (the paper simulates one
    end-to-end iteration of a workload that runs for thousands of
    iterations), implemented by doubling the trace and reading distances off
    the second copy.
    """
    tensor_ids = np.asarray(tensor_ids, dtype=np.int64)
    sizes = np.asarray(sizes, dtype=np.float64)
    n = len(tensor_ids)
    if n == 0:
        return np.zeros(0)
    if not cyclic:
        return _mattson_pass(tensor_ids, sizes)
    ids2 = np.concatenate([tensor_ids, tensor_ids])
    sz2 = np.concatenate([sizes, sizes])
    return _mattson_pass(ids2, sz2)[n:]


class BlockLRU:
    """Exact fully-associative LRU over fixed-size blocks (validation only).

    Write-back, write-allocate-without-fill for full-block writes (DL stores
    stream whole tensors, so a written block needs no fill). ``fill_bytes``
    counts fetches from the next level, ``writeback_bytes`` dirty evictions.
    """

    def __init__(self, capacity_bytes: int, block_bytes: int = 1 << 20):
        from collections import OrderedDict

        self.block = block_bytes
        self.ways = max(int(capacity_bytes // block_bytes), 1)
        self.lru: "OrderedDict[tuple[int, int], bool]" = OrderedDict()
        self.fill_bytes = 0
        self.writeback_bytes = 0

    def touch_tensor(self, tensor_id: int, nbytes: int, is_write: bool) -> None:
        nblocks = max(1, -(-int(nbytes) // self.block))
        for b in range(nblocks):
            self._access((tensor_id, b), is_write)

    def _access(self, key: tuple[int, int], is_write: bool) -> None:
        if key in self.lru:
            dirty = self.lru.pop(key)
            self.lru[key] = dirty or is_write
            return
        if not is_write:
            self.fill_bytes += self.block
        self.lru[key] = is_write
        if len(self.lru) > self.ways:
            _, dirty = self.lru.popitem(last=False)
            if dirty:
                self.writeback_bytes += self.block
