"""LRU stack-distance machinery (Mattson et al. 1970).

Four implementations:

* :func:`_mattson_pass` — vectorized NumPy Mattson: for every touch it
  returns the number of *unique other bytes* touched since the previous
  touch of the same tensor. The per-touch distance decomposes into a prefix
  sum minus a weighted dominance correction, computed with argsort/
  searchsorted merge counting in O(T log^2 T) with no Python-level
  per-touch loop. This feeds the fractional-residency cache model in
  ``cachesim.py`` and the batched sweep engine in ``sweep.py``.

* :func:`_mattson_pass_batch` — the suite-level batch variant: one call
  covers a whole ``(n_traces, max_len)`` padded batch of touch streams
  (``cachesim.StreamBatch``). Every scan (prefix sums, merge counting)
  runs along ``axis=1`` so each row is computed with exactly the sequence
  of float operations :func:`_mattson_pass` performs on that stream alone
  — rows are bit-identical to per-trace calls, which is what lets the
  sweep engine batch a full scenario registry without perturbing results.

* :func:`_reference_mattson_pass` — the original per-touch Fenwick-tree
  pass, O(T log T) but Python-loop bound. Retained as the parity oracle for
  the vectorized kernels (``tests/test_sweep.py``) and for the before/after
  timing in ``benchmarks/bench_core.py``.

* :class:`BlockLRU` — an exact block-granular LRU simulator (slow, small
  traces only). Used by the property tests to validate the fractional model.
"""
from __future__ import annotations

import numpy as np

INF = float("inf")


class Fenwick:
    """Fenwick tree over float weights."""

    def __init__(self, n: int):
        self.n = n
        self.tree = np.zeros(n + 1, dtype=np.float64)

    def add(self, i: int, delta: float) -> None:
        i += 1
        while i <= self.n:
            self.tree[i] += delta
            i += i & (-i)

    def prefix(self, i: int) -> float:
        """Sum over [0, i] inclusive."""
        i += 1
        s = 0.0
        while i > 0:
            s += self.tree[i]
            i -= i & (-i)
        return s

    def range(self, lo: int, hi: int) -> float:
        """Sum over [lo, hi] inclusive; 0 when empty."""
        if lo > hi:
            return 0.0
        return self.prefix(hi) - (self.prefix(lo - 1) if lo > 0 else 0.0)


def _reference_mattson_pass(tensor_ids: np.ndarray, sizes: np.ndarray) -> np.ndarray:
    """dist[t] = unique other bytes touched strictly between the previous
    touch of tensor_ids[t] and t; +inf for first touches.

    Per-touch Fenwick-tree oracle; see :func:`_mattson_pass` for the
    vectorized production path."""
    n = len(tensor_ids)
    fen = Fenwick(n)
    pos: dict[int, int] = {}
    dist = np.full(n, INF)
    for t in range(n):
        x = int(tensor_ids[t])
        s = float(sizes[t])
        p = pos.get(x)
        if p is not None:
            dist[t] = fen.range(p + 1, t - 1)
            fen.add(p, -s)
        fen.add(t, s)
        pos[x] = t
    return dist


def _prev_occurrence(tensor_ids: np.ndarray) -> np.ndarray:
    """prev[t] = index of the previous touch of tensor_ids[t]; -1 for firsts."""
    n = len(tensor_ids)
    order = np.argsort(tensor_ids, kind="stable")  # grouped, time-ordered
    sorted_ids = tensor_ids[order]
    prev_sorted = np.full(n, -1, dtype=np.int64)
    if n > 1:
        same = sorted_ids[1:] == sorted_ids[:-1]
        prev_sorted[1:][same] = order[:-1][same]
    prev = np.empty(n, dtype=np.int64)
    prev[order] = prev_sorted
    return prev


def _weighted_larger_before(values: np.ndarray, weights: np.ndarray) -> np.ndarray:
    """out[t] = sum of weights[r] over r < t with values[r] > values[t].

    Weighted inversion counting via bottom-up merge: at each level, right
    half-blocks query the sorted left half-blocks with one global
    ``searchsorted`` (per-block composite keys keep the concatenation of
    sorted blocks globally sorted). O(n log^2 n), all NumPy.
    """
    n = len(values)
    out = np.zeros(n, dtype=np.float64)
    if n < 2:
        return out
    values = np.asarray(values, dtype=np.int64)
    base = int(values.max()) - int(values.min()) + 2
    vals = (values - int(values.min())).astype(np.int64)  # >= 0, < base - 1
    idx = np.arange(n, dtype=np.int64)
    m = 1
    while m < n:
        pair = idx // (2 * m)
        in_left = (idx // m) % 2 == 0
        left = idx[in_left]
        right = idx[~in_left]
        if len(right):
            # Sort left elements by (pair, value); composite keys make the
            # flat array globally sorted so one searchsorted serves all pairs.
            key_left = pair[left] * base + vals[left]
            ord_l = np.argsort(key_left, kind="stable")
            key_sorted = key_left[ord_l]
            w_sorted = weights[left][ord_l]
            cumw = np.concatenate([[0.0], np.cumsum(w_sorted)])
            q_pair = pair[right]
            # elements of my pair's left block with value <= mine:
            lo = np.searchsorted(key_sorted, q_pair * base + vals[right], side="right")
            # end of my pair's left block:
            hi = np.searchsorted(key_sorted, (q_pair + 1) * base, side="left")
            out[right] += cumw[hi] - cumw[lo]
        m *= 2
    return out


def _mattson_pass(tensor_ids: np.ndarray, sizes: np.ndarray) -> np.ndarray:
    """Vectorized Mattson pass, same contract as the reference.

    Decomposition: with p = prev-touch of the tensor touched at t,

        dist[t] = sum(sizes[p+1 : t])                          (all touches)
                - sum(sizes[r] for p < r < t with prev[r] > p)  (re-touches)

    i.e. every tensor in the window is counted once, at its *first* touch
    inside the window — exactly what the Fenwick reference computes (its
    tree holds each tensor's weight at its most recent touch position).
    The correction term is a weighted dominance count: prev[r] > prev[t]
    with r < t implies r > p automatically, so it reduces to weighted
    inversion counting over the prev[] sequence.
    """
    tensor_ids = np.asarray(tensor_ids, dtype=np.int64)
    sizes = np.asarray(sizes, dtype=np.float64)
    n = len(tensor_ids)
    dist = np.full(n, INF)
    if n == 0:
        return dist
    prev = _prev_occurrence(tensor_ids)
    has_prev = prev >= 0
    prefix = np.concatenate([[0.0], np.cumsum(sizes)])  # prefix[k] = sum sizes[:k]
    window = prefix[np.arange(n)] - prefix[np.clip(prev, 0, None) + 1]
    corr = _weighted_larger_before(prev, sizes)
    dist[has_prev] = window[has_prev] - corr[has_prev]
    return dist


#: Tensor-id padding sentinel for batched streams: larger than any dense id,
#: so pad slots group at the tail of every per-row stable sort.
PAD_ID = np.int64(1) << 62


def _prev_occurrence_batch(tensor_ids: np.ndarray) -> np.ndarray:
    """Row-wise :func:`_prev_occurrence`: ``prev[r, t]`` is the column of the
    previous touch of ``tensor_ids[r, t]`` within row ``r`` (-1 for firsts).
    Pad slots (``PAD_ID``) chain among themselves; callers mask them out."""
    n_rows, n = tensor_ids.shape
    order = np.argsort(tensor_ids, axis=1, kind="stable")
    sorted_ids = np.take_along_axis(tensor_ids, order, axis=1)
    prev_sorted = np.full((n_rows, n), -1, dtype=np.int64)
    if n > 1:
        same = sorted_ids[:, 1:] == sorted_ids[:, :-1]
        prev_sorted[:, 1:][same] = order[:, :-1][same]
    prev = np.empty((n_rows, n), dtype=np.int64)
    np.put_along_axis(prev, order, prev_sorted, axis=1)
    return prev


def _weighted_larger_before_batch(
    values: np.ndarray, weights: np.ndarray
) -> np.ndarray:
    """Row-wise :func:`_weighted_larger_before`: ``out[r, t]`` sums
    ``weights[r, q]`` over ``q < t`` with ``values[r, q] > values[r, t]``.

    The merge tree is positional, so every row shares the same level/block
    structure; per-row ``argsort``/``cumsum`` along ``axis=1`` plus one
    row-offset ``searchsorted`` per level batch all rows through each merge
    level at once. Rows whose stream is shorter than the padded width see
    only weight-0 pad entries in their blocks, which add exact zeros to the
    prefix sums — each row's result is bit-identical to the 1D kernel on
    that row alone (asserted in tests).
    """
    n_rows, n = values.shape
    out = np.zeros((n_rows, n), dtype=np.float64)
    if n < 2 or n_rows == 0:
        return out
    values = np.asarray(values, dtype=np.int64)
    vmin = int(values.min())
    base = int(values.max()) - vmin + 2
    vals = (values - vmin).astype(np.int64)
    cols = np.arange(n, dtype=np.int64)
    rows = np.arange(n_rows, dtype=np.int64)[:, None]
    m = 1
    while m < n:
        pair = cols // (2 * m)
        in_left = (cols // m) % 2 == 0
        left = cols[in_left]
        right = cols[~in_left]
        if len(right):
            key_left = pair[left][None, :] * base + vals[:, left]
            ord_l = np.argsort(key_left, axis=1, kind="stable")
            key_sorted = np.take_along_axis(key_left, ord_l, axis=1)
            w_sorted = np.take_along_axis(weights[:, left], ord_l, axis=1)
            cumw = np.concatenate(
                [np.zeros((n_rows, 1)), np.cumsum(w_sorted, axis=1)], axis=1
            )
            q_pair = pair[right]
            # Per-row searchsorted: offset every row's (sorted) keys into a
            # disjoint band so one flat call serves the whole batch.
            row_base = (int(pair[-1]) + 2) * base
            flat_keys = (rows * row_base + key_sorted).ravel()
            q_lo = (rows * row_base + q_pair[None, :] * base + vals[:, right])
            q_hi = (rows * row_base + (q_pair + 1)[None, :] * base)
            lo = np.searchsorted(flat_keys, q_lo.ravel(), side="right") \
                .reshape(n_rows, -1) - rows * len(left)
            hi = np.searchsorted(flat_keys, q_hi.ravel(), side="left") \
                .reshape(n_rows, -1) - rows * len(left)
            out[:, right] += np.take_along_axis(cumw, hi, axis=1) \
                - np.take_along_axis(cumw, lo, axis=1)
        m *= 2
    return out


def _mattson_pass_batch(tensor_ids: np.ndarray, sizes: np.ndarray) -> np.ndarray:
    """Batched Mattson over a padded ``(n_traces, max_len)`` touch batch.

    Pad slots carry ``PAD_ID`` ids and zero sizes; their distances are
    meaningless (callers slice rows to their true lengths). Every real row
    prefix is computed with the same per-row operation sequence as
    :func:`_mattson_pass`, so results are bit-identical to calling the 1D
    kernel once per trace — zero-weight pads only ever append exact zeros
    to the row-local prefix sums.
    """
    tensor_ids = np.asarray(tensor_ids, dtype=np.int64)
    sizes = np.asarray(sizes, dtype=np.float64)
    n_rows, n = tensor_ids.shape
    dist = np.full((n_rows, n), INF)
    if n == 0 or n_rows == 0:
        return dist
    prev = _prev_occurrence_batch(tensor_ids)
    has_prev = prev >= 0
    prefix = np.concatenate(
        [np.zeros((n_rows, 1)), np.cumsum(sizes, axis=1)], axis=1
    )
    window = prefix[:, :n] - np.take_along_axis(prefix, np.clip(prev, 0, None) + 1, axis=1)
    corr = _weighted_larger_before_batch(prev, sizes)
    dist[has_prev] = window[has_prev] - corr[has_prev]
    return dist


def reuse_distances(
    tensor_ids: np.ndarray,
    sizes: np.ndarray,
    cyclic: bool = True,
) -> np.ndarray:
    """Bytes-weighted unique-reuse distance per touch.

    ``tensor_ids[t]`` identifies the tensor touched at step t; ``sizes[t]``
    its size in bytes. First touches are cold (+inf) unless ``cyclic``: then
    the trace is treated as a steady-state loop (the paper simulates one
    end-to-end iteration of a workload that runs for thousands of
    iterations), implemented by doubling the trace and reading distances off
    the second copy.
    """
    tensor_ids = np.asarray(tensor_ids, dtype=np.int64)
    sizes = np.asarray(sizes, dtype=np.float64)
    n = len(tensor_ids)
    if n == 0:
        return np.zeros(0)
    if not cyclic:
        return _mattson_pass(tensor_ids, sizes)
    ids2 = np.concatenate([tensor_ids, tensor_ids])
    sz2 = np.concatenate([sizes, sizes])
    return _mattson_pass(ids2, sz2)[n:]


class BlockLRU:
    """Exact fully-associative LRU over fixed-size blocks (validation only).

    Write-back, write-allocate-without-fill for full-block writes (DL stores
    stream whole tensors, so a written block needs no fill). ``fill_bytes``
    counts fetches from the next level, ``writeback_bytes`` dirty evictions.
    """

    def __init__(self, capacity_bytes: int, block_bytes: int = 1 << 20):
        from collections import OrderedDict

        self.block = block_bytes
        self.ways = max(int(capacity_bytes // block_bytes), 1)
        self.lru: "OrderedDict[tuple[int, int], bool]" = OrderedDict()
        self.fill_bytes = 0
        self.writeback_bytes = 0

    def touch_tensor(self, tensor_id: int, nbytes: int, is_write: bool) -> None:
        nblocks = max(1, -(-int(nbytes) // self.block))
        for b in range(nblocks):
            self._access((tensor_id, b), is_write)

    def _access(self, key: tuple[int, int], is_write: bool) -> None:
        if key in self.lru:
            dirty = self.lru.pop(key)
            self.lru[key] = dirty or is_write
            return
        if not is_write:
            self.fill_bytes += self.block
        self.lru[key] = is_write
        if len(self.lru) > self.ways:
            _, dirty = self.lru.popitem(last=False)
            if dirty:
                self.writeback_bytes += self.block
