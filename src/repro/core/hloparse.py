"""Extract collective-traffic and shape information from HLO text.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
bytes, so we parse the (stable-)HLO/XLA text for collective ops and sum their
operand sizes. Works on both ``lowered.as_text()`` (StableHLO) and
``compiled.as_text()`` (optimized HLO); the latter is preferred because SPMD
partitioning has already materialized the real collective schedule.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %all-gather.3 = bf16[16,1024,4096]{2,1,0} all-gather(%param.1), ...
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims_str: str) -> int:
    n = 1
    if dims_str.strip():
        for d in dims_str.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    """Bytes moved per collective kind (operand bytes, per device)."""

    bytes_by_kind: dict[str, float] = field(default_factory=lambda: defaultdict(float))
    count_by_kind: dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count_by_kind.values()))

    def as_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "by_kind": {k: float(v) for k, v in self.bytes_by_kind.items()},
            "counts": {k: int(v) for k, v in self.count_by_kind.items()},
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in an HLO module dump.

    Each matching instruction line looks like
    ``%name = <out-shape-or-tuple> <kind>(...operands...)``; the *output*
    shape(s) equal the data each device sends/receives for these collectives
    (all-gather output includes the gathered axis; all-reduce output equals
    input). We count output bytes, the standard convention for link-traffic
    accounting, and ignore `-start/-done` duplicate pairs by counting only
    `-start` when both forms are present on the same value name.
    """
    stats = CollectiveStats()
    seen_started: set[str] = set()
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        m = re.search(
            r"=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(-start|-done)?\(",
            s,
        )
        if not m:
            continue
        shapes_part, kind, phase = m.group(1), m.group(2), m.group(3) or ""
        name = s.split("=", 1)[0].strip()
        if phase == "-done":
            continue  # counted at -start
        if phase == "-start":
            seen_started.add(name)
        nbytes = 0
        for dm in _SHAPE_RE.finditer(shapes_part):
            nbytes += shape_bytes(dm.group(1), dm.group(2))
        stats.bytes_by_kind[kind] += nbytes
        stats.count_by_kind[kind] += 1
    return stats


def parse_stablehlo_collectives(text: str) -> CollectiveStats:
    """Same accounting for StableHLO (``lowered.as_text()``) dialect ops.

    StableHLO spells them ``stablehlo.all_reduce`` etc. with
    ``tensor<16x1024xbf16>`` result types.
    """
    stats = CollectiveStats()
    kinds = {
        "all_gather": "all-gather",
        "all_reduce": "all-reduce",
        "reduce_scatter": "reduce-scatter",
        "all_to_all": "all-to-all",
        "collective_permute": "collective-permute",
    }
    tensor_re = re.compile(r"tensor<([0-9x]*)x?(f64|f32|f16|bf16|i64|i32|i16|i8|i1)>")
    dt_map = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "i64": 8, "i32": 4, "i16": 2, "i8": 1, "i1": 1}
    for line in text.splitlines():
        for op, kind in kinds.items():
            if f"stablehlo.{op}" in line or f'"stablehlo.{op}"' in line:
                # result type is after '->' (or ':' for single-result ops)
                tail = line.split("->")[-1]
                nbytes = 0
                for tm in tensor_re.finditer(tail):
                    n = 1
                    dims = tm.group(1)
                    if dims:
                        for d in dims.split("x"):
                            if d:
                                n *= int(d)
                    nbytes += n * dt_map[tm.group(2)]
                stats.bytes_by_kind[kind] += nbytes
                stats.count_by_kind[kind] += 1
                break
    return stats


def count_hlo_ops(hlo_text: str, opname: str) -> int:
    """Count occurrences of an HLO op (e.g. 'fusion', 'dot', 'while')."""
    pat = re.compile(rf"=\s*[^=]*\b{re.escape(opname)}\(")
    return sum(1 for line in hlo_text.splitlines() if pat.search(line))
