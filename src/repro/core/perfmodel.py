"""Bottleneck execution-time model + the paper's Fig-2-style attribution.

Per kernel (trace Op):

    t_op = max(t_math, t_L2, t_UHB, t_DRAM) + t_launch

with an SM-occupancy factor applied to the GPM-internal rates (math, L2) —
a kernel that cannot fill the machine neither computes nor streams at full
rate, which is the paper's "SM underutilization" term. Off-die rates (UHB,
DRAM) saturate at much lower occupancy and are left unscaled.

Attribution follows the paper exactly: the cost of a component is the time
recovered by idealizing it, peeled in the paper's order:

    DRAM BW       = T(actual) - T(DRAM -> inf)
    Memory others = T(DRAM -> inf) - T(DRAM, L2, UHB -> inf)
    SM util       = T(all mem -> inf) - T(all mem -> inf, occupancy -> 1)
    Math          = the remainder (pure math at full occupancy)

The computation itself lives in :class:`repro.core.sweep.TraceAnalysis` —
one shared, capacity-batched implementation for this class, ``msm.analyze``
and the :class:`~repro.core.sweep.SweepEngine`. :class:`PerfModel` is the
single-trace facade kept for its established API.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import copa as copa_mod
from repro.core.cachesim import HierarchyTraffic, TouchStream
from repro.core.hw import GpuSpec
from repro.core.sweep import (  # noqa: F401
    LAUNCH_OVERHEAD_S,
    TraceAnalysis,
    bottleneck_of,
    geomean,
)
from repro.core.trace import Trace


@dataclass
class PerfResult:
    trace_name: str
    spec_name: str
    time_s: float
    per_op_s: np.ndarray
    segments: dict[str, float] = field(default_factory=dict)
    dram_bytes: float = 0.0
    l3_bytes: float = 0.0
    uhb_bytes: float = 0.0

    @property
    def bottleneck(self) -> str:
        return bottleneck_of(self.segments)


class PerfModel:
    """Single-trace facade over :class:`~repro.core.sweep.TraceAnalysis`.

    Capacity-batched traffic is cached inside the analysis, so sweeping many
    specs over one trace shares a single trace pass per new capacity set.
    """

    def __init__(self, trace: Trace, cyclic: bool = True,
                 analysis: TraceAnalysis | None = None):
        self.trace = trace
        self.cyclic = cyclic
        self.analysis = analysis if analysis is not None else TraceAnalysis(
            trace, cyclic=cyclic
        )
        self.stream: TouchStream = self.analysis.stream

    @classmethod
    def batch(cls, traces: list[Trace], cyclic: bool = True) -> list["PerfModel"]:
        """Suite-batched construction: one padded
        :class:`~repro.core.sweep.SuiteAnalysis` builds every trace's
        stream in a single batched Mattson pass and shares the suite
        traffic cache, so the returned models run from warm state. Each
        model is bit-identical to ``PerfModel(trace)`` built alone."""
        from repro.core.sweep import suite_analysis_for

        suite = suite_analysis_for(list(traces), cyclic=cyclic)
        return [cls(t, cyclic=cyclic, analysis=ta)
                for t, ta in zip(suite.traces, suite.analyses)]

    @property
    def flops(self) -> np.ndarray:
        return self.analysis.flops

    @property
    def parallelism(self) -> np.ndarray:
        return self.analysis.parallelism

    @property
    def is_tc(self) -> np.ndarray:
        return self.analysis.is_tc

    def traffic(self, spec: GpuSpec) -> HierarchyTraffic:
        return self.analysis.hierarchy(spec)

    # -- core time estimate ----------------------------------------------------
    def time(
        self,
        spec: GpuSpec,
        ideal_dram: bool = False,
        ideal_mem_other: bool = False,
        ideal_occupancy: bool = False,
        per_op: bool = False,
    ):
        return self.analysis.time(
            spec,
            ideal_dram=ideal_dram,
            ideal_mem_other=ideal_mem_other,
            ideal_occupancy=ideal_occupancy,
            per_op=per_op,
        )

    # -- paper-style outputs ---------------------------------------------------
    def run(self, spec: GpuSpec) -> PerfResult:
        t_act, segments = self.analysis.attribution(spec)
        tr = self.analysis.hierarchy(spec)
        return PerfResult(
            trace_name=self.trace.name,
            spec_name=spec.name,
            time_s=t_act,
            per_op_s=self.analysis.time(spec, per_op=True),
            segments=segments,
            dram_bytes=tr.dram.total,
            l3_bytes=tr.l3_bytes,
            uhb_bytes=tr.post_l2.total if tr.has_l3 else 0.0,
        )

    def energy(self, spec: GpuSpec) -> copa_mod.EnergyReport:
        return self.analysis.energy(spec)


def speedup(model: PerfModel, spec: GpuSpec, baseline: GpuSpec) -> float:
    return model.time(baseline) / model.time(spec)
