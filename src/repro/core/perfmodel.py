"""Bottleneck execution-time model + the paper's Fig-2-style attribution.

Per kernel (trace Op):

    t_op = max(t_math, t_L2, t_UHB, t_DRAM) + t_launch

with an SM-occupancy factor applied to the GPM-internal rates (math, L2) —
a kernel that cannot fill the machine neither computes nor streams at full
rate, which is the paper's "SM underutilization" term. Off-die rates (UHB,
DRAM) saturate at much lower occupancy and are left unscaled.

Attribution follows the paper exactly: the cost of a component is the time
recovered by idealizing it, peeled in the paper's order:

    DRAM BW       = T(actual) - T(DRAM -> inf)
    Memory others = T(DRAM -> inf) - T(DRAM, L2, UHB -> inf)
    SM util       = T(all mem -> inf) - T(all mem -> inf, occupancy -> 1)
    Math          = the remainder (pure math at full occupancy)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import copa as copa_mod
from repro.core.cachesim import (
    HierarchyTraffic,
    TouchStream,
    build_stream,
    simulate_hierarchy,
)
from repro.core.hw import GpuSpec
from repro.core.trace import Trace

LAUNCH_OVERHEAD_S = 2.0e-6  # per-kernel launch/dependency latency

# Math throughput class per trace precision.
_TENSOR_CORE = {"fp16", "bf16", "int8", "fp8"}


@dataclass
class PerfResult:
    trace_name: str
    spec_name: str
    time_s: float
    per_op_s: np.ndarray
    segments: dict[str, float] = field(default_factory=dict)
    dram_bytes: float = 0.0
    l3_bytes: float = 0.0
    uhb_bytes: float = 0.0

    @property
    def bottleneck(self) -> str:
        segs = {k: v for k, v in self.segments.items()}
        segs.pop("Math", None)
        return max(segs, key=segs.get) if segs else "Math"


class PerfModel:
    """Caches the capacity-independent trace analysis across spec sweeps."""

    def __init__(self, trace: Trace, cyclic: bool = True):
        self.trace = trace
        self.cyclic = cyclic
        self.stream: TouchStream = build_stream(trace, cyclic=cyclic)
        self._traffic_cache: dict[tuple[int, int], HierarchyTraffic] = {}
        # Static per-op vectors.
        self.flops = np.array([op.flops for op in trace.ops])
        self.parallelism = np.array([op.parallelism for op in trace.ops])
        self.is_tc = np.array([op.precision in _TENSOR_CORE for op in trace.ops])

    def traffic(self, spec: GpuSpec) -> HierarchyTraffic:
        key = (int(spec.l2_capacity), int(spec.l3_capacity))
        if key not in self._traffic_cache:
            self._traffic_cache[key] = simulate_hierarchy(
                self.trace, spec, cyclic=self.cyclic, stream=self.stream
            )
        return self._traffic_cache[key]

    # -- core time estimate ----------------------------------------------------
    def time(
        self,
        spec: GpuSpec,
        ideal_dram: bool = False,
        ideal_mem_other: bool = False,
        ideal_occupancy: bool = False,
        per_op: bool = False,
    ):
        tr = self.traffic(spec)
        # Occupancy is sublinear in exposed parallelism: a kernel filling 10%
        # of the machine still extracts >10% of peak thanks to ILP, split-K
        # decompositions and cache effects (exponent calibrated against the
        # paper's Fig-2 small-batch attribution).
        occ = (
            np.ones_like(self.parallelism)
            if ideal_occupancy
            else np.minimum(1.0, self.parallelism / spec.concurrency) ** 0.55
        )
        f_tc = spec.fp16_tflops * 1e12
        f_fp32 = spec.fp32_tflops * 1e12
        fmath = np.where(self.is_tc, f_tc, f_fp32) * occ
        t_math = np.divide(self.flops, fmath, out=np.zeros_like(self.flops), where=fmath > 0)

        if ideal_mem_other:
            t_l2 = np.zeros(len(self.flops))
            t_uhb = np.zeros(len(self.flops))
        else:
            t_l2 = tr.l2_touch / (spec.l2_bandwidth * occ)
            if tr.has_l3 and spec.l3_bandwidth > 0:
                # UHB is per-direction (paper: 2xRD + 2xWR).
                t_uhb = np.maximum(
                    tr.post_l2.fill / spec.l3_bandwidth,
                    tr.post_l2.writeback / spec.l3_bandwidth,
                )
            else:
                t_uhb = np.zeros(len(self.flops))

        if ideal_dram:
            t_dram = np.zeros(len(self.flops))
        else:
            t_dram = (tr.dram.fill + tr.dram.writeback) / spec.dram_bandwidth

        overhead = 0.0 if ideal_occupancy else LAUNCH_OVERHEAD_S
        t_op = np.maximum.reduce([t_math, t_l2, t_uhb, t_dram]) + overhead
        if per_op:
            return t_op
        return float(t_op.sum())

    # -- paper-style outputs ---------------------------------------------------
    def run(self, spec: GpuSpec) -> PerfResult:
        t_act = self.time(spec)
        t_no_dram = self.time(spec, ideal_dram=True)
        t_no_mem = self.time(spec, ideal_dram=True, ideal_mem_other=True)
        t_math = self.time(
            spec, ideal_dram=True, ideal_mem_other=True, ideal_occupancy=True
        )
        tr = self.traffic(spec)
        return PerfResult(
            trace_name=self.trace.name,
            spec_name=spec.name,
            time_s=t_act,
            per_op_s=self.time(spec, per_op=True),
            segments={
                "Math": t_math,
                "SM util": max(t_no_mem - t_math, 0.0),
                "Memory others": max(t_no_dram - t_no_mem, 0.0),
                "DRAM BW": max(t_act - t_no_dram, 0.0),
            },
            dram_bytes=tr.dram.total,
            l3_bytes=tr.l3_bytes,
            uhb_bytes=tr.post_l2.total if tr.has_l3 else 0.0,
        )

    def energy(self, spec: GpuSpec) -> copa_mod.EnergyReport:
        tr = self.traffic(spec)
        return copa_mod.memory_energy(spec, tr.dram.total, tr.l3_bytes)


def speedup(model: PerfModel, spec: GpuSpec, baseline: GpuSpec) -> float:
    return model.time(baseline) / model.time(spec)


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.log(xs).mean())) if len(xs) else float("nan")
