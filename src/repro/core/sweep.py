"""Batched design-space sweep engine: trace -> cache hierarchy -> perf/energy.

The paper's entire evaluation (Figs 2, 4, 8-12) is one shape of computation:
every workload trace replayed against every memory configuration. This module
is the single substrate for that shape:

* :class:`TraceAnalysis` — everything capacity-independent about one trace
  (the :class:`~repro.core.cachesim.TouchStream`, per-op static vectors, the
  per-op L2 touch bytes) plus a capacity-keyed cache of
  :class:`~repro.core.cachesim.LevelTraffic`. Missing capacities are computed
  in ONE vectorized :func:`~repro.core.cachesim.traffic_below` call; since
  capacity columns are independent there, batching is bit-identical to
  evaluating capacities one at a time. The bottleneck time model and the
  paper's Fig-2 attribution live here; ``repro.core.perfmodel.PerfModel`` is
  now a thin facade over this class.

* :class:`SweepEngine` — evaluates a grid of (trace x config x extra LLC
  capacity) in one pass per trace: the union of every capacity any config
  needs is prefetched in a single batched traffic call, then each config is
  costed from the shared cache. Configs may be
  :class:`~repro.core.copa.CopaConfig` (``build()`` is called for you) or
  raw :class:`~repro.core.hw.GpuSpec` (for bandwidth/capacity sensitivity
  sweeps like Figs 8-10). Traces may be :class:`~repro.core.trace.Trace`
  objects or scenario names resolved through
  ``repro.workloads.registry``.

* :class:`SweepResult` / :class:`SweepGrid` — structured rows (time,
  per-segment attribution, DRAM/L3/UHB bytes, energy, speedup vs baseline)
  with geomean helpers over arbitrary trace subsets.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Iterable, Sequence, Union

import numpy as np

from repro.core import copa as copa_mod
from repro.core.cachesim import (
    HierarchyTraffic,
    LevelTraffic,
    TouchStream,
    build_stream,
    traffic_below,
)
from repro.core.copa import CopaConfig, EnergyReport
from repro.core.hw import GpuSpec
from repro.core.trace import Trace

LAUNCH_OVERHEAD_S = 2.0e-6  # per-kernel launch/dependency latency

# Math throughput class per trace precision.
_TENSOR_CORE = {"fp16", "bf16", "int8", "fp8"}

ConfigLike = Union[CopaConfig, GpuSpec]
TraceLike = Union[Trace, str]


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.log(xs).mean())) if len(xs) else float("nan")


def bottleneck_of(segments: dict[str, float]) -> str:
    """Dominant non-Math attribution segment ('Math' when nothing else)."""
    segs = {k: v for k, v in segments.items() if k != "Math"}
    return max(segs, key=segs.get) if segs else "Math"


def _as_spec(config: ConfigLike) -> GpuSpec:
    return config.build() if isinstance(config, CopaConfig) else config


def _config_name(config: ConfigLike) -> str:
    return config.name


def _resolve_trace(t: TraceLike) -> Trace:
    if isinstance(t, str):
        from repro.workloads import registry  # lazy: workloads sit above core

        return registry.scenario(t)
    return t


class TraceAnalysis:
    """Capacity-independent analysis of one trace + shared traffic cache."""

    def __init__(self, trace: Trace, cyclic: bool = True,
                 stream: TouchStream | None = None):
        self.trace = trace
        self.cyclic = cyclic
        self.stream = stream if stream is not None else build_stream(trace, cyclic=cyclic)
        self.flops = np.array([op.flops for op in trace.ops])
        self.parallelism = np.array([op.parallelism for op in trace.ops])
        self.is_tc = np.array([op.precision in _TENSOR_CORE for op in trace.ops])
        self._levels: dict[float, LevelTraffic] = {}
        self._l2_touch: np.ndarray | None = None
        self._occ: dict[int, np.ndarray] = {}  # spec concurrency -> occupancy

    # -- traffic ---------------------------------------------------------------
    @property
    def l2_touch(self) -> np.ndarray:
        """Bytes served by the L2 per op (all touches, steady-state copy)."""
        if self._l2_touch is None:
            l2 = np.zeros(self.stream.n_ops)
            half = self.stream.second_half
            np.add.at(l2, self.stream.op_idx[half:], self.stream.sizes[half:])
            self._l2_touch = l2
        return self._l2_touch

    def prefetch(self, capacities: Iterable[float]) -> None:
        """Compute all not-yet-cached capacities in one batched trace pass."""
        missing = sorted({float(c) for c in capacities} - self._levels.keys())
        if missing:
            for cap, lt in zip(missing, traffic_below(self.stream, missing)):
                self._levels[cap] = lt

    def level_traffic(self, capacity: float) -> LevelTraffic:
        self.prefetch([capacity])
        return self._levels[float(capacity)]

    def dram_traffic(self, capacities: Sequence[float]) -> dict[float, float]:
        """Total DRAM traffic vs LLC capacity (paper Fig 4)."""
        self.prefetch(capacities)
        return {c: self._levels[float(c)].total for c in capacities}

    @staticmethod
    def capacities_for(spec: GpuSpec) -> list[float]:
        """LRU pool capacities the §III-C hierarchy needs for one spec."""
        if spec.l3_capacity:
            return [float(spec.l2_capacity),
                    float(spec.l2_capacity + spec.l3_capacity)]
        return [float(spec.l2_capacity)]

    def hierarchy(self, spec: GpuSpec) -> HierarchyTraffic:
        if spec.l3_capacity:
            post_l2 = self.level_traffic(spec.l2_capacity)
            dram = self.level_traffic(spec.l2_capacity + spec.l3_capacity)
            return HierarchyTraffic(self.l2_touch, post_l2, dram, has_l3=True)
        post_l2 = self.level_traffic(spec.l2_capacity)
        return HierarchyTraffic(self.l2_touch, post_l2, post_l2, has_l3=False)

    # -- bottleneck time model (paper Fig-2 machinery) -------------------------
    def time(
        self,
        spec: GpuSpec,
        ideal_dram: bool = False,
        ideal_mem_other: bool = False,
        ideal_occupancy: bool = False,
        per_op: bool = False,
    ):
        tr = self.hierarchy(spec)
        # Occupancy is sublinear in exposed parallelism: a kernel filling 10%
        # of the machine still extracts >10% of peak thanks to ILP, split-K
        # decompositions and cache effects (exponent calibrated against the
        # paper's Fig-2 small-batch attribution).
        if ideal_occupancy:
            occ = np.ones_like(self.parallelism)
        else:
            occ = self._occ.get(spec.concurrency)
            if occ is None:
                occ = np.minimum(1.0, self.parallelism / spec.concurrency) ** 0.55
                self._occ[spec.concurrency] = occ
        f_tc = spec.fp16_tflops * 1e12
        f_fp32 = spec.fp32_tflops * 1e12
        fmath = np.where(self.is_tc, f_tc, f_fp32) * occ
        t_math = np.divide(self.flops, fmath, out=np.zeros_like(self.flops), where=fmath > 0)

        if ideal_mem_other:
            t_l2 = np.zeros(len(self.flops))
            t_uhb = np.zeros(len(self.flops))
        else:
            t_l2 = tr.l2_touch / (spec.l2_bandwidth * occ)
            if tr.has_l3 and spec.l3_bandwidth > 0:
                # UHB is per-direction (paper: 2xRD + 2xWR).
                t_uhb = np.maximum(
                    tr.post_l2.fill / spec.l3_bandwidth,
                    tr.post_l2.writeback / spec.l3_bandwidth,
                )
            else:
                t_uhb = np.zeros(len(self.flops))

        if ideal_dram:
            t_dram = np.zeros(len(self.flops))
        else:
            t_dram = (tr.dram.fill + tr.dram.writeback) / spec.dram_bandwidth

        overhead = 0.0 if ideal_occupancy else LAUNCH_OVERHEAD_S
        t_op = np.maximum.reduce([t_math, t_l2, t_uhb, t_dram]) + overhead
        if per_op:
            return t_op
        return float(t_op.sum())

    def attribution(self, spec: GpuSpec) -> tuple[float, dict[str, float]]:
        """Actual time + the paper's peel-order cost attribution."""
        t_act = self.time(spec)
        t_no_dram = self.time(spec, ideal_dram=True)
        t_no_mem = self.time(spec, ideal_dram=True, ideal_mem_other=True)
        t_math = self.time(
            spec, ideal_dram=True, ideal_mem_other=True, ideal_occupancy=True
        )
        return t_act, {
            "Math": t_math,
            "SM util": max(t_no_mem - t_math, 0.0),
            "Memory others": max(t_no_dram - t_no_mem, 0.0),
            "DRAM BW": max(t_act - t_no_dram, 0.0),
        }

    def energy(self, spec: GpuSpec) -> EnergyReport:
        tr = self.hierarchy(spec)
        return copa_mod.memory_energy(spec, tr.dram.total, tr.l3_bytes)


# Shared per-process analyses so benchmarks/examples/tests reuse streams.
# Bounded LRU: callers like dram_traffic_sweep may analyze an unbounded
# stream of ephemeral traces (property tests generate thousands), and each
# analysis pins O(touches x capacities) arrays — evict the oldest instead of
# leaking. The workload-registry traces are lru-cached module-side, so the
# hot set stays comfortably within the bound.
_ANALYSES: OrderedDict[tuple[int, bool], tuple[Trace, TraceAnalysis]] = OrderedDict()
_ANALYSES_MAX = 512


def analysis_for(trace: Trace, cyclic: bool = True) -> TraceAnalysis:
    """Process-wide TraceAnalysis cache (keyed by trace identity)."""
    key = (id(trace), cyclic)
    hit = _ANALYSES.get(key)
    if hit is None or hit[0] is not trace:
        _ANALYSES[key] = (trace, TraceAnalysis(trace, cyclic=cyclic))
        if len(_ANALYSES) > _ANALYSES_MAX:
            _ANALYSES.popitem(last=False)
    else:
        _ANALYSES.move_to_end(key)
    return _ANALYSES[key][1]


@dataclass(frozen=True)
class SweepResult:
    """One (trace, config) cell of the design-space grid."""

    trace: str
    kind: str                     # "training" | "inference" | "hpc" | ...
    config: str
    spec_name: str
    time_s: float
    baseline_time_s: float
    speedup: float                # baseline_time / time
    segments: dict[str, float]    # paper Fig-2 attribution
    dram_bytes: float
    l3_bytes: float
    uhb_bytes: float
    l2_bytes: float
    dram_joules: float
    l3_joules: float

    @property
    def total_joules(self) -> float:
        return self.dram_joules + self.l3_joules

    @property
    def bottleneck(self) -> str:
        return bottleneck_of(self.segments)


@dataclass
class SweepGrid:
    """Structured result of a SweepEngine run."""

    baseline: str
    rows: list[SweepResult] = field(default_factory=list)
    # trace name -> LLC capacity -> total traffic below that capacity
    llc_traffic: dict[str, dict[float, float]] = field(default_factory=dict)
    _index: dict[tuple[str, str], SweepResult] = field(default_factory=dict)

    def add(self, row: SweepResult) -> None:
        self.rows.append(row)
        self._index[(row.trace, row.config)] = row

    def result(self, trace: str, config: str) -> SweepResult:
        return self._index[(trace, config)]

    @property
    def configs(self) -> list[str]:
        seen: dict[str, None] = {}
        for r in self.rows:
            seen.setdefault(r.config)
        return list(seen)

    @property
    def traces(self) -> list[str]:
        seen: dict[str, None] = {}
        for r in self.rows:
            seen.setdefault(r.trace)
        return list(seen)

    def speedups(self, config: str, traces: Sequence[str] | None = None) -> list[float]:
        names = list(traces) if traces is not None else self.traces
        return [self._index[(t, config)].speedup for t in names]

    def geomean_speedup(self, config: str, traces: Sequence[str] | None = None) -> float:
        return geomean(self.speedups(config, traces))


class SweepEngine:
    """One batched pipeline over (traces x configs x extra LLC capacities).

    Per trace the engine builds (or reuses) a :class:`TraceAnalysis`,
    prefetches the union of every capacity any config touches in a single
    vectorized pass, then costs each config from the shared cache — the
    whole Table-V design space costs one trace walk instead of one per
    config.
    """

    def __init__(
        self,
        traces: Iterable[TraceLike],
        configs: Sequence[ConfigLike] | None = None,
        baseline: ConfigLike | None = None,
        extra_llc_capacities: Sequence[float] = (),
        cyclic: bool = True,
        share_analyses: bool = True,
    ):
        self.traces = [_resolve_trace(t) for t in traces]
        self.configs = list(configs if configs is not None else copa_mod.TABLE_V)
        self.baseline = baseline if baseline is not None else copa_mod.GPU_N_BASE
        self.extra_llc_capacities = [float(c) for c in extra_llc_capacities]
        self.cyclic = cyclic
        # share_analyses=False keeps this engine's analyses private — used by
        # cold-cache benchmarking; everything else should share the process
        # cache so figures/tests reuse streams and traffic.
        self._share = share_analyses
        self._private: dict[int, TraceAnalysis] = {}

    def analysis(self, trace: Trace) -> TraceAnalysis:
        if self._share:
            return analysis_for(trace, cyclic=self.cyclic)
        key = id(trace)
        if key not in self._private:
            self._private[key] = TraceAnalysis(trace, cyclic=self.cyclic)
        return self._private[key]

    def run(self) -> SweepGrid:
        base_spec = _as_spec(self.baseline)
        specs = [(_config_name(c), _as_spec(c)) for c in self.configs]
        grid = SweepGrid(baseline=_config_name(self.baseline))
        for trace in self.traces:
            ta = self.analysis(trace)
            caps: set[float] = set(self.extra_llc_capacities)
            for _, spec in specs:
                caps.update(TraceAnalysis.capacities_for(spec))
            caps.update(TraceAnalysis.capacities_for(base_spec))
            ta.prefetch(caps)

            t_base = ta.time(base_spec)
            for name, spec in specs:
                t_act, segments = ta.attribution(spec)
                tr = ta.hierarchy(spec)
                en = ta.energy(spec)
                grid.add(SweepResult(
                    trace=trace.name,
                    kind=trace.kind,
                    config=name,
                    spec_name=spec.name,
                    time_s=t_act,
                    baseline_time_s=t_base,
                    speedup=t_base / t_act,
                    segments=segments,
                    dram_bytes=tr.dram.total,
                    l3_bytes=tr.l3_bytes,
                    uhb_bytes=tr.post_l2.total if tr.has_l3 else 0.0,
                    l2_bytes=float(ta.l2_touch.sum()),
                    dram_joules=en.dram_joules,
                    l3_joules=en.l3_joules,
                ))
            if self.extra_llc_capacities:
                grid.llc_traffic[trace.name] = ta.dram_traffic(
                    self.extra_llc_capacities
                )
        return grid
