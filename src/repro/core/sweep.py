"""Batched design-space sweep engine: trace -> cache hierarchy -> perf/energy.

The paper's entire evaluation (Figs 2, 4, 8-12) is one shape of computation:
every workload trace replayed against every memory configuration. This module
is the single substrate for that shape:

* :class:`TraceAnalysis` — everything capacity-independent about one trace
  (the :class:`~repro.core.cachesim.TouchStream`, per-op static vectors, the
  per-op L2 touch bytes) plus a capacity-keyed cache of
  :class:`~repro.core.cachesim.LevelTraffic`. Missing capacities are computed
  in ONE vectorized :func:`~repro.core.cachesim.traffic_below` call; since
  capacity columns are independent there, batching is bit-identical to
  evaluating capacities one at a time. The bottleneck time model evaluates a
  whole config list as one (config x op) matrix (:meth:`TraceAnalysis
  .time_batch`); the per-spec scalar loop survives as the
  :meth:`TraceAnalysis._reference_time` parity oracle. The paper's Fig-2
  attribution lives here too; ``repro.core.perfmodel.PerfModel`` is a thin
  facade over this class.

* :class:`SuiteAnalysis` — the suite level: every member trace's stream
  padded into one :class:`~repro.core.cachesim.StreamBatch`, traffic for a
  whole (trace x capacity) plane computed in one batched scan, and the
  bottleneck time model evaluated as a single (config x total-ops) matrix
  with per-trace slice sums — bit-identical, per trace, to the member
  :class:`TraceAnalysis` objects (whose caches it fills). Shared
  process-wide through :func:`suite_analysis_for`.

* :class:`SweepEngine` — evaluates a grid of (trace x config x extra LLC
  capacity x GPU count) in ONE suite pass (``run()``; the original
  per-trace loop survives as ``run(batched=False)``, the bit-for-bit
  parity oracle): the union of every capacity any config touches is
  prefetched in a single batched traffic call, then every config is costed
  from the shared cache with one suite-wide matrix evaluation per
  attribution term. Configs may be
  :class:`~repro.core.copa.CopaConfig` (``build()`` is called for you) or
  raw :class:`~repro.core.hw.GpuSpec` (for bandwidth/capacity sensitivity
  sweeps like Figs 8-10). Workloads may be :class:`~repro.core.trace.Trace`
  objects, scenario names resolved through ``repro.workloads.registry``, or
  :class:`ScaleOutWorkload` families whose per-GPU trace depends on the
  instance count (the paper's Fig-12 fixed-global-batch scale-out).

* :class:`SweepResult` / :class:`SweepGrid` — structured rows (time,
  per-segment attribution, DRAM/L3/UHB bytes, energy, speedup vs baseline,
  scale-out terms: per-GPU vs collective time, throughput, scaling
  efficiency) with geomean and instances-to-target-throughput helpers over
  arbitrary trace subsets.

Scale-out model (paper Fig 12 / §V): ``n`` data-parallel GPU instances each
replay the per-GPU trace; training instances synchronize gradients with a
ring all-reduce over the inter-GPU fabric (``ici_bandwidth`` per direction,
:func:`ring_allreduce_time`). The default fabric is ideal (infinite
bandwidth), matching the paper's methodology of charging scale-out only for
the lost per-GPU batch efficiency; a finite bandwidth adds the collective
term to every training step.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Callable, Iterable, Sequence, Union

import numpy as np

from repro.core import copa as copa_mod
from repro.core.cachesim import (
    HierarchyTraffic,
    LevelTraffic,
    StreamBatch,
    TouchStream,
    build_stream,
    build_streams,
    traffic_below,
)
from repro.core.copa import CopaConfig, EnergyReport
from repro.core.hw import GpuSpec
from repro.core.trace import Trace

LAUNCH_OVERHEAD_S = 2.0e-6  # per-kernel launch/dependency latency

# Resource axis order of the component stack returned by
# ``components=True`` / :meth:`SuiteAnalysis.component_batch`
# (``repro.obs.explain`` ranks per-cell bottlenecks from it).
TIME_COMPONENTS = ("math", "llc", "uhb", "dram")

# Math throughput class per trace precision.
_TENSOR_CORE = {"fp16", "bf16", "int8", "fp8"}

ConfigLike = Union[CopaConfig, GpuSpec]
TraceLike = Union[Trace, str]


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.log(xs).mean())) if len(xs) else float("nan")


def bottleneck_of(segments: dict[str, float]) -> str:
    """Dominant non-Math attribution segment ('Math' when nothing else)."""
    segs = {k: v for k, v in segments.items() if k != "Math"}
    return max(segs, key=segs.get) if segs else "Math"


def ring_allreduce_time(nbytes: float, n_gpus: int, bandwidth: float,
                        latency_s: float = 0.0) -> float:
    """Ring all-reduce step time: each GPU moves ``2(n-1)/n`` of the payload
    through its ``bandwidth`` (bytes/s per direction) link in ``2(n-1)``
    latency-bound steps. Zero for one GPU, nothing to reduce, or an ideal
    (infinite-bandwidth) fabric. A non-positive bandwidth is an error, not
    a free fabric — 0 cannot mean both 'no link' and 'ideal link'."""
    if bandwidth <= 0:
        raise ValueError(f"ici bandwidth must be > 0, got {bandwidth!r}")
    if n_gpus <= 1 or nbytes <= 0 or not np.isfinite(bandwidth):
        return 0.0
    return (2.0 * (n_gpus - 1) / n_gpus * nbytes / bandwidth
            + 2.0 * (n_gpus - 1) * latency_s)


def _as_spec(config: ConfigLike) -> GpuSpec:
    return config.build() if isinstance(config, CopaConfig) else config


def _config_name(config: ConfigLike) -> str:
    return config.name


def _resolve_trace(t: TraceLike) -> Trace:
    if isinstance(t, str):
        from repro.workloads import registry  # lazy: workloads sit above core

        return registry.scenario(t)
    return t


def _dram_cap(spec: GpuSpec) -> float:
    """The LRU pool capacity DRAM sees for one spec (L2, or L2+L3)."""
    return float(spec.l2_capacity + spec.l3_capacity) if spec.l3_capacity \
        else float(spec.l2_capacity)


def _bottleneck_time_matrix(
    specs: Sequence[GpuSpec],
    flops: np.ndarray,
    is_tc: np.ndarray,
    occupancy_for,
    l2_touch: np.ndarray,
    uhb_rows,
    dram_rows,
    ideal_dram: bool,
    ideal_mem_other: bool,
    ideal_occupancy: bool,
    components: bool = False,
) -> np.ndarray:
    """THE bottleneck time model as one (config x op) matrix — the single
    implementation behind :meth:`TraceAnalysis.time_batch` (ops of one
    trace) and :meth:`SuiteAnalysis.time_batch` (a whole suite's global op
    axis); only the per-spec row sources differ, so the two can never
    drift apart. ``occupancy_for(spec)`` returns the per-op occupancy,
    ``uhb_rows(spec)`` the post-L2 (fill, writeback) rows and
    ``dram_rows(spec)`` the total DRAM-traffic row for the relevant
    capacities. Every step is elementwise per op column.
    ``TraceAnalysis._reference_time`` stays a deliberate per-spec copy —
    it is the parity oracle this matrix is tested against.

    With ``components=True`` the four per-resource pressure matrices are
    returned stacked as ``(4, n_specs, n_ops)`` in :data:`TIME_COMPONENTS`
    order, WITHOUT the launch overhead — ``stack.max(axis=0) + overhead``
    reproduces the default return exactly (asserted in tests)."""
    n_ops = len(flops)
    if ideal_occupancy:
        occ = np.ones((len(specs), n_ops))
    else:
        occ = np.stack([occupancy_for(sp) for sp in specs]) \
            if n_ops else np.ones((len(specs), 0))
    f_tc = np.array([sp.fp16_tflops for sp in specs])[:, None] * 1e12
    f_fp32 = np.array([sp.fp32_tflops for sp in specs])[:, None] * 1e12
    fmath = np.where(is_tc[None, :], f_tc, f_fp32) * occ
    flops_b = np.broadcast_to(flops[None, :], fmath.shape)
    t_math = np.divide(flops_b, fmath, out=np.zeros_like(fmath),
                       where=fmath > 0)

    if ideal_mem_other:
        t_l2 = np.zeros_like(fmath)
        t_uhb = np.zeros_like(fmath)
    else:
        l2_bw = np.array([sp.l2_bandwidth for sp in specs])[:, None]
        t_l2 = l2_touch[None, :] / (l2_bw * occ)
        has_uhb = np.array([bool(sp.l3_capacity) and sp.l3_bandwidth > 0
                            for sp in specs])
        if has_uhb.any():
            # UHB is per-direction (paper: 2xRD + 2xWR).
            l3_bw = np.array([sp.l3_bandwidth if u else 1.0
                              for sp, u in zip(specs, has_uhb)])[:, None]
            rows = [uhb_rows(sp) for sp in specs]
            fill = np.stack([r[0] for r in rows])
            wb = np.stack([r[1] for r in rows])
            t_uhb = np.where(has_uhb[:, None],
                             np.maximum(fill / l3_bw, wb / l3_bw), 0.0)
        else:
            t_uhb = np.zeros_like(fmath)

    if ideal_dram:
        t_dram = np.zeros_like(fmath)
    else:
        dram_bw = np.array([sp.dram_bandwidth for sp in specs])[:, None]
        dram_tot = np.stack([dram_rows(sp) for sp in specs])
        t_dram = dram_tot / dram_bw

    if components:
        return np.stack([t_math, t_l2, t_uhb, t_dram])
    overhead = 0.0 if ideal_occupancy else LAUNCH_OVERHEAD_S
    return np.maximum.reduce([t_math, t_l2, t_uhb, t_dram]) + overhead


@dataclass(frozen=True)
class ScaleOutWorkload:
    """A workload family whose per-GPU trace depends on the instance count.

    ``trace_for(n)`` returns the trace ONE GPU replays when the workload is
    spread across ``n`` data-parallel instances. Fixed-global-batch training
    (paper Fig 12) shrinks the per-GPU batch as ``n`` grows (strong
    scaling); returning the same trace at every ``n`` models weak scaling
    (per-instance serving at fixed per-GPU load). ``trace_for(1)`` anchors
    the baseline time and throughput."""

    name: str
    trace_for: Callable[[int], Trace]


WorkloadLike = Union[Trace, str, ScaleOutWorkload]


def _as_workload(t: WorkloadLike) -> ScaleOutWorkload:
    if isinstance(t, ScaleOutWorkload):
        return t
    if isinstance(t, str):
        from repro.workloads import registry  # lazy: workloads sit above core

        resolved = registry.resolve(t)
        if isinstance(resolved, ScaleOutWorkload):
            return resolved
        t = resolved
    if not isinstance(t, Trace):
        raise TypeError(
            f"not a sweepable workload: {t!r} (expected Trace, scenario "
            f"name, or ScaleOutWorkload — arrival specs drive "
            f"repro.serve.sim, not the sweep engine)")
    trace = t
    return ScaleOutWorkload(name=trace.name, trace_for=lambda n: trace)


def _expand_workloads(traces: Iterable[WorkloadLike]) -> list[ScaleOutWorkload]:
    """Resolve every workload; glob-pattern strings expand through the
    registry to every matching scenario/scale-out name."""
    out: list[ScaleOutWorkload] = []
    for t in traces:
        if isinstance(t, str) and any(ch in t for ch in "*?["):
            from repro.workloads import registry  # lazy

            out.extend(_as_workload(r) for r in registry.resolve(t))
        else:
            out.append(_as_workload(t))
    return out


class TraceAnalysis:
    """Capacity-independent analysis of one trace + shared traffic cache."""

    def __init__(self, trace: Trace, cyclic: bool = True,
                 stream: TouchStream | None = None):
        self.trace = trace
        self.cyclic = cyclic
        self.stream = stream if stream is not None else build_stream(trace, cyclic=cyclic)
        self.flops = np.array([op.flops for op in trace.ops])
        self.parallelism = np.array([op.parallelism for op in trace.ops])
        self.is_tc = np.array([op.precision in _TENSOR_CORE for op in trace.ops])
        self._levels: dict[float, LevelTraffic] = {}
        self._l2_touch: np.ndarray | None = None
        self._occ: dict[int, np.ndarray] = {}  # spec concurrency -> occupancy
        self._grad_bytes: float | None = None

    # -- traffic ---------------------------------------------------------------
    @property
    def l2_touch(self) -> np.ndarray:
        """Bytes served by the L2 per op (all touches, steady-state copy)."""
        if self._l2_touch is None:
            l2 = np.zeros(self.stream.n_ops)
            half = self.stream.second_half
            np.add.at(l2, self.stream.op_idx[half:], self.stream.sizes[half:])
            self._l2_touch = l2
        return self._l2_touch

    @property
    def grad_bytes(self) -> float:
        """Bytes all-reduced per iteration under data parallelism: the
        unique gradient tensors (``g.*``) this trace writes. Zero for
        inference traces (no gradients, instances are independent)."""
        if self._grad_bytes is None:
            seen: dict[str, int] = {}
            for op in self.trace.ops:
                for t, b in op.writes:
                    if t.startswith("g."):
                        seen[t] = max(seen.get(t, 0), b)
            self._grad_bytes = float(sum(seen.values()))
        return self._grad_bytes

    def prefetch(self, capacities: Iterable[float]) -> None:
        """Compute all not-yet-cached capacities in one batched trace pass."""
        missing = sorted({float(c) for c in capacities} - self._levels.keys())
        if missing:
            for cap, lt in zip(missing, traffic_below(self.stream, missing)):
                self._levels[cap] = lt

    def level_traffic(self, capacity: float) -> LevelTraffic:
        self.prefetch([capacity])
        return self._levels[float(capacity)]

    def dram_traffic(self, capacities: Sequence[float]) -> dict[float, float]:
        """Total DRAM traffic vs LLC capacity (paper Fig 4)."""
        self.prefetch(capacities)
        return {c: self._levels[float(c)].total for c in capacities}

    @staticmethod
    def capacities_for(spec: GpuSpec) -> list[float]:
        """LRU pool capacities the §III-C hierarchy needs for one spec."""
        if spec.l3_capacity:
            return [float(spec.l2_capacity),
                    float(spec.l2_capacity + spec.l3_capacity)]
        return [float(spec.l2_capacity)]

    def hierarchy(self, spec: GpuSpec) -> HierarchyTraffic:
        if spec.l3_capacity:
            post_l2 = self.level_traffic(spec.l2_capacity)
            dram = self.level_traffic(spec.l2_capacity + spec.l3_capacity)
            return HierarchyTraffic(self.l2_touch, post_l2, dram, has_l3=True)
        post_l2 = self.level_traffic(spec.l2_capacity)
        return HierarchyTraffic(self.l2_touch, post_l2, post_l2, has_l3=False)

    # -- bottleneck time model (paper Fig-2 machinery) -------------------------
    def _occupancy(self, spec: GpuSpec) -> np.ndarray:
        # Occupancy is sublinear in exposed parallelism: a kernel filling 10%
        # of the machine still extracts >10% of peak thanks to ILP, split-K
        # decompositions and cache effects (exponent calibrated against the
        # paper's Fig-2 small-batch attribution).
        occ = self._occ.get(spec.concurrency)
        if occ is None:
            occ = np.minimum(1.0, self.parallelism / spec.concurrency) ** 0.55
            self._occ[spec.concurrency] = occ
        return occ

    def time_batch(
        self,
        specs: Sequence[GpuSpec],
        ideal_dram: bool = False,
        ideal_mem_other: bool = False,
        ideal_occupancy: bool = False,
        per_op: bool = False,
    ) -> np.ndarray:
        """One (config x op) matrix evaluation of the bottleneck time model.

        Returns per-spec total seconds of shape ``(len(specs),)`` — or the
        full ``(len(specs), n_ops)`` matrix with ``per_op=True``. Each row is
        bit-identical to :meth:`_reference_time` on that spec alone: every
        step of :func:`_bottleneck_time_matrix` is elementwise, so batching
        configs cannot change a row.
        """
        specs = list(specs)
        n_ops = len(self.flops)
        if not specs:
            return np.zeros((0, n_ops)) if per_op else np.zeros(0)
        self.prefetch({c for sp in specs for c in self.capacities_for(sp)})
        t_op = _bottleneck_time_matrix(
            specs, self.flops, self.is_tc, self._occupancy, self.l2_touch,
            uhb_rows=lambda sp: (
                self._levels[float(sp.l2_capacity)].fill,
                self._levels[float(sp.l2_capacity)].writeback,
            ),
            dram_rows=lambda sp: (
                self._levels[_dram_cap(sp)].fill
                + self._levels[_dram_cap(sp)].writeback
            ),
            ideal_dram=ideal_dram,
            ideal_mem_other=ideal_mem_other,
            ideal_occupancy=ideal_occupancy,
        )
        if per_op:
            return t_op
        return t_op.sum(axis=-1)

    def time(
        self,
        spec: GpuSpec,
        ideal_dram: bool = False,
        ideal_mem_other: bool = False,
        ideal_occupancy: bool = False,
        per_op: bool = False,
    ):
        """Single-spec facade over :meth:`time_batch` (one-row matrix)."""
        out = self.time_batch(
            [spec],
            ideal_dram=ideal_dram,
            ideal_mem_other=ideal_mem_other,
            ideal_occupancy=ideal_occupancy,
            per_op=per_op,
        )
        return out[0] if per_op else float(out[0])

    def _reference_time(
        self,
        spec: GpuSpec,
        ideal_dram: bool = False,
        ideal_mem_other: bool = False,
        ideal_occupancy: bool = False,
        per_op: bool = False,
    ):
        """Per-spec scalar-loop oracle the batched path is tested against."""
        tr = self.hierarchy(spec)
        if ideal_occupancy:
            occ = np.ones_like(self.parallelism)
        else:
            occ = self._occupancy(spec)
        f_tc = spec.fp16_tflops * 1e12
        f_fp32 = spec.fp32_tflops * 1e12
        fmath = np.where(self.is_tc, f_tc, f_fp32) * occ
        t_math = np.divide(self.flops, fmath, out=np.zeros_like(self.flops),
                           where=fmath > 0)

        if ideal_mem_other:
            t_l2 = np.zeros(len(self.flops))
            t_uhb = np.zeros(len(self.flops))
        else:
            t_l2 = tr.l2_touch / (spec.l2_bandwidth * occ)
            if tr.has_l3 and spec.l3_bandwidth > 0:
                # UHB is per-direction (paper: 2xRD + 2xWR).
                t_uhb = np.maximum(
                    tr.post_l2.fill / spec.l3_bandwidth,
                    tr.post_l2.writeback / spec.l3_bandwidth,
                )
            else:
                t_uhb = np.zeros(len(self.flops))

        if ideal_dram:
            t_dram = np.zeros(len(self.flops))
        else:
            t_dram = (tr.dram.fill + tr.dram.writeback) / spec.dram_bandwidth

        overhead = 0.0 if ideal_occupancy else LAUNCH_OVERHEAD_S
        t_op = np.maximum.reduce([t_math, t_l2, t_uhb, t_dram]) + overhead
        if per_op:
            return t_op
        return float(t_op.sum())

    def attribution_batch(
        self, specs: Sequence[GpuSpec]
    ) -> list[tuple[float, dict[str, float]]]:
        """Actual time + the paper's peel-order attribution for every spec.

        Four matrix evaluations total — instead of four per config — which
        is where the engine's remaining per-config cost used to go.
        """
        specs = list(specs)
        t_act = self.time_batch(specs)
        t_no_dram = self.time_batch(specs, ideal_dram=True)
        t_no_mem = self.time_batch(specs, ideal_dram=True,
                                   ideal_mem_other=True)
        t_math = self.time_batch(specs, ideal_dram=True, ideal_mem_other=True,
                                 ideal_occupancy=True)
        out = []
        for act, nd, nm, m in zip(t_act, t_no_dram, t_no_mem, t_math):
            out.append((float(act), {
                "Math": float(m),
                "SM util": max(float(nm) - float(m), 0.0),
                "Memory others": max(float(nd) - float(nm), 0.0),
                "DRAM BW": max(float(act) - float(nd), 0.0),
            }))
        return out

    def attribution(self, spec: GpuSpec) -> tuple[float, dict[str, float]]:
        """Actual time + the paper's peel-order cost attribution."""
        return self.attribution_batch([spec])[0]

    def energy(self, spec: GpuSpec) -> EnergyReport:
        tr = self.hierarchy(spec)
        return copa_mod.memory_energy(spec, tr.dram.total, tr.l3_bytes)


# Shared per-process analyses so benchmarks/examples/tests reuse streams.
# Bounded LRU: callers like dram_traffic_sweep may analyze an unbounded
# stream of ephemeral traces (property tests generate thousands), and each
# analysis pins O(touches x capacities) arrays — evict the oldest instead of
# leaking. The workload-registry traces are lru-cached module-side, so the
# hot set stays comfortably within the bound.
_ANALYSES: OrderedDict[tuple[int, int, bool], tuple[Trace, TraceAnalysis]] = OrderedDict()
_ANALYSES_MAX = 512


def analysis_for(trace: Trace, cyclic: bool = True) -> TraceAnalysis:
    """Process-wide TraceAnalysis cache (keyed by trace identity).

    The op count is part of the key so a trace that grows after being
    analyzed (emit() between sweeps) gets a fresh analysis instead of the
    stale stream; in-place edits of existing ops are still on the caller.
    """
    key = (id(trace), len(trace.ops), cyclic)
    hit = _ANALYSES.get(key)
    if hit is None or hit[0] is not trace:
        _ANALYSES[key] = (trace, TraceAnalysis(trace, cyclic=cyclic))
        if len(_ANALYSES) > _ANALYSES_MAX:
            _ANALYSES.popitem(last=False)
    else:
        _ANALYSES.move_to_end(key)
    return _ANALYSES[key][1]


class SuiteAnalysis:
    """Suite-level analysis: a whole set of traces behind ONE batched pass.

    Pads every member trace's touch stream into a
    :class:`~repro.core.cachesim.StreamBatch` (one batched Mattson pass for
    construction, one batched segmented scan per new capacity set) and
    concatenates the per-op static vectors onto a single global op axis, so
    the bottleneck time model evaluates the *entire suite* as one
    (config x total-ops) matrix. Every number is bit-identical to running
    the member :class:`TraceAnalysis` objects one at a time (asserted in
    tests): padded rows are scanned with exactly the per-trace operation
    sequence, and the time model is elementwise with per-trace slice sums.

    Member analyses share the suite's traffic cache (levels are installed
    into each member's ``_levels``), so single-trace APIs — ``PerfModel``,
    ``msm.analyze``, ``dram_traffic_sweep`` — stay warm after a suite pass.
    """

    def __init__(self, traces: Sequence[Trace], cyclic: bool = True,
                 analyses: Sequence[TraceAnalysis] | None = None):
        self.traces = list(traces)
        self.cyclic = cyclic
        if analyses is None:
            streams = build_streams(self.traces, cyclic=cyclic)
            analyses = [TraceAnalysis(t, cyclic=cyclic, stream=s)
                        for t, s in zip(self.traces, streams)]
        self.analyses = list(analyses)
        self.batch = StreamBatch.pad([ta.stream for ta in self.analyses])
        self.flops = np.concatenate(
            [ta.flops for ta in self.analyses]) if self.analyses \
            else np.zeros(0)
        self.parallelism = np.concatenate(
            [ta.parallelism for ta in self.analyses]) if self.analyses \
            else np.zeros(0)
        self.is_tc = np.concatenate(
            [ta.is_tc for ta in self.analyses]) if self.analyses \
            else np.zeros(0, dtype=bool)
        self._occ: dict[int, np.ndarray] = {}
        self._l2_touch: np.ndarray | None = None
        # capacity -> (per-op fill row, per-op writeback row) on the global
        # op axis; rows come from the batched scan (or are concatenated from
        # member caches when a member was analyzed before this suite).
        self._levels_cat: dict[float, tuple[np.ndarray, np.ndarray]] = {}
        self._totals: dict[float, np.ndarray] = {}

    @property
    def n_traces(self) -> int:
        return len(self.analyses)

    def op_slice(self, i: int) -> slice:
        return self.batch.op_slice(i)

    @property
    def l2_touch(self) -> np.ndarray:
        """Per-op L2 touch bytes on the global op axis (and installed into
        every member's cache as its slice view)."""
        if self._l2_touch is None:
            l2 = np.zeros(self.batch.n_ops_total)
            for i, ta in enumerate(self.analyses):
                s = ta.stream
                half = s.second_half
                sl = self.op_slice(i)
                if ta._l2_touch is not None:
                    l2[sl] = ta._l2_touch
                    continue
                seg = l2[sl]
                np.add.at(seg, s.op_idx[half:], s.sizes[half:])
                ta._l2_touch = seg
            self._l2_touch = l2
        return self._l2_touch

    # -- traffic ---------------------------------------------------------------
    def prefetch(self, capacities: Iterable[float]) -> None:
        """Make every requested capacity known suite-wide, scanning as
        little as possible: rows whose member :class:`TraceAnalysis` already
        carries a capacity (from an earlier suite in this session, or a
        per-trace call) are *gathered* from that cache; only blocks holding
        at least one uncovered row go through a batched
        :meth:`~repro.core.cachesim.StreamBatch.traffic_matrices` scan — one
        call for the union of all missing capacities. A cached member row is
        bit-identical to a rescan of it (the per-row independence the batch
        is built on), so assembled and scanned planes cannot differ."""
        want = sorted({float(c) for c in capacities})
        missing = [c for c in want if c not in self._levels_cat]
        if not missing:
            return
        scan_caps = [c for c in missing
                     if any(c not in ta._levels for ta in self.analyses)]
        if scan_caps:
            need = {i for i, ta in enumerate(self.analyses)
                    if any(c not in ta._levels for c in scan_caps)}
            blocks = [b for b in self.batch._blocks
                      if any(m in need for m in b.members)]
            if len(blocks) == len(self.batch._blocks):
                blocks = None  # full scan; skip the membership indirection
            fills, wbs = self.batch.traffic_matrices(scan_caps, blocks=blocks)
        for c in missing:
            if c in scan_caps:
                k = scan_caps.index(c)
                fill, wb = fills[k], wbs[k]
                for i, ta in enumerate(self.analyses):
                    lt = ta._levels.get(c)
                    sl = self.op_slice(i)
                    if lt is not None:
                        # covered row: its block may not have been scanned;
                        # the cached values are bit-identical to a scan
                        fill[sl] = lt.fill
                        wb[sl] = lt.writeback
                    else:
                        ta._levels[c] = LevelTraffic(fill[sl], wb[sl])
            else:
                # every member already has this capacity: pure gather
                fill = np.concatenate(
                    [ta._levels[c].fill for ta in self.analyses]) \
                    if self.analyses else np.zeros(0)
                wb = np.concatenate(
                    [ta._levels[c].writeback for ta in self.analyses]) \
                    if self.analyses else np.zeros(0)
            self._levels_cat[c] = (fill, wb)

    def append(self, traces: Sequence[Trace],
               analyses: Sequence[TraceAnalysis] | None = None) -> None:
        """Grow the suite in place: new traces join the batch as fresh
        blocks (O(new trace) — no re-pad of existing rows) and every cached
        plane is extended for them — the static vectors, the occupancy
        cache, the L2 touch row, and each capacity in ``_levels_cat`` via
        ONE partial scan over just the new blocks (the session-level
        capacity union: whatever capacities this suite has ever seen, a new
        scenario gets them all on arrival). The grown suite is
        bit-identical, field for field, to a cold build over the full list
        (asserted in tests).

        NOTE: callers holding this object see it grow. Use
        :func:`suite_append` to also keep the :func:`suite_analysis_for`
        memo layer consistent."""
        traces = list(traces)
        if not traces:
            return
        if analyses is None:
            streams = build_streams(traces, cyclic=self.cyclic)
            analyses = [TraceAnalysis(t, cyclic=self.cyclic, stream=s)
                        for t, s in zip(traces, streams)]
        analyses = list(analyses)
        old_total = self.batch.n_ops_total
        old_n = self.n_traces
        new_blocks = self.batch.append([ta.stream for ta in analyses])
        self.traces.extend(traces)
        self.analyses.extend(analyses)
        self.flops = np.concatenate(
            [self.flops] + [ta.flops for ta in analyses])
        self.parallelism = np.concatenate(
            [self.parallelism] + [ta.parallelism for ta in analyses])
        self.is_tc = np.concatenate(
            [self.is_tc] + [ta.is_tc for ta in analyses])
        for conc, occ in list(self._occ.items()):
            self._occ[conc] = np.concatenate([
                occ,
                np.minimum(1.0, self.parallelism[old_total:] / conc) ** 0.55,
            ])
        if self._l2_touch is not None:
            l2 = np.zeros(self.batch.n_ops_total)
            l2[:old_total] = self._l2_touch
            self._l2_touch = l2
            for i, ta in enumerate(analyses, start=old_n):
                s = ta.stream
                sl = self.op_slice(i)
                if ta._l2_touch is not None:
                    l2[sl] = ta._l2_touch
                    continue
                seg = l2[sl]
                np.add.at(seg, s.op_idx[s.second_half:],
                          s.sizes[s.second_half:])
                ta._l2_touch = seg
        caps_known = sorted(self._levels_cat)
        if caps_known:
            fills, wbs = self.batch.traffic_matrices(caps_known,
                                                     blocks=new_blocks)
            for k, cap in enumerate(caps_known):
                of, ow = self._levels_cat[cap]
                fills[k, :old_total] = of
                wbs[k, :old_total] = ow
                self._levels_cat[cap] = (fills[k], wbs[k])
                for i, ta in enumerate(analyses, start=old_n):
                    sl = self.op_slice(i)
                    lt = ta._levels.get(cap)
                    if lt is not None:
                        fills[k, sl] = lt.fill
                        wbs[k, sl] = lt.writeback
                    else:
                        ta._levels[cap] = LevelTraffic(fills[k, sl],
                                                       wbs[k, sl])
        for cap in list(self._totals):
            self._totals[cap] = np.concatenate([
                self._totals[cap],
                [ta._levels[cap].total for ta in analyses],
            ])

    def invalidate(self, traces: Trace | Sequence[Trace]) -> None:
        """Drop member traces in place (a scenario whose trace object was
        rebuilt or grew stale). Surviving rows are re-grouped into a fresh
        batch (cheap: per-stream layouts are cached) and every cached plane
        is *gathered* down to the surviving columns — no rescan. Unknown
        traces are ignored."""
        if isinstance(traces, Trace):
            traces = [traces]
        drop = {id(t) for t in traces}
        keep = [i for i, t in enumerate(self.traces) if id(t) not in drop]
        if len(keep) == len(self.traces):
            return
        cols = np.concatenate(
            [np.arange(self.op_slice(i).start, self.op_slice(i).stop)
             for i in keep]) if keep else np.zeros(0, dtype=np.int64)
        self.traces = [self.traces[i] for i in keep]
        self.analyses = [self.analyses[i] for i in keep]
        self.batch = StreamBatch.pad([ta.stream for ta in self.analyses])
        self.flops = self.flops[cols]
        self.parallelism = self.parallelism[cols]
        self.is_tc = self.is_tc[cols]
        self._occ = {c: occ[cols] for c, occ in self._occ.items()}
        if self._l2_touch is not None:
            self._l2_touch = self._l2_touch[cols]
        self._levels_cat = {c: (f[cols], w[cols])
                            for c, (f, w) in self._levels_cat.items()}
        self._totals = {c: tot[keep] for c, tot in self._totals.items()}

    def totals_below(self, capacity: float) -> np.ndarray:
        """Per-trace total traffic below one capacity, shape (n_traces,)."""
        cap = float(capacity)
        if cap not in self._totals:
            self.prefetch([cap])
            self._totals[cap] = np.array(
                [ta._levels[cap].total for ta in self.analyses])
        return self._totals[cap]

    def dram_traffic(self, capacities: Sequence[float]) -> np.ndarray:
        """(n_traces, n_capacities) DRAM-traffic tensor in one call — the
        suite-level paper Fig 4."""
        caps = [float(c) for c in capacities]
        self.prefetch(caps)
        if not caps or not self.traces:
            return np.zeros((len(self.traces), len(caps)))
        return np.column_stack([self.totals_below(c) for c in caps])

    # -- suite time model --------------------------------------------------------
    def _occupancy(self, spec: GpuSpec) -> np.ndarray:
        occ = self._occ.get(spec.concurrency)
        if occ is None:
            occ = np.minimum(1.0, self.parallelism / spec.concurrency) ** 0.55
            self._occ[spec.concurrency] = occ
        return occ

    def _level_rows(self, cap: float) -> tuple[np.ndarray, np.ndarray]:
        self.prefetch([cap])
        return self._levels_cat[float(cap)]

    def time_batch(
        self,
        specs: Sequence[GpuSpec],
        ideal_dram: bool = False,
        ideal_mem_other: bool = False,
        ideal_occupancy: bool = False,
        per_op: bool = False,
    ) -> np.ndarray:
        """The (config x op) bottleneck matrix over the WHOLE suite's global
        op axis. Returns per-(spec, trace) totals of shape
        ``(len(specs), n_traces)`` — or the ``(len(specs), n_ops_total)``
        matrix with ``per_op=True``. Every step is elementwise and the
        per-trace sums run over each trace's own slice, so each
        (spec, trace) cell is bit-identical to
        ``TraceAnalysis.time_batch`` on that trace alone."""
        specs = list(specs)
        n_ops = len(self.flops)
        if not specs:
            return np.zeros((0, n_ops)) if per_op \
                else np.zeros((0, self.n_traces))
        self.prefetch({c for sp in specs
                       for c in TraceAnalysis.capacities_for(sp)})
        t_op = _bottleneck_time_matrix(
            specs, self.flops, self.is_tc, self._occupancy, self.l2_touch,
            uhb_rows=lambda sp: self._level_rows(sp.l2_capacity),
            dram_rows=lambda sp: np.add(*self._level_rows(_dram_cap(sp))),
            ideal_dram=ideal_dram,
            ideal_mem_other=ideal_mem_other,
            ideal_occupancy=ideal_occupancy,
        )
        if per_op:
            return t_op
        return np.stack(
            [t_op[:, self.op_slice(i)].sum(axis=1)
             for i in range(self.n_traces)], axis=1,
        ) if self.n_traces else np.zeros((len(specs), 0))

    def component_batch(self, specs: Sequence[GpuSpec]) -> np.ndarray:
        """Per-resource component times of the bottleneck model, shape
        ``(4, len(specs), n_ops_total)`` in :data:`TIME_COMPONENTS` order
        (math, llc, uhb, dram). ``stack.max(axis=0) + LAUNCH_OVERHEAD_S``
        reproduces ``time_batch(per_op=True)`` exactly (asserted in
        tests) — this is the raw material ``repro.obs.explain`` ranks
        per-cell bottlenecks from."""
        specs = list(specs)
        if not specs:
            return np.zeros((4, 0, len(self.flops)))
        self.prefetch({c for sp in specs
                       for c in TraceAnalysis.capacities_for(sp)})
        return _bottleneck_time_matrix(
            specs, self.flops, self.is_tc, self._occupancy, self.l2_touch,
            uhb_rows=lambda sp: self._level_rows(sp.l2_capacity),
            dram_rows=lambda sp: np.add(*self._level_rows(_dram_cap(sp))),
            ideal_dram=False, ideal_mem_other=False, ideal_occupancy=False,
            components=True,
        )

    def attribution_grid(
        self, specs: Sequence[GpuSpec]
    ) -> list[list[tuple[float, dict[str, float]]]]:
        """Actual time + the paper's peel-order attribution for every
        (trace, spec) cell: four suite-wide matrix evaluations total.
        ``out[i][j]`` matches ``analyses[i].attribution_batch(specs)[j]``
        bit for bit."""
        specs = list(specs)
        t_act = self.time_batch(specs)
        t_nd = self.time_batch(specs, ideal_dram=True)
        t_nm = self.time_batch(specs, ideal_dram=True, ideal_mem_other=True)
        t_m = self.time_batch(specs, ideal_dram=True, ideal_mem_other=True,
                              ideal_occupancy=True)
        out = []
        for i in range(self.n_traces):
            row = []
            for j in range(len(specs)):
                act, nd, nm, m = (float(t_act[j, i]), float(t_nd[j, i]),
                                  float(t_nm[j, i]), float(t_m[j, i]))
                row.append((act, {
                    "Math": m,
                    "SM util": max(nm - m, 0.0),
                    "Memory others": max(nd - nm, 0.0),
                    "DRAM BW": max(act - nd, 0.0),
                }))
            out.append(row)
        return out


# Process-wide SuiteAnalysis cache, keyed by the member-trace identities:
# repeated suite sweeps (benchmarks re-running figures, serve grids priced
# after an engine run) reuse the padded batch and every computed capacity.
_SUITES: OrderedDict[tuple, SuiteAnalysis] = OrderedDict()
_SUITES_MAX = 32


def _suite_key(traces: Sequence[Trace], cyclic: bool) -> tuple:
    return (cyclic,) + tuple((id(t), len(t.ops)) for t in traces)


def suite_analysis_for(traces: Sequence[Trace], cyclic: bool = True) -> SuiteAnalysis:
    """Process-wide :class:`SuiteAnalysis` cache (keyed by trace identities).

    Member analyses are shared with :func:`analysis_for`'s per-trace cache,
    so suite passes and single-trace APIs warm each other — and since
    :meth:`SuiteAnalysis.prefetch` gathers member-cached capacities instead
    of rescanning them, a *miss* here over already-analyzed traces is a
    warm rebuild (padded-row assembly from cached stream layouts, no
    Mattson pass, no traffic scan), not a cold one. To grow or shrink a
    cached suite in place, use :func:`suite_append` /
    :func:`suite_invalidate`."""
    traces = list(traces)
    key = _suite_key(traces, cyclic)
    hit = _SUITES.get(key)
    if hit is not None and hit.n_traces == len(traces) \
            and all(a is b for a, b in zip(hit.traces, traces)):
        _SUITES.move_to_end(key)
        return hit
    # Build member streams in one batched pass BEFORE analysis_for would
    # build them one at a time, then share the per-trace analysis cache.
    build_streams(traces, cyclic=cyclic)
    suite = SuiteAnalysis(
        traces, cyclic=cyclic,
        analyses=[analysis_for(t, cyclic=cyclic) for t in traces],
    )
    _SUITES[key] = suite
    if len(_SUITES) > _SUITES_MAX:
        _SUITES.popitem(last=False)
    return suite


def _rekey_suite(suite: SuiteAnalysis) -> None:
    """Re-index ``suite`` in the process cache under its current members."""
    for k, s in list(_SUITES.items()):
        if s is suite:
            del _SUITES[k]
    _SUITES[_suite_key(suite.traces, suite.cyclic)] = suite
    if len(_SUITES) > _SUITES_MAX:
        _SUITES.popitem(last=False)


def suite_append(suite: SuiteAnalysis, traces: Sequence[Trace]) -> SuiteAnalysis:
    """Append scenarios to a live suite in O(new trace) — the incremental
    half of :func:`suite_analysis_for`'s append/invalidate API. New traces
    join the padded batch as fresh blocks, inherit every capacity the
    suite has ever computed via one partial scan, and the suite is re-keyed
    in the process cache so a later ``suite_analysis_for`` call with the
    grown membership hits it. Traces already in the suite are skipped.
    Returns ``suite`` (grown in place)."""
    have = {id(t) for t in suite.traces}
    new = [t for t in traces if id(t) not in have]
    if new:
        build_streams(new, cyclic=suite.cyclic)
        suite.append(new, analyses=[analysis_for(t, cyclic=suite.cyclic)
                                    for t in new])
        _rekey_suite(suite)
    return suite


def suite_invalidate(suite: SuiteAnalysis,
                     traces: Trace | Sequence[Trace]) -> SuiteAnalysis:
    """Drop scenarios from a live suite (stale/rebuilt trace objects) and
    re-key it in the process cache — the invalidate half of the API. Cached
    planes are gathered down to the surviving columns; nothing is
    rescanned. Returns ``suite`` (shrunk in place)."""
    n = suite.n_traces
    suite.invalidate(traces)
    if suite.n_traces != n:
        _rekey_suite(suite)
    return suite


@dataclass(frozen=True)
class SweepResult:
    """One (trace, config, GPU count) cell of the design-space grid."""

    trace: str
    kind: str                     # "training" | "inference" | "hpc" | ...
    config: str
    spec_name: str
    time_s: float                 # full step: per-GPU compute + collective
    baseline_time_s: float        # baseline config, ONE GPU, full batch
    speedup: float                # throughput ratio vs that 1-GPU baseline
    segments: dict[str, float]    # paper Fig-2 attribution (per-GPU compute)
    dram_bytes: float
    l3_bytes: float
    uhb_bytes: float
    l2_bytes: float
    dram_joules: float
    l3_joules: float
    # -- scale-out terms (all trivial at the default n_gpus=1) -----------------
    n_gpus: int = 1
    per_gpu_time_s: float = 0.0   # compute-only time of one instance
    collective_time_s: float = 0.0  # gradient all-reduce over the ICI fabric
    throughput: float = 0.0       # samples/s across all instances
    scaling_efficiency: float = 1.0  # speedup / (n_gpus * speedup@1GPU)

    @property
    def total_joules(self) -> float:
        return self.dram_joules + self.l3_joules

    @property
    def per_instance_throughput(self) -> float:
        return self.throughput / max(self.n_gpus, 1)

    @property
    def bottleneck(self) -> str:
        return bottleneck_of(self.segments)


@dataclass
class SweepGrid:
    """Structured result of a SweepEngine run."""

    baseline: str
    rows: list[SweepResult] = field(default_factory=list)
    # trace name -> LLC capacity -> total traffic below that capacity
    llc_traffic: dict[str, dict[float, float]] = field(default_factory=dict)
    _index: dict[tuple[str, str, int], SweepResult] = field(default_factory=dict)

    def add(self, row: SweepResult) -> None:
        self.rows.append(row)
        self._index[(row.trace, row.config, row.n_gpus)] = row

    def result(self, trace: str, config: str, n_gpus: int = 1) -> SweepResult:
        try:
            return self._index[(trace, config, n_gpus)]
        except KeyError:
            raise KeyError(
                f"no grid row (trace={trace!r}, config={config!r}, "
                f"n_gpus={n_gpus}); this grid swept gpu_counts="
                f"{self.gpu_counts} over configs {self.configs}"
            ) from None

    @property
    def configs(self) -> list[str]:
        seen: dict[str, None] = {}
        for r in self.rows:
            seen.setdefault(r.config)
        return list(seen)

    @property
    def traces(self) -> list[str]:
        seen: dict[str, None] = {}
        for r in self.rows:
            seen.setdefault(r.trace)
        return list(seen)

    @property
    def gpu_counts(self) -> list[int]:
        return sorted({r.n_gpus for r in self.rows})

    def speedups(self, config: str, traces: Sequence[str] | None = None,
                 n_gpus: int = 1) -> list[float]:
        names = list(traces) if traces is not None else self.traces
        return [self._index[(t, config, n_gpus)].speedup for t in names]

    def geomean_speedup(self, config: str,
                        traces: Sequence[str] | None = None,
                        n_gpus: int = 1) -> float:
        return geomean(self.speedups(config, traces, n_gpus=n_gpus))

    def instances_to_target(self, trace: str, config: str,
                            target_speedup: float) -> int | None:
        """Smallest swept instance count at which ``config`` reaches the
        target throughput speedup on ``trace`` (None when no swept count
        does) — the paper's GPU-instances-to-match-COPA question."""
        rows = sorted((r for r in self.rows
                       if r.trace == trace and r.config == config),
                      key=lambda r: r.n_gpus)
        for r in rows:
            if r.speedup >= target_speedup:
                return r.n_gpus
        return None

    def instances_to_match(self, config: str, target_config: str,
                           traces: Sequence[str] | None = None
                           ) -> dict[str, int | None]:
        """Per trace: swept instances of ``config`` needed to match one
        ``target_config`` GPU's throughput (None where even the largest
        swept count falls short — report it, don't invent a number)."""
        names = list(traces) if traces is not None else self.traces
        return {t: self.instances_to_target(
                    t, config, self.result(t, target_config).speedup)
                for t in names}


# -- step-cost export for the request-level serving simulator -----------------

#: Resident-KV bucket edges (tokens) for serving cost grids.
DEFAULT_SEQ_EDGES = (4096, 16384, 65536, 262144, 1048576)


@dataclass(frozen=True)
class CostGrid:
    """Precomputed (batch, resident-KV-bucket) step times for ONE config.

    The serving simulator (``repro.serve.sim``) charges every engine
    iteration one cell of this grid: ``step_time(batch, resident_tokens)``
    rounds the batch UP to the next priced bucket and the resident-token
    count UP to the next ``seq_edges`` bucket (conservative within a
    bucket; counts past the last edge use the last bucket). Lookups are
    vectorized — arrays in, arrays out.

    Under the paged residency model (``repro.serve.paged``) the resident
    count an engine passes in is ``pages_mapped * page_size`` — mapped
    pages, not reserved peaks — so a grid built with page-aligned edges
    (``serve_cost_grids(page_size=...)``) prices resident-PAGE buckets:
    eviction/recompute shows up as extra prefill charges and smaller
    resident sweeps, and a compressed-KV policy's bandwidth tax is baked
    into the bucket sweep times. ``page_size`` here is metadata recording
    that alignment (None: plain token buckets).
    """

    config: str
    batches: tuple[int, ...]          # ascending priced batch sizes
    seq_edges: tuple[float, ...]      # ascending resident-token bucket edges
    step_time_s: np.ndarray           # (len(batches), len(seq_edges)) seconds
    prefill_s_per_token: float = 0.0
    page_size: int | None = None      # edges are multiples of this (paged KV)

    def __post_init__(self):
        if list(self.batches) != sorted(set(self.batches)) or not self.batches:
            raise ValueError("batches must be non-empty, ascending, unique")
        if list(self.seq_edges) != sorted(set(self.seq_edges)):
            raise ValueError("seq_edges must be ascending and unique")
        if self.step_time_s.shape != (len(self.batches), len(self.seq_edges)):
            raise ValueError("step_time_s shape mismatch")
        # cache the lookup arrays once — step_time() is the hottest call in
        # the serving simulators and np.searchsorted over a tuple would
        # otherwise rebuild an ndarray on every step
        object.__setattr__(self, "_batches_arr",
                           np.asarray(self.batches, dtype=np.int64))
        object.__setattr__(self, "_edges_arr",
                           np.asarray(self.seq_edges, dtype=float))

    @property
    def max_batch(self) -> int:
        return self.batches[-1]

    def step_time(self, batch, resident_tokens=0):
        b = np.asarray(batch)
        if np.any(b < 1) or np.any(b > self.max_batch):
            raise ValueError(
                f"batch outside priced range [1, {self.max_batch}]: {batch!r}")
        i = np.searchsorted(self._batches_arr, b, side="left")
        j = np.minimum(np.searchsorted(self._edges_arr,
                                       np.asarray(resident_tokens),
                                       side="left"),
                       len(self.seq_edges) - 1)
        out = self.step_time_s[i, j]
        return float(out) if np.ndim(batch) == 0 and np.ndim(resident_tokens) == 0 \
            else out

    def prefill_time(self, prompt_tokens):
        return np.asarray(prompt_tokens) * self.prefill_s_per_token \
            if np.ndim(prompt_tokens) else prompt_tokens * self.prefill_s_per_token

    def saturated_rps(self, output_tokens: int = 1) -> float:
        """Steady-state requests/s at a permanently full batch with empty-KV
        step costs — the closed-loop ceiling the saturation tests pin against
        the ``SweepEngine`` serve rows."""
        return self.max_batch / (self.step_time_s[-1, 0] * output_tokens)


@lru_cache(maxsize=4096)
def _kv_sweep_trace(kv_bytes: int) -> Trace:
    """One decode iteration's KV sweep as a trace: the whole resident cache
    is read once per step. Priced cyclically, the cache model keeps the
    LLC-resident fraction on package and streams only the remainder from
    DRAM — the closed form this replaced charged the whole sweep to a
    single level and over-priced partially-resident caches. Bounded: a
    long repricing session sweeps an open-ended set of byte counts."""
    tr = Trace(name=f"serve.kvsweep.{int(kv_bytes)}", kind="inference")
    tr.emit("kv.sweep", 0.0, reads=[("kvcache", int(kv_bytes))],
            precision="bf16")
    return tr


# KV-sweep pricing session: ONE growing SuiteAnalysis serves every
# kv_sweep_times call in the process. A new byte count (grid repriced with a
# different compression tax, page size, or bytes/token) APPENDS a row in
# O(new trace) and inherits the session's whole capacity union, instead of
# keying a fresh suite per size set and rescanning the overlap.
_KV_SESSION_MAX = 1024
_KV_SESSION: dict[int, int] = {}   # kv byte count -> session row index
_KV_SUITE: SuiteAnalysis | None = None


def _kv_session_suite(sizes: Sequence[int]) -> SuiteAnalysis:
    global _KV_SUITE
    new = [s for s in sizes if s not in _KV_SESSION]
    if _KV_SUITE is None or len(_KV_SESSION) + len(new) > _KV_SESSION_MAX:
        _KV_SESSION.clear()
        _KV_SESSION.update({s: i for i, s in enumerate(sizes)})
        _KV_SUITE = suite_analysis_for([_kv_sweep_trace(s) for s in sizes])
    elif new:
        suite_append(_KV_SUITE, [_kv_sweep_trace(s) for s in new])
        for s in new:
            _KV_SESSION[s] = len(_KV_SESSION)
    return _KV_SUITE


def kv_sweep_times(specs: Sequence[GpuSpec],
                   kv_bytes_seq: Sequence[float]) -> np.ndarray:
    """Per-step KV read times of shape ``(len(kv_bytes_seq), len(specs))``,
    priced through the cache model (steady-state cyclic residency; ideal
    occupancy and no launch overhead — the sweep rides along the decode
    math it accompanies). All sizes share one suite-level ``time_batch``
    over the process-wide KV session suite, so repricing with new sizes
    pays only for the new rows."""
    sizes = [float(b) for b in kv_bytes_seq]
    finite = sorted({int(s) for s in sizes if s > 0 and np.isfinite(s)})
    out = np.zeros((len(sizes), len(specs)))
    if finite:
        suite = _kv_session_suite(finite)
        times = suite.time_batch(list(specs), ideal_occupancy=True)
        lookup = {s: times[:, _KV_SESSION[s]] for s in finite}
    for r, s in enumerate(sizes):
        if s > 0:
            out[r] = lookup[int(s)] if np.isfinite(s) else np.inf
    return out


def prefill_cost_per_token(scenario: str, configs: Sequence[ConfigLike]) -> np.ndarray:
    """Per-config prefill seconds/token priced from a REAL prefill trace.

    ``scenario`` names a registry prefill cell (``lm.<arch>.prefill_*``);
    its trace models one prefill chunk of ``batch x seq_len`` prompt
    tokens, so ONE ``time_batch`` call over all configs divided by the
    chunk's token count yields the per-token prefill cost each config's
    :class:`CostGrid` charges (ROADMAP serving follow-up: the flat s/token
    knob, replaced by trace-sourced pricing)."""
    from repro.configs import SHAPES  # lazy: configs sit above core
    from repro.workloads import registry  # lazy: workloads sit above core

    shape = scenario.rsplit(".", 1)[1]
    if shape not in SHAPES or SHAPES[shape].step != "prefill":
        raise KeyError(
            f"{scenario!r} is not a prefill scenario (expected an "
            f"lm.<arch>.prefill_* registry cell)")
    trace = registry.scenario(scenario)
    tokens = max(trace.batch_size, 1) * SHAPES[shape].seq_len
    specs = [_as_spec(c) for c in configs]
    return analysis_for(trace).time_batch(specs) / tokens


def serve_cost_grids(
    bench: str,
    configs: Sequence[ConfigLike],
    *,
    kv_bytes_per_token: float = 0.0,
    seq_edges: Sequence[float] = DEFAULT_SEQ_EDGES,
    prefill_s_per_token: float = 0.0,
    prefill_scenario: str | None = None,
    tokens_per_pass: int = 1,
    scenario_prefix: str = "serve.mlperf",
    page_size: int | None = None,
    kv_policy=None,
) -> dict[str, CostGrid]:
    """Export (batch x KV-bucket) step-time grids for every config, priced
    from the registry's ``serve.<bench>.b<batch>`` scenarios.

    ONE suite-level ``time_batch`` call covers every (batch bucket, config)
    cell: the batch scenarios share a :class:`SuiteAnalysis`, so pricing a
    serve grid after an engine run re-uses the same padded batch and
    traffic instead of re-running the per-scenario pipeline.
    ``tokens_per_pass`` divides the trace time for scenarios whose one pass
    decodes several tokens (e.g. gnmt's 50-step decoder), yielding a
    per-output-token step cost. With ``kv_bytes_per_token`` zero (the
    one-shot MLPerf semantics) the grid has a single KV bucket and step
    times equal the engine's serve-row times bit-for-bit.

    Prefill pricing: ``prefill_scenario`` names an ``lm.<arch>.prefill_*``
    cell whose trace prices prefill per config (one extra ``time_batch``
    over the prefill chunk — see :func:`prefill_cost_per_token`); it
    overrides the flat ``prefill_s_per_token`` knob.

    Paged residency: ``page_size`` snaps every KV bucket edge UP to the
    next page multiple (deduplicated, order preserved) so the grid's
    buckets land on resident-page boundaries — the counts the paged
    engines actually report. ``kv_policy`` (a
    :class:`repro.core.msm.MemoryPolicy`) applies its
    ``kv_compression_bw_tax`` to the per-bucket KV sweep bytes: compressed
    KV moves ``(1 + tax)`` bytes per resident byte read, pricing the
    Buddy-Compression bandwidth cost into the same grid whose *capacity*
    side grows via ``msm.kv_token_capacity``."""
    from repro.workloads import registry  # lazy: workloads sit above core

    names = registry.scenarios(f"{scenario_prefix}.{bench}.b")
    if not names:
        raise KeyError(f"no {scenario_prefix}.{bench}.b* scenarios registered")
    by_batch = sorted((int(n.rsplit(".b", 1)[1]), n) for n in names)
    batches = tuple(b for b, _ in by_batch)
    specs = [(_config_name(c), _as_spec(c)) for c in configs]
    spec_objs = [s for _, s in specs]
    suite = suite_analysis_for([registry.scenario(scen) for _, scen in by_batch])
    base = suite.time_batch(spec_objs).T / max(int(tokens_per_pass), 1)

    if prefill_scenario is not None:
        prefill = prefill_cost_per_token(prefill_scenario, configs)
    else:
        prefill = np.full(len(specs), float(prefill_s_per_token))
    if kv_bytes_per_token > 0:
        edges = [float(e) for e in seq_edges]
        if page_size is not None:
            if page_size < 1:
                raise ValueError("page_size must be >= 1")
            snapped = [float(-(-int(e) // page_size) * page_size)
                       for e in edges if np.isfinite(e)]
            snapped += [e for e in edges if not np.isfinite(e)]
            edges = sorted(set(snapped))
        edges = tuple(edges)
    else:
        edges = (float("inf"),)
    bw_tax = 0.0 if kv_policy is None else float(kv_policy.kv_compression_bw_tax)
    kv = kv_sweep_times(spec_objs,
                        [e * kv_bytes_per_token * (1.0 + bw_tax)
                         for e in edges]) \
        if kv_bytes_per_token > 0 else np.zeros((1, len(specs)))
    out = {}
    for ci, (name, spec) in enumerate(specs):
        out[name] = CostGrid(
            config=name,
            batches=batches,
            seq_edges=edges,
            step_time_s=base[:, ci][:, None] + kv[:, ci][None, :],
            prefill_s_per_token=float(prefill[ci]),
            page_size=page_size,
        )
    return out


class SweepEngine:
    """One batched pipeline over (traces x configs x LLC capacities x GPUs).

    Per workload the engine builds (or reuses) a :class:`TraceAnalysis`,
    prefetches the union of every capacity any config touches in a single
    vectorized pass, then costs ALL configs from the shared cache with one
    (config x op) matrix evaluation per attribution term — the whole
    Table-V design space costs one trace walk instead of one per config.

    ``gpu_counts`` adds the scale-out dimension: every workload is also
    projected onto n data-parallel instances (per-GPU trace from
    :class:`ScaleOutWorkload.trace_for`, or the same trace for weak
    scaling), with training steps charged a gradient ring all-reduce over
    the ``ici_bandwidth`` fabric. Rows carry throughput and scaling
    efficiency against the 1-GPU baseline config.
    """

    def __init__(
        self,
        traces: Iterable[WorkloadLike],
        configs: Sequence[ConfigLike] | None = None,
        baseline: ConfigLike | None = None,
        extra_llc_capacities: Sequence[float] = (),
        cyclic: bool = True,
        share_analyses: bool = True,
        gpu_counts: Sequence[int] = (1,),
        ici_bandwidth: float = float("inf"),
        ici_latency_s: float = 0.0,
    ):
        self.workloads = _expand_workloads(traces)
        self.configs = list(configs if configs is not None else copa_mod.TABLE_V)
        self.baseline = baseline if baseline is not None else copa_mod.GPU_N_BASE
        self.extra_llc_capacities = [float(c) for c in extra_llc_capacities]
        self.cyclic = cyclic
        self.gpu_counts = sorted({int(n) for n in gpu_counts})
        if any(n < 1 for n in self.gpu_counts):
            raise ValueError("gpu_counts must be >= 1")
        if float(ici_bandwidth) <= 0:
            raise ValueError("ici_bandwidth must be > 0 bytes/s "
                             "(use the default inf for an ideal fabric)")
        self.ici_bandwidth = float(ici_bandwidth)
        self.ici_latency_s = float(ici_latency_s)
        # share_analyses=False keeps this engine's analyses private — used by
        # cold-cache benchmarking; everything else should share the process
        # cache so figures/tests reuse streams and traffic.
        self._share = share_analyses
        self._private: dict[int, TraceAnalysis] = {}

    @property
    def traces(self) -> list[Trace]:
        """The 1-GPU trace of every workload (back-compat accessor)."""
        return [w.trace_for(1) for w in self.workloads]

    def analysis(self, trace: Trace) -> TraceAnalysis:
        if self._share:
            return analysis_for(trace, cyclic=self.cyclic)
        key = id(trace)
        if key not in self._private:
            self._private[key] = TraceAnalysis(trace, cyclic=self.cyclic)
        return self._private[key]

    def suite_analysis(self, traces: Sequence[Trace]) -> SuiteAnalysis:
        if self._share:
            return suite_analysis_for(traces, cyclic=self.cyclic)
        streams = build_streams(traces, cyclic=self.cyclic)
        for t, s in zip(traces, streams):
            if id(t) not in self._private:
                self._private[id(t)] = TraceAnalysis(t, cyclic=self.cyclic,
                                                     stream=s)
        return SuiteAnalysis(traces, cyclic=self.cyclic,
                             analyses=[self._private[id(t)] for t in traces])

    def run(self, batched: bool = True) -> SweepGrid:
        """Evaluate the grid. The default path pads every workload's touch
        stream into one :class:`~repro.core.cachesim.StreamBatch` and costs
        the whole (trace x config x capacity x GPU count) space through a
        single :class:`SuiteAnalysis` pass; ``batched=False`` runs the
        original per-trace loop, kept as the bit-for-bit parity oracle
        (asserted in tests) and the before/after benchmark baseline."""
        if not batched:
            return self._run_per_trace()
        base_spec = _as_spec(self.baseline)
        specs = [(_config_name(c), _as_spec(c)) for c in self.configs]
        spec_objs = [spec for _, spec in specs]
        grid = SweepGrid(baseline=_config_name(self.baseline))
        caps: set[float] = set(self.extra_llc_capacities)
        for _, spec in specs:
            caps.update(TraceAnalysis.capacities_for(spec))
        caps.update(TraceAnalysis.capacities_for(base_spec))

        # Materialize every (workload, n) trace, dedup by identity: scale-out
        # families often return the same object at several instance counts.
        jobs: list[tuple[ScaleOutWorkload, Trace, list[tuple[int, Trace]]]] = []
        index: dict[int, int] = {}
        suite_traces: list[Trace] = []
        for w in self.workloads:
            trace1 = w.trace_for(1)
            per_n = [(n, trace1 if n == 1 else w.trace_for(n))
                     for n in self.gpu_counts]
            jobs.append((w, trace1, per_n))
            for _, t in [(1, trace1)] + per_n:
                if id(t) not in index:
                    index[id(t)] = len(suite_traces)
                    suite_traces.append(t)
        suite = self.suite_analysis(suite_traces)
        suite.prefetch(caps)

        # One suite pass: base-config times, the four-term attribution, and
        # per-(spec, trace) traffic/energy vectors.
        t_base_all = suite.time_batch([base_spec])[0] \
            if suite_traces else np.zeros(0)
        att_all = suite.attribution_grid(spec_objs)
        post_tot = {spec.l2_capacity: suite.totals_below(spec.l2_capacity)
                    for _, spec in specs}
        dram_tot = {_dram_cap(spec):
                    suite.totals_below(_dram_cap(spec))
                    for _, spec in specs}
        l2_sum = np.array([float(ta.l2_touch.sum())
                           for ta in suite.analyses])

        for w, trace1, per_n in jobs:
            i1 = index[id(trace1)]
            t_base = float(t_base_all[i1])
            base_batch = trace1.batch_size
            # 1-GPU speedup per config anchors the scaling-efficiency ratio.
            sp1 = {name: (t_base / att[0] if att[0] > 0 else float("inf"))
                   for (name, _), att in zip(specs, att_all[i1])}

            for n, trace_n in per_n:
                i = index[id(trace_n)]
                ta = suite.analyses[i]
                coll = ring_allreduce_time(
                    ta.grad_bytes, n, self.ici_bandwidth, self.ici_latency_s
                ) if trace_n.kind == "training" else 0.0
                batch_n = trace_n.batch_size

                for (name, spec), (t_act, segments) in zip(specs, att_all[i]):
                    time_s = t_act + coll
                    if n == 1 and coll == 0.0:
                        sp = t_base / time_s
                    elif batch_n and base_batch:
                        # throughput ratio at whatever the global batch is
                        sp = (batch_n * n / time_s) / (base_batch / t_base)
                    else:
                        sp = n * t_base / time_s  # batchless: weak scaling
                    eff = sp / (n * sp1[name]) if sp1[name] > 0 else 1.0
                    post = float(post_tot[spec.l2_capacity][i])
                    dram = float(dram_tot[_dram_cap(spec)][i])
                    has_l3 = bool(spec.l3_capacity)
                    l3_bytes = max(post - dram, 0.0) if has_l3 else 0.0
                    dram_j = dram * 8.0 * spec.dram_energy_pj_per_bit * 1e-12
                    l3_j = l3_bytes * 8.0 \
                        * (spec.dram_energy_pj_per_bit / 4.0) * 1e-12
                    grid.add(SweepResult(
                        trace=w.name,
                        kind=trace_n.kind,
                        config=name,
                        spec_name=spec.name,
                        time_s=time_s,
                        baseline_time_s=t_base,
                        speedup=sp,
                        segments=segments,
                        dram_bytes=dram,
                        l3_bytes=l3_bytes,
                        uhb_bytes=post if has_l3 else 0.0,
                        l2_bytes=float(l2_sum[i]),
                        dram_joules=dram_j,
                        l3_joules=l3_j,
                        n_gpus=n,
                        per_gpu_time_s=t_act,
                        collective_time_s=coll,
                        throughput=(batch_n or 1) * n / time_s,
                        scaling_efficiency=eff,
                    ))
            if self.extra_llc_capacities:
                grid.llc_traffic[w.name] = suite.analyses[i1].dram_traffic(
                    self.extra_llc_capacities
                )
        return grid

    def _run_per_trace(self) -> SweepGrid:
        """The pre-batch per-trace loop: one TraceAnalysis, one traffic
        prefetch and one attribution per trace. Parity oracle for
        :meth:`run` and the benchmark baseline in ``bench_core``."""
        base_spec = _as_spec(self.baseline)
        specs = [(_config_name(c), _as_spec(c)) for c in self.configs]
        spec_objs = [spec for _, spec in specs]
        grid = SweepGrid(baseline=_config_name(self.baseline))
        caps: set[float] = set(self.extra_llc_capacities)
        for _, spec in specs:
            caps.update(TraceAnalysis.capacities_for(spec))
        caps.update(TraceAnalysis.capacities_for(base_spec))

        for w in self.workloads:
            trace1 = w.trace_for(1)
            ta1 = self.analysis(trace1)
            ta1.prefetch(caps)
            t_base = ta1.time(base_spec)
            att1 = ta1.attribution_batch(spec_objs)
            base_batch = trace1.batch_size
            # 1-GPU speedup per config anchors the scaling-efficiency ratio.
            sp1 = {name: (t_base / att[0] if att[0] > 0 else float("inf"))
                   for (name, _), att in zip(specs, att1)}

            for n in self.gpu_counts:
                trace_n = trace1 if n == 1 else w.trace_for(n)
                if trace_n is trace1:
                    ta, att = ta1, att1
                else:
                    ta = self.analysis(trace_n)
                    ta.prefetch(caps)
                    att = ta.attribution_batch(spec_objs)
                coll = ring_allreduce_time(
                    ta.grad_bytes, n, self.ici_bandwidth, self.ici_latency_s
                ) if trace_n.kind == "training" else 0.0
                batch_n = trace_n.batch_size

                for (name, spec), (t_act, segments) in zip(specs, att):
                    time_s = t_act + coll
                    if n == 1 and coll == 0.0:
                        sp = t_base / time_s
                    elif batch_n and base_batch:
                        # throughput ratio at whatever the global batch is
                        sp = (batch_n * n / time_s) / (base_batch / t_base)
                    else:
                        sp = n * t_base / time_s  # batchless: weak scaling
                    eff = sp / (n * sp1[name]) if sp1[name] > 0 else 1.0
                    tr = ta.hierarchy(spec)
                    en = ta.energy(spec)
                    grid.add(SweepResult(
                        trace=w.name,
                        kind=trace_n.kind,
                        config=name,
                        spec_name=spec.name,
                        time_s=time_s,
                        baseline_time_s=t_base,
                        speedup=sp,
                        segments=segments,
                        dram_bytes=tr.dram.total,
                        l3_bytes=tr.l3_bytes,
                        uhb_bytes=tr.post_l2.total if tr.has_l3 else 0.0,
                        l2_bytes=float(ta.l2_touch.sum()),
                        dram_joules=en.dram_joules,
                        l3_joules=en.l3_joules,
                        n_gpus=n,
                        per_gpu_time_s=t_act,
                        collective_time_s=coll,
                        throughput=(batch_n or 1) * n / time_s,
                        scaling_efficiency=eff,
                    ))
            if self.extra_llc_capacities:
                grid.llc_traffic[w.name] = ta1.dram_traffic(
                    self.extra_llc_capacities
                )
        return grid
