"""COPA-GPU core: the paper's analytical machinery + TPU adaptation.

Public API:
    hw         — hardware descriptions (GPU-N, COPA links, TPU v5e)
    copa       — Table V design space + energy model
    trace      — tensor-access trace IR
    stackdist  — LRU stack distances (Mattson)
    cachesim   — L2 -> L3 -> DRAM hierarchy traffic model
    sweep      — batched design-space sweep engine (TraceAnalysis/SweepEngine)
    perfmodel  — bottleneck time model + Fig-2 attribution (facade over sweep)
    roofline   — 3-term TPU roofline from dry-run artifacts
    hloparse   — collective-bytes extraction from HLO
    msm        — software memory-system-module policies (TPU adaptation)
"""
from repro.core import (
    cachesim,
    copa,
    hloparse,
    hw,
    msm,
    perfmodel,
    roofline,
    stackdist,
    sweep,
    trace,
)

__all__ = [
    "cachesim",
    "copa",
    "hloparse",
    "hw",
    "msm",
    "perfmodel",
    "roofline",
    "stackdist",
    "sweep",
    "trace",
]
