"""Three-term roofline analysis from compiled dry-run artifacts.

Per (architecture x shape x mesh):

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
already per-partition under SPMD — see note in ``launch/dryrun.py``);
collective_bytes from :mod:`repro.core.hloparse` over the optimized HLO.
The dominant term is the bottleneck the §Perf loop iterates on.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.hw import TPU_V5E, TpuSpec


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per-device FLOPs of one step
    hlo_bytes: float            # per-device HBM bytes accessed
    collective_bytes: float     # per-device bytes crossing ICI
    model_flops: float          # 6*N*D useful-model FLOPs (global)
    peak_memory_bytes: float = 0.0
    collective_breakdown: dict = field(default_factory=dict)
    spec: TpuSpec = TPU_V5E

    # -- the three terms, in seconds -------------------------------------------
    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.spec.bf16_tflops * 1e12)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / self.spec.hbm_bandwidth

    @property
    def collective_s(self) -> float:
        # Bytes leave a chip over its ICI links; a ring collective streams over
        # one link-pair at a time, so the conservative bound uses one link.
        return self.collective_bytes / self.spec.ici_link_bandwidth

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the step achieves if it runs exactly at the max
        term (the overlap-perfect bound): useful-FLOPs utilization."""
        if self.bound_s <= 0:
            return 0.0
        per_dev_model_flops = self.model_flops / max(self.chips, 1)
        return per_dev_model_flops / (self.bound_s * self.spec.bf16_tflops * 1e12)

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much of compiled compute is useful
        (catches remat/redundancy waste). >1 means HLO under-counts (e.g.
        fused ops); <1 means recompute/padding overheads."""
        total_hlo = self.hlo_flops * max(self.chips, 1)
        return self.model_flops / total_hlo if total_hlo else float("nan")

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "roofline_fraction": self.roofline_fraction,
            "model_flops_ratio": self.model_flops_ratio,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def model_flops_lm(n_params_active: float, tokens: float, training: bool) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D for a pure forward/decode step."""
    return (6.0 if training else 2.0) * n_params_active * tokens


def useful_flops_cell(cfg, shape) -> float:
    """Useful model FLOPs for one step of an (arch x shape) cell: the
    parameter term (6ND / 2ND) PLUS the sequence-mixing term, which at 32k+
    dominates and which 6ND ignores (attention: 4*B*H*S^2*hd per layer with
    causal halving; SSD: linear in S). Recompute (remat/flash-bwd) is
    deliberately excluded — that is what model_flops_ratio exposes."""
    training = shape.step == "train"
    fwd_bwd = 3.0 if training else 1.0
    gb, s = shape.global_batch, shape.seq_len
    tokens = gb * (1 if shape.step == "decode" else s)
    total = (2.0 * fwd_bwd) * cfg.n_active_params() * tokens

    def attn_flops(n_layers, s_q, s_kv, causal):
        hd = cfg.head_dim + (cfg.rope_head_dim if cfg.use_mla else 0)
        per_layer = 2.0 * 2.0 * gb * cfg.n_heads * s_q * s_kv * hd
        if causal and s_q == s_kv:
            per_layer *= 0.5
        return n_layers * per_layer * fwd_bwd

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        if shape.step == "decode":
            total += attn_flops(cfg.n_layers, 1, s, causal=False)
        else:
            total += attn_flops(cfg.n_layers, s, s, causal=True)
    elif fam == "ssm":
        di = cfg.d_inner
        total += (2.0 * 2.0 * gb * (1 if shape.step == "decode" else s)
                  * di * cfg.ssm_state * fwd_bwd * cfg.n_layers)
    elif fam == "hybrid":
        di = cfg.d_inner
        steps = 1 if shape.step == "decode" else s
        total += (2.0 * 2.0 * gb * steps * di * cfg.ssm_state * fwd_bwd
                  * cfg.n_layers)
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        if shape.step == "decode":
            total += attn_flops(n_attn, 1, s, causal=False)
        else:
            total += attn_flops(n_attn, s, s, causal=True)
    elif fam == "audio":
        if shape.step == "decode":
            total += attn_flops(cfg.n_layers, 1, s, causal=False)
        else:
            total += attn_flops(cfg.n_encoder_layers, s, s, causal=False)
            total += attn_flops(cfg.n_layers, s // 4, s // 4, causal=True)
            total += attn_flops(cfg.n_layers, s // 4, s, causal=False)
    return total


def format_table(reports: list[RooflineReport]) -> str:
    hdr = (
        f"{'arch':24s} {'shape':12s} {'mesh':10s} {'compute_s':>11s} {'memory_s':>11s} "
        f"{'collect_s':>11s} {'dominant':>10s} {'roofline%':>10s} {'useful%':>8s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in reports:
        ratio = r.model_flops_ratio
        ratio_s = f"{100*min(ratio, 9.99):7.1f}%" if ratio == ratio else "      —"
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.mesh:10s} {r.compute_s:11.4e} {r.memory_s:11.4e} "
            f"{r.collective_s:11.4e} {r.dominant:>10s} {100*r.roofline_fraction:9.1f}% "
            f"{ratio_s}"
        )
    return "\n".join(lines)
