"""Hardware descriptions for the COPA-GPU study and the TPU target.

Numbers come straight from the paper (Tables I, II, IV) and public TPU v5e
specifications. Everything is a frozen dataclass so configs hash and compare
cleanly and can be used as pytree aux data.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

KB = 1024
MB = 1024 * KB
GB = 1024 * MB
TB = 1024 * GB

# Throughputs use decimal units as in vendor datasheets.
GBPS = 1e9
TBPS = 1e12
TFLOPS = 1e12


@dataclass(frozen=True)
class LinkSpec:
    """An on-package UHB link (paper Table II) or an off-chip interconnect."""

    name: str
    bandwidth: float            # bytes/s, unidirectional unless noted
    energy_pj_per_bit: float    # pJ/b
    # Paper: 2.5D = 256 GB/s/mm edge density, 3D = 512 GB/s/mm^2 areal density.
    density: float = 0.0
    density_unit: str = ""

    def energy_joules(self, num_bytes: float) -> float:
        return num_bytes * 8.0 * self.energy_pj_per_bit * 1e-12


@dataclass(frozen=True)
class GpuSpec:
    """A converged-GPU (or GPM) compute+memory description (paper Table IV).

    ``l3_capacity``/``l3_bandwidth`` are zero for monolithic designs; COPA
    variants are built by ``repro.core.copa`` layering an MSM on top of a GPM.
    """

    name: str
    num_sms: int
    frequency_ghz: float
    fp32_tflops: float
    fp16_tflops: float
    l2_capacity: int            # bytes
    dram_bandwidth: float       # bytes/s
    dram_capacity: int          # bytes
    # L2 is the bandwidth filter in front of everything (GPM-internal).
    # Aggregate L2 bandwidth on modern GPUs is ~10x DRAM bandwidth.
    l2_bandwidth_ratio: float = 10.0
    # Memory-side L3 (MSM) — zero when absent.
    l3_capacity: int = 0
    l3_bandwidth: float = 0.0   # post-L2 UHB link bandwidth (per direction RD/WR)
    l3_energy_pj_per_bit: float = 0.0
    # DRAM access energy, used by the §III-D energy model. The paper states a
    # COPA L3 hit costs ~4x less than HBM access.
    dram_energy_pj_per_bit: float = 7.0
    max_threads_per_sm: int = 2048

    @property
    def l2_bandwidth(self) -> float:
        return self.dram_bandwidth * self.l2_bandwidth_ratio

    @property
    def llc_capacity(self) -> int:
        """Last-level cache the DRAM sees: L3 when present, else L2."""
        return self.l3_capacity if self.l3_capacity else self.l2_capacity

    @property
    def concurrency(self) -> int:
        return self.num_sms * self.max_threads_per_sm

    def with_(self, **kw) -> "GpuSpec":
        return dataclasses.replace(self, **kw)


# --- Paper Table IV configurations -----------------------------------------

V100 = GpuSpec(
    name="V100", num_sms=80, frequency_ghz=1.4, fp32_tflops=15.7,
    fp16_tflops=125.0, l2_capacity=6 * MB, dram_bandwidth=900 * GBPS,
    dram_capacity=16 * GB,
)

A100 = GpuSpec(
    name="A100", num_sms=108, frequency_ghz=1.4, fp32_tflops=19.5,
    fp16_tflops=312.0, l2_capacity=40 * MB, dram_bandwidth=1555 * GBPS,
    dram_capacity=40 * GB,
)

# The paper's forward projection ("GPU-N", Tables I/IV).
GPU_N = GpuSpec(
    name="GPU-N", num_sms=134, frequency_ghz=1.4, fp32_tflops=24.2,
    fp16_tflops=779.0, l2_capacity=60 * MB, dram_bandwidth=2687 * GBPS,
    dram_capacity=100 * GB,
)

P100 = GpuSpec(
    name="P100", num_sms=56, frequency_ghz=1.3, fp32_tflops=11.0,
    fp16_tflops=21.0, l2_capacity=4 * MB, dram_bandwidth=732 * GBPS,
    dram_capacity=16 * GB,
)

# --- Paper Table II link technologies ---------------------------------------

UHB_2_5D = LinkSpec(
    name="UHB-2.5D", bandwidth=14.7 * TBPS, energy_pj_per_bit=0.3,
    density=256 * GBPS, density_unit="GB/s/mm",
)
UHB_3D = LinkSpec(
    name="UHB-3D", bandwidth=14.7 * TBPS, energy_pj_per_bit=0.05,
    density=512 * GBPS, density_unit="GB/s/mm^2",
)


# --- TPU target (assignment constants) ---------------------------------------

@dataclass(frozen=True)
class TpuSpec:
    """Per-chip TPU description used by the roofline analysis."""

    name: str
    bf16_tflops: float          # peak dense matmul throughput
    hbm_bandwidth: float        # bytes/s
    hbm_capacity: int           # bytes
    ici_link_bandwidth: float   # bytes/s per link direction
    ici_links: int              # links per chip in the 2D/3D torus
    vmem_capacity: int          # on-chip vector memory

    @property
    def flops_per_byte_hbm(self) -> float:
        return self.bf16_tflops * TFLOPS / self.hbm_bandwidth


# Assignment-provided constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
TPU_V5E = TpuSpec(
    name="TPUv5e", bf16_tflops=197.0, hbm_bandwidth=819 * GBPS,
    hbm_capacity=16 * GB, ici_link_bandwidth=50 * GBPS, ici_links=4,
    vmem_capacity=128 * MB,
)


# --- TPU tiling + Pallas budgets (used by the repro.check static analyzer) ----

# The MXU is a 128x128 systolic array; the VPU operates on (8, 128) f32
# registers. VMEM tiles are (sublane, lane) with lane fixed at 128 and the
# minimum sublane count scaling inversely with dtype width.
MXU_TILE = (128, 128)
VPU_TILE = (8, 128)
TPU_LANE = 128
# dtype itemsize (bytes) -> minimum sublane count of one VMEM tile.
TPU_MIN_SUBLANE = {8: 8, 4: 8, 2: 16, 1: 32}


def min_tile(dtype_itemsize: int) -> tuple[int, int]:
    """Minimum (sublane, lane) VMEM tile for a dtype of the given width."""
    return (TPU_MIN_SUBLANE.get(int(dtype_itemsize), 8), TPU_LANE)


# Pallas double-buffers every grid-blocked operand so the next block's DMA
# overlaps the current compute step; the R5 footprint rule charges each
# in/out block twice and scratch once.
PALLAS_PIPELINE_BUFFERS = 2
PALLAS_VMEM_BUDGET = TPU_V5E.vmem_capacity
# SMEM holds scalars/control state only; budget is deliberately tight.
PALLAS_SMEM_BUDGET = 1 * MB
