"""Memory-hierarchy traffic model: L2 -> (optional memory-side L3) -> DRAM.

Faithful to the paper's §III-C microarchitecture:

* the L2 (inside the GPM) is the point of coherence and the first bandwidth
  filter; every post-L2 miss/writeback crosses the UHB link when an MSM with
  L3 is present;
* the L3 is a *memory-side* cache: it only observes post-L2 traffic, is
  neither inclusive nor exclusive, and needs no coherence. We model the
  (L2, L3) pair for DRAM-traffic purposes as a single LRU pool of capacity
  ``C_L2 + C_L3`` observed by DRAM — exact for the steady-state streaming
  traffic that dominates DL iterations (validated against BlockLRU in tests).

Residency is fractional at tensor granularity: a touch of tensor T with
bytes-weighted unique-reuse distance U against a cache of capacity C finds
``clip(C - U, 0, |T|)`` of its bytes resident. Writebacks use a per-tensor
dirty fraction; dirty bytes evicted before the next touch are charged to the
next level (attributed, for per-op accounting, to the touching op — the
evicting op is not identifiable at this granularity).

Steady state: the paper simulates one end-to-end iteration of workloads that
run for thousands of iterations, so cold misses are amortized; we double the
trace and read statistics off the second copy (``cyclic=True``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.hw import GpuSpec
from repro.core.stackdist import _mattson_pass
from repro.core.trace import Trace


@dataclass
class TouchStream:
    """Flattened, doubled touch arrays for one trace (capacity-independent)."""

    n_ops: int
    op_idx: np.ndarray     # int32, len 2T (doubled)
    sizes: np.ndarray      # float64
    is_write: np.ndarray   # bool
    dist: np.ndarray       # bytes-weighted unique reuse distance per touch
    tensor_idx: np.ndarray  # int64 dense tensor ids
    n_tensors: int
    second_half: int       # index where the steady-state copy begins


def _assign_buffers(trace: Trace) -> dict[str, str]:
    """Caching-allocator model: transient tensors (first touched by a write,
    later dead) recycle buffers freed by earlier-dying tensors, exactly like
    the framework allocators under the paper's traces. Returns a tensor->
    buffer mapping; persistent tensors (weights, optimizer state — read
    before written) and streaming inputs keep their own identity.

    Without this, every dirty activation would be charged a DRAM writeback
    once per iteration when its (never-reused) address range is evicted;
    with buffer recycling the next owner overwrites the dirty lines while
    they are still resident — which is what lets a large L3 collapse
    inference traffic (paper Fig 4's 16x)."""
    touches = list(trace.touches())
    first: dict[str, int] = {}
    last: dict[str, int] = {}
    size: dict[str, int] = {}
    first_is_write: dict[str, bool] = {}
    for pos, (_, t, b, w) in enumerate(touches):
        if t not in first:
            first[t] = pos
            first_is_write[t] = w
        last[t] = pos
        size[t] = max(size.get(t, 0), b)

    def transient(t: str) -> bool:
        return first_is_write[t] and not t.startswith("in.")

    # Free events sorted by position; greedy best-fit (smallest buffer >= size).
    # Buffers are recycled REUSE_DELAY touches after death: asynchronous
    # execution keeps freed buffers pinned briefly, so reuse is near- but not
    # perfectly-immediate (calibrated against Fig 4's inference saturation
    # capacities).
    REUSE_DELAY = 24
    mapping: dict[str, str] = {}
    free: list[tuple[int, str]] = []  # (buffer_size, buffer_name)
    deaths = sorted((last[t] + REUSE_DELAY, t) for t in first if transient(t))
    di = 0
    buf_of: dict[str, str] = {}
    import bisect

    for pos, (_, t, b, w) in enumerate(touches):
        while di < len(deaths) and deaths[di][0] < pos:
            dead = deaths[di][1]
            if dead in buf_of:
                bisect.insort(free, (size[dead], buf_of[dead]))
            di += 1
        if t in mapping or not transient(t) or first[t] != pos:
            continue
        i = bisect.bisect_left(free, (size[t], ""))
        if i < len(free):
            _, buf = free.pop(i)
        else:
            buf = f"__buf{len(buf_of)}.{t}"
        mapping[t] = buf
        buf_of[t] = buf
    return mapping


def build_stream(trace: Trace, cyclic: bool = True, reuse_buffers: bool = True,
                 dist_fn=_mattson_pass) -> TouchStream:
    """Tensors whose name starts with ``in.`` are *streaming*: fresh data
    arrives every iteration (input batches, labels), so consecutive
    iterations never reuse them — they get one tensor identity per iteration
    copy instead of wrapping around. Transient tensors share recycled buffer
    identities (see :func:`_assign_buffers`). ``dist_fn`` selects the Mattson
    implementation (the per-touch reference is used by parity/benchmark
    paths)."""
    mapping = _assign_buffers(trace) if reuse_buffers else {}
    op_idx, tids, sizes, is_write = [], [], [], []
    intern: dict[str, int] = {}
    stream_seq = 0
    for i, t, b, w in trace.touches():
        op_idx.append(i)
        t = mapping.get(t, t)
        if t.startswith("in.") and t not in intern:
            # unique id now; forget it so the doubled copy gets a fresh one
            tids.append(len(intern) + 1_000_000_000 + stream_seq)
            stream_seq += 1
        else:
            tids.append(intern.setdefault(t, len(intern)))
        sizes.append(float(b))
        is_write.append(w)
    op_idx = np.asarray(op_idx, dtype=np.int32)
    tids = np.asarray(tids, dtype=np.int64)
    sizes = np.asarray(sizes, dtype=np.float64)
    is_write = np.asarray(is_write, dtype=bool)
    n = len(op_idx)
    if cyclic and n:
        op_idx = np.concatenate([op_idx, op_idx])
        # Streaming tensors (ids >= 1e9) must NOT alias across the two copies.
        tids2 = np.where(tids >= 1_000_000_000, tids + 1_000_000_000, tids)
        tids = np.concatenate([tids, tids2])
        sizes = np.concatenate([sizes, sizes])
        is_write = np.concatenate([is_write, is_write])
    # Dense tensor ids (streaming copies included) for state arrays.
    if n:
        _, dense = np.unique(tids, return_inverse=True)
    else:
        dense = tids
    dist = dist_fn(dense, sizes) if n else np.zeros(0)
    return TouchStream(
        n_ops=len(trace.ops),
        op_idx=op_idx,
        sizes=sizes,
        is_write=is_write,
        dist=dist,
        tensor_idx=dense,
        n_tensors=int(dense.max()) + 1 if n else 0,
        second_half=n if cyclic else 0,
    )


@dataclass
class LevelTraffic:
    """Per-op traffic crossing out the bottom of one cache level."""

    fill: np.ndarray        # bytes fetched per op (read misses)
    writeback: np.ndarray   # dirty bytes written back per op

    @property
    def total(self) -> float:
        return float(self.fill.sum() + self.writeback.sum())

    @property
    def total_fill(self) -> float:
        return float(self.fill.sum())

    @property
    def total_writeback(self) -> float:
        return float(self.writeback.sum())


def _reference_traffic_below(
    stream: TouchStream, capacities: list[float]
) -> list[LevelTraffic]:
    """Per-touch oracle for :func:`traffic_below` (sequential dirty-state
    recurrence carrying a (n_tensors x n_caps) state). Retained for parity
    tests and the before/after timing in ``benchmarks/bench_core.py``."""
    caps = np.asarray(capacities, dtype=np.float64)
    ncap = len(caps)
    fills = np.zeros((ncap, stream.n_ops))
    wbs = np.zeros((ncap, stream.n_ops))
    if len(stream.op_idx) == 0:
        return [LevelTraffic(fills[i], wbs[i]) for i in range(ncap)]

    dirty = np.zeros((stream.n_tensors, ncap))
    start_attrib = stream.second_half
    for t in range(len(stream.op_idx)):
        size = stream.sizes[t]
        d = stream.dist[t]
        x = stream.tensor_idx[t]
        op = stream.op_idx[t]
        record = t >= start_attrib
        if np.isinf(d):
            resident = np.zeros(ncap)
        else:
            resident = np.clip(caps - d, 0.0, size)
        evicted = size - resident
        wb_bytes = evicted * dirty[x]
        if record:
            wbs[:, op] += wb_bytes
        if stream.is_write[t]:
            if record:
                # full-tensor stores: no fill on write-allocate
                pass
            dirty[x] = 1.0
        else:
            if record:
                fills[:, op] += evicted
            # evicted dirty bytes were flushed; resident dirty bytes remain
            frac = np.divide(resident, size, out=np.zeros_like(resident), where=size > 0)
            dirty[x] = dirty[x] * frac
    return [LevelTraffic(fills[i], wbs[i]) for i in range(ncap)]


def traffic_below(stream: TouchStream, capacities: list[float]) -> list[LevelTraffic]:
    """Traffic leaving an LRU pool of each capacity, one trace pass total.

    Fully vectorized over (touches x capacities). The dirty fraction seen by
    a touch is a product of residency fractions along its tensor's chain of
    reads since the last write (writes reset it to 1, chain starts to 0), so
    grouping touches by tensor turns the sequential recurrence into a
    segmented cumulative-product scan: a log-space cumsum with per-segment
    base subtraction, plus an explicit zero counter so exact-zero fractions
    stay exact. Each capacity column is independent, so batching capacities
    is bit-identical to evaluating them one at a time — the property the
    sweep engine relies on to share one pass across a whole design space.
    """
    caps = np.asarray(capacities, dtype=np.float64)
    ncap = len(caps)
    n_ops = stream.n_ops
    n = len(stream.op_idx)
    if n == 0 or ncap == 0:
        return [LevelTraffic(np.zeros(n_ops), np.zeros(n_ops))
                for _ in range(ncap)]

    # Group touches by tensor, preserving time order inside each chain.
    order = np.argsort(stream.tensor_idx, kind="stable")
    sizes = stream.sizes[order]
    dist = stream.dist[order]
    is_write = stream.is_write[order]
    tid = stream.tensor_idx[order]
    op_idx = stream.op_idx[order]
    record = order >= stream.second_half

    # Residency per (touch, capacity); +inf distance -> nothing resident.
    with np.errstate(invalid="ignore"):  # inf cap - inf dist
        resident = np.clip(caps[None, :] - dist[:, None], 0.0, sizes[:, None])
    resident[np.isinf(dist)] = 0.0
    evicted = sizes[:, None] - resident
    frac = np.divide(
        resident, sizes[:, None], out=np.zeros_like(resident),
        where=sizes[:, None] > 0,
    )

    pos = np.arange(n)
    chain_start = np.maximum.accumulate(
        np.where(np.concatenate([[True], tid[1:] != tid[:-1]]), pos, 0)
    )
    # Last write strictly before each touch (global running max; valid only
    # when it falls inside the touch's own chain).
    last_write_incl = np.maximum.accumulate(np.where(is_write, pos, -1))
    last_write = np.concatenate([[-1], last_write_incl[:-1]])
    has_base = last_write >= chain_start

    # Segmented product of read fractions over (last_write, touch), in log
    # space; zero fractions tracked separately so they yield exactly 0.
    is_read_col = ~is_write[:, None]
    log_safe = np.log(np.where(is_read_col & (frac > 0), frac, 1.0))
    zero_read = is_read_col & (frac <= 0.0)
    log_cum = np.concatenate([np.zeros((1, ncap)), np.cumsum(log_safe, axis=0)])
    zero_cum = np.concatenate(
        [np.zeros((1, ncap), dtype=np.int64), np.cumsum(zero_read, axis=0)]
    )
    seg_lo = last_write + 1  # first read after the resetting write
    dirty = np.exp(log_cum[pos] - log_cum[seg_lo])
    dirty[(zero_cum[pos] - zero_cum[seg_lo]) > 0] = 0.0
    dirty[~has_base] = 0.0

    # Scatter recorded traffic back to (capacity, op): flat index c*n_ops+op,
    # one weighted bincount for writebacks and one for fills.
    cap_offsets = np.arange(ncap, dtype=np.int64)[None, :] * n_ops
    rec = np.nonzero(record)[0]
    flat = (op_idx[rec, None].astype(np.int64) + cap_offsets).ravel()
    wbs = np.bincount(
        flat, weights=(evicted[rec] * dirty[rec]).ravel(), minlength=ncap * n_ops
    ).reshape(ncap, n_ops)
    rd = np.nonzero(record & ~is_write)[0]
    flat_rd = (op_idx[rd, None].astype(np.int64) + cap_offsets).ravel()
    fills = np.bincount(
        flat_rd, weights=evicted[rd].ravel(), minlength=ncap * n_ops
    ).reshape(ncap, n_ops)
    return [LevelTraffic(fills[i], wbs[i]) for i in range(ncap)]


@dataclass
class HierarchyTraffic:
    """Traffic at each boundary of the §III-C memory system, per op."""

    l2_touch: np.ndarray          # bytes served by the L2 (all touches)
    post_l2: LevelTraffic         # traffic crossing the UHB link (or to DRAM)
    dram: LevelTraffic            # traffic reaching DRAM
    has_l3: bool

    @property
    def l3_bytes(self) -> float:
        """Bytes served by the L3 = post-L2 traffic that did not reach DRAM."""
        return max(self.post_l2.total - self.dram.total, 0.0)


def simulate_hierarchy(
    trace: Trace, spec: GpuSpec, cyclic: bool = True, stream: TouchStream | None = None
) -> HierarchyTraffic:
    """One-shot §III-C hierarchy simulation. Thin wrapper over the single
    implementation in :class:`~repro.core.sweep.TraceAnalysis` (which adds
    capacity caching for sweeps)."""
    from repro.core.sweep import TraceAnalysis  # lazy: sweep imports cachesim

    return TraceAnalysis(trace, cyclic=cyclic, stream=stream).hierarchy(spec)


def dram_traffic_sweep(
    trace: Trace, llc_capacities: list[float], cyclic: bool = True
) -> dict[float, float]:
    """Total DRAM traffic vs LLC capacity (paper Fig 4). The LLC here is the
    union pool DRAM sees (L2, or L2+L3 when composed).

    Thin wrapper over the shared :class:`~repro.core.sweep.TraceAnalysis`
    cache, so repeated sweeps of one trace (across figures, configs, tests)
    reuse the stream and every previously computed capacity."""
    from repro.core.sweep import analysis_for  # lazy: sweep imports cachesim

    return analysis_for(trace, cyclic=cyclic).dram_traffic(list(llc_capacities))
