"""Memory-hierarchy traffic model: L2 -> (optional memory-side L3) -> DRAM.

Faithful to the paper's §III-C microarchitecture:

* the L2 (inside the GPM) is the point of coherence and the first bandwidth
  filter; every post-L2 miss/writeback crosses the UHB link when an MSM with
  L3 is present;
* the L3 is a *memory-side* cache: it only observes post-L2 traffic, is
  neither inclusive nor exclusive, and needs no coherence. We model the
  (L2, L3) pair for DRAM-traffic purposes as a single LRU pool of capacity
  ``C_L2 + C_L3`` observed by DRAM — exact for the steady-state streaming
  traffic that dominates DL iterations (validated against BlockLRU in tests).

Residency is fractional at tensor granularity: a touch of tensor T with
bytes-weighted unique-reuse distance U against a cache of capacity C finds
``clip(C - U, 0, |T|)`` of its bytes resident. Writebacks use a per-tensor
dirty fraction; dirty bytes evicted before the next touch are charged to the
next level (attributed, for per-op accounting, to the touching op — the
evicting op is not identifiable at this granularity).

Steady state: the paper simulates one end-to-end iteration of workloads that
run for thousands of iterations, so cold misses are amortized; we double the
trace and read statistics off the second copy (``cyclic=True``).

Suite batching: :class:`StreamBatch` pads many traces' touch streams into
``(n_traces, max_len)`` tensors and runs the same scans over the batch axis
(bit-identical per row to the per-trace kernels), which is what lets
``repro.core.sweep.SuiteAnalysis`` evaluate a whole scenario registry in a
single trace x config x capacity pass; :func:`build_streams` builds many
streams with one batched Mattson call, and streams are memoized
process-wide.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

from repro.core.hw import GpuSpec
from repro.core.stackdist import PAD_ID, _mattson_pass, _mattson_pass_batch
from repro.core.trace import Trace


@dataclass
class TouchStream:
    """Flattened, doubled touch arrays for one trace (capacity-independent)."""

    n_ops: int
    op_idx: np.ndarray     # int32, len 2T (doubled)
    sizes: np.ndarray      # float64
    is_write: np.ndarray   # bool
    dist: np.ndarray       # bytes-weighted unique reuse distance per touch
    tensor_idx: np.ndarray  # int64 dense tensor ids
    n_tensors: int
    second_half: int       # index where the steady-state copy begins
    # lazily-built tensor-sorted scan layout (see _stream_layout); cached on
    # the stream so re-padding a suite never recomputes the sort/segment pass
    _layout: "_StreamLayout | None" = field(default=None, repr=False,
                                            compare=False)

    @property
    def nbytes(self) -> int:
        """Approximate pinned bytes (stream cache accounting)."""
        total = (self.op_idx.nbytes + self.sizes.nbytes + self.is_write.nbytes
                 + self.dist.nbytes + self.tensor_idx.nbytes)
        if self._layout is not None:
            total += self._layout.nbytes
        return total


@dataclass
class _StreamLayout:
    """One stream's touches in tensor-sorted order with every
    capacity-independent quantity of the :func:`traffic_below` scan
    precomputed per stream: the sorted columns, the segment structure
    (first read after the last write, has-a-write-base) reduced to the
    recorded touches, and the local scatter indices. Computed once per
    stream (cached on the :class:`TouchStream`), so building or appending
    to a :class:`StreamBatch` is pure row assembly — no per-pad argsort or
    segment scans."""

    n: int                          # touch count (= row width before pads)
    sizes: np.ndarray               # (n,) float64, tensor-sorted
    dist: np.ndarray                # (n,) float64
    is_write: np.ndarray            # (n,) bool
    is_inf: np.ndarray              # (n,) bool: +inf distance
    rec_cols: np.ndarray            # (n_rec,) sorted-position column
    seg_rec: np.ndarray             # (n_rec,) first read after the last write
    has_base_rec: np.ndarray        # (n_rec,) last write inside own chain
    iw_rec: np.ndarray              # (n_rec,) is-write flag
    sizes_rec: np.ndarray           # (n_rec,) touch bytes
    op_rec: np.ndarray              # (n_rec,) LOCAL op id (pre-offset)

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in (
            self.sizes, self.dist, self.is_write, self.is_inf, self.rec_cols,
            self.seg_rec, self.has_base_rec, self.iw_rec, self.sizes_rec,
            self.op_rec))


def _stream_layout(stream: TouchStream) -> _StreamLayout:
    """The per-stream half of the old block build: sort by tensor id
    (stable, preserving time order inside each chain), derive the segment
    structure, and keep only the recorded-touch reductions. Identical math
    to the former in-block 2-D pass, evaluated per row — the values feeding
    :meth:`StreamBatch._block_traffic` are bit-identical either way."""
    lay = stream._layout
    if lay is not None:
        return lay
    n = len(stream.op_idx)
    order = np.argsort(stream.tensor_idx, kind="stable")
    sizes = stream.sizes[order]
    dist = stream.dist[order]
    is_write = stream.is_write[order]
    tid = stream.tensor_idx[order]
    pos = np.arange(n, dtype=np.int64)
    is_new = np.concatenate([[True], tid[1:] != tid[:-1]]) if n \
        else np.zeros(0, dtype=bool)
    chain_start = np.maximum.accumulate(np.where(is_new, pos, 0))
    last_write_incl = np.maximum.accumulate(np.where(is_write, pos, -1))
    last_write = np.concatenate([[-1], last_write_incl[:-1]]) if n \
        else np.zeros(0, dtype=np.int64)
    rec = np.nonzero(order >= stream.second_half)[0]
    lay = _StreamLayout(
        n=n,
        sizes=sizes,
        dist=dist,
        is_write=is_write,
        is_inf=np.isinf(dist),
        rec_cols=rec,
        seg_rec=(last_write + 1)[rec],
        has_base_rec=(last_write >= chain_start)[rec],
        iw_rec=is_write[rec],
        sizes_rec=sizes[rec],
        op_rec=stream.op_idx[order][rec].astype(np.int64),
    )
    stream._layout = lay
    return lay


#: Buffers are recycled REUSE_DELAY touches after death: asynchronous
#: execution keeps freed buffers pinned briefly, so reuse is near- but not
#: perfectly-immediate (calibrated against Fig 4's inference saturation
#: capacities).
REUSE_DELAY = 24


def _assign_buffers(trace: Trace) -> dict[str, str]:
    """Caching-allocator model: transient tensors (first touched by a write,
    later dead) recycle buffers freed by earlier-dying tensors, exactly like
    the framework allocators under the paper's traces. Returns a tensor->
    buffer mapping; persistent tensors (weights, optimizer state — read
    before written) and streaming inputs keep their own identity.

    Without this, every dirty activation would be charged a DRAM writeback
    once per iteration when its (never-reused) address range is evicted;
    with buffer recycling the next owner overwrites the dirty lines while
    they are still resident — which is what lets a large L3 collapse
    inference traffic (paper Fig 4's 16x)."""
    touches = list(trace.touches())
    first: dict[str, int] = {}
    last: dict[str, int] = {}
    size: dict[str, int] = {}
    first_is_write: dict[str, bool] = {}
    for pos, (_, t, b, w) in enumerate(touches):
        if t not in first:
            first[t] = pos
            first_is_write[t] = w
        last[t] = pos
        size[t] = max(size.get(t, 0), b)

    def transient(t: str) -> bool:
        return first_is_write[t] and not t.startswith("in.")

    # Free events sorted by position; greedy best-fit (smallest buffer >= size).
    mapping: dict[str, str] = {}
    free: list[tuple[int, str]] = []  # (buffer_size, buffer_name)
    deaths = sorted((last[t] + REUSE_DELAY, t) for t in first if transient(t))
    di = 0
    buf_of: dict[str, str] = {}
    import bisect

    for pos, (_, t, b, w) in enumerate(touches):
        while di < len(deaths) and deaths[di][0] < pos:
            dead = deaths[di][1]
            if dead in buf_of:
                bisect.insort(free, (size[dead], buf_of[dead]))
            di += 1
        if t in mapping or not transient(t) or first[t] != pos:
            continue
        i = bisect.bisect_left(free, (size[t], ""))
        if i < len(free):
            _, buf = free.pop(i)
        else:
            buf = f"__buf{len(buf_of)}.{t}"
        mapping[t] = buf
        buf_of[t] = buf
    return mapping


def _reference_flatten(trace: Trace, cyclic: bool, reuse_buffers: bool):
    """Per-touch oracle for :func:`_flatten_trace` (the original dict-based
    interning loop). Retained for parity tests and as the fallback for
    pathological tensor names that could alias a recycled-buffer name."""
    mapping = _assign_buffers(trace) if reuse_buffers else {}
    op_idx, tids, sizes, is_write = [], [], [], []
    intern: dict[str, int] = {}
    stream_seq = 0
    for i, t, b, w in trace.touches():
        op_idx.append(i)
        t = mapping.get(t, t)
        if t.startswith("in.") and t not in intern:
            # unique id now; forget it so the doubled copy gets a fresh one
            tids.append(len(intern) + 1_000_000_000 + stream_seq)
            stream_seq += 1
        else:
            tids.append(intern.setdefault(t, len(intern)))
        sizes.append(float(b))
        is_write.append(w)
    op_idx = np.asarray(op_idx, dtype=np.int32)
    tids = np.asarray(tids, dtype=np.int64)
    sizes = np.asarray(sizes, dtype=np.float64)
    is_write = np.asarray(is_write, dtype=bool)
    n = len(op_idx)
    if cyclic and n:
        op_idx = np.concatenate([op_idx, op_idx])
        # Streaming tensors (ids >= 1e9) must NOT alias across the two copies.
        tids2 = np.where(tids >= 1_000_000_000, tids + 1_000_000_000, tids)
        tids = np.concatenate([tids, tids2])
        sizes = np.concatenate([sizes, sizes])
        is_write = np.concatenate([is_write, is_write])
    # Dense tensor ids (streaming copies included) for state arrays.
    if n:
        _, dense = np.unique(tids, return_inverse=True)
    else:
        dense = tids
    n_tensors = int(dense.max()) + 1 if n else 0
    return op_idx, dense, sizes, is_write, n_tensors, (n if cyclic else 0)


def _assign_buffer_ids(table) -> tuple[np.ndarray, int]:
    """Array-based twin of :func:`_assign_buffers`: the same greedy best-fit
    recycling, but iterating only over transient-tensor *births* (a handful
    per trace) instead of every touch. Free events accumulated between two
    births are drained at the later birth — the free list any allocation
    sees is identical, so the resulting tensor->buffer partition is
    bit-identical to the per-touch oracle (asserted in tests).

    Returns ``(map_id, n_fresh)``: ``map_id[k]`` is name id ``k``'s mapped
    id (itself for persistent/streaming tensors, ``K + j`` for the ``j``-th
    fresh buffer); ties in the free list and death order are broken on the
    exact buffer/tensor name strings the oracle uses."""
    import bisect

    K = table.n_names
    map_id = np.arange(K, dtype=np.int64)
    transient = table.first_is_write & ~table.stream_flag
    t_ids = np.nonzero(transient)[0]
    if not len(t_ids):
        return map_id, 0
    names = table.names
    births = t_ids[np.argsort(table.first[t_ids])]
    deaths = sorted((int(table.last[t]) + REUSE_DELAY, names[t], int(t))
                    for t in t_ids)
    free: list[tuple[float, str, int]] = []  # (size, buf_name, buf_id)
    allocated: dict[int, tuple[str, int]] = {}  # name id -> (buf_name, id)
    di = 0
    n_fresh = 0
    for t in births:
        birth = int(table.first[t])
        while di < len(deaths) and deaths[di][0] < birth:
            dead = deaths[di][2]
            if dead in allocated:
                bname, bid = allocated[dead]
                bisect.insort(free, (float(table.max_size[dead]), bname, bid))
            di += 1
        i = bisect.bisect_left(free, (float(table.max_size[t]), ""))
        if i < len(free):
            _, bname, bid = free.pop(i)
        else:
            # the oracle's fresh-name counter is "transients allocated so
            # far" (reusers included), so names match it exactly
            bname = f"__buf{len(allocated)}.{names[int(t)]}"
            bid = K + n_fresh
            n_fresh += 1
        allocated[int(t)] = (bname, bid)
        map_id[t] = bid
    return map_id, n_fresh


def _flatten_trace(trace: Trace, cyclic: bool, reuse_buffers: bool):
    """The capacity- and distance-independent part of :func:`build_stream`:
    flatten, buffer-recycle, double, and densify one trace's touches.
    Returns ``(op_idx, dense_tensor_ids, sizes, is_write, n_tensors,
    second_half)`` — everything a :class:`TouchStream` needs except the
    reuse distances.

    Array-based: raw touch columns come from the cached
    :meth:`~repro.core.trace.Trace.touch_table`, recycling from
    :func:`_assign_buffer_ids`, and the dense ids in closed form — a
    non-streaming tensor's dense id is its first-appearance rank among
    non-streaming (mapped) names, the ``j``-th streaming touch gets
    ``K + j`` (second copy ``K + S + j``): exactly the order
    ``np.unique`` gave the oracle's sentinel ids. Bit-identical to
    :func:`_reference_flatten` (asserted in tests)."""
    table = trace.touch_table()
    n = table.n_touches
    if n == 0:
        z = np.zeros(0, dtype=np.int64)
        return (np.zeros(0, dtype=np.int32), z, np.zeros(0),
                np.zeros(0, dtype=bool), 0, 0)
    if reuse_buffers:
        if table.has_buf_names:
            # a real tensor could alias a recycled-buffer name; take the
            # string-keyed oracle for this (pathological) trace
            return _reference_flatten(trace, cyclic, reuse_buffers)
        map_id, n_fresh = _assign_buffer_ids(table)
    else:
        map_id, n_fresh = np.arange(table.n_names, dtype=np.int64), 0
    mids = map_id[table.name_id]
    stream_ext = np.concatenate(
        [table.stream_flag, np.zeros(n_fresh, dtype=bool)])
    st = stream_ext[mids]
    S = int(np.count_nonzero(st))
    dense = np.empty(n, dtype=np.int64)
    ns = mids[~st]
    if len(ns):
        uniq, first_idx, inv = np.unique(ns, return_index=True,
                                         return_inverse=True)
        rank = np.empty(len(uniq), dtype=np.int64)
        rank[np.argsort(first_idx)] = np.arange(len(uniq), dtype=np.int64)
        dense[~st] = rank[inv]
        k_ns = len(uniq)
    else:
        k_ns = 0
    dense[st] = k_ns + np.arange(S, dtype=np.int64)
    op_idx, sizes, is_write = table.op_idx, table.sizes, table.is_write
    if cyclic:
        op_idx = np.concatenate([op_idx, op_idx])
        # streaming tensors must NOT alias across the two copies
        dense = np.concatenate([dense, np.where(st, dense + S, dense)])
        sizes = np.concatenate([sizes, sizes])
        is_write = np.concatenate([is_write, is_write])
        n_tensors = k_ns + 2 * S
    else:
        n_tensors = k_ns + S
    return op_idx, dense, sizes, is_write, n_tensors, (n if cyclic else 0)


# Process-wide stream cache: streams are pure functions of the trace (keyed
# by identity + op count like sweep._ANALYSES), and flattening them is
# Python-loop bound, so repeated sweeps over registry traces should never
# re-pay it. Bounded LRU — by entry count AND by a byte budget, so a long
# session sweeping many large ad-hoc traces cannot grow it without limit —
# with hit/miss/eviction counters (``stream_cache_stats``) so incremental
# build behavior is observable. Only default-kernel streams are cached
# (reference dist_fn calls from parity tests/benchmarks always rebuild).
# Value tuples carry the stream's byte estimate at insertion time (the scan
# layout attaches lazily afterwards, so the real footprint can be somewhat
# larger); a raw ``_STREAMS.clear()`` stays valid — bytes are summed from
# the stored entries, never kept as a separate running total.
_STREAMS: OrderedDict[
    tuple[int, int, bool, bool], tuple[Trace, TouchStream, int]
] = OrderedDict()
_STREAMS_MAX = 512
_STREAMS_MAX_BYTES = 256 * 1024 * 1024
_STREAM_COUNTERS = {"hits": 0, "misses": 0, "evictions": 0}


def stream_cache_stats() -> dict[str, int]:
    """Observable stream-cache state: hit/miss/eviction counters plus the
    current entry count, resident byte estimate, and configured bounds."""
    return {
        **_STREAM_COUNTERS,
        "entries": len(_STREAMS),
        "bytes": sum(nb for _, _, nb in _STREAMS.values()),
        "max_entries": _STREAMS_MAX,
        "max_bytes": _STREAMS_MAX_BYTES,
    }


def stream_cache_clear() -> None:
    """Drop every cached stream and zero the counters."""
    _STREAMS.clear()
    for k in _STREAM_COUNTERS:
        _STREAM_COUNTERS[k] = 0


def set_stream_cache_limit(max_entries: int | None = None,
                           max_bytes: int | None = None) -> None:
    """Re-bound the stream LRU (``None`` keeps a bound unchanged). Shrinking
    a bound evicts immediately from the LRU end."""
    global _STREAMS_MAX, _STREAMS_MAX_BYTES
    if max_entries is not None:
        _STREAMS_MAX = int(max_entries)
    if max_bytes is not None:
        _STREAMS_MAX_BYTES = int(max_bytes)
    _stream_cache_trim()


def _stream_cache_trim() -> None:
    total = sum(nb for _, _, nb in _STREAMS.values())
    while _STREAMS and (len(_STREAMS) > _STREAMS_MAX
                        or total > _STREAMS_MAX_BYTES):
        _, (_, _, nb) = _STREAMS.popitem(last=False)
        total -= nb
        _STREAM_COUNTERS["evictions"] += 1


def _stream_cache_get(trace: Trace, cyclic: bool, reuse_buffers: bool) -> TouchStream | None:
    key = (id(trace), len(trace.ops), cyclic, reuse_buffers)
    hit = _STREAMS.get(key)
    if hit is not None and hit[0] is trace:
        _STREAMS.move_to_end(key)
        _STREAM_COUNTERS["hits"] += 1
        return hit[1]
    _STREAM_COUNTERS["misses"] += 1
    return None


def _stream_cache_put(trace: Trace, cyclic: bool, reuse_buffers: bool,
                      stream: TouchStream) -> None:
    key = (id(trace), len(trace.ops), cyclic, reuse_buffers)
    _STREAMS[key] = (trace, stream, int(stream.nbytes))
    _stream_cache_trim()


def build_stream(trace: Trace, cyclic: bool = True, reuse_buffers: bool = True,
                 dist_fn=_mattson_pass) -> TouchStream:
    """Tensors whose name starts with ``in.`` are *streaming*: fresh data
    arrives every iteration (input batches, labels), so consecutive
    iterations never reuse them — they get one tensor identity per iteration
    copy instead of wrapping around. Transient tensors share recycled buffer
    identities (see :func:`_assign_buffers`). ``dist_fn`` selects the Mattson
    implementation (the per-touch reference is used by parity/benchmark
    paths)."""
    default_kernel = dist_fn is _mattson_pass
    if default_kernel:
        hit = _stream_cache_get(trace, cyclic, reuse_buffers)
        if hit is not None:
            return hit
    op_idx, dense, sizes, is_write, n_tensors, second_half = _flatten_trace(
        trace, cyclic, reuse_buffers
    )
    dist = dist_fn(dense, sizes) if len(op_idx) else np.zeros(0)
    stream = TouchStream(
        n_ops=len(trace.ops),
        op_idx=op_idx,
        sizes=sizes,
        is_write=is_write,
        dist=dist,
        tensor_idx=dense,
        n_tensors=n_tensors,
        second_half=second_half,
    )
    if default_kernel:
        _stream_cache_put(trace, cyclic, reuse_buffers, stream)
    return stream


#: Streams at or below this (doubled) length run the batched Mattson kernel;
#: longer ones are work-dominated and the per-stream kernel is faster.
_BATCH_MATTSON_MAX_LEN = 1024


def build_streams(traces: Sequence[Trace], cyclic: bool = True,
                  reuse_buffers: bool = True) -> list[TouchStream]:
    """Build every trace's :class:`TouchStream` with ONE batched Mattson
    pass over all short streams (grouped into padded pow2-width blocks;
    long streams keep the per-stream kernel, which is faster once the merge
    levels are work-dominated). Row results are bit-identical to
    :func:`build_stream` per trace — both land in the shared stream cache.
    """
    out: list[TouchStream | None] = [None] * len(traces)
    flat: dict[int, tuple] = {}
    for i, trace in enumerate(traces):
        hit = _stream_cache_get(trace, cyclic, reuse_buffers)
        if hit is not None:
            out[i] = hit
        else:
            flat[i] = _flatten_trace(trace, cyclic, reuse_buffers)
    # Group the short streams into pow2-width blocks for the batched kernel.
    blocks: dict[int, list[int]] = {}
    for i, (op_idx, dense, sizes, *_rest) in flat.items():
        n = len(op_idx)
        if 0 < n <= _BATCH_MATTSON_MAX_LEN:
            width = 1 << max(int(np.ceil(np.log2(n))), 0)
            blocks.setdefault(width, []).append(i)
    dists: dict[int, np.ndarray] = {}
    for width, members in blocks.items():
        ids2 = np.full((len(members), width), PAD_ID, dtype=np.int64)
        sz2 = np.zeros((len(members), width))
        for r, i in enumerate(members):
            _, dense, sizes, *_ = flat[i]
            ids2[r, : len(dense)] = dense
            sz2[r, : len(dense)] = sizes
        dist2 = _mattson_pass_batch(ids2, sz2)
        for r, i in enumerate(members):
            dists[i] = dist2[r, : len(flat[i][1])].copy()
    for i, (op_idx, dense, sizes, is_write, n_tensors, second_half) in flat.items():
        if i in dists:
            dist = dists[i]
        else:
            dist = _mattson_pass(dense, sizes) if len(op_idx) else np.zeros(0)
        stream = TouchStream(
            n_ops=len(traces[i].ops),
            op_idx=op_idx,
            sizes=sizes,
            is_write=is_write,
            dist=dist,
            tensor_idx=dense,
            n_tensors=n_tensors,
            second_half=second_half,
        )
        _stream_cache_put(traces[i], cyclic, reuse_buffers, stream)
        out[i] = stream
    return out


@dataclass
class LevelTraffic:
    """Per-op traffic crossing out the bottom of one cache level."""

    fill: np.ndarray        # bytes fetched per op (read misses)
    writeback: np.ndarray   # dirty bytes written back per op

    @property
    def total(self) -> float:
        return float(self.fill.sum() + self.writeback.sum())

    @property
    def total_fill(self) -> float:
        return float(self.fill.sum())

    @property
    def total_writeback(self) -> float:
        return float(self.writeback.sum())


def _reference_traffic_below(
    stream: TouchStream, capacities: list[float]
) -> list[LevelTraffic]:
    """Per-touch oracle for :func:`traffic_below` (sequential dirty-state
    recurrence carrying a (n_tensors x n_caps) state). Retained for parity
    tests and the before/after timing in ``benchmarks/bench_core.py``."""
    caps = np.asarray(capacities, dtype=np.float64)
    ncap = len(caps)
    fills = np.zeros((ncap, stream.n_ops))
    wbs = np.zeros((ncap, stream.n_ops))
    if len(stream.op_idx) == 0:
        return [LevelTraffic(fills[i], wbs[i]) for i in range(ncap)]

    dirty = np.zeros((stream.n_tensors, ncap))
    start_attrib = stream.second_half
    for t in range(len(stream.op_idx)):
        size = stream.sizes[t]
        d = stream.dist[t]
        x = stream.tensor_idx[t]
        op = stream.op_idx[t]
        record = t >= start_attrib
        if np.isinf(d):
            resident = np.zeros(ncap)
        else:
            resident = np.clip(caps - d, 0.0, size)
        evicted = size - resident
        wb_bytes = evicted * dirty[x]
        if record:
            wbs[:, op] += wb_bytes
        if stream.is_write[t]:
            if record:
                # full-tensor stores: no fill on write-allocate
                pass
            dirty[x] = 1.0
        else:
            if record:
                fills[:, op] += evicted
            # evicted dirty bytes were flushed; resident dirty bytes remain
            frac = np.divide(resident, size, out=np.zeros_like(resident), where=size > 0)
            dirty[x] = dirty[x] * frac
    return [LevelTraffic(fills[i], wbs[i]) for i in range(ncap)]


def traffic_below(stream: TouchStream, capacities: list[float]) -> list[LevelTraffic]:
    """Traffic leaving an LRU pool of each capacity, one trace pass total.

    Fully vectorized over (touches x capacities). The dirty fraction seen by
    a touch is a product of residency fractions along its tensor's chain of
    reads since the last write (writes reset it to 1, chain starts to 0), so
    grouping touches by tensor turns the sequential recurrence into a
    segmented cumulative-product scan: a log-space cumsum with per-segment
    base subtraction, plus an explicit zero counter so exact-zero fractions
    stay exact. Each capacity column is independent, so batching capacities
    is bit-identical to evaluating them one at a time — the property the
    sweep engine relies on to share one pass across a whole design space.
    """
    caps = np.asarray(capacities, dtype=np.float64)
    ncap = len(caps)
    n_ops = stream.n_ops
    n = len(stream.op_idx)
    if n == 0 or ncap == 0:
        return [LevelTraffic(np.zeros(n_ops), np.zeros(n_ops))
                for _ in range(ncap)]

    # Group touches by tensor, preserving time order inside each chain.
    order = np.argsort(stream.tensor_idx, kind="stable")
    sizes = stream.sizes[order]
    dist = stream.dist[order]
    is_write = stream.is_write[order]
    tid = stream.tensor_idx[order]
    op_idx = stream.op_idx[order]
    record = order >= stream.second_half

    # Residency per (touch, capacity); +inf distance -> nothing resident.
    with np.errstate(invalid="ignore"):  # inf cap - inf dist
        resident = np.clip(caps[None, :] - dist[:, None], 0.0, sizes[:, None])
    resident[np.isinf(dist)] = 0.0
    evicted = sizes[:, None] - resident
    frac = np.divide(
        resident, sizes[:, None], out=np.zeros_like(resident),
        where=sizes[:, None] > 0,
    )

    pos = np.arange(n)
    chain_start = np.maximum.accumulate(
        np.where(np.concatenate([[True], tid[1:] != tid[:-1]]), pos, 0)
    )
    # Last write strictly before each touch (global running max; valid only
    # when it falls inside the touch's own chain).
    last_write_incl = np.maximum.accumulate(np.where(is_write, pos, -1))
    last_write = np.concatenate([[-1], last_write_incl[:-1]])
    has_base = last_write >= chain_start

    # Segmented product of read fractions over (last_write, touch), in log
    # space; zero fractions tracked separately so they yield exactly 0.
    is_read_col = ~is_write[:, None]
    log_safe = np.log(np.where(is_read_col & (frac > 0), frac, 1.0))
    zero_read = is_read_col & (frac <= 0.0)
    log_cum = np.concatenate([np.zeros((1, ncap)), np.cumsum(log_safe, axis=0)])
    zero_cum = np.concatenate(
        [np.zeros((1, ncap), dtype=np.int64), np.cumsum(zero_read, axis=0)]
    )
    seg_lo = last_write + 1  # first read after the resetting write
    dirty = np.exp(log_cum[pos] - log_cum[seg_lo])
    dirty[(zero_cum[pos] - zero_cum[seg_lo]) > 0] = 0.0
    dirty[~has_base] = 0.0

    # Scatter recorded traffic back to (capacity, op): flat index c*n_ops+op,
    # one weighted bincount for writebacks and one for fills.
    cap_offsets = np.arange(ncap, dtype=np.int64)[None, :] * n_ops
    rec = np.nonzero(record)[0]
    flat = (op_idx[rec, None].astype(np.int64) + cap_offsets).ravel()
    wbs = np.bincount(
        flat, weights=(evicted[rec] * dirty[rec]).ravel(), minlength=ncap * n_ops
    ).reshape(ncap, n_ops)
    rd = np.nonzero(record & ~is_write)[0]
    flat_rd = (op_idx[rd, None].astype(np.int64) + cap_offsets).ravel()
    fills = np.bincount(
        flat_rd, weights=evicted[rd].ravel(), minlength=ncap * n_ops
    ).reshape(ncap, n_ops)
    return [LevelTraffic(fills[i], wbs[i]) for i in range(ncap)]


#: A block absorbs shorter streams down to this fraction of its width;
#: padding waste inside a block is bounded by 1/_BLOCK_FILL.
_BLOCK_FILL = 0.75

#: Row x width bound per block (keeps the (R, L, ncap) temporaries small).
_BLOCK_SLOTS = 1 << 20


@dataclass
class _PaddedBlock:
    """One same-width row block of a :class:`StreamBatch`, stored in
    tensor-sorted order with every capacity-independent quantity of the
    :func:`traffic_below` scan precomputed: the segment structure (chain
    starts, last writes) reduced to the recorded touches, and the scatter
    indices. A traffic call only runs the capacity-dependent residency and
    dirty math."""

    members: list[int]              # stream indices, same order as rows
    sizes: np.ndarray               # (R, L) float64, tensor-sorted, pads 0
    dist: np.ndarray                # (R, L) float64, pads +inf
    is_write: np.ndarray            # (R, L) bool, pads False
    is_inf: np.ndarray              # (R, L) bool: +inf distance
    # -- recorded (steady-state) touches, flattened --------------------------
    rec_rows: np.ndarray            # (n_rec,) block row of each recorded touch
    rec_cols: np.ndarray            # (n_rec,) sorted-position column
    seg_rec: np.ndarray             # (n_rec,) first read after the last write
    has_base_rec: np.ndarray        # (n_rec,) last write inside own chain
    iw_rec: np.ndarray              # (n_rec,) is-write flag
    sizes_rec: np.ndarray           # (n_rec,) touch bytes
    op_rec: np.ndarray              # (n_rec,) global op id


@dataclass
class StreamBatch:
    """A whole suite of touch streams padded into batched tensors.

    The suite-level counterpart of :class:`TouchStream`: every member
    stream's (doubled) touch arrays are padded to a common row width —
    sizes, op-segment ids (offset into one global op axis), write flags,
    reuse distances, and a validity/record mask per ``(n_traces, max_len)``
    row. Rows are grouped into similar-width blocks internally (a block
    only absorbs streams within ``_BLOCK_FILL`` of its width), so a
    registry that mixes 24-touch HPC proxies with 26k-touch MLPerf traces
    never pads a short stream to the longest one.

    :meth:`traffic_below` runs the segmented stack-distance/dirty-capacity
    scan of :func:`traffic_below` over the whole batch — every cumulative
    scan runs along the row axis, so each row is evaluated with exactly the
    float-operation sequence the per-trace kernel performs on that stream
    alone: results are bit-identical to per-trace calls (asserted in
    tests), which is what lets the sweep engine evaluate a whole registry
    in one trace x config x capacity pass. The sort and segment structure
    are capacity-independent, so :meth:`pad` computes them once; repeated
    sweeps pay only the residency/dirty math.
    """

    streams: list[TouchStream]
    op_offsets: np.ndarray          # (n_traces + 1,) int64 global op segments
    _blocks: list[_PaddedBlock] = field(default_factory=list, repr=False)

    @property
    def n_traces(self) -> int:
        return len(self.streams)

    @property
    def n_ops_total(self) -> int:
        return int(self.op_offsets[-1])

    def op_slice(self, i: int) -> slice:
        return slice(int(self.op_offsets[i]), int(self.op_offsets[i + 1]))

    @classmethod
    def pad(cls, streams: Iterable[TouchStream]) -> "StreamBatch":
        streams = list(streams)
        op_offsets = np.zeros(len(streams) + 1, dtype=np.int64)
        if streams:
            np.cumsum(np.array([s.n_ops for s in streams], dtype=np.int64),
                      out=op_offsets[1:])
        batch = cls(streams=streams, op_offsets=op_offsets)
        batch._append_blocks(range(len(streams)))
        return batch

    def append(self, streams: Iterable[TouchStream]) -> list[_PaddedBlock]:
        """Append rows to a live batch: new streams extend the global op
        axis and are grouped into NEW blocks (same policy as :meth:`pad`
        over the new rows alone — existing blocks are never rebuilt). Row
        results are per-row, so the grown batch is bit-identical, stream
        for stream, to a cold :meth:`pad` of the full list (asserted in
        tests). Returns the blocks added, for partial (new-rows-only)
        :meth:`traffic_matrices` scans."""
        streams = list(streams)
        start = len(self.streams)
        self.streams.extend(streams)
        if streams:
            self.op_offsets = np.concatenate([
                self.op_offsets,
                self.op_offsets[-1] + np.cumsum(
                    np.array([s.n_ops for s in streams], dtype=np.int64)),
            ])
        k0 = len(self._blocks)
        self._append_blocks(range(start, len(self.streams)))
        return self._blocks[k0:]

    def _append_blocks(self, indices: Iterable[int]) -> None:
        # Group by length, longest first: a block absorbs streams down to
        # _BLOCK_FILL of its width (bounding padding waste) and splits when
        # its padded slot count would exceed _BLOCK_SLOTS (bounding the
        # temporaries of one scan).
        streams = self.streams
        by_len = sorted((i for i in indices if len(streams[i].op_idx)),
                        key=lambda i: -len(streams[i].op_idx))
        group: list[int] = []
        for i in by_len:
            n = len(streams[i].op_idx)
            if group:
                width = len(streams[group[0]].op_idx)
                if n < _BLOCK_FILL * width or \
                        (len(group) + 1) * width > _BLOCK_SLOTS:
                    self._blocks.append(self._build_block(group))
                    group = []
            group.append(i)
        if group:
            self._blocks.append(self._build_block(group))

    def _build_block(self, members: list[int]) -> _PaddedBlock:
        """Assemble one padded block from the members' cached
        :class:`_StreamLayout` rows: padded 2-D columns by row copy, the
        recorded-touch reductions by concatenation (np.nonzero on a 2-D
        mask is row-major, so per-row concatenation reproduces the old
        in-block ordering exactly). Pad cells keep their exact neutral
        values: zero size, +inf distance, not-a-write."""
        streams, op_offsets = self.streams, self.op_offsets
        lays = [_stream_layout(streams[i]) for i in members]
        width = lays[0].n
        shape = (len(members), width)
        sizes = np.zeros(shape)
        dist = np.full(shape, np.inf)
        is_write = np.zeros(shape, dtype=bool)
        is_inf = np.ones(shape, dtype=bool)
        for r, lay in enumerate(lays):
            n = lay.n
            sizes[r, :n] = lay.sizes
            dist[r, :n] = lay.dist
            is_write[r, :n] = lay.is_write
            is_inf[r, :n] = lay.is_inf
        counts = [len(lay.rec_cols) for lay in lays]
        return _PaddedBlock(
            members=list(members),
            sizes=sizes,
            dist=dist,
            is_write=is_write,
            is_inf=is_inf,
            rec_rows=np.repeat(np.arange(len(members), dtype=np.int64),
                               counts),
            rec_cols=np.concatenate([lay.rec_cols for lay in lays]),
            seg_rec=np.concatenate([lay.seg_rec for lay in lays]),
            has_base_rec=np.concatenate([lay.has_base_rec for lay in lays]),
            iw_rec=np.concatenate([lay.iw_rec for lay in lays]),
            sizes_rec=np.concatenate([lay.sizes_rec for lay in lays]),
            op_rec=np.concatenate(
                [lay.op_rec + op_offsets[i] for lay, i in zip(lays, members)]),
        )

    def traffic_matrices(
        self, capacities: Sequence[float],
        blocks: Sequence[_PaddedBlock] | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """One batched scan over all rows: per-op fill/writeback bytes as two
        ``(n_capacities, n_ops_total)`` matrices over the global op axis.
        Stream ``i``'s columns are ``op_slice(i)``. ``blocks`` restricts the
        scan to a subset of row blocks (appended rows) — other columns stay
        zero."""
        caps = np.asarray(capacities, dtype=np.float64)
        ncap = len(caps)
        n_ops_total = self.n_ops_total
        fills = np.zeros((ncap, n_ops_total))
        wbs = np.zeros((ncap, n_ops_total))
        if ncap:
            for block in (self._blocks if blocks is None else blocks):
                self._block_traffic(block, caps, fills, wbs)
        return fills, wbs

    def traffic_below(self, capacities: Sequence[float]) -> list[list[LevelTraffic]]:
        """Per-stream, per-capacity traffic: one batched scan over all rows.

        Returns ``out[i][k]`` = :class:`LevelTraffic` of stream ``i`` under
        an LRU pool of ``capacities[k]`` — each bit-identical to
        ``traffic_below(streams[i], capacities)[k]``.
        """
        fills, wbs = self.traffic_matrices(capacities)
        return [
            [LevelTraffic(fills[k, self.op_slice(i)], wbs[k, self.op_slice(i)])
             for k in range(len(fills))]
            for i in range(self.n_traces)
        ]

    def dram_traffic(self, capacities: Sequence[float]) -> np.ndarray:
        """Total traffic below each capacity: a ``(n_traces, n_capacities)``
        tensor from one batched pass (the suite-level paper Fig 4)."""
        per = self.traffic_below(capacities)
        return np.array([[lt.total for lt in row] for row in per])

    @staticmethod
    def _block_traffic(block: _PaddedBlock, caps: np.ndarray,
                       fills: np.ndarray, wbs: np.ndarray) -> None:
        """The capacity-dependent half of the :func:`traffic_below` scan,
        batched over rows: per-row residency, the segmented log-space dirty
        product (cumsum along the row axis), one global scatter.

        Bit-identity with the per-trace kernel survives the masked
        evaluation tricks below because the skipped cells have *exact*
        values: ``log(1.0) == 0.0`` for fully-resident reads, and
        ``exp(0.0) == 1.0`` for segments without partial reads — only
        partial-residency cells (the narrow band ``0 < cap - dist < size``)
        ever see a transcendental. Pad slots have zero size and their own
        tensor chain, so they contribute exact zeros everywhere."""
        ncap = len(caps)
        sizes = block.sizes
        sizes3 = sizes[:, :, None]
        R, L = sizes.shape
        n_ops_total = fills.shape[1]

        with np.errstate(invalid="ignore"):  # inf cap - inf dist
            resident = np.clip(caps[None, None, :] - block.dist[:, :, None],
                               0.0, sizes3)
        resident[block.is_inf] = 0.0

        # log of the residency fraction, evaluated ONLY on partial reads.
        is_read3 = ~block.is_write[:, :, None]
        partial = (resident > 0.0) & (resident < sizes3) & is_read3
        log_safe = np.zeros_like(resident)
        np.divide(resident, sizes3, out=log_safe, where=partial)
        np.log(log_safe, out=log_safe, where=partial)
        zero_read = is_read3 & (resident <= 0.0)
        log_cum = np.concatenate(
            [np.zeros((R, 1, ncap)), np.cumsum(log_safe, axis=1)], axis=1
        )
        zero_cum = np.concatenate(
            [np.zeros((R, 1, ncap), dtype=np.int32),
             np.cumsum(zero_read, axis=1, dtype=np.int32)], axis=1
        )

        # Segmented product at the recorded touches only.
        rows, cols, seg = block.rec_rows, block.rec_cols, block.seg_rec
        diff = log_cum[rows, cols] - log_cum[rows, seg]
        dirty = np.ones_like(diff)
        np.exp(diff, out=dirty, where=diff != 0.0)
        dirty[(zero_cum[rows, cols] - zero_cum[rows, seg]) > 0] = 0.0
        dirty[~block.has_base_rec] = 0.0

        evicted = block.sizes_rec[:, None] - resident[rows, cols]
        cap_offsets = np.arange(ncap, dtype=np.int64)[None, :] * n_ops_total
        flat = (block.op_rec[:, None] + cap_offsets)
        wbs += np.bincount(
            flat.ravel(), weights=(evicted * dirty).ravel(),
            minlength=ncap * n_ops_total,
        ).reshape(ncap, n_ops_total)
        rd = ~block.iw_rec
        fills += np.bincount(
            flat[rd].ravel(), weights=evicted[rd].ravel(),
            minlength=ncap * n_ops_total,
        ).reshape(ncap, n_ops_total)


@dataclass
class HierarchyTraffic:
    """Traffic at each boundary of the §III-C memory system, per op."""

    l2_touch: np.ndarray          # bytes served by the L2 (all touches)
    post_l2: LevelTraffic         # traffic crossing the UHB link (or to DRAM)
    dram: LevelTraffic            # traffic reaching DRAM
    has_l3: bool

    @property
    def l3_bytes(self) -> float:
        """Bytes served by the L3 = post-L2 traffic that did not reach DRAM."""
        return max(self.post_l2.total - self.dram.total, 0.0)


def simulate_hierarchy(
    trace: Trace, spec: GpuSpec, cyclic: bool = True, stream: TouchStream | None = None
) -> HierarchyTraffic:
    """One-shot §III-C hierarchy simulation. Thin wrapper over the single
    implementation in :class:`~repro.core.sweep.TraceAnalysis` (which adds
    capacity caching for sweeps)."""
    from repro.core.sweep import TraceAnalysis  # lazy: sweep imports cachesim

    return TraceAnalysis(trace, cyclic=cyclic, stream=stream).hierarchy(spec)


def dram_traffic_sweep(
    trace: Trace, llc_capacities: list[float], cyclic: bool = True
) -> dict[float, float]:
    """Total DRAM traffic vs LLC capacity (paper Fig 4). The LLC here is the
    union pool DRAM sees (L2, or L2+L3 when composed).

    Thin wrapper over the shared :class:`~repro.core.sweep.TraceAnalysis`
    cache, so repeated sweeps of one trace (across figures, configs, tests)
    reuse the stream and every previously computed capacity."""
    from repro.core.sweep import analysis_for  # lazy: sweep imports cachesim

    return analysis_for(trace, cyclic=cyclic).dram_traffic(list(llc_capacities))


def dram_traffic_sweep_suite(
    traces: Sequence[Trace], llc_capacities: Sequence[float],
    cyclic: bool = True,
) -> dict[str, dict[float, float]]:
    """Suite-level Fig 4: DRAM traffic vs LLC capacity for MANY traces from
    one padded :class:`StreamBatch` pass (bit-identical, per trace, to
    :func:`dram_traffic_sweep`). Returns ``{trace_name: {capacity: bytes}}``
    in input order."""
    from repro.core.sweep import suite_analysis_for  # lazy: sweep imports us

    traces = list(traces)
    caps = [float(c) for c in llc_capacities]
    mat = suite_analysis_for(traces, cyclic=cyclic).dram_traffic(caps)
    return {t.name: {c: float(v) for c, v in zip(caps, mat[i])}
            for i, t in enumerate(traces)}
