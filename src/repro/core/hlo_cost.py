"""Trip-count-expanded cost analysis from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so a
scan-over-layers program under-reports FLOPs/bytes/collectives by ~n_layers
(verified empirically: a 7-trip scan of a 128³ matmul reports 1/7 the
FLOPs). This module parses the optimized HLO, builds the call graph
(entry → while bodies/conditions → fusions), reads each while op's
``known_trip_count`` backend config, and accumulates:

* ``dot_flops`` — 2·prod(result)·prod(contracted) per dot, anywhere
  (including inside fusions), multiplied down the call chain;
* ``bytes`` — operand+result bytes of *top-level* ops per computation
  (fusion internals excluded: a fusion is one kernel, its internals stay in
  registers/VMEM — matching how "bytes accessed" should count HBM);
* ``collective_bytes`` — per kind, trip-expanded.

This is the §Roofline accounting; raw cost_analysis numbers are kept in the
dry-run JSON for comparison.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DT = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
       "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
       "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_CALLED_RE = re.compile(
    r"(?:to_apply|body|condition|calls|branch_computations)=\{?%?([\w\.\-]+)"
    r"(?:, ?%?([\w\.\-]+))*\}?")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")


def _shape_bytes_all(segment: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(segment):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DT[m.group(1)]
    return total


@dataclass
class _Comp:
    name: str
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    coll: dict = field(default_factory=lambda: defaultdict(float))
    calls: list = field(default_factory=list)   # (callee, multiplier)
    is_fusion_body: bool = False


@dataclass
class HloCost:
    dot_flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_by_kind: dict


def raw_cost_analysis(compiled) -> dict:
    """XLA's own (un-trip-expanded) cost properties, version-normalized.

    ``compiled.cost_analysis()`` returns a dict on newer jax but a
    one-element list of dicts on older releases (one entry per executable);
    every consumer that wants the raw numbers next to :func:`analyze_hlo_cost`
    should go through this accessor instead of indexing the raw return.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def _parse_computations(
    text: str, lhs_shapes: dict[str, tuple[int, ...]]
) -> tuple[dict[str, "_Comp"], str]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry = None
    fusion_bodies: set[str] = set()
    shapes: dict[str, int] = {}  # %name -> result bytes (per computation scope)

    for line in text.splitlines():
        s = line.rstrip()
        st = s.strip()
        header = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->", st)
        if header and st.endswith("{"):
            cur = comps.setdefault(header.group(2), _Comp(header.group(2)))
            if header.group(1):
                entry = header.group(2)
            shapes = {}
            continue
        if cur is None or "=" not in st or not st.startswith("%"):
            continue
        lhs, rhs = st.split("=", 1)
        name = lhs.strip()
        out_bytes = _shape_bytes_all(rhs.split("(")[0])
        shapes[name] = out_bytes

        opm = re.search(r"^\s*(?:\(.*?\)|\S+)\s+([\w\-]+)\(", rhs)
        opcode = opm.group(1) if opm else ""

        # --- dot flops (counted even inside fusion bodies) ---
        if opcode == "dot":
            flops = _dot_flops(rhs, lhs_shapes)
            cur.dot_flops += flops

        # --- call edges ---
        trip = 1.0
        tm = _TRIP_RE.search(rhs)
        if tm:
            trip = float(tm.group(1))
        cm = re.search(r"body=%?([\w\.\-]+)", rhs)
        if cm:
            cur.calls.append((cm.group(1), trip))
        cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
        if cm:
            cur.calls.append((cm.group(1), trip))
        cm = re.search(r"to_apply=%?([\w\.\-]+)", rhs)
        if cm and opcode not in ("reduce", "all-reduce", "reduce-scatter",
                                 "reduce-window", "scatter", "sort", "map",
                                 "select-and-scatter"):
            cur.calls.append((cm.group(1), 1.0))
        cm = re.search(r"calls=%?([\w\.\-]+)", rhs)
        if cm:
            fusion_bodies.add(cm.group(1))
            cur.calls.append((cm.group(1), 1.0))
        cm = re.search(r"branch_computations=\{([^}]*)\}", rhs)
        if cm:
            for b in cm.group(1).split(","):
                cur.calls.append((b.strip().lstrip("%"), 1.0))

        # --- bytes: operands (looked up by name) + result ---
        operands = re.findall(r"%([\w\.\-]+)", rhs.split("(", 1)[-1])
        in_bytes = sum(shapes.get(f"%{o}", 0) for o in operands)
        cur.bytes_accessed += out_bytes + in_bytes

        # --- collectives ---
        collm = _COLL_RE.search(rhs)
        if collm and collm.group(2) != "-done":
            cur.coll[collm.group(1)] += out_bytes

    for fb in fusion_bodies:
        if fb in comps:
            comps[fb].is_fusion_body = True
    return comps, entry


def _dot_flops(rhs: str, lhs_shapes: dict[str, tuple[int, ...]]) -> float:
    """2 * prod(result dims) * prod(contracted dims of lhs)."""
    out_m = _SHAPE_RE.search(rhs.split("dot(")[0])
    if not out_m:
        return 0.0
    out_elems = 1
    if out_m.group(2):
        for d in out_m.group(2).split(","):
            out_elems *= int(d)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    ops = re.findall(r"%([\w\.\-]+)", rhs.split("dot(", 1)[-1])
    if not cm or not ops:
        return 2.0 * out_elems  # fallback: at least count outputs
    # need lhs dims: find its definition shape string
    lhs_shape = lhs_shapes.get(ops[0])
    if lhs_shape is None:
        return 2.0 * out_elems
    contracted = 1
    for idx in cm.group(1).split(","):
        if idx != "":
            contracted *= lhs_shape[int(idx)]
    return 2.0 * out_elems * contracted


def analyze_hlo_cost(text: str) -> HloCost:
    # pre-pass: record every instruction's dims for dot contraction lookup.
    # Local to this call — a module-global here would leak shapes across
    # analyses of different programs (reentrancy bug).
    lhs_shapes: dict[str, tuple[int, ...]] = {}
    for line in text.splitlines():
        st = line.strip()
        if not st.startswith("%") or "=" not in st:
            continue
        lhs, rhs = st.split("=", 1)
        m = _SHAPE_RE.search(rhs.split("(")[0])
        if m:
            dims = tuple(int(d) for d in m.group(2).split(",") if d) or ()
            lhs_shapes[lhs.strip().lstrip("%")] = dims

    comps, entry = _parse_computations(text, lhs_shapes)
    if entry is None:
        return HloCost(0.0, 0.0, 0.0, {})

    # accumulate multipliers over the call graph (memoized DFS)
    totals = {"flops": 0.0, "bytes": 0.0}
    coll_total: dict[str, float] = defaultdict(float)
    visiting: set[str] = set()

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None or name in visiting:
            return
        visiting.add(name)
        totals["flops"] += comp.dot_flops * mult
        if not comp.is_fusion_body:
            totals["bytes"] += comp.bytes_accessed * mult
            for k, v in comp.coll.items():
                coll_total[k] += v * mult
        for callee, trip in comp.calls:
            walk(callee, mult * trip)
        visiting.discard(name)

    walk(entry, 1.0)
    return HloCost(
        dot_flops=totals["flops"],
        bytes_accessed=totals["bytes"],
        collective_bytes=float(sum(coll_total.values())),
        collective_by_kind=dict(coll_total),
    )
