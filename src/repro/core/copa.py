"""The COPA-GPU design space (paper §III, Table V) and its energy model.

A COPA config = a GPM (compute module, identical across all variants — that
is the whole point of composability) + an MSM choice (memory-side L3 and/or
extra HBM sites). ``build()`` materializes a :class:`~repro.core.hw.GpuSpec`
the perf model can consume.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import hw
from repro.core.hw import MB, GpuSpec, LinkSpec

# Paper §IV-D: UHB link set to 2x RD + 2x WR of *half* the baseline DRAM BW
# each direction: total 10.8 TB/s for GPU-N's 2.7 TB/s DRAM.
def uhb_bandwidth_for(dram_bandwidth: float, scale: float = 2.0) -> float:
    """Per-direction UHB bandwidth given the paper's NxRD+NxWR convention."""
    return scale * dram_bandwidth


@dataclass(frozen=True)
class MsmSpec:
    """A Memory System Module: what the 2.5D/3D package composes onto the GPM."""

    name: str
    l3_capacity: int                 # bytes; 0 = no L3 (HPC variant)
    dram_bandwidth_scale: float      # vs the GPM baseline DRAM BW
    dram_capacity_scale: float
    integration: str = "2.5D"        # "2.5D" | "3D" | "none"
    uhb_scale: float = 2.0           # per-direction UHB = scale x DRAM BW

    @property
    def link(self) -> LinkSpec:
        return hw.UHB_3D if self.integration == "3D" else hw.UHB_2_5D


# --- Paper Table V -----------------------------------------------------------
# name                  LLC        DRAM BW   DRAM cap
# GPU-N                 60MB(L2)   2.7TB/s   100GB
# HBM+L3                960MB      2.7TB/s   100GB
# HBML+L3               960MB      4.5TB/s   167GB
# HBM+L3L               1920MB     2.7TB/s   100GB
# HBML+L3L              1920MB     4.5TB/s   167GB
# HBMLL+L3L             1920MB     6.3TB/s   233GB
# Perfect L2            inf        inf       inf

MSM_NONE = MsmSpec("baseline", 0, 1.0, 1.0, integration="none")
MSM_L3 = MsmSpec("L3", 960 * MB, 1.0, 1.0, integration="3D")
MSM_HBML_L3 = MsmSpec("HBML+L3", 960 * MB, 4500.0 / 2687.0, 1.67)
MSM_L3L = MsmSpec("L3L", 1920 * MB, 1.0, 1.0)
MSM_HBML_L3L = MsmSpec("HBML+L3L", 1920 * MB, 4500.0 / 2687.0, 1.67)
MSM_HBMLL_L3L = MsmSpec("HBMLL+L3L", 1920 * MB, 6300.0 / 2687.0, 2.33)


@dataclass(frozen=True)
class CopaConfig:
    name: str
    gpm: GpuSpec = field(default_factory=lambda: hw.GPU_N)
    msm: MsmSpec = MSM_NONE
    perfect_llc: bool = False   # the paper's "Perfect L2" upper bound

    def build(self) -> GpuSpec:
        """Compose GPM + MSM into a flat GpuSpec for the perf model."""
        g = self.gpm
        if self.perfect_llc:
            # Infinite LLC and DRAM: modelled as enormous-but-finite values so
            # arithmetic stays well defined.
            return g.with_(
                name=f"{g.name}/PerfectL2",
                l2_capacity=1 << 50,
                dram_bandwidth=1e18,
            )
        if self.msm.integration == "none":
            return g
        dram_bw = g.dram_bandwidth * self.msm.dram_bandwidth_scale
        return g.with_(
            name=f"{g.name}/{self.name}",
            l3_capacity=self.msm.l3_capacity,
            # Paper §IV-D: UHB fixed at 2xRD+2xWR of the *baseline* DRAM BW.
            l3_bandwidth=uhb_bandwidth_for(g.dram_bandwidth, self.msm.uhb_scale),
            l3_energy_pj_per_bit=self.msm.link.energy_pj_per_bit,
            dram_bandwidth=dram_bw,
            dram_capacity=int(g.dram_capacity * self.msm.dram_capacity_scale),
        )


GPU_N_BASE = CopaConfig("GPU-N")
HBM_L3 = CopaConfig("HBM+L3", msm=MSM_L3)
HBML_L3 = CopaConfig("HBML+L3", msm=MSM_HBML_L3)
HBM_L3L = CopaConfig("HBM+L3L", msm=MSM_L3L)
HBML_L3L = CopaConfig("HBML+L3L", msm=MSM_HBML_L3L)
HBMLL_L3L = CopaConfig("HBMLL+L3L", msm=MSM_HBMLL_L3L)
PERFECT_L2 = CopaConfig("PerfectL2", perfect_llc=True)

TABLE_V = [GPU_N_BASE, HBM_L3, HBML_L3, HBM_L3L, HBML_L3L, HBMLL_L3L, PERFECT_L2]
TABLE_V_BY_NAME = {c.name: c for c in TABLE_V}


# --- Energy model (paper §III-D) ---------------------------------------------

@dataclass(frozen=True)
class EnergyReport:
    dram_bytes: float
    l3_bytes: float
    dram_joules: float
    l3_joules: float

    @property
    def total_joules(self) -> float:
        return self.dram_joules + self.l3_joules


def memory_energy(spec: GpuSpec, dram_bytes: float, l3_bytes: float) -> EnergyReport:
    """HBM-related energy. Paper: an L3 fetch costs ~4x less than HBM."""
    dram_j = dram_bytes * 8.0 * spec.dram_energy_pj_per_bit * 1e-12
    # L3 hit energy = link traversal + SRAM subarray; paper folds this into
    # "~4x less than HBM".
    l3_pj_per_bit = spec.dram_energy_pj_per_bit / 4.0
    l3_j = l3_bytes * 8.0 * l3_pj_per_bit * 1e-12
    return EnergyReport(dram_bytes, l3_bytes, dram_j, l3_j)
