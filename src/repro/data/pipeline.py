"""Deterministic, restartable token data pipeline.

Production shape: each host reads only its shard of the global batch
(``host_batch_slice``), a background thread prefetches and device-puts the
next batches, and the stream is a pure function of (seed, step) so restarts
resume bit-exactly from a step counter — no data-state checkpointing needed
beyond the step itself (the same determinism contract as MaxText's grain
pipelines).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic_lm"   # synthetic_lm | zipf_lm


def host_batch_slice(cfg: DataConfig, process_index: int, process_count: int):
    per = cfg.global_batch // process_count
    return slice(process_index * per, (process_index + 1) * per)


def _batch_at(cfg: DataConfig, step: int, rows: slice) -> dict[str, np.ndarray]:
    """Pure function of (seed, step): every host can regenerate any batch."""
    n = rows.stop - rows.start
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, rows.start]))
    if cfg.kind == "zipf_lm":
        toks = rng.zipf(1.3, size=(n, cfg.seq_len + 1)).astype(np.int64)
        toks = (toks - 1) % cfg.vocab_size
    else:
        toks = rng.integers(0, cfg.vocab_size, (n, cfg.seq_len + 1))
    toks = toks.astype(np.int32)
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
        "positions": np.broadcast_to(
            np.arange(cfg.seq_len, dtype=np.int32), (n, cfg.seq_len)).copy(),
    }


class DataLoader:
    """Prefetching iterator over deterministic batches.

    ``start_step`` makes restart-from-checkpoint trivial: the loader is
    stateless apart from the step counter it was constructed with.
    """

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2, process_index: int | None = None,
                 process_count: int | None = None):
        self.cfg = cfg
        self.rows = host_batch_slice(
            cfg,
            jax.process_index() if process_index is None else process_index,
            jax.process_count() if process_count is None else process_count)
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self.step
        while not self._stop.is_set():
            batch = _batch_at(self.cfg, step, self.rows)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
