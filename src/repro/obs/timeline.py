"""Chrome ``trace_event`` / Perfetto timelines from serving results.

Everything here is post-hoc: :meth:`Timeline.derive` turns the artifacts a
finished run already carries (step-log columns, request timing columns,
autoscale events) into a struct-of-arrays timeline with pure numpy slicing
— no per-event Python work, which is why derivation is priced at <=15% of
the batched sim itself on the ``serving.obs.*`` bench row. Building the
actual ``trace_event`` dicts (:func:`trace_events` / :func:`chrome_trace`)
is presentation-layer work proportional to the event count and is benched
separately, un-floored.

Track layout (open the JSON at https://ui.perfetto.dev or
``chrome://tracing``):

* ``pid 0`` ("fleet") — counter tracks for fleet size and queued/running
  totals, sampled at every autoscale tick.
* ``pid 1..N`` ("instance i") — one lane per instance: ``X`` complete
  events per engine iteration, named ``prefill+decode`` when the step
  consumed prompt chunks (exact under ``ObsConfig(level=1)``, inferred
  from admissions otherwise) and ``decode`` when purely decoding, with
  batch / committed-KV / mapped-page args; per-instance ``C`` counters for
  queue depth and KV occupancy.
* ``pid N+1`` ("requests") — request lifecycles as nestable async spans
  (``ph: b/e`` keyed by ``id`` = rid, which Perfetto lane-packs for us):
  ``queue`` (arrival -> admission), ``prefill`` (admission -> first
  token), ``decode`` (first token -> done), plus an instant ``i`` mark on
  requests the paged allocator evicted.
"""
from __future__ import annotations

import json
from dataclasses import dataclass

import numpy as np

_US = 1e6                 # trace_event timestamps are microseconds
_FLEET_PID = 0
_PHASES = frozenset({"X", "C", "M", "b", "e", "i"})


@dataclass
class InstanceTrack:
    """One instance's step history (views over its :class:`StepLog`)."""

    t_start: np.ndarray
    t_end: np.ndarray
    batch: np.ndarray
    kv_reserved: np.ndarray
    queued: np.ndarray
    admitted: np.ndarray
    pages: np.ndarray
    prefill_tokens: np.ndarray | None   # exact, ObsConfig(level>=1) only
    is_prefill: np.ndarray              # bool per step

    def __len__(self) -> int:
        return len(self.t_start)


@dataclass
class Timeline:
    """Struct-of-arrays timeline derived from a SimResult/FleetResult."""

    instances: list[InstanceTrack]
    # -- request columns (arrival-sorted views) --------------------------------
    rid: np.ndarray
    t_arrival: np.ndarray
    t_admitted: np.ndarray
    t_first: np.ndarray
    t_done: np.ndarray
    prompt_tokens: np.ndarray
    output_tokens: np.ndarray
    evictions: np.ndarray
    # -- autoscale samples -----------------------------------------------------
    scale_t: np.ndarray
    scale_n: np.ndarray
    scale_queued: np.ndarray
    scale_running: np.ndarray
    # -- run envelope ----------------------------------------------------------
    t0: float
    t1: float
    paged: bool
    n_requests_total: int
    dropped_requests: int     # requests beyond max_requests (not silent)

    @classmethod
    def derive(cls, result, max_requests: int | None = None) -> "Timeline":
        """Vectorized derivation — numpy slicing only, no per-event work.

        ``max_requests`` caps the request-lane columns (instance lanes and
        counters always cover the full run); the drop count is kept on the
        timeline and surfaced in the export, never silent."""
        batch, logs, events = _unpack(result)
        n_total = len(batch)
        keep = n_total if max_requests is None \
            else max(0, min(int(max_requests), n_total))

        tracks = []
        paged = False
        for log in logs:
            pf = log.prefill_tokens
            if pf is not None:
                is_pref = pf > 0
            else:
                # level 0: admission implies prompt consumption on the fast
                # path; a chunked-prefill run needs level 1 for exact labels
                is_pref = log.admitted > 0
            paged = paged or bool(len(log.pages) and log.pages.any())
            tracks.append(InstanceTrack(
                t_start=log.t_start, t_end=log.t_end, batch=log.batch,
                kv_reserved=log.kv_reserved, queued=log.queued,
                admitted=log.admitted, pages=log.pages,
                prefill_tokens=pf, is_prefill=is_pref))

        scale_t = np.array([e.t for e in events], dtype=float)
        scale_n = np.array([e.n_active for e in events], dtype=np.int64)
        scale_q = np.array([e.queued for e in events], dtype=np.int64)
        scale_r = np.array([e.running for e in events], dtype=np.int64)

        t0 = float(batch.t_arrival.min()) if n_total else 0.0
        highs = [float(tr.t_end.max()) for tr in tracks if len(tr)]
        if n_total:
            highs.append(float(batch.t_done.max()))
        t1 = max(highs) if highs else 0.0
        return cls(
            instances=tracks,
            rid=batch.rid[:keep], t_arrival=batch.t_arrival[:keep],
            t_admitted=batch.t_admitted[:keep],
            t_first=batch.t_first_token[:keep], t_done=batch.t_done[:keep],
            prompt_tokens=batch.prompt_tokens[:keep],
            output_tokens=batch.output_tokens[:keep],
            evictions=batch.evictions[:keep],
            scale_t=scale_t, scale_n=scale_n, scale_queued=scale_q,
            scale_running=scale_r,
            t0=t0, t1=t1, paged=paged,
            n_requests_total=n_total, dropped_requests=n_total - keep)

    @property
    def n_steps_total(self) -> int:
        return sum(len(tr) for tr in self.instances)


def _unpack(result):
    """(RequestBatch, step logs, scale events) from either result type."""
    if hasattr(result, "step_logs"):        # FleetResult
        return result.batch, result.step_logs, result.scale_events
    from repro.serve.sim import RequestBatch

    return (RequestBatch.from_completed(result.requests),
            [result.step_log], [])


def trace_events(result, *, max_requests: int | None = None) -> list[dict]:
    """The flat ``traceEvents`` list for ``result`` (see module docstring
    for the track layout). Accepts a result object or a pre-derived
    :class:`Timeline`."""
    tl = result if isinstance(result, Timeline) \
        else Timeline.derive(result, max_requests=max_requests)
    ev: list[dict] = []
    add = ev.append

    # -- fleet-wide process + autoscale counters -------------------------------
    add({"ph": "M", "name": "process_name", "pid": _FLEET_PID, "tid": 0,
         "ts": 0, "args": {"name": "fleet"}})
    for t, nact, q, r in zip(tl.scale_t.tolist(), tl.scale_n.tolist(),
                             tl.scale_queued.tolist(),
                             tl.scale_running.tolist()):
        ts = t * _US
        add({"ph": "C", "name": "fleet size", "pid": _FLEET_PID, "tid": 0,
             "ts": ts, "args": {"instances": nact}})
        add({"ph": "C", "name": "fleet load", "pid": _FLEET_PID, "tid": 0,
             "ts": ts, "args": {"queued": q, "running": r}})

    # -- one lane per instance -------------------------------------------------
    for idx, tr in enumerate(tl.instances):
        pid = idx + 1
        add({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
             "ts": 0, "args": {"name": f"instance {idx}"}})
        add({"ph": "M", "name": "thread_name", "pid": pid, "tid": 0,
             "ts": 0, "args": {"name": "steps"}})
        ts_l = (tr.t_start * _US).tolist()
        dur_l = ((tr.t_end - tr.t_start) * _US).tolist()
        b_l = tr.batch.tolist()
        kv_l = tr.kv_reserved.tolist()
        q_l = tr.queued.tolist()
        adm_l = tr.admitted.tolist()
        pg_l = tr.pages.tolist()
        pf_l = None if tr.prefill_tokens is None \
            else tr.prefill_tokens.tolist()
        pref_l = tr.is_prefill.tolist()
        for k in range(len(ts_l)):
            args = {"batch": b_l[k], "kv_committed_tokens": kv_l[k],
                    "admitted": adm_l[k]}
            if tl.paged:
                args["mapped_pages"] = pg_l[k]
            if pf_l is not None:
                args["prefill_tokens"] = pf_l[k]
            add({"ph": "X", "name": ("prefill+decode" if pref_l[k]
                                     else "decode"),
                 "pid": pid, "tid": 0, "ts": ts_l[k], "dur": dur_l[k],
                 "args": args})
            add({"ph": "C", "name": "queue depth", "pid": pid, "tid": 0,
                 "ts": ts_l[k], "args": {"queued": q_l[k]}})
            add({"ph": "C", "name": "kv occupancy", "pid": pid, "tid": 0,
                 "ts": ts_l[k],
                 "args": ({"mapped_pages": pg_l[k]} if tl.paged
                          else {"committed_tokens": kv_l[k]})})

    # -- request lifecycles (nestable async spans, lane-packed by id) ----------
    rpid = len(tl.instances) + 1
    add({"ph": "M", "name": "process_name", "pid": rpid, "tid": 0,
         "ts": 0, "args": {"name": "requests"}})
    rid_l = tl.rid.tolist()
    arr_l = (tl.t_arrival * _US).tolist()
    adm_l = (tl.t_admitted * _US).tolist()
    first_l = (tl.t_first * _US).tolist()
    done_l = (tl.t_done * _US).tolist()
    p_l = tl.prompt_tokens.tolist()
    o_l = tl.output_tokens.tolist()
    ev_l = tl.evictions.tolist()
    for k in range(len(rid_l)):
        rid = rid_l[k]
        base = {"cat": "request", "id": rid, "pid": rpid, "tid": 0}
        add({"ph": "b", "name": "queue", "ts": arr_l[k],
             "args": {"rid": rid, "prompt_tokens": p_l[k],
                      "output_tokens": o_l[k]}, **base})
        add({"ph": "e", "name": "queue", "ts": adm_l[k], **base})
        add({"ph": "b", "name": "prefill", "ts": adm_l[k], **base})
        add({"ph": "e", "name": "prefill", "ts": first_l[k], **base})
        if done_l[k] > first_l[k]:
            add({"ph": "b", "name": "decode", "ts": first_l[k], **base})
            add({"ph": "e", "name": "decode", "ts": done_l[k], **base})
        if ev_l[k]:
            add({"ph": "i", "name": "evicted", "s": "p", "pid": rpid,
                 "tid": 0, "ts": first_l[k], "args": {"rid": rid,
                                                      "evictions": ev_l[k]}})
    return ev


def chrome_trace(result, *, max_requests: int | None = None) -> dict:
    """The full Chrome trace document (``{"traceEvents": [...], ...}``)."""
    tl = result if isinstance(result, Timeline) \
        else Timeline.derive(result, max_requests=max_requests)
    return {
        "traceEvents": trace_events(tl),
        "displayTimeUnit": "ms",
        "otherData": {
            "generator": "repro.obs",
            "n_instances": len(tl.instances),
            "n_requests": tl.n_requests_total - tl.dropped_requests,
            "n_steps": tl.n_steps_total,
            "dropped_requests": tl.dropped_requests,
            "span_s": tl.t1 - tl.t0,
        },
    }


def write_chrome_trace(path, result, *,
                       max_requests: int | None = None) -> dict:
    """Serialize :func:`chrome_trace` to ``path``; returns the document."""
    doc = chrome_trace(result, max_requests=max_requests)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_chrome_trace(doc) -> list[str]:
    """Schema-check a trace document; returns problems (empty == valid).

    Covers what Perfetto/chrome://tracing need to load the file: known
    ``ph``, numeric non-negative ``ts`` (and ``dur`` for ``X``), integer
    ``pid``/``tid``, ``id`` on nestable async events, numeric counter args,
    and per-(pid, name) counters monotone non-decreasing in ``ts``."""
    probs: list[str] = []
    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    counter_ts: dict[tuple, float] = {}
    open_async: dict[tuple, int] = {}
    for k, e in enumerate(events):
        if not isinstance(e, dict):
            probs.append(f"event {k}: not an object")
            continue
        ph = e.get("ph")
        if ph not in _PHASES:
            probs.append(f"event {k}: unknown ph {ph!r}")
            continue
        for key in ("pid", "tid"):
            v = e.get(key)
            if not isinstance(v, int) or v < 0:
                probs.append(f"event {k}: bad {key} {v!r}")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or not np.isfinite(ts) or ts < 0:
            probs.append(f"event {k}: bad ts {ts!r}")
            continue
        if not isinstance(e.get("name"), str):
            probs.append(f"event {k}: missing name")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or not np.isfinite(dur) \
                    or dur < 0:
                probs.append(f"event {k}: bad dur {dur!r}")
        elif ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                probs.append(f"event {k}: counter args must be numbers")
            key = (e.get("pid"), e.get("name"))
            if ts < counter_ts.get(key, float("-inf")):
                probs.append(
                    f"event {k}: counter {key[1]!r} ts not monotone")
            counter_ts[key] = ts
        elif ph in ("b", "e"):
            if "id" not in e:
                probs.append(f"event {k}: async event without id")
            key = (e.get("cat"), e.get("id"), e.get("name"))
            open_async[key] = open_async.get(key, 0) + (1 if ph == "b"
                                                        else -1)
            if open_async[key] < 0:
                probs.append(f"event {k}: async end without begin {key!r}")
    for key, depth in open_async.items():
        if depth != 0:
            probs.append(f"unbalanced async span {key!r} (depth {depth})")
    return probs
