"""Observability: post-hoc, vectorized views over engine artifacts.

The serving engines (``repro.serve.sim`` / ``repro.serve.fleetbatch``) and
the sweep engine (``repro.core.sweep``) already record everything a
timeline needs — :class:`~repro.serve.sim.StepLog` columns, the
:class:`~repro.serve.sim.RequestBatch` timing columns, autoscale
:class:`~repro.serve.fleet.ScaleEvent` lists and the
:class:`~repro.core.sweep.SuiteAnalysis` attribution matrices. This package
derives observability FROM those artifacts after the run, never by hooking
per-event callbacks into the hot paths, so the batched fleet core keeps its
CI speed floor and its bit-identical parity oracles untouched.

Three layers:

* ``repro.obs.timeline`` — Chrome ``trace_event`` / Perfetto JSON export
  from any ``SimResult``/``FleetResult``: one lane per instance
  (prefill/decode step spans), request-lifecycle spans (queue -> first
  token -> done with eviction marks), counter tracks for queue depth, KV
  occupancy and fleet size.
* ``repro.obs.series`` — windowed :class:`MetricSeries` rollups
  (``FleetResult.timeseries(window_s)``): per-window goodput, TTFT/TPOT
  percentiles, batch occupancy, eviction rate, utilization.
* ``repro.obs.attribution`` — bottleneck attribution over the sweep engine:
  which resource (math / LLC / UHB / DRAM / ICI) bounds each
  workload x config cell and by what margin, as text tables and a
  plot-ready JSON roofline export.

``python -m repro.obs`` exposes trace/timeseries/explain over saved
results (``repro.obs.store``). The one engine knob is
:class:`~repro.serve.sim.ObsConfig` (re-exported here): level 1 adds a
``prefill_tokens`` step-log column for richer phase spans, with timing
results bit-identical either way.

Submodules import lazily so ``repro.serve`` never pays for this package
(and the serve -> obs -> serve cycle never materializes at import time).
"""

_HOMES = {
    "ObsConfig": "repro.serve.sim",
    "Timeline": "repro.obs.timeline",
    "trace_events": "repro.obs.timeline",
    "chrome_trace": "repro.obs.timeline",
    "write_chrome_trace": "repro.obs.timeline",
    "validate_chrome_trace": "repro.obs.timeline",
    "MetricSeries": "repro.obs.series",
    "timeseries": "repro.obs.series",
    "explain": "repro.obs.attribution",
    "ExplainReport": "repro.obs.attribution",
    "CellExplain": "repro.obs.attribution",
    "save_result": "repro.obs.store",
    "load_result": "repro.obs.store",
}

__all__ = sorted(_HOMES)


def __getattr__(name):
    home = _HOMES.get(name)
    if home is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(home), name)
    # Pin the resolved object: importing a submodule binds the MODULE over
    # its name on this package (so ``from repro.obs import explain`` would
    # otherwise resolve to repro.obs.explain the module, not the function —
    # from-import looks the name up twice and only the first consults us).
    globals()[name] = value
    return value
