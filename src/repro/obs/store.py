"""Persist a ``FleetResult`` as one ``.npz`` so the CLI can run the sim
once and derive traces / timeseries / tables from the saved artifact.

Columnar all the way down: the request batch saves as its SoA columns, the
per-instance step logs concatenate onto one axis with an offsets vector
(exactly how the batched engine thinks about them), and scale events save
as four parallel arrays. ``load_result`` rebuilds a ``FleetResult`` whose
metrics are recomputed from the batch — the saved file carries raw
artifacts, never derived numbers that could go stale."""
from __future__ import annotations

import numpy as np

_SCHEMA = "repro.obs.result/v1"


def save_result(path, result) -> None:
    """Save a ``FleetResult`` (or anything shaped like one: ``batch``,
    ``step_logs``, ``scale_events``, instance counts) to ``path``."""
    b = result.batch
    logs = result.step_logs
    offsets = np.cumsum([0] + [len(sl.t_start) for sl in logs])

    def cat(name):
        cols = [getattr(sl, name) for sl in logs]
        return np.concatenate(cols) if cols else np.zeros(0)

    has_pf = bool(logs) and all(sl.prefill_tokens is not None for sl in logs)
    ev = result.scale_events
    n_init = result.n_instances_initial
    arrays = {
        "schema": np.array(_SCHEMA),
        "rid": b.rid, "t_arrival": b.t_arrival,
        "prompt_tokens": b.prompt_tokens, "output_tokens": b.output_tokens,
        "t_admitted": b.t_admitted, "t_first_token": b.t_first_token,
        "t_done": b.t_done, "tokens_emitted": b.tokens_emitted,
        "evictions": b.evictions,
        "log_offsets": offsets,
        "log_t_start": cat("t_start"), "log_t_end": cat("t_end"),
        "log_batch": cat("batch"), "log_kv_reserved": cat("kv_reserved"),
        "log_queued": cat("queued"), "log_admitted": cat("admitted"),
        "log_pages": cat("pages"),
        "scale_t": np.array([e.t for e in ev], dtype=float),
        "scale_n": np.array([e.n_active for e in ev], dtype=np.int64),
        "scale_queued": np.array([e.queued for e in ev], dtype=np.int64),
        "scale_running": np.array([e.running for e in ev], dtype=np.int64),
        "n_instances_final": np.int64(result.n_instances_final),
        "n_instances_initial": np.int64(
            n_init if n_init is not None else -1),
    }
    if has_pf:
        arrays["log_prefill_tokens"] = cat("prefill_tokens")
    np.savez_compressed(path, **arrays)


def load_result(path):
    """Rebuild the ``FleetResult`` saved by :func:`save_result` (metrics
    recomputed from the request columns)."""
    from repro.serve.fleet import FleetResult, ScaleEvent
    from repro.serve.sim import RequestBatch, SimMetrics, StepLog

    with np.load(path, allow_pickle=False) as z:
        schema = str(z["schema"])
        if schema != _SCHEMA:
            raise ValueError(f"{path}: schema {schema!r}, "
                             f"expected {_SCHEMA!r}")
        batch = RequestBatch(
            rid=z["rid"], t_arrival=z["t_arrival"],
            prompt_tokens=z["prompt_tokens"],
            output_tokens=z["output_tokens"],
            t_admitted=z["t_admitted"], t_first_token=z["t_first_token"],
            t_done=z["t_done"], tokens_emitted=z["tokens_emitted"],
            evictions=z["evictions"])
        off = z["log_offsets"]
        pf = z["log_prefill_tokens"] if "log_prefill_tokens" in z else None
        logs = []
        for i in range(len(off) - 1):
            sl = slice(int(off[i]), int(off[i + 1]))
            logs.append(StepLog(
                t_start=z["log_t_start"][sl], t_end=z["log_t_end"][sl],
                batch=z["log_batch"][sl].astype(int),
                kv_reserved=z["log_kv_reserved"][sl],
                queued=z["log_queued"][sl].astype(int),
                admitted=z["log_admitted"][sl].astype(int),
                pages=z["log_pages"][sl].astype(int),
                prefill_tokens=None if pf is None else pf[sl].astype(int)))
        events = [ScaleEvent(t=float(t), n_active=int(n), queued=int(q),
                             running=int(r))
                  for t, n, q, r in zip(z["scale_t"], z["scale_n"],
                                        z["scale_queued"],
                                        z["scale_running"])]
        n_init = int(z["n_instances_initial"])
        return FleetResult(
            batch=batch, metrics=SimMetrics.from_batch(batch),
            step_logs=logs,
            n_instances_final=int(z["n_instances_final"]),
            scale_events=events,
            n_instances_initial=None if n_init < 0 else n_init)
