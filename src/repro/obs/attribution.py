"""Bottleneck attribution: WHY each sweep cell costs what it costs.

:func:`explain` runs the same deduplicated (workload x config x GPU-count)
grid as :meth:`~repro.core.sweep.SweepEngine.run`, but keeps the per-op
resource components (:meth:`~repro.core.sweep.SuiteAnalysis
.component_batch`) instead of collapsing them: every op is *bound* by the
resource whose component time wins the max, so each cell decomposes into
time bound by math / LLC / UHB / DRAM (plus the ICI collective for
scale-out training). The report ranks resources per cell, quotes the
binding margin (top resource over runner-up — how close the cell is to
tipping), and exports a plot-ready roofline JSON (arithmetic intensity vs
achieved throughput against each config's compute/DRAM ceilings).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.sweep import (
    LAUNCH_OVERHEAD_S,
    TIME_COMPONENTS,
    SweepEngine,
    _as_spec,
    _config_name,
    _dram_cap,
    ring_allreduce_time,
)

RESOURCES = TIME_COMPONENTS + ("ici",)


def _json_margin(margin: float) -> float | None:
    """inf margins (single-resource cells) are not valid JSON numbers."""
    return None if not np.isfinite(margin) else float(margin)


@dataclass(frozen=True)
class CellExplain:
    """One (workload, config, n_gpus) cell of the attribution grid."""

    workload: str
    config: str
    n_gpus: int
    kind: str
    time_s: float                  # total: per-op bottleneck sum + ici
    bound_s: dict[str, float]      # resource -> seconds of ops it binds
    bound_ops: dict[str, int]      # resource -> number of ops it binds
    flops: float                   # total FLOPs of the per-GPU trace
    dram_bytes: float              # DRAM traffic of the per-GPU trace

    @property
    def bottleneck(self) -> str:
        return max(self.bound_s, key=self.bound_s.get)

    @property
    def margin(self) -> float:
        """Top resource's bound time over the runner-up's — 1.0 means a
        dead heat, inf means every second is bound by one resource."""
        ts = sorted(self.bound_s.values(), reverse=True)
        return ts[0] / ts[1] if ts[1] > 0 else float("inf")

    @property
    def shares(self) -> dict[str, float]:
        tot = sum(self.bound_s.values())
        return {r: (v / tot if tot > 0 else 0.0)
                for r, v in self.bound_s.items()}

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / self.dram_bytes if self.dram_bytes > 0 \
            else float("inf")

    @property
    def achieved_tflops(self) -> float:
        return self.flops / self.time_s / 1e12 if self.time_s > 0 else 0.0

    def to_json(self) -> dict:
        return {
            "workload": self.workload,
            "config": self.config,
            "n_gpus": self.n_gpus,
            "kind": self.kind,
            "time_s": self.time_s,
            "bottleneck": self.bottleneck,
            "margin": _json_margin(self.margin),
            "bound_s": dict(self.bound_s),
            "bound_ops": dict(self.bound_ops),
            "shares": self.shares,
            "arithmetic_intensity": _json_margin(self.arithmetic_intensity),
            "achieved_tflops": self.achieved_tflops,
        }


@dataclass
class ExplainReport:
    """The full attribution grid plus the spec peaks a roofline needs."""

    cells: list[CellExplain]
    peaks: dict[str, dict[str, float]] = field(default_factory=dict)

    def cell(self, workload: str, config: str,
             n_gpus: int = 1) -> CellExplain:
        for c in self.cells:
            if (c.workload == workload and c.config == config
                    and c.n_gpus == n_gpus):
                return c
        raise KeyError(f"no cell ({workload!r}, {config!r}, n={n_gpus})")

    @property
    def workloads(self) -> list[str]:
        seen: dict[str, None] = {}
        for c in self.cells:
            seen.setdefault(c.workload)
        return list(seen)

    @property
    def configs(self) -> list[str]:
        seen: dict[str, None] = {}
        for c in self.cells:
            seen.setdefault(c.config)
        return list(seen)

    def table(self) -> str:
        """Text table: one row per cell, the resource ranking inline."""
        rows = []
        hdr = (f"{'workload':<28s} {'config':<18s} {'n':>3s} "
               f"{'time':>10s} {'bound by':<8s} {'margin':>7s}  shares")
        rows.append(hdr)
        rows.append("-" * len(hdr))
        for c in self.cells:
            shares = c.shares
            ranked = sorted((r for r in RESOURCES if shares.get(r, 0) > 0),
                            key=lambda r: -shares[r])
            share_txt = "  ".join(f"{r}:{shares[r]:.0%}" for r in ranked)
            mg = c.margin
            mg_txt = f"{mg:7.2f}" if np.isfinite(mg) else "    inf"
            rows.append(
                f"{c.workload:<28.28s} {c.config:<18.18s} {c.n_gpus:>3d} "
                f"{c.time_s:9.4g}s {c.bottleneck:<8s} {mg_txt}  {share_txt}")
        return "\n".join(rows)

    def roofline(self) -> dict:
        """Plot-ready roofline: per-config compute/DRAM ceilings plus one
        (AI, achieved TFLOP/s) point per cell."""
        return {
            "schema": "repro.obs.roofline/v1",
            "ceilings": {
                name: {
                    "fp16_tflops": pk["fp16_tflops"],
                    "fp32_tflops": pk["fp32_tflops"],
                    "dram_gbps": pk["dram_bandwidth"] / 1e9,
                    # the memory roof: achievable TFLOP/s at intensity AI is
                    # min(peak, AI * dram_bw) — the knee sits at
                    # peak_flops / dram_bw flop-per-byte.
                    "knee_flop_per_byte":
                        pk["fp16_tflops"] * 1e12 / pk["dram_bandwidth"],
                }
                for name, pk in self.peaks.items()
            },
            "points": [
                {
                    "workload": c.workload,
                    "config": c.config,
                    "n_gpus": c.n_gpus,
                    "ai_flop_per_byte": _json_margin(c.arithmetic_intensity),
                    "achieved_tflops": c.achieved_tflops,
                    "bottleneck": c.bottleneck,
                }
                for c in self.cells
            ],
        }

    def to_json(self) -> dict:
        return {
            "schema": "repro.obs.explain/v1",
            "resources": list(RESOURCES),
            "cells": [c.to_json() for c in self.cells],
            "roofline": self.roofline(),
        }


def explain_engine(engine: SweepEngine) -> ExplainReport:
    """Attribution over an existing engine's grid. Mirrors
    :meth:`SweepEngine.run`'s dedup loop (same workload expansion, same
    trace-identity sharing), but reduces the per-op component stack with
    argmax instead of max: each op's whole bottleneck time (launch overhead
    included) is charged to the resource that binds it, so per-cell
    ``sum(bound_s.values()) == time_s`` exactly."""
    specs = [(_config_name(c), _as_spec(c)) for c in engine.configs]
    spec_objs = [spec for _, spec in specs]

    jobs = []
    index: dict[int, int] = {}
    suite_traces = []
    for w in engine.workloads:
        trace1 = w.trace_for(1)
        per_n = [(n, trace1 if n == 1 else w.trace_for(n))
                 for n in engine.gpu_counts]
        jobs.append((w, per_n))
        for _, t in per_n:
            if id(t) not in index:
                index[id(t)] = len(suite_traces)
                suite_traces.append(t)
    suite = engine.suite_analysis(suite_traces)

    comp = suite.component_batch(spec_objs)     # (4, n_specs, n_ops)
    binding = comp.argmax(axis=0)               # ties -> first (math first)
    t_op = comp.max(axis=0) + LAUNCH_OVERHEAD_S
    dram_bytes = {_dram_cap(spec): suite.totals_below(_dram_cap(spec))
                  for _, spec in specs}

    cells: list[CellExplain] = []
    for w, per_n in jobs:
        for n, trace_n in per_n:
            i = index[id(trace_n)]
            ta = suite.analyses[i]
            sl = suite.op_slice(i)
            flops = float(suite.flops[sl].sum())
            coll = ring_allreduce_time(
                ta.grad_bytes, n, engine.ici_bandwidth, engine.ici_latency_s
            ) if trace_n.kind == "training" else 0.0
            for j, (name, spec) in enumerate(specs):
                b = binding[j, sl]
                t = t_op[j, sl]
                bound_s = {r: float(t[b == k].sum())
                           for k, r in enumerate(TIME_COMPONENTS)}
                bound_ops = {r: int((b == k).sum())
                             for k, r in enumerate(TIME_COMPONENTS)}
                bound_s["ici"] = coll
                bound_ops["ici"] = 1 if coll > 0 else 0
                cells.append(CellExplain(
                    workload=w.name, config=name, n_gpus=n,
                    kind=trace_n.kind, time_s=float(t.sum()) + coll,
                    bound_s=bound_s, bound_ops=bound_ops, flops=flops,
                    dram_bytes=float(dram_bytes[_dram_cap(spec)][i]),
                ))

    peaks = {name: {"fp16_tflops": spec.fp16_tflops,
                    "fp32_tflops": spec.fp32_tflops,
                    "dram_bandwidth": spec.dram_bandwidth}
             for name, spec in specs}
    return ExplainReport(cells=cells, peaks=peaks)


def explain(workloads, configs=None, **engine_kw) -> ExplainReport:
    """Build a :class:`SweepEngine` over ``workloads`` x ``configs`` (same
    defaults: Table V configs, GPU-N baseline, scenario-name globs expand
    through the registry) and attribute every cell. ``engine_kw`` passes
    through — ``gpu_counts``, ``ici_bandwidth``, ``ici_latency_s``, ..."""
    return explain_engine(SweepEngine(workloads, configs, **engine_kw))
