"""``python -m repro.obs`` — trace / timeseries / explain over saved runs.

Workflow: ``run`` executes a fleet simulation once (a named arrival spec,
or the self-contained ``--demo NxM`` fleet that replicates the serving
bench's synthetic grid) and saves the raw artifacts as ``.npz``; ``trace``
and ``timeseries`` then derive views from the saved file — or straight
from ``--demo`` for one-shot use. ``explain`` needs no saved run: it
attributes sweep-engine cells from workload/config names.

The ``--demo`` fleet is deliberately a replica of ``benchmarks/
bench_serving.py``'s fixed-seed 64x20k row (same synthetic cost grid,
same 0.8x-saturation Poisson arrivals), NOT an import of it: CI's obs
smoke step must be able to generate and schema-check the flagship
timeline without depending on the benchmark package.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _demo_result(shape: str, *, obs_level: int = 1, paged: bool = False,
                 seed: int = 0):
    """Run the self-contained demo fleet: ``shape`` is ``NxM`` instances x
    requests, e.g. ``64x20000`` (the bench flagship) or ``4x200``."""
    from repro.core.sweep import CostGrid
    from repro.serve.fleet import FleetSim
    from repro.serve.paged import PagedKvSpec
    from repro.serve.sim import ArrivalSpec, LengthDist, ObsConfig

    try:
        n_inst, n_req = (int(x) for x in shape.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--demo wants NxM (e.g. 64x20000), got {shape!r}")
    mb = 16
    batches = tuple(2 ** k for k in range(mb.bit_length()))
    edges = (2048.0, 8192.0, float("inf"))
    tab = np.asarray([[1e-3 * (1.0 + 0.02 * b + 0.05 * j)
                       for j in range(len(edges))] for b in batches])
    grid = CostGrid("obs-demo", batches, edges, tab,
                    prefill_s_per_token=1e-6)
    step = float(grid.step_time(mb, 4096.0))
    rate = n_inst * 0.8 * mb / (step * 64.0)
    spec = ArrivalSpec("obs.demo", rate, n_req,
                       prompt=LengthDist("fixed", 128),
                       output=LengthDist("uniform", low=32, high=96))
    kw = dict(max_batch=mb, kv_capacity_tokens=float("inf"),
              obs=ObsConfig(level=obs_level))
    if paged:
        kw["paged"] = PagedKvSpec(page_size=16)
    return FleetSim(grid, n_inst, **kw).run(spec, seed=seed)


def _load_or_demo(ns):
    from repro.obs.store import load_result

    if ns.demo:
        return _demo_result(ns.demo, obs_level=ns.obs_level,
                            paged=ns.paged, seed=ns.seed)
    if not ns.result:
        raise SystemExit("need a RESULT.npz (or --demo NxM)")
    return load_result(ns.result)


def _add_source_args(p):
    p.add_argument("result", nargs="?", default=None,
                   help="saved .npz from the run subcommand")
    p.add_argument("--demo", metavar="NxM", default=None,
                   help="run the demo fleet instead (instances x requests)")
    p.add_argument("--obs-level", type=int, default=1, choices=(0, 1),
                   help="ObsConfig level for --demo (default 1)")
    p.add_argument("--paged", action="store_true",
                   help="paged KV residency for --demo")
    p.add_argument("--seed", type=int, default=0)


def cmd_run(ns) -> int:
    from repro.obs.store import save_result

    res = _demo_result(ns.demo or "8x2000", obs_level=ns.obs_level,
                       paged=ns.paged, seed=ns.seed)
    save_result(ns.out, res)
    m = res.metrics
    print(f"{ns.out}: {len(res.batch)} requests, "
          f"{sum(len(sl.t_start) for sl in res.step_logs)} steps, "
          f"{res.n_instances_final} instances, "
          f"makespan {m.makespan_s:.2f}s, "
          f"throughput {m.throughput_rps:.1f} r/s")
    return 0


def cmd_trace(ns) -> int:
    from repro.obs.timeline import chrome_trace, validate_chrome_trace

    res = _load_or_demo(ns)
    doc = chrome_trace(res, max_requests=ns.max_requests)
    if ns.check:
        errs = validate_chrome_trace(doc)
        if errs:
            for e in errs[:20]:
                print(f"SCHEMA: {e}", file=sys.stderr)
            print(f"{len(errs)} schema error(s)", file=sys.stderr)
            return 1
    if ns.out:
        with open(ns.out, "w") as f:
            json.dump(doc, f)
        od = doc["otherData"]
        print(f"{ns.out}: {len(doc['traceEvents'])} events "
              f"({od['n_instances']} instances, {od['n_requests']} requests"
              + (f", {od['dropped_requests']} dropped)"
                 if od["dropped_requests"] else ")")
              + (" [schema ok]" if ns.check else ""))
    else:
        json.dump(doc, sys.stdout)
        print()
    return 0


def cmd_timeseries(ns) -> int:
    from repro.obs.series import timeseries
    from repro.serve.sim import Slo

    res = _load_or_demo(ns)
    slo = Slo(ttft_s=ns.slo_ttft, percentile=95) \
        if ns.slo_ttft is not None else None
    window = ns.window
    if window is None:
        window = max(res.metrics.makespan_s / 40.0, 1e-9)
    series = timeseries(res, window, slo=slo)
    print(series.table())
    if ns.json:
        with open(ns.json, "w") as f:
            json.dump(series.to_json(), f, indent=1)
        print(f"\nwrote {ns.json} ({len(series)} windows)")
    return 0


def cmd_explain(ns) -> int:
    from repro.core import copa
    from repro.obs.attribution import explain

    configs = None
    if ns.configs:
        try:
            configs = [copa.TABLE_V_BY_NAME[c] for c in ns.configs]
        except KeyError as e:
            raise SystemExit(
                f"unknown config {e.args[0]!r}; choose from "
                f"{sorted(copa.TABLE_V_BY_NAME)}")
    kw = {}
    if ns.gpu_counts:
        kw["gpu_counts"] = ns.gpu_counts
    if ns.ici_bandwidth is not None:
        kw["ici_bandwidth"] = ns.ici_bandwidth
    report = explain(ns.workloads, configs, **kw)
    print(report.table())
    if ns.roofline:
        with open(ns.roofline, "w") as f:
            json.dump(report.roofline(), f, indent=1)
        print(f"\nwrote {ns.roofline} "
              f"({len(report.cells)} points, "
              f"{len(report.peaks)} config ceilings)")
    if ns.json:
        with open(ns.json, "w") as f:
            json.dump(report.to_json(), f, indent=1)
        print(f"wrote {ns.json}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="post-hoc observability: timelines, windowed metrics, "
                    "bottleneck attribution")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("run", help="run the demo fleet, save raw artifacts")
    p.add_argument("--demo", metavar="NxM", default="8x2000",
                   help="instances x requests (default 8x2000)")
    p.add_argument("-o", "--out", default="fleet_result.npz")
    p.add_argument("--obs-level", type=int, default=1, choices=(0, 1))
    p.add_argument("--paged", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("trace",
                       help="Chrome trace_event JSON (chrome://tracing, "
                            "Perfetto)")
    _add_source_args(p)
    p.add_argument("-o", "--out", default=None,
                   help="output .json (default: stdout)")
    p.add_argument("--max-requests", type=int, default=None,
                   help="cap request-lifecycle spans (instance lanes and "
                        "counters always cover the full run)")
    p.add_argument("--check", action="store_true",
                   help="schema-validate the emitted document")
    p.set_defaults(fn=cmd_trace)

    p = sub.add_parser("timeseries", help="windowed metric table")
    _add_source_args(p)
    p.add_argument("--window", type=float, default=None,
                   help="window width in seconds (default: makespan/40)")
    p.add_argument("--slo-ttft", type=float, default=None,
                   help="TTFT SLO seconds: adds ok/goodput columns (p95)")
    p.add_argument("--json", default=None, help="also write JSON rollup")
    p.set_defaults(fn=cmd_timeseries)

    p = sub.add_parser("explain",
                       help="bottleneck attribution over the sweep engine")
    p.add_argument("workloads", nargs="+",
                   help="scenario names or globs, e.g. 'mlperf.train.*.large'")
    p.add_argument("--configs", nargs="+", default=None,
                   help="Table-V config names (default: all)")
    p.add_argument("--gpu-counts", nargs="+", type=int, default=None)
    p.add_argument("--ici-bandwidth", type=float, default=None,
                   help="bytes/s per direction (default: ideal fabric)")
    p.add_argument("--roofline", default=None,
                   help="write plot-ready roofline JSON here")
    p.add_argument("--json", default=None, help="write the full report JSON")
    p.set_defaults(fn=cmd_explain)

    ns = ap.parse_args(argv)
    return ns.fn(ns)


if __name__ == "__main__":
    raise SystemExit(main())
