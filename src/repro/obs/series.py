"""Windowed metric rollups: when did the SLO break, not just whether.

:func:`timeseries` buckets a finished run into fixed-width windows over
``[t_first_arrival, t_last_done]`` and reduces each bucket with pure numpy
(bincounts for the per-request columns, an interval-overlap accumulation
for the step-log integrals) — part of the post-hoc derivation priced on
the ``serving.obs.*`` bench row.

Exactness contract (property-tested for arbitrary ``window_s``): requests
are assigned to windows by clipped ``floor((t - t0) / window_s)``, so the
per-window ``arrived`` / ``completed`` / ``ok`` / ``tokens`` /
``evictions`` columns sum EXACTLY to the aggregate
:class:`~repro.serve.sim.SimMetrics` values — no request is ever lost to
edge rounding.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class MetricSeries:
    """Per-window rollup of one run. Rates are per second of window."""

    window_s: float
    t0: float                    # absolute left edge of window 0
    t1: float                    # end of the covered span (last done)
    n_instances: int             # initial fleet size the capacity tracks
    # -- per-request columns, bucketed -----------------------------------------
    arrived: np.ndarray          # requests arriving in the window
    completed: np.ndarray        # requests completing in the window
    ok: np.ndarray               # completions meeting the SLO (0s w/o slo)
    tokens: np.ndarray           # output tokens of those completions
    evictions: np.ndarray        # evictions of those completions
    ttft_p50: np.ndarray         # NaN where a window has no completions
    ttft_p95: np.ndarray
    tpot_p95: np.ndarray
    # -- step-log integrals ----------------------------------------------------
    busy_s: np.ndarray           # instance-seconds spent stepping
    capacity_s: np.ndarray       # instance-seconds available (fleet integral)
    batch_mean: np.ndarray       # busy-time-weighted running batch
    queue_mean: np.ndarray       # busy-time-weighted waiting-queue depth
    has_slo: bool = field(default=False)

    def __len__(self) -> int:
        return len(self.arrived)

    @property
    def t_start(self) -> np.ndarray:
        """Absolute left edge of every window."""
        return self.t0 + self.window_s * np.arange(len(self))

    @property
    def throughput_rps(self) -> np.ndarray:
        return self.completed / self.window_s

    @property
    def goodput_rps(self) -> np.ndarray:
        return self.ok / self.window_s

    @property
    def tokens_per_s(self) -> np.ndarray:
        return self.tokens / self.window_s

    @property
    def eviction_rate_rps(self) -> np.ndarray:
        return self.evictions / self.window_s

    @property
    def utilization(self) -> np.ndarray:
        """busy instance-seconds / available instance-seconds (NaN when a
        window has no capacity, e.g. past the end of the run)."""
        return np.divide(self.busy_s, self.capacity_s,
                         out=np.full(len(self), np.nan),
                         where=self.capacity_s > 0)

    def rows(self) -> list[dict]:
        out = []
        t_start = self.t_start
        util = self.utilization
        for j in range(len(self)):
            out.append({
                "t_start_s": float(t_start[j]),
                "arrived": int(self.arrived[j]),
                "completed": int(self.completed[j]),
                "ok": int(self.ok[j]),
                "throughput_rps": float(self.throughput_rps[j]),
                "goodput_rps": float(self.goodput_rps[j]),
                "tokens_per_s": float(self.tokens_per_s[j]),
                "evictions": int(self.evictions[j]),
                "ttft_p50_s": float(self.ttft_p50[j]),
                "ttft_p95_s": float(self.ttft_p95[j]),
                "tpot_p95_s": float(self.tpot_p95[j]),
                "batch_mean": float(self.batch_mean[j]),
                "queue_mean": float(self.queue_mean[j]),
                "utilization": float(util[j]),
            })
        return out

    def to_json(self) -> dict:
        return {
            "schema": "repro.obs.timeseries/v1",
            "window_s": self.window_s,
            "t0_s": self.t0,
            "n_windows": len(self),
            "n_instances_initial": self.n_instances,
            "has_slo": self.has_slo,
            "windows": self.rows(),
        }

    def table(self) -> str:
        """Text table, one row per window."""
        hdr = (f"{'t+':>8s} {'arr':>6s} {'done':>6s} "
               f"{'ok' if self.has_slo else '-':>6s} {'thru r/s':>9s} "
               f"{'good r/s':>9s} {'tok/s':>9s} {'ttft p95':>9s} "
               f"{'batch':>6s} {'queue':>7s} {'util':>5s} {'evict':>5s}")
        lines = [hdr, "-" * len(hdr)]
        t_rel = self.t_start - self.t0
        util = self.utilization
        for j in range(len(self)):
            u = f"{util[j]:5.0%}" if np.isfinite(util[j]) else "    -"
            p95 = f"{self.ttft_p95[j]:8.3f}s" \
                if np.isfinite(self.ttft_p95[j]) else "        -"
            lines.append(
                f"{t_rel[j]:7.1f}s {self.arrived[j]:6d} "
                f"{self.completed[j]:6d} "
                f"{(self.ok[j] if self.has_slo else 0):6d} "
                f"{self.throughput_rps[j]:9.1f} {self.goodput_rps[j]:9.1f} "
                f"{self.tokens_per_s[j]:9.0f} {p95} "
                f"{self.batch_mean[j]:6.1f} {self.queue_mean[j]:7.1f} "
                f"{u} {self.evictions[j]:5d}")
        return "\n".join(lines)


def _window_percentiles(vals: np.ndarray, widx: np.ndarray, n_win: int,
                        p: float) -> np.ndarray:
    """Per-window ``p``-th percentile of ``vals`` grouped by ``widx``
    (NaN for empty windows) — one stable argsort, then per-window slices."""
    out = np.full(n_win, np.nan)
    if len(vals) == 0:
        return out
    order = np.argsort(widx, kind="stable")
    sv = vals[order]
    sw = widx[order]
    bounds = np.searchsorted(sw, np.arange(n_win + 1))
    for j in range(n_win):
        lo, hi = bounds[j], bounds[j + 1]
        if hi > lo:
            out[j] = np.percentile(sv[lo:hi], p)
    return out


def _overlap_integrals(a: np.ndarray, b: np.ndarray,
                       weights: list[np.ndarray], t0: float, w: float,
                       n_win: int) -> tuple[np.ndarray, list[np.ndarray]]:
    """Per-window overlap integrals for intervals ``[a, b)`` carrying
    constant per-interval ``weights``: returns (duration integral, one
    weighted integral per weight array). Within-window intervals accumulate
    vectorized; only boundary-crossing intervals (rare for windows much
    wider than a step) walk their window range in Python."""
    dur = np.zeros(n_win)
    outs = [np.zeros(n_win) for _ in weights]
    if len(a) == 0 or n_win == 0:
        return dur, outs
    ia = np.clip(((a - t0) // w).astype(np.int64), 0, n_win - 1)
    ib = np.clip(((b - t0) // w).astype(np.int64), 0, n_win - 1)
    d = b - a
    same = ia == ib
    np.add.at(dur, ia[same], d[same])
    for o, wt in zip(outs, weights):
        np.add.at(o, ia[same], (d * wt)[same])
    cross = np.nonzero(~same)[0]
    if len(cross):
        edges = t0 + w * np.arange(n_win + 1)
        for k in cross.tolist():
            lo, hi = a[k], b[k]
            for j in range(int(ia[k]), int(ib[k]) + 1):
                seg = min(hi, edges[j + 1]) - max(lo, edges[j])
                if seg > 0:
                    dur[j] += seg
                    for o, wt in zip(outs, weights):
                        o[j] += seg * wt[k]
    return dur, outs


def timeseries(result, window_s: float, *, slo=None) -> MetricSeries:
    """Windowed rollup of a ``SimResult``/``FleetResult`` (see module
    docstring for the exact-sum contract). ``slo`` enables the ``ok`` /
    goodput columns (a :class:`~repro.serve.sim.Slo`)."""
    from repro.obs.timeline import _unpack

    w = float(window_s)
    if not (w > 0 and np.isfinite(w)):
        raise ValueError(f"window_s must be finite and > 0, got {window_s!r}")
    batch, logs, events = _unpack(result)
    m = result.metrics
    n = len(batch)
    n_init = getattr(result, "n_instances_initial", None)
    if n_init is None:
        n_init = max(len(logs), 1)

    if n == 0:
        z = np.zeros(0)
        zi = np.zeros(0, dtype=np.int64)
        return MetricSeries(window_s=w, t0=0.0, t1=0.0,
                            n_instances=n_init, arrived=zi, completed=zi,
                            ok=zi, tokens=zi, evictions=zi, ttft_p50=z,
                            ttft_p95=z, tpot_p95=z, busy_s=z, capacity_s=z,
                            batch_mean=z, queue_mean=z,
                            has_slo=slo is not None)

    t0, t1 = m.t_first_arrival, m.t_last_done
    n_win = max(1, int(np.ceil((t1 - t0) / w))) if t1 > t0 else 1

    def widx(t):
        return np.clip(((t - t0) // w).astype(np.int64), 0, n_win - 1)

    wa = widx(batch.t_arrival)
    wc = widx(batch.t_done)
    arrived = np.bincount(wa, minlength=n_win)
    completed = np.bincount(wc, minlength=n_win)
    tokens = np.bincount(wc, weights=batch.output_tokens,
                         minlength=n_win).astype(np.int64)
    evicts = np.bincount(wc, weights=batch.evictions,
                         minlength=n_win).astype(np.int64)
    if slo is not None:
        ok = np.bincount(wc, weights=slo.ok_mask(m),
                         minlength=n_win).astype(np.int64)
    else:
        ok = np.zeros(n_win, dtype=np.int64)

    ttft_p50 = _window_percentiles(m.ttft, wc, n_win, 50)
    ttft_p95 = _window_percentiles(m.ttft, wc, n_win, 95)
    multi = m.output_tokens > 1
    tpot_p95 = _window_percentiles(m.tpot[multi], wc[multi], n_win, 95)

    # -- step-log integrals (busy time, running batch, queue depth) ------------
    if logs and any(len(sl.t_start) for sl in logs):
        a = np.concatenate([sl.t_start for sl in logs])
        bnd = np.concatenate([sl.t_end for sl in logs])
        bsz = np.concatenate([sl.batch for sl in logs]).astype(float)
        qd = np.concatenate([sl.queued for sl in logs]).astype(float)
        busy, (bint, qint) = _overlap_integrals(a, bnd, [bsz, qd],
                                                t0, w, n_win)
    else:
        busy = np.zeros(n_win)
        bint = qint = np.zeros(n_win)
    batch_mean = np.divide(bint, busy, out=np.zeros(n_win), where=busy > 0)
    queue_mean = np.divide(qint, busy, out=np.zeros(n_win), where=busy > 0)

    # -- fleet capacity integral over [t0, t1] (autoscale-aware) ---------------
    if events:
        st = np.array([e.t for e in events], dtype=float)
        sn = np.array([e.n_active for e in events], dtype=float)
        starts = np.concatenate([[t0], st])
        ends = np.minimum(np.concatenate([st, [t1]]), t1)
        vals = np.concatenate([[float(n_init)], sn])
    else:
        starts = np.array([t0])
        ends = np.array([t1])
        vals = np.array([float(n_init)])
    keep = ends > starts
    _, (capacity,) = _overlap_integrals(starts[keep], ends[keep],
                                        [vals[keep]], t0, w, n_win)

    return MetricSeries(window_s=w, t0=t0, t1=t1, n_instances=int(n_init),
                        arrived=arrived, completed=completed, ok=ok,
                        tokens=tokens, evictions=evicts, ttft_p50=ttft_p50,
                        ttft_p95=ttft_p95, tpot_p95=tpot_p95, busy_s=busy,
                        capacity_s=capacity, batch_mean=batch_mean,
                        queue_mean=queue_mean, has_slo=slo is not None)
