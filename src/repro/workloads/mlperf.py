"""MLPerf-proxy workloads at the paper's Table III batch sizes.

The paper traces NVIDIA's MLPerf Training v0.6 / Inference v0.5 submissions
on a V100 and replays them through a proprietary simulator. We cannot ship
those traces, so each benchmark is regenerated *analytically* from its
published architecture at the paper's per-GPU batch sizes. Footprints land
within the same regime as Table III (asserted in tests); exact per-kernel
fidelity is neither possible nor required — the evaluation reproduces the
paper's aggregate behaviours (Figs 2,4,8,9,11,12).

Table III (paper):
    training:   resnet 12/128, ssd 4/128, maskrcnn 1/6, minigo 128/2048,
                gnmt 32/256, transformer 640/5120 (tokens), ncf 65,536/1,048,576
    inference:  resnet 1/232, mobilenet 1/704, ssd-small 1/288,
                ssd-large 1/6, gnmt 1/128
"""
from __future__ import annotations

from functools import lru_cache

from repro.core.trace import Trace
from repro.workloads.common import ModelBuilder

MB = 1024 * 1024


# --------------------------------------------------------------------------------
# Vision backbones
# --------------------------------------------------------------------------------

def _resnet_backbone(mb: ModelBuilder, n: int, h: int, w: int,
                     stages: tuple[int, ...], widths: tuple[int, ...],
                     bottleneck: bool, in_ch: int = 3,
                     fuse_residual: bool = False) -> tuple[str, int, int, int]:
    x, hh, ww = mb.conv("conv1", "in.img", n, h, w, in_ch, 64, 7, 7, stride=2)
    x = mb.eltwise("bn1", x, n * hh * ww * 64 * mb.dtype_bytes())
    hh, ww = hh // 2, ww // 2  # maxpool
    cin = 64
    for si, (blocks, width) in enumerate(zip(stages, widths)):
        for bi in range(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            name = f"s{si}b{bi}"
            # BN+ReLU are fused into the producing conv (as in NVIDIA's MLPerf
            # submissions), so they add no standalone traffic; the residual
            # add remains a real kernel.
            if bottleneck:
                cout = width * 4
                y, h2, w2 = mb.conv(f"{name}.c1", x, n, hh, ww, cin, width, 1, 1, stride)
                y, _, _ = mb.conv(f"{name}.c2", y, n, h2, w2, width, width, 3, 3)
                y, _, _ = mb.conv(f"{name}.c3", y, n, h2, w2, width, cout, 1, 1)
            else:
                cout = width
                y, h2, w2 = mb.conv(f"{name}.c1", x, n, hh, ww, cin, width, 3, 3, stride)
                y, _, _ = mb.conv(f"{name}.c2", y, n, h2, w2, width, cout, 3, 3)
            act_bytes = n * h2 * w2 * cout * mb.dtype_bytes()
            if fuse_residual:
                # Inference deployments (TensorRT-class) fuse conv+add+relu:
                # the skip connection is consumed inside the last conv kernel.
                if stride == 1 and cin == cout:
                    self_read = (x, act_bytes)
                    mb.layers[-1].extra_reads = mb.layers[-1].extra_reads + (self_read,)
            else:
                y = mb.eltwise(f"{name}.bnadd", y, act_bytes,
                               extra_reads=((x, act_bytes if (stride == 1 and cin == cout) else 0),))
            x, hh, ww, cin = y, h2, w2, cout
    return x, hh, ww, cin


def resnet50(batch: int, h: int = 224, w: int = 224,
             fuse_residual: bool = False) -> ModelBuilder:
    mb = ModelBuilder(f"resnet50.b{batch}")
    x, hh, ww, c = _resnet_backbone(mb, batch, h, w, (3, 4, 6, 3),
                                    (64, 128, 256, 512), bottleneck=True,
                                    fuse_residual=fuse_residual)
    x = mb.eltwise("gap", x, batch * c * mb.dtype_bytes(), stash=False)
    mb.gemm("fc", x, batch, c, 1000)
    return mb


def resnet34_ssd(batch: int, res: int, fuse_residual: bool = False) -> ModelBuilder:
    mb = ModelBuilder(f"ssd.r34.{res}.b{batch}")
    x, hh, ww, c = _resnet_backbone(mb, batch, res, res, (3, 4, 6),
                                    (64, 128, 256), bottleneck=False,
                                    fuse_residual=fuse_residual)
    # SSD extra feature layers + multibox heads
    feats = [(x, hh, ww, c)]
    for i, cout in enumerate((512, 512, 256, 256)):
        x, _, _ = mb.conv(f"extra{i}.a", x, batch, hh, ww, c, cout // 2, 1, 1)
        x, hh, ww = mb.conv(f"extra{i}.b", x, batch, hh, ww, cout // 2, cout, 3, 3, stride=2)
        c = cout
        feats.append((x, hh, ww, c))
    for i, (f, fh, fw, fc) in enumerate(feats):
        mb.conv(f"head{i}.loc", f, batch, fh, fw, fc, 4 * 4, 3, 3)
        mb.conv(f"head{i}.conf", f, batch, fh, fw, fc, 4 * 81, 3, 3)
    return mb


def mobilenet_v1(batch: int, res: int = 224, width: float = 1.0,
                 fuse_dw: bool = False) -> ModelBuilder:
    mb = ModelBuilder(f"mobilenet.b{batch}")
    ch = int(32 * width)
    x, h, w = mb.conv("conv1", "in.img", batch, res, res, 3, ch, 3, 3, stride=2)
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
            *[(512, 1)] * 5, (1024, 2), (1024, 1)]
    for i, (cout, s) in enumerate(plan):
        cout = int(cout * width)
        if fuse_dw:
            # TensorRT-class inference fuses dw+pw+relu: the depthwise
            # intermediate never leaves on-chip storage.
            dw_flops = 2.0 * batch * (h // s) * (w // s) * ch * 9
            x, h, w = mb.conv(f"dwpw{i}", x, batch, h, w, ch, cout, 1, 1)
            if s > 1:
                h, w = -(-h // s), -(-w // s)
                mb.layers[-1].y_bytes = batch * h * w * cout * mb.dtype_bytes()
            mb.layers[-1].flops += dw_flops
            mb.layers[-1].w_bytes += ch * 9 * mb.dtype_bytes()
        else:
            x, h, w = mb.dwconv(f"dw{i}", x, batch, h, w, ch, 3, 3, stride=s)
            x, h, w = mb.conv(f"pw{i}", x, batch, h, w, ch, cout, 1, 1)
        ch = cout
    x = mb.eltwise("gap", x, batch * ch * mb.dtype_bytes(), stash=False)
    mb.gemm("fc", x, batch, ch, 1000)
    return mb


def maskrcnn(batch: int) -> ModelBuilder:
    """ResNet50-FPN @ 800x1344 + RPN + box/mask heads over 1000 ROIs."""
    mb = ModelBuilder(f"maskrcnn.b{batch}")
    x, hh, ww, c = _resnet_backbone(mb, batch, 800, 1344, (3, 4, 6, 3),
                                    (64, 128, 256, 512), bottleneck=True)
    # FPN lateral+output convs on 4 levels (approximate level sizes)
    for lvl, (fh, fw, fc) in enumerate(((100, 168, 2048), (100, 168, 1024),
                                        (200, 336, 512), (400, 672, 256))):
        l, _, _ = mb.conv(f"fpn.lat{lvl}", None, batch, fh, fw, fc, 256, 1, 1)
        o, _, _ = mb.conv(f"fpn.out{lvl}", l, batch, fh, fw, 256, 256, 3, 3)
        mb.conv(f"rpn{lvl}", o, batch, fh, fw, 256, 256, 3, 3)
    rois = 1000 * batch
    # box head: 2 FC on 7x7x256 pooled features
    x = mb.gemm("box.fc1", None, rois, 7 * 7 * 256, 1024,
                x_bytes=rois * 7 * 7 * 256 * mb.dtype_bytes())
    x = mb.gemm("box.fc2", x, rois, 1024, 1024)
    mb.gemm("box.cls", x, rois, 1024, 81 * 5)
    # mask head: 4 conv on 14x14 + deconv
    m = None
    fh = 14
    for i in range(4):
        m, _, _ = mb.conv(f"mask.c{i}", m, rois, fh, fh, 256, 256, 3, 3)
    mb.conv("mask.deconv", m, rois, 28, 28, 256, 81, 1, 1)
    return mb


def minigo(batch: int) -> ModelBuilder:
    """AlphaZero-style residual tower on a 19x19 board (proxy: 9 blocks x64,
    BN folded/recomputed — calibrated to Table III's 105MB/1.5GB footprints)."""
    mb = ModelBuilder(f"minigo.b{batch}")
    ch = 64
    x, _, _ = mb.conv("stem", "in.board", batch, 19, 19, 17, ch, 3, 3)
    for i in range(9):
        y, _, _ = mb.conv(f"rb{i}.c1", x, batch, 19, 19, ch, ch, 3, 3)
        y = mb.eltwise(f"rb{i}.bn1", y, batch * 361 * ch * mb.dtype_bytes(),
                       stash=False)
        y, _, _ = mb.conv(f"rb{i}.c2", y, batch, 19, 19, ch, ch, 3, 3)
        act = batch * 361 * ch * mb.dtype_bytes()
        x = mb.eltwise(f"rb{i}.add", y, act, extra_reads=((x, act),),
                       stash=False)
    p, _, _ = mb.conv("policy.conv", x, batch, 19, 19, ch, 2, 1, 1)
    mb.gemm("policy.fc", p, batch, 2 * 361, 362)
    v, _, _ = mb.conv("value.conv", x, batch, 19, 19, ch, 1, 1, 1)
    v = mb.gemm("value.fc1", v, batch, 361, 256)
    mb.gemm("value.fc2", v, batch, 256, 1)
    return mb


# --------------------------------------------------------------------------------
# NLP / recommender
# --------------------------------------------------------------------------------

def gnmt(batch: int, seq: int = 50, hidden: int = 1024, vocab: int = 32000,
         decode_only: bool = False) -> ModelBuilder:
    """GNMT: 8-layer encoder + 8-layer decoder LSTM w/ attention.

    LSTM steps are emitted per (layer, unrolled-chunk): the recurrent GEMMs
    at a given layer reuse their weights every timestep — the inter-kernel
    reuse pattern the paper highlights. We chunk timesteps by 8 to keep the
    trace compact while preserving the reuse structure.
    """
    mb = ModelBuilder(f"gnmt.b{batch}")
    e = mb.dtype_bytes()
    chunk = 8
    mb.gather("src.embed", vocab * hidden * e, batch * seq * hidden * e)
    sides = ["dec"] if decode_only else ["enc", "dec"]
    for side in sides:
        for layer in range(8):
            w_x, w_h = f"{side}.l{layer}.W", f"{side}.l{layer}.U"
            for t0 in range(0, seq, chunk):
                steps = min(chunk, seq - t0)
                m = batch * steps
                name = f"{side}.l{layer}.t{t0}"
                x = mb.gemm(f"{name}.xw", None, m, hidden, 4 * hidden,
                            x_bytes=m * hidden * e, shared_w=w_x)
                h = mb.gemm(f"{name}.hu", None, m, hidden, 4 * hidden,
                            x_bytes=m * hidden * e, shared_w=w_h)
                mb.eltwise(f"{name}.gates", x, m * 4 * hidden * e,
                           extra_reads=((h, m * 4 * hidden * e),))
            if side == "dec" and layer == 0:
                # Bahdanau-ish attention over encoder states
                mb.attention(f"attn", None if decode_only else x, batch,
                             seq, seq, heads=1, dim=hidden, chunked=False,
                             causal=False)
    mb.gemm("logits", None, batch * seq, hidden, vocab,
            x_bytes=batch * seq * hidden * e)
    return mb


def transformer_big(batch_tokens: int, seq: int = 64, d: int = 1024,
                    ff: int = 4096, heads: int = 16, vocab: int = 32768) -> ModelBuilder:
    """MLPerf 'transformer' = Transformer-big for WMT en-de."""
    mb = ModelBuilder(f"transformer.t{batch_tokens}")
    e = mb.dtype_bytes()
    b = max(batch_tokens // seq, 1)
    tokens = b * seq
    mb.gather("embed", vocab * d * e, tokens * d * e)
    x = None
    for side, nlayers in (("enc", 6), ("dec", 6)):
        for l in range(nlayers):
            name = f"{side}{l}"
            x = mb.attention(f"{name}.self", x, b, seq, seq, heads, d // heads,
                             chunked=False, causal=(side == "dec"))
            x = mb.eltwise(f"{name}.ln1", x, tokens * d * e)
            if side == "dec":
                x = mb.attention(f"{name}.cross", x, b, seq, seq, heads,
                                 d // heads, chunked=False, causal=False)
                x = mb.eltwise(f"{name}.lnx", x, tokens * d * e)
            h = mb.gemm(f"{name}.ff1", x, tokens, d, ff)
            h = mb.eltwise(f"{name}.relu", h, tokens * ff * e, stash=False)
            x = mb.gemm(f"{name}.ff2", h, tokens, ff, d)
            x = mb.eltwise(f"{name}.ln2", x, tokens * d * e)
    mb.gemm("logits", x, tokens, d, vocab)
    return mb


def ncf(batch: int, n_users: int = 138_493, n_items: int = 26_744,
        dim: int = 128) -> ModelBuilder:
    """Neural collaborative filtering on ml-20m (MLPerf v0.6 scale)."""
    mb = ModelBuilder(f"ncf.b{batch}")
    e = mb.dtype_bytes()
    mb.gather("user.embed", n_users * dim * e, batch * dim * e)
    mb.gather("item.embed", n_items * dim * e, batch * dim * e)
    mb.gather("user.embed.mf", n_users * (dim // 2) * e, batch * (dim // 2) * e)
    mb.gather("item.embed.mf", n_items * (dim // 2) * e, batch * (dim // 2) * e)
    x = mb.gemm("mlp1", None, batch, 2 * dim, 256, x_bytes=batch * 2 * dim * e)
    x = mb.gemm("mlp2", x, batch, 256, 128)
    x = mb.gemm("mlp3", x, batch, 128, 64)
    mb.gemm("out", x, batch, 64 + dim // 2, 1, x_bytes=batch * (64 + dim // 2) * e)
    return mb


# --------------------------------------------------------------------------------
# Suite assembly (Table III)
# --------------------------------------------------------------------------------

TRAIN_BATCHES = {  # name -> (small, large)
    "resnet": (12, 128),
    "ssd": (4, 128),
    "maskrcnn": (1, 6),
    "minigo": (128, 2048),
    "gnmt": (32, 256),
    "transformer": (640, 5120),
    "ncf": (65536, 1048576),
}

INFER_BATCHES = {
    "resnet": (1, 232),
    "mobilenet": (1, 704),
    "ssd-small": (1, 288),
    "ssd-large": (1, 6),
    "gnmt": (1, 128),
}


def _build_train(name: str, batch: int) -> ModelBuilder:
    if name == "resnet":
        return resnet50(batch)
    if name == "ssd":
        return resnet34_ssd(batch, res=300)
    if name == "maskrcnn":
        return maskrcnn(batch)
    if name == "minigo":
        return minigo(batch)
    if name == "gnmt":
        return gnmt(batch)
    if name == "transformer":
        return transformer_big(batch)
    if name == "ncf":
        return ncf(batch)
    raise KeyError(name)


def _build_infer(name: str, batch: int) -> ModelBuilder:
    if name == "resnet":
        return resnet50(batch, fuse_residual=True)
    if name == "mobilenet":
        return mobilenet_v1(batch, fuse_dw=True)
    if name == "ssd-small":
        mb = mobilenet_v1(batch, res=300, fuse_dw=True)
        mb.name = f"ssd-small.b{batch}"
        return mb
    if name == "ssd-large":
        return resnet34_ssd(batch, res=1200, fuse_residual=True)
    if name == "gnmt":
        return gnmt(batch, decode_only=True)
    raise KeyError(name)


_OPTIM = {"resnet": "sgdm", "ssd": "sgdm", "maskrcnn": "sgdm",
          "minigo": "sgdm", "gnmt": "adam", "transformer": "adam", "ncf": "adam"}


@lru_cache(maxsize=64)
def training_trace(name: str, batch_setting: str = "large",
                   batch_override: int | None = None) -> Trace:
    small, large = TRAIN_BATCHES[name]
    batch = batch_override or (large if batch_setting == "large" else small)
    mb = _build_train(name, batch)
    t = mb.trace(training=True, batch_size=batch, optimizer=_OPTIM[name])
    # Batch-override traces get a distinct name: grids key rows by trace
    # name, and a scale-out sweep holds several batches of one benchmark.
    t.name = f"{name}.train.{batch_setting}" if batch_override is None \
        else f"{name}.train.b{batch}"
    return t


@lru_cache(maxsize=64)
def inference_trace(name: str, batch_setting: str = "large",
                    batch_override: int | None = None) -> Trace:
    small, large = INFER_BATCHES[name]
    batch = batch_override or (large if batch_setting == "large" else small)
    mb = _build_infer(name, batch)
    t = mb.trace(training=False, batch_size=batch)
    t.name = f"{name}.infer.{batch_setting}" if batch_override is None \
        else f"{name}.infer.b{batch}"
    return t


def training_suite(batch_setting: str) -> list[Trace]:
    return [training_trace(n, batch_setting) for n in TRAIN_BATCHES]


def inference_suite(batch_setting: str) -> list[Trace]:
    return [inference_trace(n, batch_setting) for n in INFER_BATCHES]
