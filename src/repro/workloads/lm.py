"""Traces for the assigned LM architectures, fed to the paper's COPA
analysis — this is the integration point: the same cache/perf model that
reproduces the paper's MLPerf study runs over our 10 architectures x 4
shapes, and its traffic sweeps drive the software-MSM policy choices.

Per-GPU scope: the trace models ONE device's shard of the workload
(global_batch / 256 chips, TP shard of weights), matching the paper's
per-GPU methodology (§IV-A: all-reduce omitted).
"""
from __future__ import annotations

from functools import lru_cache

from repro.configs import SHAPES, get
from repro.configs.base import ModelConfig
from repro.core.trace import Trace, gemm_parallelism
from repro.workloads.common import ModelBuilder

CHIPS = 256
TP = 16  # model-axis shard of weights


def _attn_layer(mb: ModelBuilder, cfg: ModelConfig, name: str, tokens: int,
                seq: int, decode: bool):
    e = mb.dtype_bytes()
    d = cfg.d_model
    h = max(cfg.n_heads // TP, 1) * TP  # pad tiny models to one head/shard
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.use_mla:
        q = mb.gemm(f"{name}.q_a", None, tokens, d, cfg.q_lora_rank,
                    x_bytes=tokens * d * e)
        q = mb.gemm(f"{name}.q_b", q, tokens, cfg.q_lora_rank,
                    (h // TP) * (hd + cfg.rope_head_dim))
        kv = mb.gemm(f"{name}.kv_a", None, tokens, d,
                     cfg.kv_lora_rank + cfg.rope_head_dim,
                     x_bytes=tokens * d * e)
        if decode:
            # absorbed decode: score against latent cache
            cache_bytes = seq * (cfg.kv_lora_rank + cfg.rope_head_dim) * e \
                * mb._batch
            mb.emit(f"{name}.sdpa", 2.0 * mb._batch * (h // TP) * seq
                    * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2,
                    reads=[(f"{name}.kvcache", cache_bytes),
                           (q, tokens * (h // TP) * (hd + cfg.rope_head_dim) * e)],
                    writes=[(f"{name}.attnout", tokens * (h // TP) * hd * e)],
                    parallelism=float(mb._batch * (h // TP) * 128))
        else:
            kvx = mb.gemm(f"{name}.kv_b", kv, tokens, cfg.kv_lora_rank,
                          (h // TP) * (hd + cfg.v_head_dim))
            mb.attention(f"{name}.sdpa_core", q, mb._batch, seq, seq,
                         h // TP, hd, kv_heads=h // TP, chunked=True)
        mb.gemm(f"{name}.o", None, tokens, (h // TP) * cfg.v_head_dim, d,
                x_bytes=tokens * (h // TP) * cfg.v_head_dim * e)
        return
    kvh_t = max(kvh // TP, 1)
    q = mb.gemm(f"{name}.q", None, tokens, d, (h // TP) * hd,
                x_bytes=tokens * d * e)
    mb.gemm(f"{name}.k", None, tokens, d, kvh_t * hd, x_bytes=tokens * d * e)
    mb.gemm(f"{name}.v", None, tokens, d, kvh_t * hd, x_bytes=tokens * d * e)
    if decode:
        cache = seq * kvh_t * hd * 2 * e * mb._batch
        mb.emit(f"{name}.sdpa", 2.0 * mb._batch * (h // TP) * seq * hd * 2,
                reads=[(f"{name}.kvcache", cache),
                       (q, tokens * (h // TP) * hd * e)],
                writes=[(f"{name}.attnout", tokens * (h // TP) * hd * e)],
                parallelism=float(mb._batch * (h // TP) * 128))
    else:
        mb.attention(f"{name}.sdpa_core", q, mb._batch, seq, seq, h // TP,
                     hd, kv_heads=kvh_t, chunked=True)
    mb.gemm(f"{name}.o", None, tokens, (h // TP) * hd, d,
            x_bytes=tokens * (h // TP) * hd * e)


def _ffn_layer(mb: ModelBuilder, cfg: ModelConfig, name: str, tokens: int,
               d_ff: int):
    e = mb.dtype_bytes()
    d = cfg.d_model
    f = max(d_ff // TP, 1)
    h1 = mb.gemm(f"{name}.gate", None, tokens, d, f, x_bytes=tokens * d * e)
    mb.gemm(f"{name}.up", None, tokens, d, f, x_bytes=tokens * d * e)
    mb.gemm(f"{name}.down", h1, tokens, f, d)


def _moe_layer(mb: ModelBuilder, cfg: ModelConfig, name: str, tokens: int):
    e = mb.dtype_bytes()
    d = cfg.d_model
    e_local = max(cfg.n_experts // TP, 1)
    # activated fraction of the local expert weights
    frac = min(1.0, tokens * cfg.top_k / max(cfg.n_experts, 1) / 8.0 + 0.1) \
        if tokens < cfg.n_experts * 8 else 1.0
    w_bytes = int(3 * d * cfg.moe_d_ff * e_local * e * frac)
    act_tokens = tokens * cfg.top_k // TP
    mb.gemm(f"{name}.router", None, tokens, d, cfg.n_experts,
            x_bytes=tokens * d * e)
    mb.emit(f"{name}.experts",
            2.0 * act_tokens * 3 * d * cfg.moe_d_ff,
            reads=[(f"{name}.expert_w", w_bytes),
                   (f"{name}.dispatch_in", act_tokens * d * e)],
            writes=[(f"{name}.dispatch_out", act_tokens * d * e)],
            parallelism=gemm_parallelism(act_tokens, cfg.moe_d_ff))
    if cfg.n_shared_experts:
        _ffn_layer(mb, cfg, f"{name}.shared", tokens,
                   cfg.moe_d_ff * cfg.n_shared_experts)


def _ssm_layer(mb: ModelBuilder, cfg: ModelConfig, name: str, tokens: int,
               decode: bool):
    e = mb.dtype_bytes()
    d, di = cfg.d_model, cfg.d_inner
    proj = (2 * di + 2 * cfg.ssm_state + cfg.ssm_heads) // 1
    x = mb.gemm(f"{name}.in", None, tokens, d, max(proj // TP, 1),
                x_bytes=tokens * d * e)
    state_bytes = mb._batch * cfg.ssm_heads * cfg.ssm_head_dim \
        * cfg.ssm_state * 4 // TP
    flops = 2.0 * tokens * (cfg.ssm_heads // TP + 1) * cfg.ssm_head_dim \
        * cfg.ssm_state * (2 if not decode else 2)
    mb.emit(f"{name}.ssd", flops,
            reads=[(x, tokens * max(di // TP, 1) * e),
                   (f"{name}.state", state_bytes)],
            writes=[(f"{name}.y", tokens * max(di // TP, 1) * e),
                    (f"{name}.state", state_bytes)],
            parallelism=float(tokens * max(cfg.ssm_heads // TP, 1)))
    mb.gemm(f"{name}.out", None, tokens, max(di // TP, 1), d,
            x_bytes=tokens * max(di // TP, 1) * e)


@lru_cache(maxsize=128)
def arch_trace(arch: str, shape_name: str) -> Trace:
    cfg = get(arch)
    shape = SHAPES[shape_name]
    decode = shape.step == "decode"
    batch = max(shape.global_batch // (CHIPS // TP), 1)
    seq = shape.seq_len
    tokens = batch * (1 if decode else seq)
    mb = ModelBuilder(f"{arch}.{shape_name}")
    mb._batch = batch
    e = mb.dtype_bytes()

    mb.gather("embed", cfg.vocab_size * cfg.d_model * e // TP,
              tokens * cfg.d_model * e)
    enc = cfg.n_encoder_layers if cfg.family == "audio" and not decode else 0
    for i in range(enc):
        _attn_layer(mb, cfg, f"enc{i}", tokens, seq, False)
        _ffn_layer(mb, cfg, f"enc{i}.ffn", tokens, cfg.d_ff)
    for i in range(cfg.n_layers):
        nm = f"l{i}"
        if cfg.family in ("dense", "vlm", "audio"):
            _attn_layer(mb, cfg, nm, tokens, seq, decode)
            _ffn_layer(mb, cfg, f"{nm}.ffn", tokens, cfg.d_ff)
        elif cfg.family == "moe":
            _attn_layer(mb, cfg, nm, tokens, seq, decode)
            if i < cfg.first_k_dense:
                _ffn_layer(mb, cfg, f"{nm}.ffn", tokens,
                           cfg.dense_d_ff or cfg.d_ff)
            else:
                _moe_layer(mb, cfg, f"{nm}.moe", tokens)
        elif cfg.family == "ssm":
            _ssm_layer(mb, cfg, nm, tokens, decode)
        elif cfg.family == "hybrid":
            _ssm_layer(mb, cfg, nm, tokens, decode)
            if cfg.attn_every and (i + 1) % cfg.attn_every == 0:
                _attn_layer(mb, cfg, f"{nm}.shared", tokens, seq, decode)
                _ffn_layer(mb, cfg, f"{nm}.sffn", tokens, cfg.d_ff)
    mb.gemm("logits", None, tokens, cfg.d_model,
            max(cfg.vocab_size // TP, 1),
            x_bytes=tokens * cfg.d_model * e)
    tr = mb.trace(training=(shape.step == "train"), batch_size=batch,
                  optimizer="adam")
    tr.name = f"{arch}.{shape_name}"
    return tr
