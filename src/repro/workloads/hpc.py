"""HPC proxy population for the paper's Fig 3 DRAM-bandwidth study.

The paper sweeps 130 HPC workloads (CORAL/CORAL-2, Amber18, FUN3D,
SPECFEM3D, GROMACS, Laghos, RELION) and finds them remarkably insensitive to
DRAM bandwidth: +5% geomean at infinite BW, -4% at 0.75x, -14% at 0.5x. The
asymmetry is the signature of a population whose kernels sit mostly *above*
the machine-balance point (FP32/FP64 arithmetic intensity >> 9 flop/byte on
GPU-N after L2 filtering): lowering BW drags borderline kernels below the
roofline ridge, while raising BW frees only the few already-bound ones.

We reproduce that population: 130 deterministic proxy apps, each a mix of
phase-kernels whose post-L2 arithmetic intensities are drawn (seeded) from a
lognormal centred above machine balance. Traces use streaming tensors so the
cache hierarchy is already accounted (HPC's L2 locality is folded into the
post-L2 AI, as the paper's own Fig 3 does by construction).
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

from repro.core.trace import Trace

APP_FAMILIES = [
    ("amber", 12), ("gromacs", 10), ("laghos", 8), ("relion", 8),
    ("specfem3d", 8), ("fun3d", 10), ("coral_qmcpack", 10), ("coral_lammps", 10),
    ("coral_nekbone", 8), ("coral_amg", 8), ("coral2_quicksilver", 8),
    ("coral2_pennant", 8), ("coral2_big", 10), ("misc_cfd", 12),
]  # totals 130

# Lognormal over post-L2 arithmetic intensity (flop/byte, FP32-class math).
# GPU-N machine balance is 24.2 TFLOPS / 2.687 TB/s ~= 9 flop/byte.
_AI_MU = float(np.log(19.0))
_AI_SIGMA = 0.90
_PHASES = 6


@lru_cache(maxsize=1)
def hpc_suite() -> list[Trace]:
    rng = np.random.default_rng(20210401)  # paper's arXiv month
    traces: list[Trace] = []
    idx = 0
    for family, count in APP_FAMILIES:
        for k in range(count):
            tr = Trace(f"hpc.{family}.{k}", kind="hpc")
            n_phases = int(rng.integers(3, _PHASES + 1))
            weights = rng.dirichlet(np.ones(n_phases))
            total_flops = float(rng.uniform(0.5e12, 5e12))
            for p in range(n_phases):
                ai = float(rng.lognormal(_AI_MU, _AI_SIGMA))
                flops = total_flops * float(weights[p])
                nbytes = flops / ai
                # ~12% of phases are latency/occupancy-limited (sparse,
                # irregular), matching the long tail in the paper's Fig 3.
                par = float("inf")
                if rng.random() < 0.12:
                    par = float(rng.uniform(3e4, 2e5))
                tr.emit(
                    f"phase{p}",
                    flops=flops,
                    reads=[(f"in.{family}.{idx}.{p}.r", int(nbytes * 0.7))],
                    writes=[(f"in.{family}.{idx}.{p}.w", int(nbytes * 0.3))],
                    precision="fp32",
                    parallelism=par,
                )
            traces.append(tr)
            idx += 1
    assert len(traces) == 130
    return traces
