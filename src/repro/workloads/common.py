"""Layer-graph builder that emits one-iteration tensor traces.

A workload is described once as a forward graph of primitive layers; the
builder derives the backward pass (dgrad + wgrad per layer, reverse order)
and the optimizer step, emitting :class:`repro.core.trace.Op` records with
correct FLOP counts, tensor sizes and kernel parallelism. This mirrors the
paper's methodology of tracing one *end-to-end* iteration (fwd+bwd+update)
rather than isolated kernels, which is what exposes inter-kernel reuse.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.trace import BYTES, Trace, gemm_parallelism


@dataclass
class LayerRec:
    kind: str                 # gemm | conv | dwconv | eltwise | reduce | gather
    name: str
    flops: float
    x: str | None             # input activation tensor (None = graph input)
    w: str | None             # weight tensor (None = no params)
    y: str                    # output activation tensor
    x_bytes: int
    w_bytes: int
    y_bytes: int
    extra_reads: tuple[tuple[str, int], ...] = ()
    extra_writes: tuple[tuple[str, int], ...] = ()
    parallelism: float = float("inf")
    bwd_flop_scale: float = 2.0   # dgrad+wgrad ≈ 2x fwd for gemm/conv
    trainable: bool = True
    stash_for_bwd: bool = True    # activation needed again in backward


class ModelBuilder:
    """Collects layers; ``trace()`` emits fwd [+ bwd + optimizer]."""

    def __init__(self, name: str, precision: str = "fp16"):
        self.name = name
        self.precision = precision
        self.layers: list[LayerRec] = []
        self._uid = 0

    # ---- naming ----------------------------------------------------------------
    def fresh(self, stem: str) -> str:
        self._uid += 1
        return f"{stem}.{self._uid}"

    def dtype_bytes(self) -> int:
        return BYTES[self.precision]

    # ---- primitive layers --------------------------------------------------------
    def gemm(self, name: str, x: str | None, m: int, k: int, n: int,
             x_bytes: int | None = None, weight: bool = True,
             shared_w: str | None = None) -> str:
        e = self.dtype_bytes()
        y = self.fresh(f"{name}.out")
        w_name = shared_w if shared_w else (self.fresh(f"{name}.w") if weight else None)
        self.layers.append(LayerRec(
            kind="gemm", name=name, flops=2.0 * m * k * n,
            x=x, w=w_name if weight else None, y=y,
            x_bytes=x_bytes if x_bytes is not None else m * k * e,
            w_bytes=k * n * e if weight else 0,
            y_bytes=m * n * e,
            parallelism=gemm_parallelism(m, n),
        ))
        return y

    def conv(self, name: str, x: str | None, n: int, h: int, w: int, cin: int,
             cout: int, kh: int, kw: int, stride: int = 1) -> tuple[str, int, int]:
        """Returns (out_tensor, out_h, out_w)."""
        e = self.dtype_bytes()
        oh, ow = math.ceil(h / stride), math.ceil(w / stride)
        y = self.fresh(f"{name}.out")
        self.layers.append(LayerRec(
            kind="conv", name=name,
            flops=2.0 * n * oh * ow * cout * cin * kh * kw,
            x=x, w=self.fresh(f"{name}.w"), y=y,
            x_bytes=n * h * w * cin * e,
            w_bytes=cout * cin * kh * kw * e,
            y_bytes=n * oh * ow * cout * e,
            parallelism=gemm_parallelism(n * oh * ow, cout),
        ))
        return y, oh, ow

    def dwconv(self, name: str, x: str | None, n: int, h: int, w: int, c: int,
               kh: int, kw: int, stride: int = 1) -> tuple[str, int, int]:
        e = self.dtype_bytes()
        oh, ow = math.ceil(h / stride), math.ceil(w / stride)
        y = self.fresh(f"{name}.out")
        self.layers.append(LayerRec(
            kind="dwconv", name=name,
            flops=2.0 * n * oh * ow * c * kh * kw,
            x=x, w=self.fresh(f"{name}.w"), y=y,
            x_bytes=n * h * w * c * e,
            w_bytes=c * kh * kw * e,
            y_bytes=n * oh * ow * c * e,
            parallelism=float(n * oh * ow * c),
        ))
        return y, oh, ow

    def eltwise(self, name: str, x: str | None, nbytes: int,
                flops_per_byte: float = 0.5, extra_reads: tuple = (),
                trainable: bool = False, stash: bool = True,
                w_bytes: int = 0) -> str:
        """BN/ReLU/residual-add/softmax-ish kernels: BW-bound by design."""
        y = self.fresh(f"{name}.out")
        self.layers.append(LayerRec(
            kind="eltwise", name=name, flops=nbytes * flops_per_byte,
            x=x, w=self.fresh(f"{name}.w") if trainable else None, y=y,
            x_bytes=nbytes, w_bytes=w_bytes, y_bytes=nbytes,
            extra_reads=tuple(extra_reads),
            parallelism=float(nbytes // self.dtype_bytes()),
            bwd_flop_scale=1.0, trainable=trainable, stash_for_bwd=stash,
        ))
        return y

    def emit(self, name: str, flops: float, reads=(), writes=(),
             parallelism: float = float("inf")) -> str:
        """Raw op passthrough (custom fused kernels, cache reads, SSD scans).
        First write is the nominal output; backward (when training) reads
        d.out + the forward reads and writes d.<first-read>."""
        writes = tuple(writes)
        reads = tuple(reads)
        y, y_bytes = writes[0]
        self.layers.append(LayerRec(
            kind="raw", name=name, flops=flops, x=None, w=None, y=y,
            x_bytes=0, w_bytes=0, y_bytes=y_bytes,
            extra_reads=reads, extra_writes=writes[1:],
            parallelism=parallelism, bwd_flop_scale=1.5, trainable=False,
        ))
        return y

    def gather(self, name: str, table_bytes: int, gathered_bytes: int,
               trainable: bool = True) -> str:
        """Embedding lookup: reads a *fraction* of a big table."""
        y = self.fresh(f"{name}.out")
        self.layers.append(LayerRec(
            kind="gather", name=name, flops=gathered_bytes * 0.1,
            x=None, w=self.fresh(f"{name}.table"), y=y,
            x_bytes=0, w_bytes=min(table_bytes, gathered_bytes), y_bytes=gathered_bytes,
            parallelism=float(gathered_bytes // self.dtype_bytes()),
            bwd_flop_scale=1.0, trainable=trainable,
        ))
        # The full table participates in the optimizer step.
        self.layers[-1].extra_reads = (("__tablesize__", table_bytes),)
        return y

    def attention(self, name: str, x: str, b: int, s_q: int, s_kv: int,
                  heads: int, dim: int, kv_heads: int | None = None,
                  chunked: bool = True, causal: bool = True) -> str:
        """QKV proj + SDPA + out proj. ``chunked`` = flash-style (the score
        matrix never leaves on-chip memory: no S tensor in the trace)."""
        e = self.dtype_bytes()
        kvh = kv_heads or heads
        d_model = heads * dim
        q = self.gemm(f"{name}.q", x, b * s_q, d_model, heads * dim)
        k = self.gemm(f"{name}.k", x, b * s_q if s_q == s_kv else b * s_kv,
                      d_model, kvh * dim, x_bytes=b * s_kv * d_model * e)
        v = self.gemm(f"{name}.v", x, b * s_kv, d_model, kvh * dim,
                      x_bytes=b * s_kv * d_model * e)
        sdpa_flops = 2.0 * 2.0 * b * heads * s_q * s_kv * dim
        if causal and s_q == s_kv:
            sdpa_flops *= 0.5
        y = self.fresh(f"{name}.sdpa.out")
        reads = [(q, b * s_q * heads * dim * e),
                 (k, b * s_kv * kvh * dim * e),
                 (v, b * s_kv * kvh * dim * e)]
        writes_bytes = b * s_q * heads * dim * e
        if not chunked:
            # naive attention materializes the score matrix twice (S, P)
            s_bytes = b * heads * s_q * s_kv * e
            smat = self.fresh(f"{name}.scores")
            self.layers.append(LayerRec(
                kind="eltwise", name=f"{name}.scores", flops=sdpa_flops / 2,
                x=None, w=None, y=smat, x_bytes=0, w_bytes=0, y_bytes=s_bytes,
                extra_reads=tuple(reads[:2]),
                parallelism=gemm_parallelism(b * heads * s_q, s_kv),
                bwd_flop_scale=2.0, trainable=False,
            ))
            self.layers.append(LayerRec(
                kind="eltwise", name=f"{name}.pv", flops=sdpa_flops / 2,
                x=smat, w=None, y=y, x_bytes=s_bytes, w_bytes=0,
                y_bytes=writes_bytes, extra_reads=(reads[2],),
                parallelism=gemm_parallelism(b * heads * s_q, dim),
                bwd_flop_scale=2.0, trainable=False,
            ))
        else:
            self.layers.append(LayerRec(
                kind="gemm", name=f"{name}.sdpa", flops=sdpa_flops,
                x=q, w=None, y=y,
                x_bytes=b * s_q * heads * dim * e, w_bytes=0,
                y_bytes=writes_bytes, extra_reads=tuple(reads[1:]),
                parallelism=gemm_parallelism(b * heads * s_q, dim),
                bwd_flop_scale=2.5,  # flash bwd recomputes scores
            ))
        return self.gemm(f"{name}.o", y, b * s_q, heads * dim, d_model)

    # ---- trace emission ------------------------------------------------------------
    def param_tensors(self) -> list[tuple[str, int]]:
        out: dict[str, int] = {}
        for l in self.layers:
            if l.w is not None and l.trainable:
                full = l.w_bytes
                for t, b in l.extra_reads:
                    if t == "__tablesize__":
                        full = b
                out[l.w] = max(out.get(l.w, 0), full)
        return list(out.items())

    def n_params(self) -> float:
        return sum(b for _, b in self.param_tensors()) / self.dtype_bytes()

    def trace(self, training: bool, batch_size: int = 0,
              optimizer: str = "adam") -> Trace:
        tr = Trace(self.name, batch_size=batch_size,
                   kind="training" if training else "inference")
        e = self.dtype_bytes()
        # ---- forward ----
        for l in self.layers:
            reads = []
            if l.x is not None and l.x_bytes:
                reads.append((l.x, l.x_bytes))
            if l.w is not None and l.w_bytes:
                reads.append((l.w, l.w_bytes))
            reads += [(t, b) for t, b in l.extra_reads if t != "__tablesize__"]
            tr.emit(f"fwd.{l.name}", l.flops, reads=reads,
                    writes=[(l.y, l.y_bytes)] + list(l.extra_writes),
                    precision=self.precision, parallelism=l.parallelism)
        if not training:
            return tr
        # ---- backward (reverse order): dgrad reads dy+w, wgrad reads dy+x ----
        for l in reversed(self.layers):
            dy = f"d.{l.y}"
            if l.kind == "raw":
                if l.extra_reads:
                    src = l.extra_reads[0][0]
                    tr.emit(f"bwd.{l.name}", l.flops * l.bwd_flop_scale,
                            reads=[(dy, l.y_bytes)] + list(l.extra_reads),
                            writes=[(f"d.{src}", l.extra_reads[0][1])],
                            precision=self.precision,
                            parallelism=l.parallelism)
                continue
            dgrad_reads = [(dy, l.y_bytes)]
            if l.w is not None and l.w_bytes:
                dgrad_reads.append((l.w, l.w_bytes))
            if l.stash_for_bwd and l.kind in ("eltwise", "gather") and l.x:
                dgrad_reads.append((l.x, l.x_bytes))
            if l.x is not None and l.x_bytes:
                tr.emit(f"bwd.dgrad.{l.name}", l.flops * (l.bwd_flop_scale / 2.0),
                        reads=dgrad_reads, writes=[(f"d.{l.x}", l.x_bytes)],
                        precision=self.precision, parallelism=l.parallelism)
            if l.w is not None and l.trainable:
                wgrad_reads = [(dy, l.y_bytes)]
                if l.x is not None and l.x_bytes and l.stash_for_bwd:
                    wgrad_reads.append((l.x, l.x_bytes))
                gsize = l.w_bytes
                for t, b in l.extra_reads:
                    if t == "__tablesize__":
                        gsize = min(gsize, b)
                tr.emit(f"bwd.wgrad.{l.name}", l.flops * (l.bwd_flop_scale / 2.0),
                        reads=wgrad_reads, writes=[(f"g.{l.w}", gsize)],
                        precision=self.precision, parallelism=l.parallelism)
        # ---- optimizer: fp32 master + moments (mixed-precision recipe) ----
        n_states = {"adam": 2, "sgdm": 1, "sgd": 0}[optimizer]
        for w, nbytes in self.param_tensors():
            n_el = nbytes // e
            master = n_el * 4
            reads = [(f"g.{w}", nbytes), (f"m32.{w}", master)]
            writes = [(w, nbytes), (f"m32.{w}", master)]
            for i in range(n_states):
                reads.append((f"opt{i}.{w}", master))
                writes.append((f"opt{i}.{w}", master))
            tr.emit(f"opt.{w}", flops=n_el * (4 + 4 * n_states), reads=reads,
                    writes=writes, precision="fp32", parallelism=float(n_el))
        return tr
