"""``kernel.*`` scenarios: measured-structure Pallas kernel touch streams.

Each scenario replays the block placements the static analyzer
(``repro.check``) extracts from a real kernel's ``pallas_call`` — one touch
per block fetch, in grid-iteration order — so the sweep engine prices the
*actual* DMA pattern of the shipped kernels rather than a hand-written
per-tensor stream (ROADMAP direction 5's kernel->registry bridge).

Names mirror the analyzer catalog: ``kernel.<kernel>.<case>``, e.g.
``kernel.flash_attention.b2s512``. Building a trace imports jax (the
kernel is abstract-evaluated, never run); enumerating names does not.
"""
from __future__ import annotations

from repro.check import catalog
from repro.core.trace import Trace


def case_names() -> list[str]:
    """Catalog case names (without the ``kernel.`` prefix)."""
    return catalog.case_names()


def kernel_trace(case: str) -> Trace:
    """Abstract-trace one catalog case and compile it to a touch stream."""
    from repro.check import streams  # lazy: pulls in jax via facts

    facts = catalog.trace_case(case)
    return streams.compile_trace(list(facts), name=f"kernel.{case}")
