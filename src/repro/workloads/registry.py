"""Scenario registry: every known workload trace behind one namespace.

Maps scenario names -> trace factories across the three workload families so
the sweep engine (``repro.core.sweep.SweepEngine``) can enumerate the whole
evaluation space by name:

* ``mlperf.train.<bench>.<setting>`` / ``mlperf.infer.<bench>.<setting>`` —
  the paper's Table-III MLPerf proxies at ``large``/``small`` batch;
* ``serve.mlperf.<bench>.b<batch>`` — batched-decode serving grid points:
  the inference benchmarks at explicit batch sizes, so latency/throughput
  grids sweep batch x MSM policy (Table-V config), not just per hardware
  config;
* ``lm.<arch>.<shape>`` — the assigned LM architectures x shapes
  (``repro.configs``), e.g. ``lm.deepseek_v2_236b.decode_32k``;
* ``hpc.<family>.<k>`` — the 130-app Fig-3 HPC proxy population;
* ``kernel.<kernel>.<case>`` — measured-structure touch streams extracted
  statically from the real Pallas kernels by ``repro.check`` (one touch per
  block fetch, grid-iteration ordered).

Scale-out *families* (``repro.core.sweep.ScaleOutWorkload``) live behind the
same namespace with a ``scaleout.`` prefix: each maps an instance count to
the per-GPU trace one instance replays.

* ``scaleout.mlperf.train.<bench>`` — fixed-global-batch data-parallel
  training (paper Fig 12): per-GPU batch = global / n;
* ``scaleout.serve.<bench>`` — a fixed offered request batch split across
  serving instances (strong-scaling latency grids).

Arrival processes for the request-level serving simulator
(``repro.serve.sim``) live under ``arrivals.*`` — named open-loop request
streams (steady Poisson, burst-modulated) that :func:`resolve` returns as
``ArrivalSpec`` objects.

``SweepEngine`` resolves any scenario OR scale-out name through
:func:`resolve`; glob patterns (``serve.mlperf.*``, ``arrivals.poisson.*``)
resolve to every matching name. Suites group scenarios the way the paper's
figures do (``mlperf.train.large``, ``serve.mlperf``, ``hpc``, ...).
Factories are lazy, and built traces are memoized registry-side by scenario
name (:func:`scenario`), so enumerating names costs nothing and repeated
sweeps never re-run a factory. :func:`suite_analysis` resolves a suite (or
scenario glob) straight to the shared suite-level
:class:`~repro.core.sweep.SuiteAnalysis` — one batched pass over all its
traces.
"""
from __future__ import annotations

from fnmatch import fnmatchcase
from functools import lru_cache
from typing import Callable

from repro.core.sweep import ScaleOutWorkload
from repro.core.trace import Trace
from repro.workloads import hpc as hpc_mod
from repro.workloads import kernels as kernels_mod
from repro.workloads import lm as lm_mod
from repro.workloads import mlperf as mlperf_mod

_FACTORIES: dict[str, Callable[[], Trace]] = {}
_SCALEOUT: dict[str, ScaleOutWorkload] = {}
_ARRIVALS: dict[str, Callable[[], object]] = {}  # -> repro.serve.sim.ArrivalSpec
_SUITES: dict[str, list[str]] = {}
_GLOB_CHARS = "*?["


def register(name: str, factory: Callable[[], Trace],
             suites: tuple[str, ...] = ()) -> None:
    """Register one scenario; ``suites`` are group names it belongs to."""
    if name in _FACTORIES:
        raise ValueError(f"scenario {name!r} already registered")
    _FACTORIES[name] = factory
    for s in suites:
        _SUITES.setdefault(s, []).append(name)


def register_scaleout(name: str, workload: ScaleOutWorkload,
                      suites: tuple[str, ...] = ()) -> None:
    """Register one scale-out family under the ``scaleout.`` namespace."""
    if name in _SCALEOUT:
        raise ValueError(f"scale-out workload {name!r} already registered")
    _SCALEOUT[name] = workload
    for s in suites:
        _SUITES.setdefault(s, []).append(name)


@lru_cache(maxsize=None)
def _build_scenario(name: str) -> Trace:
    """Registry-level trace memo, keyed on scenario name: repeated
    ``resolve()``/``suite_traces()``/sweep calls must not re-enter the
    factory (several factories are themselves lru-cached, but with bounded
    sizes that a full-registry sweep can evict). Unbounded is safe — the
    key space is the fixed registry. ``register()`` only adds new names,
    so entries never go stale."""
    return _FACTORIES[name]()


def scenario(name: str) -> Trace:
    """Build (or fetch the memoized) trace for one scenario name."""
    if name not in _FACTORIES:
        raise KeyError(
            f"unknown scenario {name!r}; see repro.workloads.registry.scenarios()"
        )
    return _build_scenario(name)


def scaleout(name: str) -> ScaleOutWorkload:
    """The scale-out family for one ``scaleout.*`` name."""
    try:
        return _SCALEOUT[name]
    except KeyError:
        raise KeyError(
            f"unknown scale-out workload {name!r}; see "
            f"repro.workloads.registry.scaleout_names()"
        ) from None


def register_arrivals(name: str, factory: Callable[[], object],
                      suites: tuple[str, ...] = ()) -> None:
    """Register one named arrival process (``arrivals.*`` namespace) for the
    request-level serving simulator; factories return
    :class:`repro.serve.sim.ArrivalSpec` objects lazily."""
    if name in _ARRIVALS:
        raise ValueError(f"arrival process {name!r} already registered")
    _ARRIVALS[name] = factory
    for s in suites:
        _SUITES.setdefault(s, []).append(name)


def arrivals(name: str):
    """The :class:`~repro.serve.sim.ArrivalSpec` for one ``arrivals.*`` name."""
    try:
        return _ARRIVALS[name]()
    except KeyError:
        raise KeyError(
            f"unknown arrival process {name!r}; see "
            f"repro.workloads.registry.arrival_names()"
        ) from None


def resolve(name: str):
    """Resolve a name to its registered object — a scenario ``Trace``, a
    ``ScaleOutWorkload`` family, or an ``ArrivalSpec``.

    Glob patterns (fnmatch: ``*?[``) resolve to the LIST of every matching
    name across all three namespaces, in registration order — e.g.
    ``resolve("serve.mlperf.resnet.*")`` or ``resolve("arrivals.poisson.*")``
    — raising ``KeyError`` when nothing matches."""
    if any(ch in name for ch in _GLOB_CHARS):
        hits = match(name)
        if not hits:
            raise KeyError(f"no registered name matches pattern {name!r}")
        return [resolve(n) for n in hits]
    if name in _SCALEOUT:
        return _SCALEOUT[name]
    if name in _ARRIVALS:
        return _ARRIVALS[name]()
    return scenario(name)


def names() -> list[str]:
    """Every registered name across all namespaces, registration order."""
    return [*_FACTORIES, *_SCALEOUT, *_ARRIVALS]


def match(pattern: str) -> list[str]:
    """Registered names matching an fnmatch pattern (registration order)."""
    return [n for n in names() if fnmatchcase(n, pattern)]


def scenarios(prefix: str = "") -> list[str]:
    return [n for n in _FACTORIES if n.startswith(prefix)]


def scaleout_names(prefix: str = "") -> list[str]:
    return [n for n in _SCALEOUT if n.startswith(prefix)]


def arrival_names(prefix: str = "") -> list[str]:
    return [n for n in _ARRIVALS if n.startswith(prefix)]


def suites() -> list[str]:
    return list(_SUITES)


def suite(name: str) -> list[str]:
    """Scenario names in a suite (KeyError on unknown suite)."""
    return list(_SUITES[name])


def suite_traces(name: str) -> list[Trace]:
    """Traces of a suite's members. Suites may also group scale-out
    families and arrival processes — those have no single trace, so asking
    for their traces is an error, not a silent skip."""
    out = []
    for n in suite(name):
        obj = resolve(n)
        if not isinstance(obj, Trace):
            raise TypeError(
                f"suite {name!r} member {n!r} is a {type(obj).__name__}, "
                f"not a scenario trace; resolve() it directly")
        out.append(obj)
    return out


def suite_analysis(name: str):
    """One-call suite-level analysis: resolve a suite name (or a glob over
    scenario names) and return the shared
    :class:`~repro.core.sweep.SuiteAnalysis` over its traces — every
    member's touch stream built in one batched Mattson pass, traffic and
    time evaluated suite-wide per capacity/config set."""
    from repro.core.sweep import suite_analysis_for  # lazy: avoid cycle

    if name in _SUITES:
        traces = suite_traces(name)
    else:
        hits = [n for n in match(name) if n in _FACTORIES] \
            if any(ch in name for ch in _GLOB_CHARS) else []
        if not hits:
            raise KeyError(
                f"{name!r} is neither a suite nor a glob matching scenarios; "
                f"see suites() and scenarios()")
        traces = [scenario(n) for n in hits]
    return suite_analysis_for(traces)


# --- built-in population ------------------------------------------------------

def _register_mlperf() -> None:
    for setting in ("large", "small"):
        for bench in mlperf_mod.TRAIN_BATCHES:
            register(
                f"mlperf.train.{bench}.{setting}",
                lambda b=bench, s=setting: mlperf_mod.training_trace(b, s),
                suites=(f"mlperf.train.{setting}", "mlperf"),
            )
        for bench in mlperf_mod.INFER_BATCHES:
            register(
                f"mlperf.infer.{bench}.{setting}",
                lambda b=bench, s=setting: mlperf_mod.inference_trace(b, s),
                suites=(f"mlperf.infer.{setting}", "mlperf"),
            )


# Batched-decode serving grid: requests served per instance at once. Grid
# points above a benchmark's Table-III large batch (its calibrated maximum —
# e.g. ssd-large tops out at 6) are NOT registered: those cells would
# extrapolate outside the paper's measured range.
SERVE_BATCHES = (1, 4, 16, 64)


def _register_serve() -> None:
    for bench, (_, large) in mlperf_mod.INFER_BATCHES.items():
        for b in SERVE_BATCHES:
            if b > large:
                continue
            register(
                f"serve.mlperf.{bench}.b{b}",
                lambda bench=bench, b=b: mlperf_mod.inference_trace(
                    bench, "large", batch_override=b),
                suites=(f"serve.mlperf.{bench}", f"serve.b{b}", "serve.mlperf"),
            )


def _register_lm() -> None:
    from repro.configs import ARCHS, SHAPES

    for arch in ARCHS:
        for shape in SHAPES:
            register(
                f"lm.{arch}.{shape}",
                lambda a=arch, s=shape: lm_mod.arch_trace(a, s),
                suites=(f"lm.{shape}", "lm"),
            )


def _register_hpc() -> None:
    # One scenario per proxy app; the suite builds all 130 in one cached call.
    idx = 0
    for family, count in hpc_mod.APP_FAMILIES:
        for k in range(count):
            register(
                f"hpc.{family}.{k}",
                lambda i=idx: hpc_mod.hpc_suite()[i],
                suites=("hpc",),
            )
            idx += 1


def _register_kernels() -> None:
    # Measured-structure Pallas kernel streams from the static analyzer
    # (repro.check): one scenario per catalog (kernel, shape) case. The
    # factory abstract-traces the kernel on first build (jax import deferred
    # until then); names enumerate import-light like every other namespace.
    for case in kernels_mod.case_names():
        kernel = case.split(".", 1)[0]
        register(
            f"kernel.{case}",
            lambda c=case: kernels_mod.kernel_trace(c),
            suites=(f"kernel.{kernel}", "kernel"),
        )


def _register_scaleout() -> None:
    # Fig-12 fixed-global-batch data-parallel training: n instances split the
    # Table-III large batch, so the per-GPU trace shrinks (strong scaling).
    # trace_for(1) is the plain large-batch scenario object (same lru-cached
    # trace), so 1-GPU rows are bit-identical to the non-scale-out grid.
    for bench in mlperf_mod.TRAIN_BATCHES:
        lb = mlperf_mod.TRAIN_BATCHES[bench][1]
        register_scaleout(
            f"scaleout.mlperf.train.{bench}",
            ScaleOutWorkload(
                name=f"{bench}.train.large",
                trace_for=lambda n, bench=bench, lb=lb:
                    mlperf_mod.training_trace(bench, "large")
                    if n == 1 else mlperf_mod.training_trace(
                        bench, "large", batch_override=max(lb // n, 1)),
            ),
            suites=("scaleout.mlperf.train",),
        )
    # Serving scale-out: a fixed offered batch of requests split across
    # instances — the latency knob of the serve grid.
    for bench in mlperf_mod.INFER_BATCHES:
        lb = mlperf_mod.INFER_BATCHES[bench][1]
        register_scaleout(
            f"scaleout.serve.{bench}",
            ScaleOutWorkload(
                name=f"{bench}.infer.large",
                trace_for=lambda n, bench=bench, lb=lb:
                    mlperf_mod.inference_trace(bench, "large")
                    if n == 1 else mlperf_mod.inference_trace(
                        bench, "large", batch_override=max(lb // n, 1)),
            ),
            suites=("scaleout.serve",),
        )


# Open-loop arrival processes for the request-level serving simulator
# (repro.serve.sim): steady Poisson and 4x-burst-modulated Poisson at a
# log-spaced rate ladder, one-shot request semantics (prompt 0 / output 1 —
# the MLPerf serving scenarios). Factories import the sim module lazily so
# enumerating names stays import-light.
ARRIVAL_RATES = (4, 16, 64, 256, 1024)


def _register_arrivals() -> None:
    def poisson(rate: int):
        from repro.serve.sim import ArrivalSpec

        return ArrivalSpec(name=f"arrivals.poisson.r{rate}", rate=float(rate),
                           n_requests=512)

    def burst(rate: int):
        from repro.serve.sim import ArrivalSpec

        return ArrivalSpec(name=f"arrivals.burst.r{rate}.x4", rate=float(rate),
                           n_requests=512, burst_factor=4.0,
                           burst_fraction=0.25, period_s=64.0 / rate)

    for rate in ARRIVAL_RATES:
        register_arrivals(f"arrivals.poisson.r{rate}",
                          lambda rate=rate: poisson(rate),
                          suites=("arrivals.poisson", "arrivals"))
        register_arrivals(f"arrivals.burst.r{rate}.x4",
                          lambda rate=rate: burst(rate),
                          suites=("arrivals.burst", "arrivals"))


# Production-shaped diurnal load curves: hourly rate multipliers over one
# 24-"hour" day (time-compressed to DIURNAL_PERIOD_S so a 512-request trace
# spans a couple of days), normalized by ArrivalSpec so the long-run mean
# stays at the nominal rate. Shapes follow the usual published fleet
# telemetry: chat peaks evenings with a midday shoulder, api tracks
# business hours, batch inverts (overnight queue drain).
DIURNAL_PROFILES = {
    "chat": (0.2, 0.15, 0.1, 0.1, 0.1, 0.15, 0.3, 0.5, 0.8, 1.2, 1.5, 1.6,
             1.5, 1.4, 1.5, 1.6, 1.7, 1.8, 1.9, 1.8, 1.5, 1.1, 0.7, 0.4),
    "api": (0.3, 0.25, 0.2, 0.2, 0.2, 0.3, 0.5, 0.9, 1.4, 1.8, 1.9, 2.0,
            1.9, 1.9, 2.0, 1.9, 1.8, 1.5, 1.0, 0.7, 0.5, 0.4, 0.35, 0.3),
    "batch": (2.2, 2.4, 2.5, 2.3, 1.8, 1.2, 0.8, 0.6, 0.5, 0.5, 0.5, 0.6,
              0.6, 0.6, 0.6, 0.6, 0.7, 0.7, 0.8, 0.9, 1.1, 1.4, 1.8, 2.1),
}
DIURNAL_PERIOD_S = 4.0
_DIURNAL_LENGTHS = {         # (prompt dist, output dist) per workload shape
    "chat": (("lognormal", 512.0, 16), (32, 256)),
    "api": (("lognormal", 256.0, 8), (16, 128)),
    "batch": (("lognormal", 1024.0, 64), (64, 512)),
}


def _register_diurnal() -> None:
    def diurnal(kind: str):
        from repro.serve.sim import ArrivalSpec, LengthDist

        (pk, pmean, pfloor), (olo, ohi) = _DIURNAL_LENGTHS[kind]
        return ArrivalSpec(
            name=f"arrivals.diurnal.{kind}", rate=64.0, n_requests=512,
            prompt=LengthDist(pk, mean=pmean, floor=pfloor),
            output=LengthDist("uniform", low=olo, high=ohi),
            period_s=DIURNAL_PERIOD_S, profile=DIURNAL_PROFILES[kind])

    for kind in DIURNAL_PROFILES:
        register_arrivals(f"arrivals.diurnal.{kind}",
                          lambda kind=kind: diurnal(kind),
                          suites=("arrivals.diurnal", "arrivals"))


_register_mlperf()
_register_serve()
_register_lm()
_register_hpc()
_register_kernels()
_register_scaleout()
_register_arrivals()
_register_diurnal()
