"""Scenario registry: every known workload trace behind one namespace.

Maps scenario names -> trace factories across the three workload families so
the sweep engine (``repro.core.sweep.SweepEngine``) can enumerate the whole
evaluation space by name:

* ``mlperf.train.<bench>.<setting>`` / ``mlperf.infer.<bench>.<setting>`` —
  the paper's Table-III MLPerf proxies at ``large``/``small`` batch;
* ``lm.<arch>.<shape>`` — the assigned LM architectures x shapes
  (``repro.configs``), e.g. ``lm.deepseek_v2_236b.decode_32k``;
* ``hpc.<family>.<k>`` — the 130-app Fig-3 HPC proxy population.

Suites group scenarios the way the paper's figures do (``mlperf.train.large``,
``lm.decode_32k``, ``hpc``, ...). Factories are lazy and cached by the
underlying modules, so enumerating names costs nothing until a trace is
actually built.
"""
from __future__ import annotations

from typing import Callable

from repro.core.trace import Trace
from repro.workloads import hpc as hpc_mod
from repro.workloads import lm as lm_mod
from repro.workloads import mlperf as mlperf_mod

_FACTORIES: dict[str, Callable[[], Trace]] = {}
_SUITES: dict[str, list[str]] = {}


def register(name: str, factory: Callable[[], Trace],
             suites: tuple[str, ...] = ()) -> None:
    """Register one scenario; ``suites`` are group names it belongs to."""
    if name in _FACTORIES:
        raise ValueError(f"scenario {name!r} already registered")
    _FACTORIES[name] = factory
    for s in suites:
        _SUITES.setdefault(s, []).append(name)


def scenario(name: str) -> Trace:
    """Build (or fetch the cached) trace for one scenario name."""
    try:
        factory = _FACTORIES[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; see repro.workloads.registry.scenarios()"
        ) from None
    return factory()


def scenarios(prefix: str = "") -> list[str]:
    return [n for n in _FACTORIES if n.startswith(prefix)]


def suites() -> list[str]:
    return list(_SUITES)


def suite(name: str) -> list[str]:
    """Scenario names in a suite (KeyError on unknown suite)."""
    return list(_SUITES[name])


def suite_traces(name: str) -> list[Trace]:
    return [scenario(n) for n in suite(name)]


# --- built-in population ------------------------------------------------------

def _register_mlperf() -> None:
    for setting in ("large", "small"):
        for bench in mlperf_mod.TRAIN_BATCHES:
            register(
                f"mlperf.train.{bench}.{setting}",
                lambda b=bench, s=setting: mlperf_mod.training_trace(b, s),
                suites=(f"mlperf.train.{setting}", "mlperf"),
            )
        for bench in mlperf_mod.INFER_BATCHES:
            register(
                f"mlperf.infer.{bench}.{setting}",
                lambda b=bench, s=setting: mlperf_mod.inference_trace(b, s),
                suites=(f"mlperf.infer.{setting}", "mlperf"),
            )


def _register_lm() -> None:
    from repro.configs import ARCHS, SHAPES

    for arch in ARCHS:
        for shape in SHAPES:
            register(
                f"lm.{arch}.{shape}",
                lambda a=arch, s=shape: lm_mod.arch_trace(a, s),
                suites=(f"lm.{shape}", "lm"),
            )


def _register_hpc() -> None:
    # One scenario per proxy app; the suite builds all 130 in one cached call.
    idx = 0
    for family, count in hpc_mod.APP_FAMILIES:
        for k in range(count):
            register(
                f"hpc.{family}.{k}",
                lambda i=idx: hpc_mod.hpc_suite()[i],
                suites=("hpc",),
            )
            idx += 1


_register_mlperf()
_register_lm()
_register_hpc()
