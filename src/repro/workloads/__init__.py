"""Workload traces: MLPerf-proxy (paper Table III), HPC population (Fig 3),
and LM-architecture traces derived from ``repro.configs`` (our 10 assigned
architectures run through the same COPA analysis)."""
from repro.workloads import common, hpc, lm, mlperf

__all__ = ["common", "hpc", "lm", "mlperf"]
