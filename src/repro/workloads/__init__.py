"""Workload traces: MLPerf-proxy (paper Table III), HPC population (Fig 3),
and LM-architecture traces derived from ``repro.configs`` (our 10 assigned
architectures run through the same COPA analysis). ``registry`` maps
scenario names -> trace factories across all three families for the sweep
engine."""
from repro.workloads import common, hpc, lm, mlperf, registry

__all__ = ["common", "hpc", "lm", "mlperf", "registry"]
