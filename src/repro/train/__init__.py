from repro.train.optim import OptimConfig, init_state, apply_updates, lr_at, state_shardings
from repro.train.step import make_train_step, init_opt_state

__all__ = ["OptimConfig", "init_state", "apply_updates", "lr_at",
           "state_shardings", "make_train_step", "init_opt_state"]
