"""AdamW with dtype-configurable state — built from scratch (no optax).

Mixed-precision recipes (selected by the software-MSM policy):

* ``float32`` moments + fp32 master weights — the classic recipe
  (14 bytes/param with bf16 params).
* ``bfloat16`` moments (+ optional master) — the capacity-specialized recipe
  for >100B models on 16GB chips; uses stochastic rounding on the param
  update when no master is kept (6 bytes/param).

State tensors inherit the parameter logical axes, so FSDP shards optimizer
state exactly like weights.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # float32 | bfloat16
    master_weights: bool = True
    stochastic_rounding: bool = False  # SR on bf16 param updates (no master)
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptimConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params, cfg: OptimConfig):
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _stochastic_round_bf16(key, x32):
    """Unbiased fp32 -> bf16 rounding via uniform dither of the truncated bits."""
    bits = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    noise = jax.random.bits(key, bits.shape, jnp.uint32) & jnp.uint32(0xFFFF)
    return jax.lax.bitcast_convert_type(
        (bits + noise) & jnp.uint32(0xFFFF0000), jnp.float32).astype(jnp.bfloat16)


def apply_updates(params, grads, state, cfg: OptimConfig, rng=None):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    flat_params, treedef = jax.tree.flatten(params)
    flat_grads = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    flat_master = (jax.tree.leaves(state["master"])
                   if cfg.master_weights else [None] * len(flat_params))
    use_sr = cfg.stochastic_rounding and not cfg.master_weights and rng is not None
    keys = (jax.random.split(rng, len(flat_params))
            if use_sr else [None] * len(flat_params))

    new_p, new_mu, new_nu, new_master = [], [], [], []
    for p, g, mu, nu, mw, k in zip(flat_params, flat_grads, flat_mu, flat_nu,
                                   flat_master, keys):
        g32 = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + g32 * (1 - b1)
        nu32 = nu.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        upd = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
        base = mw if mw is not None else p.astype(jnp.float32)
        p32 = base - lr * (upd + cfg.weight_decay * base)
        if mw is not None:
            new_master.append(p32)
            new_p.append(p32.astype(p.dtype))
        elif k is not None and p.dtype == jnp.bfloat16:
            new_p.append(_stochastic_round_bf16(k, p32))
        else:
            new_p.append(p32.astype(p.dtype))
        new_mu.append(mu32.astype(mdt))
        new_nu.append(nu32.astype(mdt))

    new_state = {
        "step": step,
        "mu": jax.tree.unflatten(treedef, new_mu),
        "nu": jax.tree.unflatten(treedef, new_nu),
    }
    if cfg.master_weights:
        new_state["master"] = jax.tree.unflatten(treedef, new_master)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return jax.tree.unflatten(treedef, new_p), new_state, metrics


def state_shardings(param_shardings_tree, cfg: OptimConfig, mesh):
    """Optimizer state shards exactly like its parameters."""
    from jax.sharding import NamedSharding, PartitionSpec

    scalar = NamedSharding(mesh, PartitionSpec())
    out = {
        "step": scalar,
        "mu": param_shardings_tree,
        "nu": param_shardings_tree,
    }
    if cfg.master_weights:
        out["master"] = param_shardings_tree
    return out
