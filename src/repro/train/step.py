"""Training step: value_and_grad -> (optional) gradient compression -> AdamW.

``make_train_step`` builds the pjit-able step with donated params/opt-state
(in-place buffer reuse — the software analogue of keeping the working set
on-package). Gradient compression options:

* ``None``      — gradients in param dtype (bf16 wire format under SPMD).
* ``"bf16"``    — explicit cast before the optimizer (no-op when params
                  are bf16; kept for fp32-param runs).
* ``"int8_ef"`` — per-tensor int8 quantization with persistent error
                  feedback carried in the optimizer state. Halves gradient
                  wire bytes on the cross-pod reduce; the quantization error
                  is re-injected next step so convergence is preserved
                  (1-bit-Adam-style EF, arXiv:2102.02888).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.train import optim as optim_mod


def quantize_int8(x32):
    amax = jnp.max(jnp.abs(x32)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, method: str | None, ef_state):
    """Returns (effective_grads, new_ef_state)."""
    if method is None:
        return grads, ef_state
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), ef_state
    if method == "int8_ef":
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, s = quantize_int8(g32)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), (g32 - deq)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef_state)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_e = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return new_g, new_e
    raise ValueError(method)


def init_ef_state(params, method: str | None):
    if method != "int8_ef":
        return None
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_train_step(model, opt_cfg: optim_mod.OptimConfig,
                    grad_compression: str | None = None,
                    microbatches: int = 1,
                    grad_shardings=None,
                    batch_shardings=None):
    """Returns train_step(params, opt_state, batch, rng) -> (params,
    opt_state, metrics). opt_state carries the EF buffers when compressing.

    ``microbatches > 1`` runs gradient accumulation: the global batch is
    split along dim 0 and scanned; each microbatch's gradients are pinned to
    the parameter shardings (``grad_shardings``) so the accumulator stays
    fully sharded (reduce-scatter inside the loop) — without this XLA holds
    full-size fp32 gradient partials per device. This is both the
    memory-capacity fix and the compute/comm overlap point: the per-layer
    reduce-scatter of microbatch i overlaps the forward of microbatch i+1.
    """

    def constrain(tree, shardings):
        if shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, shardings)

    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss)(params, batch)

    def train_step(params, opt_state, batch, rng):
        if microbatches <= 1:
            loss, grads = grad_fn(params, batch)
            grads = constrain(grads, grad_shardings)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def body(carry, mb):
                gsum, lsum = carry
                mb = constrain(mb, batch_shardings) if batch_shardings else mb
                l, g = grad_fn(params, mb)
                g = constrain(g, grad_shardings)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                gsum = constrain(gsum, grad_shardings)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            g0 = constrain(g0, grad_shardings)
            (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        ef = opt_state.get("ef")
        grads, new_ef = compress_grads(grads, grad_compression, ef)
        core = {k: v for k, v in opt_state.items() if k != "ef"}
        new_params, new_core, metrics = optim_mod.apply_updates(
            params, grads, core, opt_cfg, rng=rng)
        if new_ef is not None:
            new_core["ef"] = new_ef
        metrics = dict(metrics, loss=loss)
        return new_params, new_core, metrics

    return train_step


def init_opt_state(params, opt_cfg: optim_mod.OptimConfig,
                   grad_compression: str | None = None):
    state = optim_mod.init_state(params, opt_cfg)
    ef = init_ef_state(params, grad_compression)
    if ef is not None:
        state["ef"] = ef
    return state
