"""Pallas TPU Mamba-2 SSD chunk-scan kernel.

One (batch, head) slice per grid row; the chunk axis is the innermost grid
dimension so the (P x N) SSM state lives in VMEM scratch across chunks —
the inter-chunk recurrence never touches HBM. Within a chunk, the quadratic
"dual form" (C B^T ⊙ decay) runs on (L x L) VMEM tiles.

HBM traffic: x, dt, B, C, y once each + nothing for the state — the
paper's traffic-filtering argument applied to the SSM working set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_scr, *,
                chunk: int, num_chunks: int):
    # check: waive[R1] — dt streams as (1, chunk) row slabs: the sublane dim
    # is deliberately 1 (one (b,h) row per grid step, chunk on the lane dim);
    # Mosaic pads the single sublane to a full tile and the slab walks in
    # lockstep with the x/b/c chunk blocks, so alignment costs nothing here.
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_scr[...] = jnp.zeros_like(st_scr)

    x = x_ref[...].reshape(chunk, -1).astype(jnp.float32)      # (L, P)
    dt = dt_ref[...].reshape(chunk, 1).astype(jnp.float32)     # (L, 1)
    a = a_ref[pl.program_id(0)]                                # scalar A_h (<0)
    b = b_ref[...].reshape(chunk, -1).astype(jnp.float32)      # (L, N)
    c = c_ref[...].reshape(chunk, -1).astype(jnp.float32)      # (L, N)

    da = dt * a                                                # (L,1)
    seg = jnp.cumsum(da, axis=0)                               # (L,1)
    total = seg[chunk - 1, 0]

    # intra-chunk: (C B^T ⊙ decay ⊙ dt_j) X
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L,L)
    li = seg                                                    # (L,1)
    lj = seg.reshape(1, chunk)
    decay = jnp.exp(li - lj)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(iota_j <= iota_i, cb * decay, 0.0)
    xdt = x * dt                                                # (L,P)
    y = jax.lax.dot_general(att, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (C exp(seg)) @ state_in ; state update
    st_in = st_scr[...]                                         # (N, P)
    c_decay = c * jnp.exp(seg)                                  # (L,N)
    y += jax.lax.dot_general(c_decay, st_in, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    decay_out = jnp.exp(total - seg)                            # (L,1)
    bwt = b * decay_out      # dt already folded into xdt       # (L,N)
    st_new = jax.lax.dot_general(bwt, xdt, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (N,P)
    st_scr[...] = st_new + jnp.exp(total) * st_in

    y_ref[...] = y.reshape(y_ref.shape).astype(y_ref.dtype)


def ssd_scan_pallas(x, dt, A, b_, c_, *, chunk: int = 128,
                    interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); b_/c_: (B,S,N) -> y (B,S,H,P).

    The state is carried in VMEM across the chunk grid dim; the final state
    is not returned (training path — decode keeps its own O(1) state)."""
    bsz, s, h, p = x.shape
    n = b_.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    xr = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    dtr = dt.transpose(0, 2, 1).reshape(bsz * h, s)
    ar = jnp.repeat(A.astype(jnp.float32)[None, :], bsz, 0).reshape(bsz * h)
    br = jnp.repeat(b_[:, None], h, 1).reshape(bsz * h, s, n)
    cr = jnp.repeat(c_[:, None], h, 1).reshape(bsz * h, s, n)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    out = pl.pallas_call(
        kernel,
        grid=(bsz * h, 1, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, _, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, _, ci: (bh, ci)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, n), lambda bh, _, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, _, ci: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda bh, _, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, ar, br, cr)
    return out.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
