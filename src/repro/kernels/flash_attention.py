"""Pallas TPU flash-attention forward kernel.

COPA's core insight — filter off-package traffic with on-package storage —
is exactly what this kernel does in software: the (Sq x Skv) score matrix
lives only in VMEM tiles; HBM sees Q, K, V, O once each.

Grid: (batch*kv_heads, num_q_blocks, num_kv_blocks), kv innermost so the
fp32 accumulator scratch persists across kv steps for a fixed q block.
Block shapes are MXU-aligned (multiples of 128 on the contracting/lane dims
when the head_dim allows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, block_q: int, block_kv: int,
                 num_kv: int, group: int):
    """One (q-block, kv-block) tile. q_ref: (block_q*G, D) for a single
    kv-head (queries of the G grouped heads stacked); k/v_ref: (block_kv, D)."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].reshape(group * block_q, -1).astype(jnp.float32)  # (G*Bq, D)
    k = k_ref[...].reshape(block_kv, -1).astype(jnp.float32)         # (Bkv, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        iq = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (group * block_q, block_kv), 0) % block_q
        # row r of the stacked (G*Bq) dim maps to query index r % Bq... rows
        # are laid out (G, Bq) flattened: query position = r mod block_q
        ik = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (group * block_q, block_kv), 1)
        s = jnp.where(ik <= iq, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    m_scr[...] = m_new
    v = v_ref[...].reshape(block_kv, -1).astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == num_kv - 1)
    def _finalize():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = o.reshape(o_ref.shape).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None,
                           block_q: int = 256, block_kv: int = 256,
                           interpret: bool = False):
    """q: (B,Sq,H,D); k/v: (B,Skv,KVH,D) -> (B,Sq,H,Dv).

    GQA handled by stacking each kv-head's G query heads into the q block
    rows, so the kernel sees 2D MXU-friendly tiles.
    """
    b, sq, h, d = q.shape
    _, skv, kvh, dv = v.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv, block_q, block_kv)
    nq, nk = sq // block_q, skv // block_kv

    # (B,S,H,D) -> (B*KVH, G, S, D) -> rows stacked (B*KVH, S*G... keep (G,Bq)
    qr = (q.reshape(b, sq, kvh, g, d).transpose(0, 2, 3, 1, 4)
          .reshape(b * kvh, g, sq, d))
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, dv)

    grid = (b * kvh, nq, nk)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, block_q=block_q,
        block_kv=block_kv, num_kv=nk, group=g)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, block_q, d),
                         lambda bh, qi, ki: (bh, 0, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, dv), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, block_q, dv),
                               lambda bh, qi, ki: (bh, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    # (B*KVH, G, Sq, Dv) -> (B, Sq, H, Dv)
    return (out.reshape(b, kvh, g, sq, dv).transpose(0, 3, 1, 2, 4)
            .reshape(b, sq, h, dv))
