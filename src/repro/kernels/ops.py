"""jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode for correctness
validation; on TPU they compile natively. The model layer calls these via
the ``pallas`` MSM policy.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.fused_ffn import fused_ffn_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "scale"))
def flash_attention_op(q, k, v, *, causal: bool = True, scale=None):
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("scale",))
def flash_decode_op(q, k, v, kv_len, *, scale=None):
    return flash_decode_pallas(q, k, v, kv_len, scale=scale,
                               interpret=not _on_tpu())


@jax.jit
def fused_ffn_op(x, w_gate, w_up, w_down):
    return fused_ffn_pallas(x, w_gate, w_up, w_down, interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan_op(x, dt, A, b_, c_, chunk: int = 128):
    return ssd_scan_pallas(x, dt, A, b_, c_, chunk=chunk,
                           interpret=not _on_tpu())
