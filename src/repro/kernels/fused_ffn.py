"""Pallas TPU fused SwiGLU FFN: three GEMMs, zero HBM round-trips for the
hidden state.

Unfused, the (T x F) gate/up/hidden tensors cost 6*T*F bytes of HBM traffic
per layer; fused, HBM sees only x, the three weight tiles and y — the same
traffic the paper's L3 would have filtered (its Fig 4 'adjacent-kernel
reuse' band). Grid: (T blocks, F blocks), F innermost; the down-projection
partial products accumulate in an fp32 VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, y_ref, acc_scr, *,
                num_f: int):
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)           # (Bt, D)
    wg = wg_ref[...].astype(jnp.float32)         # (D, Bf)
    wu = wu_ref[...].astype(jnp.float32)
    g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = (g * jax.lax.logistic(g)) * u            # silu(g) * u, (Bt, Bf)
    wd = wd_ref[...].astype(jnp.float32)         # (Bf, D)
    acc_scr[...] += jax.lax.dot_general(
        h, wd, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(fi == num_f - 1)
    def _finalize():
        y_ref[...] = acc_scr[...].astype(y_ref.dtype)


def fused_ffn_pallas(x, w_gate, w_up, w_down, *, block_t: int = 256,
                     block_f: int = 512, interpret: bool = False):
    """x: (T,D); w_gate/w_up: (D,F); w_down: (F,D) -> (T,D)."""
    t, d = x.shape
    f = w_gate.shape[1]
    block_t = min(block_t, t)
    block_f = min(block_f, f)
    assert t % block_t == 0 and f % block_f == 0
    grid = (t // block_t, f // block_f)
    kernel = functools.partial(_ffn_kernel, num_f=grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ti, fi: (ti, 0)),
            pl.BlockSpec((d, block_f), lambda ti, fi: (0, fi)),
            pl.BlockSpec((d, block_f), lambda ti, fi: (0, fi)),
            pl.BlockSpec((block_f, d), lambda ti, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, d), lambda ti, fi: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_t, d), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
