"""Pallas TPU flash-attention backward kernels (dq; dk+dv).

Completes the kernel set: the training path on TPU runs fwd
(``flash_attention.py``) + these two kernels via a custom VJP, with the
same VMEM-tiling contract — score blocks are recomputed from (q, k, lse)
and never touch HBM (flash-attention-2, arXiv:2307.08691).

Grids mirror the jnp custom-VJP reference in ``models/attention.py``:
  dq:  (B*KVH, nq, nk)  — kv innermost, dq accumulates in VMEM scratch
  dkv: (B*KVH, nk, nq)  — q innermost, dk/dv accumulate in VMEM scratch
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _p_block(q, k, lse, qi, ki, scale, causal, block_q, block_kv, rows):
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        iq = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_kv), 0) % block_q
        ik = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_kv), 1)
        s = jnp.where(ik <= iq, s, NEG_INF)
    return jnp.exp(s - lse)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, scale, causal, block_q, block_kv, num_kv, group):
    qi, ki = pl.program_id(1), pl.program_id(2)
    rows = group * block_q

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].reshape(rows, -1).astype(jnp.float32)
    k = k_ref[...].reshape(block_kv, -1).astype(jnp.float32)
    v = v_ref[...].reshape(block_kv, -1).astype(jnp.float32)
    do = do_ref[...].reshape(rows, -1).astype(jnp.float32)
    lse = lse_ref[...].reshape(rows, 1)
    delta = delta_ref[...].reshape(rows, 1)

    p = _p_block(q, k, lse, qi, ki, scale, causal, block_q, block_kv, rows)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale
    acc_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(ki == num_kv - 1)
    def _fin():
        dq_ref[...] = acc_scr[...].reshape(dq_ref.shape).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, block_q,
                block_kv, num_q, group):
    ki, qi = pl.program_id(1), pl.program_id(2)
    rows = group * block_q

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[...].reshape(rows, -1).astype(jnp.float32)
    k = k_ref[...].reshape(block_kv, -1).astype(jnp.float32)
    v = v_ref[...].reshape(block_kv, -1).astype(jnp.float32)
    do = do_ref[...].reshape(rows, -1).astype(jnp.float32)
    lse = lse_ref[...].reshape(rows, 1)
    delta = delta_ref[...].reshape(rows, 1)

    p = _p_block(q, k, lse, qi, ki, scale, causal, block_q, block_kv, rows)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(qi == num_q - 1)
    def _fin():
        dk_ref[...] = dk_scr[...].reshape(dk_ref.shape).astype(dk_ref.dtype)
        dv_ref[...] = dv_scr[...].reshape(dv_ref.shape).astype(dv_ref.dtype)


def _prep(q, k, v, out, lse, dout):
    b, sq, h, d = q.shape
    _, skv, kvh, dv = v.shape
    g = h // kvh
    qr = (q.reshape(b, sq, kvh, g, d).transpose(0, 2, 3, 1, 4)
          .reshape(b * kvh, g, sq, d))
    dor = (dout.reshape(b, sq, kvh, g, dv).transpose(0, 2, 3, 1, 4)
           .reshape(b * kvh, g, sq, dv))
    lser = (lse.reshape(b, sq, kvh, g).transpose(0, 2, 3, 1)
            .reshape(b * kvh, g, sq))
    delta = jnp.sum(out.astype(jnp.float32) * dout.astype(jnp.float32), -1)
    deltar = (delta.reshape(b, sq, kvh, g).transpose(0, 2, 3, 1)
              .reshape(b * kvh, g, sq))
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, dv)
    return qr, kr, vr, dor, lser, deltar


def flash_attention_bwd_pallas(q, k, v, out, lse, dout, *, causal=True,
                               scale=None, block_q: int = 256,
                               block_kv: int = 256, interpret=False):
    """Returns (dq, dk, dv). lse: (B,Sq,H) from the forward kernel/ref."""
    b, sq, h, d = q.shape
    _, skv, kvh, dvd = v.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    nq, nk = sq // block_q, skv // block_kv
    qr, kr, vr, dor, lser, deltar = _prep(q, k, v, out, lse, dout)

    dq_kernel = functools.partial(
        _dq_kernel, scale=scale, causal=causal, block_q=block_q,
        block_kv=block_kv, num_kv=nk, group=g)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(b * kvh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, g, block_q, d), lambda bh, qi, ki: (bh, 0, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, dvd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, g, block_q, dvd), lambda bh, qi, ki: (bh, 0, qi, 0)),
            pl.BlockSpec((1, g, block_q), lambda bh, qi, ki: (bh, 0, qi)),
            pl.BlockSpec((1, g, block_q), lambda bh, qi, ki: (bh, 0, qi)),
        ],
        out_specs=pl.BlockSpec((1, g, block_q, d),
                               lambda bh, qi, ki: (bh, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((g * block_q, d), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, dor, lser, deltar)

    dkv_kernel = functools.partial(
        _dkv_kernel, scale=scale, causal=causal, block_q=block_q,
        block_kv=block_kv, num_q=nq, group=g)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(b * kvh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, g, block_q, d), lambda bh, ki, qi: (bh, 0, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, ki, qi: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, dvd), lambda bh, ki, qi: (bh, ki, 0)),
            pl.BlockSpec((1, g, block_q, dvd), lambda bh, ki, qi: (bh, 0, qi, 0)),
            pl.BlockSpec((1, g, block_q), lambda bh, ki, qi: (bh, 0, qi)),
            pl.BlockSpec((1, g, block_q), lambda bh, ki, qi: (bh, 0, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_kv, d), lambda bh, ki, qi: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, dvd), lambda bh, ki, qi: (bh, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * kvh, skv, d), k.dtype),
            jax.ShapeDtypeStruct((b * kvh, skv, dvd), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_kv, d), jnp.float32),
            pltpu.VMEM((block_kv, dvd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, dor, lser, deltar)

    dq_out = (dq.reshape(b, kvh, g, sq, d).transpose(0, 3, 1, 2, 4)
              .reshape(b, sq, h, d))
    dk_out = dk.reshape(b, kvh, skv, d).transpose(0, 2, 1, 3)
    dv_out = dv.reshape(b, kvh, skv, dvd).transpose(0, 2, 1, 3)
    return dq_out, dk_out, dv_out
