"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

Decode is the paper's bandwidth-bound case par excellence: arithmetic
intensity ~1 flop/byte, the KV cache is the whole working set. The kernel
streams KV blocks HBM->VMEM once with online-softmax partials in VMEM
scratch — the traffic floor is |K|+|V| exactly.

Grid: (B*KVH, num_kv_blocks), kv innermost; the G grouped query heads for a
kv head form the tile rows (G x D @ D x Bkv on the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_kv: int, num_kv: int, group: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].reshape(group, -1).astype(jnp.float32)        # (G, D)
    k = k_ref[...].reshape(block_kv, -1).astype(jnp.float32)     # (Bkv, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kv_len = len_ref[0]
    ik = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (group, block_kv), 1)
    s = jnp.where(ik < kv_len, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    m_scr[...] = m_new
    v = v_ref[...].reshape(block_kv, -1).astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == num_kv - 1)
    def _finalize():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = o.reshape(o_ref.shape).astype(o_ref.dtype)


def flash_decode_pallas(q, k, v, kv_len, *, scale: float | None = None,
                        block_kv: int = 512, interpret: bool = False):
    """q: (B,H,D); k/v: (B,S,KVH,D); kv_len: scalar int32 -> (B,H,Dv)."""
    b, h, d = q.shape
    _, s, kvh, dv = v.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    block_kv = min(block_kv, s)
    assert s % block_kv == 0
    nk = s // block_kv

    qr = q.reshape(b, kvh, g, d).reshape(b * kvh, g, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, dv)
    len_arr = jnp.full((1,), kv_len, jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale, block_kv=block_kv,
                               num_kv=nk, group=g)
    out = pl.pallas_call(
        kernel,
        grid=(b * kvh, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, dv), lambda bh, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, dv), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
        interpret=interpret,
    )(len_arr, qr, kr, vr)
    return out.reshape(b, h, dv)
