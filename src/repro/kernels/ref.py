"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool, scale: float | None = None):
    """q: (B,Sq,H,D); k/v: (B,Skv,KVH,D) -> (B,Sq,H,Dv)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def flash_decode_ref(q, k, v, kv_len, scale: float | None = None):
    """q: (B,H,D); k/v: (B,S,KVH,D); kv_len scalar -> (B,H,Dv)."""
    b, h, d = q.shape
    _, s, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kvh, g, d)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, None, None, :] < kv_len
    sc = jnp.where(mask, sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, v.shape[-1]).astype(q.dtype)


def fused_ffn_ref(x, w_gate, w_up, w_down):
    """SwiGLU: (T,D) @ (D,F)x2 -> silu(g)*u @ (F,D) -> (T,D)."""
    g = x.astype(jnp.float32) @ w_gate.astype(jnp.float32)
    u = x.astype(jnp.float32) @ w_up.astype(jnp.float32)
    h = jax.nn.silu(g) * u
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def ssd_chunk_ref(x, dt, A, b_, c_, initial_state=None):
    """Sequential SSD scan oracle (token-by-token recurrence).

    x: (B,S,H,P); dt: (B,S,H); A: (H,); b_/c_: (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_.shape[-1]
    st = (jnp.zeros((bsz, h, p, n), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def step(st, inp):
        xt, dtt, bt, ct = inp                     # (B,H,P) (B,H) (B,N) (B,N)
        da = jnp.exp(dtt.astype(jnp.float32) * A[None, :])
        dbx = jnp.einsum("bn,bh,bhp->bhpn", bt.astype(jnp.float32),
                         dtt.astype(jnp.float32), xt.astype(jnp.float32))
        st = st * da[:, :, None, None] + dbx
        yt = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), st)
        return st, yt

    st, ys = jax.lax.scan(
        step, st,
        (x.swapaxes(0, 1), dt.swapaxes(0, 1), b_.swapaxes(0, 1),
         c_.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), st
