"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values — as the assignment requires."""
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.models import LanguageModel

ARCHS = list(C.ARCHS)


def make_batch(cfg, key, b=2, s=64):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            key, (b, 8, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.bfloat16)
        batch["tokens"] = tokens[:, :16]
        batch["labels"] = tokens[:, :16]
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = C.get(arch).smoke()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    h, aux = model.forward(params, batch)
    exp_s = batch["tokens"].shape[1]
    assert h.shape == (2, exp_s, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    from repro.train import OptimConfig, init_opt_state, make_train_step

    cfg = C.get(arch).smoke()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    step = make_train_step(model, opt_cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch,
                                                 jax.random.PRNGKey(2))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    leaf0 = jax.tree.leaves(params)[0]
    leaf1 = jax.tree.leaves(new_params)[0]
    assert not jnp.allclose(leaf0.astype(jnp.float32),
                            leaf1.astype(jnp.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = C.get(arch).smoke()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 32, enc_len=16)
    tok = jnp.ones((2, 1), jnp.int32)
    logits, cache = model.decode_step(params, cache, tok, jnp.int32(0))
    logits, cache = model.decode_step(params, cache, tok, jnp.int32(1))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_prefill_matches_decode_dense():
    """Teacher-forced decode must reproduce the chunked-forward logits."""
    cfg = C.get("tinyllama-1.1b").smoke()
    model = LanguageModel(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    h, _ = model.forward(params, {"tokens": tokens})
    from repro.models.layers import logits_for_tokens

    full_logits = logits_for_tokens(params["emb"], h)
    cache = model.init_cache(b, s)
    outs = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    assert jnp.allclose(full_logits.astype(jnp.float32),
                        dec_logits.astype(jnp.float32), atol=0.25, rtol=0.05)


def test_prefill_matches_decode_ssm():
    cfg = C.get("mamba2-1.3b").smoke()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    h, _ = model.forward(params, {"tokens": tokens})
    from repro.models.layers import logits_for_tokens

    full_logits = logits_for_tokens(params["emb"], h)
    cache = model.init_cache(b, s)
    outs = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    assert jnp.allclose(full_logits.astype(jnp.float32),
                        dec_logits.astype(jnp.float32), atol=0.3, rtol=0.05)


def test_param_counts_match_analytic():
    """Spec machinery vs the config-level analytic parameter count."""
    from repro.models.base import count_params

    for arch in ("tinyllama-1.1b", "yi-6b", "qwen3-moe-235b-a22b",
                 "deepseek-v2-236b", "mamba2-1.3b", "zamba2-1.2b"):
        cfg = C.get(arch)
        model = LanguageModel(cfg)
        built = count_params(model.specs())
        analytic = cfg.n_params()
        assert abs(built - analytic) / analytic < 0.02, (arch, built, analytic)
