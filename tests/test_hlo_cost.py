"""Trip-count-expanded HLO cost analysis (the §Roofline accounting)."""
import os
import subprocess
import sys
import textwrap


from repro.core.hlo_cost import analyze_hlo_cost


def test_synthetic_while_trip_expansion():
    hlo = """
%body.1 (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]{1,0}) parameter(0)
  %a = f32[64,64]{1,0} get-tuple-element(%p), index=1
  %d = f32[64,64]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] constant(1)
  ROOT %t = (s32[], f32[64,64]{1,0}) tuple(%i, %d)
}

%cond.1 (p2: (s32[], f32[64,64])) -> pred[] {
  %p2 = (s32[], f32[64,64]{1,0}) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main.1 (x: f32[64,64]) -> f32[64,64] {
  %x = f32[64,64]{1,0} parameter(0)
  %c = s32[] constant(0)
  %tup = (s32[], f32[64,64]{1,0}) tuple(%c, %x)
  %w = (s32[], f32[64,64]{1,0}) while(%tup), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""
    c = analyze_hlo_cost(hlo)
    assert c.dot_flops == 5 * 2 * 64 ** 3


def test_scan_flops_counted_fully():
    """End-to-end: compile a 7-trip scan of a 128^3 matmul in a subprocess
    and verify the analyzer recovers all 7 trips (raw cost_analysis: 1)."""
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp, sys
        sys.path.insert(0, "src")
        from repro.core.hlo_cost import analyze_hlo_cost, raw_cost_analysis
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, None, length=7)[0].sum()
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        comp = jax.jit(f).lower(x, w).compile()
        c = analyze_hlo_cost(comp.as_text())
        raw = raw_cost_analysis(comp)["flops"]
        assert abs(c.dot_flops - 7 * 2 * 128**3) < 1e5, c.dot_flops
        assert raw < c.dot_flops / 3  # the undercount this module fixes
        print("ok")
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         env=dict(os.environ, PYTHONPATH="src"),
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0 and "ok" in out.stdout, out.stderr[-1500:]
