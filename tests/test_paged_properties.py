"""Hypothesis property tests for the paged KV allocator and engines:
block-table ledger invariants over random admit/ensure/release programs,
and batched-vs-oracle bit parity over randomized paged/policy fleets."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.sweep import CostGrid
from repro.serve.fleet import FleetSim
from repro.serve.paged import PagedKv, PagedKvSpec, SchedPolicy, pages_for
from repro.serve.sim import Request

INF = float("inf")


def check_ledgers(a: PagedKv) -> None:
    mapped = sum(len(p) for p in a.page_table.values())
    assert a.pages_mapped == mapped, "mapped ledger out of sync"
    assert a.committed_pages == sum(a._committed.values())
    assert a.committed_pages <= a.commit_budget, "oversubscription bound"
    if a._free is not None:
        # free + mapped == total, and no page double-mapped or leaked
        pages = [pg for p in a.page_table.values() for pg in p]
        assert len(set(pages)) == len(pages), "page double-mapped"
        assert len(a._free) + mapped == a.capacity_pages
        assert set(a._free).isdisjoint(pages)
        assert set(a._free) | set(pages) == set(range(a.capacity_pages))


ops_st = st.lists(
    st.tuples(st.sampled_from(["admit", "ensure", "release"]),
              st.integers(min_value=0, max_value=7),      # rid
              st.integers(min_value=1, max_value=200)),   # kv tokens / pages
    min_size=1, max_size=60,
)


@settings(max_examples=100, deadline=None)
@given(ops=ops_st,
       page_size=st.sampled_from([1, 4, 16]),
       cap_pages=st.integers(min_value=4, max_value=40),
       oversub=st.sampled_from([1.0, 1.5, 3.0]))
def test_allocator_ledger_invariants(ops, page_size, cap_pages, oversub):
    spec = PagedKvSpec(page_size=page_size, oversubscription=oversub,
                       eviction="none" if oversub == 1.0 else "lru")
    a = PagedKv(float(cap_pages * page_size), spec)
    live: dict[int, int] = {}   # rid -> committed kv tokens
    for op, rid, arg in ops:
        if op == "admit" and rid not in live:
            if a.fits(arg) and a.can_admit(arg):
                a.admit(rid, arg)
                live[rid] = arg
        elif op == "ensure" and rid in live:
            want = min(pages_for(arg, page_size), pages_for(live[rid],
                                                            page_size))
            # the engine only asks for what fits physically
            grow = want - len(a.page_table[rid])
            if grow > 0 and (a._free is None or grow <= len(a._free)):
                a.ensure(rid, want)
        elif op == "release" and rid in live:
            a.release(rid, live.pop(rid))
        check_ledgers(a)
    for rid in list(live):
        a.release(rid, live.pop(rid))
    check_ledgers(a)
    assert a.pages_mapped == 0 and a.committed_pages == 0


requests_st = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=0.05, allow_nan=False),
        st.integers(min_value=0, max_value=40),    # prompt tokens
        st.integers(min_value=1, max_value=8),     # output tokens
    ),
    min_size=1, max_size=40,
)

paged_st = st.one_of(
    st.none(),
    st.builds(PagedKvSpec,
              page_size=st.sampled_from([1, 4, 16]),
              oversubscription=st.sampled_from([1.0, 2.0]),
              eviction=st.just("lru")),
)

sched_st = st.builds(SchedPolicy,
                     prefill_chunk=st.sampled_from([None, 7, 16]),
                     decode_priority=st.booleans())


def _cost():
    batches = (1, 2, 4)
    edges = (16.0, 128.0, INF)
    tab = np.asarray([[1e-3 + 1e-5 * b + 1e-6 * j for j in range(3)]
                      for b in batches])
    return CostGrid("prop", batches, edges, tab, prefill_s_per_token=1e-4)


@settings(max_examples=40, deadline=None)
@given(reqs=requests_st, paged=paged_st, sched=sched_st,
       n_instances=st.integers(min_value=1, max_value=3),
       kv_cap=st.sampled_from([INF, 96.0, 512.0]))
def test_paged_fleet_parity_randomized(reqs, paged, sched, n_instances,
                                       kv_cap):
    # capacity always physically fits the largest possible request (48 KV
    # tokens -> 48 pages at page_size 1)
    requests = [Request(rid=i, t_arrival=t, prompt_tokens=p, output_tokens=o)
                for i, (t, p, o) in enumerate(reqs)]
    kw = dict(n_instances=n_instances, max_batch=4,
              kv_capacity_tokens=kv_cap, paged=paged, sched=sched)
    rb = FleetSim(_cost(), **kw).run(requests, seed=0)
    ro = FleetSim(_cost(), **kw).run(requests, seed=0, batched=False)
    for col in ("t_admitted", "t_first_token", "t_done", "tokens_emitted",
                "evictions"):
        x, y = getattr(rb.batch, col), getattr(ro.batch, col)
        assert np.array_equal(x, y, equal_nan=(x.dtype.kind == "f")), col
    for la, lb in zip(rb.step_logs, ro.step_logs):
        for col in ("t_start", "t_end", "batch", "kv_reserved", "queued",
                    "admitted", "pages"):
            assert np.array_equal(getattr(la, col), getattr(lb, col)), col
    # conservation under every policy mix: all requests complete in full
    assert np.array_equal(rb.batch.tokens_emitted, rb.batch.output_tokens)
    if paged is not None and np.isfinite(kv_cap):
        cap_pages = int(kv_cap // paged.page_size)
        for lg in rb.step_logs:
            assert (lg.pages <= cap_pages).all()
