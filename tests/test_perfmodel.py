"""Perf-model invariants + paper-claim validation (loose tolerances)."""
import numpy as np

from repro.core import copa, hw, perfmodel
from repro.core.hw import MB
from repro.workloads import mlperf
from repro.workloads.hpc import hpc_suite

PM_CACHE = {}


def pm(trace):
    if trace.name not in PM_CACHE:
        PM_CACHE[trace.name] = perfmodel.PerfModel(trace)
    return PM_CACHE[trace.name]


def test_segments_sum_to_total():
    r = pm(mlperf.training_trace("resnet", "large")).run(hw.GPU_N)
    assert abs(sum(r.segments.values()) - r.time_s) < 1e-9


def test_idealization_monotone():
    m = pm(mlperf.training_trace("transformer", "large"))
    t_act = m.time(hw.GPU_N)
    t1 = m.time(hw.GPU_N, ideal_dram=True)
    t2 = m.time(hw.GPU_N, ideal_dram=True, ideal_mem_other=True)
    t3 = m.time(hw.GPU_N, ideal_dram=True, ideal_mem_other=True,
                ideal_occupancy=True)
    assert t_act >= t1 >= t2 >= t3 > 0


def test_more_bandwidth_never_slower():
    m = pm(mlperf.inference_trace("resnet", "large"))
    fast = hw.GPU_N.with_(dram_bandwidth=hw.GPU_N.dram_bandwidth * 2)
    assert m.time(fast) <= m.time(hw.GPU_N) + 1e-12


def test_bigger_cache_never_slower():
    m = pm(mlperf.training_trace("resnet", "large"))
    big = hw.GPU_N.with_(l2_capacity=hw.GPU_N.l2_capacity * 8)
    assert m.time(big) <= m.time(hw.GPU_N) + 1e-12


def test_copa_configs_ordered():
    """Perfect L2 bounds every COPA config; every COPA config >= GPU-N."""
    m = pm(mlperf.training_trace("resnet", "large"))
    t_base = m.time(hw.GPU_N)
    t_perfect = m.time(copa.PERFECT_L2.build())
    for cfg in (copa.HBM_L3, copa.HBML_L3, copa.HBM_L3L, copa.HBML_L3L):
        t = m.time(cfg.build())
        assert t_perfect - 1e-12 <= t <= t_base + 1e-12, cfg.name


# --- paper-claim regression tests (the §Paper-claims table) -------------------

def _geo(xs):
    return float(np.exp(np.mean(np.log(list(xs)))))


def test_paper_fig2_training_dram_fraction():
    fracs = []
    for n in mlperf.TRAIN_BATCHES:
        for s in ("large", "small"):
            r = pm(mlperf.training_trace(n, s)).run(hw.GPU_N)
            fracs.append(r.segments["DRAM BW"] / r.time_s)
    # paper: 28% mean across large+small training
    assert 0.18 <= np.mean(fracs) <= 0.38


def test_paper_fig11_hbml_l3_training():
    spec = copa.HBML_L3.build()
    sp = _geo(pm(mlperf.training_trace(n, "large")).time(hw.GPU_N)
              / pm(mlperf.training_trace(n, "large")).time(spec)
              for n in mlperf.TRAIN_BATCHES)
    # paper: +31% large-batch training
    assert 1.20 <= sp <= 1.45


def test_paper_fig11_hbm_l3_training():
    spec = copa.HBM_L3.build()
    sp = _geo(pm(mlperf.training_trace(n, "large")).time(hw.GPU_N)
              / pm(mlperf.training_trace(n, "large")).time(spec)
              for n in mlperf.TRAIN_BATCHES)
    # paper: +21%
    assert 1.10 <= sp <= 1.35


def test_paper_fig3_hpc_insensitivity():
    pms = [perfmodel.PerfModel(t) for t in hpc_suite()]
    base = [p.time(hw.GPU_N) for p in pms]
    inf_bw = hw.GPU_N.with_(dram_bandwidth=1e20)
    sp_inf = _geo(b / p.time(inf_bw) for b, p in zip(base, pms))
    half = hw.GPU_N.with_(dram_bandwidth=hw.GPU_N.dram_bandwidth * 0.5)
    sp_half = _geo(b / p.time(half) for b, p in zip(base, pms))
    assert sp_inf <= 1.10          # paper: +5%
    assert 0.78 <= sp_half <= 0.92  # paper: -14%


def test_paper_fig4_inference_traffic_collapse():
    from repro.core.cachesim import dram_traffic_sweep

    reds = []
    for t in mlperf.inference_suite("large"):
        sweep = dram_traffic_sweep(t, [60 * MB, 1020 * MB])
        reds.append(min(sweep[60 * MB] / max(sweep[1020 * MB], 1e-9), 1e3))
    # paper: 16x geomean at 960MB L3 (+60MB L2)
    assert _geo(reds) >= 6.0


def test_footprints_within_regime_of_table3():
    # per-GPU footprints should land within ~3x of the paper's Table III
    # (proxy models regenerated from public architectures, not NVIDIA's
    # internal traces; BN/activation fusion choices move vision footprints)
    targets = {"resnet": 6.0, "ssd": 7.9, "maskrcnn": 9.9, "minigo": 1.5,
               "gnmt": 8.3, "transformer": 7.9, "ncf": 4.5}
    for name, tgt in targets.items():
        got = mlperf.training_trace(name, "large").peak_live_bytes() / 2**30
        assert tgt / 3.0 <= got <= tgt * 3.0, (name, got, tgt)
