"""Sweep-engine tests: vectorized kernels vs per-touch references, the
engine grid vs PerfModel/dram_traffic_sweep (bit-for-bit), the registry."""
import numpy as np
import pytest

from repro.core import copa, hw, perfmodel
from repro.core.cachesim import (
    _reference_traffic_below,
    build_stream,
    dram_traffic_sweep,
    traffic_below,
)
from repro.core.hw import MB
from repro.core.stackdist import (
    BlockLRU,
    _mattson_pass,
    _reference_mattson_pass,
)
from repro.core.sweep import SweepEngine, TraceAnalysis, geomean
from repro.core.trace import Trace
from repro.workloads import mlperf, registry


def _random_trace(rng, n_ops, n_tensors, streaming=0.2) -> Trace:
    tr = Trace("rand")
    for i in range(n_ops):
        reads, writes = [], []
        for _ in range(int(rng.integers(0, 3))):
            t = int(rng.integers(0, n_tensors))
            nm = f"in.t{t}" if rng.random() < streaming else f"t{t}"
            reads.append((nm, int(rng.integers(1, 20)) * MB))
        for _ in range(int(rng.integers(0, 2))):
            writes.append((f"t{int(rng.integers(0, n_tensors))}",
                           int(rng.integers(1, 20)) * MB))
        if reads or writes:
            tr.emit(f"op{i}", 1e6, reads=reads, writes=writes)
    return tr


# --- kernel parity: vectorized vs per-touch reference -------------------------

def test_mattson_vectorized_matches_reference():
    rng = np.random.default_rng(7)
    for trial in range(40):
        n = int(rng.integers(1, 300))
        ids = rng.integers(0, int(rng.integers(1, 16)), n)
        if trial % 2:
            sizes = rng.integers(1, 100, n).astype(float)  # per-touch sizes
        else:
            per_id = rng.integers(1, 100, ids.max() + 1).astype(float)
            sizes = per_id[ids]                            # per-tensor sizes
        got = _mattson_pass(ids, sizes)
        want = _reference_mattson_pass(ids, sizes)
        inf = np.isinf(want)
        assert np.array_equal(np.isinf(got), inf)
        assert np.allclose(got[~inf], want[~inf], rtol=1e-9, atol=1e-6)


def test_mattson_empty_and_single():
    assert len(_mattson_pass(np.zeros(0, np.int64), np.zeros(0))) == 0
    d = _mattson_pass(np.array([3]), np.array([5.0]))
    assert np.isinf(d[0])


def test_traffic_below_vectorized_matches_reference():
    rng = np.random.default_rng(11)
    caps = [float(c) * MB for c in (1, 7, 33, 120, 1000)]
    for _ in range(20):
        tr = _random_trace(rng, int(rng.integers(3, 50)), int(rng.integers(2, 10)))
        for cyclic in (True, False):
            stream = build_stream(tr, cyclic=cyclic)
            got = traffic_below(stream, caps)
            want = _reference_traffic_below(stream, caps)
            for g, w in zip(got, want):
                assert np.allclose(g.fill, w.fill, rtol=1e-9, atol=1e-3)
                assert np.allclose(g.writeback, w.writeback, rtol=1e-9, atol=1e-3)


def test_traffic_below_capacity_batching_is_column_independent():
    """Batched capacities must equal one-at-a-time evaluation exactly —
    the property that lets the engine share one pass across a design space."""
    rng = np.random.default_rng(3)
    tr = _random_trace(rng, 40, 8)
    stream = build_stream(tr)
    caps = [float(c) * MB for c in (5, 50, 500)]
    batched = traffic_below(stream, caps)
    for i, c in enumerate(caps):
        (single,) = traffic_below(stream, [c])
        assert np.array_equal(single.fill, batched[i].fill)
        assert np.array_equal(single.writeback, batched[i].writeback)


def test_fractional_model_tracks_block_lru_random():
    """Same magnitude bound as the hypothesis test in test_cachesim.py, but
    seeded-numpy driven so it always runs (no hypothesis dependency)."""
    rng = np.random.default_rng(5)
    for _ in range(15):
        tr = Trace("rand")
        sizes = rng.integers(1, 16, 8)
        for i in range(int(rng.integers(4, 40))):
            tid = int(rng.integers(0, 8))
            if rng.random() < 0.5:
                tr.emit(f"op{i}", 0.0, writes=[(f"t{tid}", int(sizes[tid]) * MB)])
            else:
                tr.emit(f"op{i}", 0.0, reads=[(f"t{tid}", int(sizes[tid]) * MB)],
                        writes=[(f"o{i}", MB)])
        cap = int(rng.integers(2, 64)) * MB
        stream = build_stream(tr, cyclic=False, reuse_buffers=False)
        (res,) = traffic_below(stream, [cap])
        lru = BlockLRU(cap, block_bytes=MB)
        for _, t, b, w in tr.touches():
            lru.touch_tensor(hash(t) % (1 << 30), b, w)
        model, exact = res.total, lru.fill_bytes + lru.writeback_bytes
        hi, lo = max(model, exact), min(model, exact)
        assert hi - lo <= 0.80 * hi + 8 * MB


# --- engine grid vs the single-trace APIs (bit-for-bit) -----------------------

@pytest.fixture(scope="module")
def transformer_trace():
    return mlperf.training_trace("transformer", "large")


def test_engine_matches_perfmodel_bit_for_bit(transformer_trace):
    t = transformer_trace
    grid = SweepEngine([t], configs=copa.TABLE_V).run()
    pm = perfmodel.PerfModel(t)
    for cfg in copa.TABLE_V:
        spec = cfg.build()
        r = pm.run(spec)
        row = grid.result(t.name, cfg.name)
        assert row.time_s == r.time_s, cfg.name
        assert row.segments == r.segments, cfg.name
        assert row.dram_bytes == r.dram_bytes
        assert row.l3_bytes == r.l3_bytes
        assert row.uhb_bytes == r.uhb_bytes
        assert row.speedup == pm.time(hw.GPU_N) / r.time_s
        en = pm.energy(spec)
        assert row.dram_joules == en.dram_joules
        assert row.l3_joules == en.l3_joules


def test_engine_matches_dram_traffic_sweep_bit_for_bit(transformer_trace):
    t = transformer_trace
    caps = [60 * MB, 480 * MB, 960 * MB]
    grid = SweepEngine([t], configs=[], extra_llc_capacities=caps).run()
    sweep = dram_traffic_sweep(t, caps)
    for c in caps:
        assert grid.llc_traffic[t.name][float(c)] == sweep[c]


def test_engine_grid_over_mlperf_suites_matches_reference_within_1e6():
    """Acceptance: engine over (Table-V x MLPerf training+inference) matches
    the per-touch reference kernels within 1e-6 relative on time/traffic."""
    names = (registry.suite("mlperf.train.large")[:2]
             + registry.suite("mlperf.infer.large")[:2])
    traces = [registry.scenario(n) for n in names]
    grid = SweepEngine(traces, configs=copa.TABLE_V).run()
    for trace in traces:
        ref_stream = build_stream(trace, dist_fn=_reference_mattson_pass)
        ta = TraceAnalysis(trace, stream=ref_stream)
        caps = sorted({c for cfg in copa.TABLE_V
                       for c in TraceAnalysis.capacities_for(cfg.build())})
        for cap, lt in zip(caps, _reference_traffic_below(ref_stream, caps)):
            ta._levels[float(cap)] = lt
        for cfg in copa.TABLE_V:
            spec = cfg.build()
            row = grid.result(trace.name, cfg.name)
            t_ref = ta.time(spec)
            assert abs(row.time_s - t_ref) <= 1e-6 * t_ref, (trace.name, cfg.name)
            tr_ref = ta.hierarchy(spec)
            assert abs(row.dram_bytes - tr_ref.dram.total) <= \
                1e-6 * max(tr_ref.dram.total, 1.0)


def test_engine_accepts_raw_specs_and_scenario_names():
    grid = SweepEngine(
        ["mlperf.infer.resnet.large"],
        configs=[hw.GPU_N.with_(name="GPU-N@2xBW",
                                dram_bandwidth=hw.GPU_N.dram_bandwidth * 2)],
    ).run()
    (row,) = grid.rows
    assert row.config == "GPU-N@2xBW"
    assert row.speedup >= 1.0 - 1e-12
    assert row.kind == "inference"


def test_grid_geomean_and_speedups():
    names = registry.suite("mlperf.infer.large")[:3]
    grid = SweepEngine(names, configs=[copa.HBM_L3]).run()
    sp = grid.speedups("HBM+L3")
    assert len(sp) == 3 and all(s > 0 for s in sp)
    assert abs(grid.geomean_speedup("HBM+L3") - geomean(sp)) < 1e-12


# --- registry -----------------------------------------------------------------

def test_registry_enumerates_all_families():
    names = registry.scenarios()
    assert len([n for n in names if n.startswith("mlperf.train.")]) == 14
    assert len([n for n in names if n.startswith("mlperf.infer.")]) == 10
    assert len([n for n in names if n.startswith("lm.")]) == 40
    assert len([n for n in names if n.startswith("hpc.")]) == 130


def test_registry_scenario_factories_cache():
    a = registry.scenario("mlperf.train.resnet.large")
    b = registry.scenario("mlperf.train.resnet.large")
    assert a is b  # lru-cached underneath
    assert a.name == "resnet.train.large"
    with pytest.raises(KeyError):
        registry.scenario("nope.nothing")


def test_registry_suites_cover_figures():
    assert set(registry.suite("mlperf.train.large")) <= set(registry.scenarios())
    assert len(registry.suite("hpc")) == 130
    lm = registry.suite("lm.decode_32k")
    assert all(n.endswith(".decode_32k") for n in lm)
