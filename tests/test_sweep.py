"""Sweep-engine tests: vectorized kernels vs per-touch references, the
engine grid vs PerfModel/dram_traffic_sweep (bit-for-bit), the registry."""
import numpy as np
import pytest

from repro.core import copa, hw, perfmodel
from repro.core.cachesim import (
    _reference_traffic_below,
    build_stream,
    dram_traffic_sweep,
    traffic_below,
)
from repro.core.hw import MB
from repro.core.stackdist import (
    BlockLRU,
    _mattson_pass,
    _reference_mattson_pass,
)
from repro.core.sweep import (
    ScaleOutWorkload,
    SweepEngine,
    TraceAnalysis,
    geomean,
    ring_allreduce_time,
)
from repro.core.trace import Trace
from repro.workloads import mlperf, registry


def _random_trace(rng, n_ops, n_tensors, streaming=0.2) -> Trace:
    tr = Trace("rand")
    for i in range(n_ops):
        reads, writes = [], []
        for _ in range(int(rng.integers(0, 3))):
            t = int(rng.integers(0, n_tensors))
            nm = f"in.t{t}" if rng.random() < streaming else f"t{t}"
            reads.append((nm, int(rng.integers(1, 20)) * MB))
        for _ in range(int(rng.integers(0, 2))):
            writes.append((f"t{int(rng.integers(0, n_tensors))}",
                           int(rng.integers(1, 20)) * MB))
        if reads or writes:
            tr.emit(f"op{i}", 1e6, reads=reads, writes=writes)
    return tr


# --- kernel parity: vectorized vs per-touch reference -------------------------

def test_mattson_vectorized_matches_reference():
    rng = np.random.default_rng(7)
    for trial in range(40):
        n = int(rng.integers(1, 300))
        ids = rng.integers(0, int(rng.integers(1, 16)), n)
        if trial % 2:
            sizes = rng.integers(1, 100, n).astype(float)  # per-touch sizes
        else:
            per_id = rng.integers(1, 100, ids.max() + 1).astype(float)
            sizes = per_id[ids]                            # per-tensor sizes
        got = _mattson_pass(ids, sizes)
        want = _reference_mattson_pass(ids, sizes)
        inf = np.isinf(want)
        assert np.array_equal(np.isinf(got), inf)
        assert np.allclose(got[~inf], want[~inf], rtol=1e-9, atol=1e-6)


def test_mattson_empty_and_single():
    assert len(_mattson_pass(np.zeros(0, np.int64), np.zeros(0))) == 0
    d = _mattson_pass(np.array([3]), np.array([5.0]))
    assert np.isinf(d[0])


def test_traffic_below_vectorized_matches_reference():
    rng = np.random.default_rng(11)
    caps = [float(c) * MB for c in (1, 7, 33, 120, 1000)]
    for _ in range(20):
        tr = _random_trace(rng, int(rng.integers(3, 50)), int(rng.integers(2, 10)))
        for cyclic in (True, False):
            stream = build_stream(tr, cyclic=cyclic)
            got = traffic_below(stream, caps)
            want = _reference_traffic_below(stream, caps)
            for g, w in zip(got, want):
                assert np.allclose(g.fill, w.fill, rtol=1e-9, atol=1e-3)
                assert np.allclose(g.writeback, w.writeback, rtol=1e-9, atol=1e-3)


def test_traffic_below_capacity_batching_is_column_independent():
    """Batched capacities must equal one-at-a-time evaluation exactly —
    the property that lets the engine share one pass across a design space."""
    rng = np.random.default_rng(3)
    tr = _random_trace(rng, 40, 8)
    stream = build_stream(tr)
    caps = [float(c) * MB for c in (5, 50, 500)]
    batched = traffic_below(stream, caps)
    for i, c in enumerate(caps):
        (single,) = traffic_below(stream, [c])
        assert np.array_equal(single.fill, batched[i].fill)
        assert np.array_equal(single.writeback, batched[i].writeback)


def test_fractional_model_tracks_block_lru_random():
    """Same magnitude bound as the hypothesis test in test_cachesim.py, but
    seeded-numpy driven so it always runs (no hypothesis dependency)."""
    rng = np.random.default_rng(5)
    for _ in range(15):
        tr = Trace("rand")
        sizes = rng.integers(1, 16, 8)
        for i in range(int(rng.integers(4, 40))):
            tid = int(rng.integers(0, 8))
            if rng.random() < 0.5:
                tr.emit(f"op{i}", 0.0, writes=[(f"t{tid}", int(sizes[tid]) * MB)])
            else:
                tr.emit(f"op{i}", 0.0, reads=[(f"t{tid}", int(sizes[tid]) * MB)],
                        writes=[(f"o{i}", MB)])
        cap = int(rng.integers(2, 64)) * MB
        stream = build_stream(tr, cyclic=False, reuse_buffers=False)
        (res,) = traffic_below(stream, [cap])
        lru = BlockLRU(cap, block_bytes=MB)
        for _, t, b, w in tr.touches():
            lru.touch_tensor(hash(t) % (1 << 30), b, w)
        model, exact = res.total, lru.fill_bytes + lru.writeback_bytes
        hi, lo = max(model, exact), min(model, exact)
        assert hi - lo <= 0.80 * hi + 8 * MB


# --- engine grid vs the single-trace APIs (bit-for-bit) -----------------------

@pytest.fixture(scope="module")
def transformer_trace():
    return mlperf.training_trace("transformer", "large")


def test_engine_matches_perfmodel_bit_for_bit(transformer_trace):
    t = transformer_trace
    grid = SweepEngine([t], configs=copa.TABLE_V).run()
    pm = perfmodel.PerfModel(t)
    for cfg in copa.TABLE_V:
        spec = cfg.build()
        r = pm.run(spec)
        row = grid.result(t.name, cfg.name)
        assert row.time_s == r.time_s, cfg.name
        assert row.segments == r.segments, cfg.name
        assert row.dram_bytes == r.dram_bytes
        assert row.l3_bytes == r.l3_bytes
        assert row.uhb_bytes == r.uhb_bytes
        assert row.speedup == pm.time(hw.GPU_N) / r.time_s
        en = pm.energy(spec)
        assert row.dram_joules == en.dram_joules
        assert row.l3_joules == en.l3_joules


def test_engine_matches_dram_traffic_sweep_bit_for_bit(transformer_trace):
    t = transformer_trace
    caps = [60 * MB, 480 * MB, 960 * MB]
    grid = SweepEngine([t], configs=[], extra_llc_capacities=caps).run()
    sweep = dram_traffic_sweep(t, caps)
    for c in caps:
        assert grid.llc_traffic[t.name][float(c)] == sweep[c]


def test_engine_grid_over_mlperf_suites_matches_reference_within_1e6():
    """Acceptance: engine over (Table-V x MLPerf training+inference) matches
    the per-touch reference kernels within 1e-6 relative on time/traffic."""
    names = (registry.suite("mlperf.train.large")[:2]
             + registry.suite("mlperf.infer.large")[:2])
    traces = [registry.scenario(n) for n in names]
    grid = SweepEngine(traces, configs=copa.TABLE_V).run()
    for trace in traces:
        ref_stream = build_stream(trace, dist_fn=_reference_mattson_pass)
        ta = TraceAnalysis(trace, stream=ref_stream)
        caps = sorted({c for cfg in copa.TABLE_V
                       for c in TraceAnalysis.capacities_for(cfg.build())})
        for cap, lt in zip(caps, _reference_traffic_below(ref_stream, caps)):
            ta._levels[float(cap)] = lt
        for cfg in copa.TABLE_V:
            spec = cfg.build()
            row = grid.result(trace.name, cfg.name)
            t_ref = ta.time(spec)
            assert abs(row.time_s - t_ref) <= 1e-6 * t_ref, (trace.name, cfg.name)
            tr_ref = ta.hierarchy(spec)
            assert abs(row.dram_bytes - tr_ref.dram.total) <= \
                1e-6 * max(tr_ref.dram.total, 1.0)


def test_engine_accepts_raw_specs_and_scenario_names():
    grid = SweepEngine(
        ["mlperf.infer.resnet.large"],
        configs=[hw.GPU_N.with_(name="GPU-N@2xBW",
                                dram_bandwidth=hw.GPU_N.dram_bandwidth * 2)],
    ).run()
    (row,) = grid.rows
    assert row.config == "GPU-N@2xBW"
    assert row.speedup >= 1.0 - 1e-12
    assert row.kind == "inference"


def test_grid_geomean_and_speedups():
    names = registry.suite("mlperf.infer.large")[:3]
    grid = SweepEngine(names, configs=[copa.HBM_L3]).run()
    sp = grid.speedups("HBM+L3")
    assert len(sp) == 3 and all(s > 0 for s in sp)
    assert abs(grid.geomean_speedup("HBM+L3") - geomean(sp)) < 1e-12


# --- batched (config x op) time model vs the per-spec oracle ------------------

def test_time_batch_matches_reference_bit_for_bit(transformer_trace):
    """The (config x op) matrix evaluation is elementwise per row, so every
    Table-V config must come out bit-identical to the per-spec scalar loop —
    under every idealization the attribution peel uses."""
    import itertools

    ta = TraceAnalysis(transformer_trace)
    specs = [cfg.build() for cfg in copa.TABLE_V]
    for flags in itertools.product((False, True), repeat=3):
        kw = dict(zip(("ideal_dram", "ideal_mem_other", "ideal_occupancy"),
                      flags))
        totals = ta.time_batch(specs, **kw)
        per_op = ta.time_batch(specs, per_op=True, **kw)
        assert per_op.shape == (len(specs), len(ta.flops))
        for i, spec in enumerate(specs):
            assert totals[i] == ta._reference_time(spec, **kw), (flags, spec.name)
            assert np.array_equal(
                per_op[i], ta._reference_time(spec, per_op=True, **kw))


def test_attribution_batch_matches_single(transformer_trace):
    ta = TraceAnalysis(transformer_trace)
    specs = [cfg.build() for cfg in copa.TABLE_V]
    batched = ta.attribution_batch(specs)
    for spec, (t_act, segments) in zip(specs, batched):
        t_one, seg_one = ta.attribution(spec)
        assert t_act == t_one
        assert segments == seg_one


# --- scale-out projection (paper Fig 12) --------------------------------------

FIG12_BENCHES = ("resnet", "transformer", "ncf")


def test_fig12_engine_matches_bespoke_loop_bit_for_bit():
    """The engine scale-out grid must reproduce the seed's bespoke Fig-12
    loop exactly: per-trace COPA speedups and fixed-global-batch throughput
    ratios for 2x/4x GPU-N."""
    copa_spec = copa.HBML_L3.build()
    works = [f"scaleout.mlperf.train.{b}" for b in FIG12_BENCHES]
    names = [registry.scaleout(w).name for w in works]
    grid = SweepEngine(works, configs=[copa.GPU_N_BASE, copa.HBML_L3],
                       gpu_counts=(1, 2, 4)).run()
    for bench, trace_name in zip(FIG12_BENCHES, names):
        lb = mlperf.TRAIN_BATCHES[bench][1]
        pm_full = perfmodel.PerfModel(mlperf.training_trace(bench, "large"))
        t_base = pm_full.time(hw.GPU_N)
        assert grid.result(trace_name, "HBML+L3").speedup == \
            t_base / pm_full.time(copa_spec)
        for n in (2, 4):
            per_gpu = max(lb // n, 1)
            pm_n = perfmodel.PerfModel(mlperf.training_trace(
                bench, "large", batch_override=per_gpu))
            thr = (per_gpu * n / pm_n.time(hw.GPU_N)) / (lb / t_base)
            row = grid.result(trace_name, "GPU-N", n)
            assert row.speedup == thr, (bench, n)
            assert row.collective_time_s == 0.0  # default fabric is ideal
            assert row.n_gpus == n


def test_weak_scaling_ideal_fabric_is_linear(transformer_trace):
    """A plain Trace scales out weakly (same per-GPU trace): with an ideal
    fabric every instance adds full throughput."""
    grid = SweepEngine([transformer_trace], configs=[copa.GPU_N_BASE],
                       gpu_counts=(1, 2, 4)).run()
    r1 = grid.result(transformer_trace.name, "GPU-N", 1)
    for n in (2, 4):
        rn = grid.result(transformer_trace.name, "GPU-N", n)
        assert rn.per_gpu_time_s == r1.per_gpu_time_s
        assert abs(rn.speedup - n * r1.speedup) < 1e-12 * n
        assert abs(rn.scaling_efficiency - 1.0) < 1e-12
        assert abs(rn.throughput - n * r1.throughput) < 1e-6 * rn.throughput


def test_finite_ici_charges_training_collectives(transformer_trace):
    """A finite fabric adds the gradient ring all-reduce to training steps:
    efficiency drops below 1 and the collective term matches the model."""
    ici = 300e9
    grid = SweepEngine([transformer_trace], configs=[copa.GPU_N_BASE],
                       gpu_counts=(1, 2, 4), ici_bandwidth=ici).run()
    ta = TraceAnalysis(transformer_trace)
    assert ta.grad_bytes > 0
    for n in (2, 4):
        row = grid.result(transformer_trace.name, "GPU-N", n)
        want = ring_allreduce_time(ta.grad_bytes, n, ici)
        assert row.collective_time_s == want
        assert row.time_s == row.per_gpu_time_s + want
        assert row.scaling_efficiency < 1.0
    # one GPU never pays a collective
    assert grid.result(transformer_trace.name, "GPU-N", 1).collective_time_s == 0.0


def test_inference_scaleout_pays_no_collective():
    t = mlperf.inference_trace("resnet", "large")
    grid = SweepEngine([t], configs=[copa.GPU_N_BASE], gpu_counts=(1, 4),
                       ici_bandwidth=100e9).run()
    row = grid.result(t.name, "GPU-N", 4)
    assert row.collective_time_s == 0.0
    assert TraceAnalysis(t).grad_bytes == 0.0


def test_instances_to_target():
    """The paper's 50%-fewer-instances question: how many baseline GPUs
    match one COPA GPU."""
    works = ["scaleout.mlperf.train.transformer"]
    grid = SweepEngine(works, configs=[copa.GPU_N_BASE, copa.HBML_L3],
                       gpu_counts=(1, 2, 4)).run()
    name = registry.scaleout(works[0]).name
    target = grid.result(name, "HBML+L3").speedup
    assert target > 1.0
    n = grid.instances_to_target(name, "GPU-N", target)
    assert n in (2, 4)  # strictly more baseline GPUs than COPA GPUs
    assert grid.instances_to_target(name, "GPU-N", 1.0) == 1
    assert grid.instances_to_target(name, "GPU-N", 1e9) is None
    assert grid.instances_to_match("GPU-N", "HBML+L3", [name]) == {name: n}


def test_ring_allreduce_time_model():
    assert ring_allreduce_time(1e9, 1, 1e9) == 0.0
    assert ring_allreduce_time(0.0, 4, 1e9) == 0.0
    assert ring_allreduce_time(1e9, 2, float("inf")) == 0.0
    # 2(n-1)/n of the payload through the link
    assert abs(ring_allreduce_time(1e9, 4, 1e9) - 1.5) < 1e-12
    assert ring_allreduce_time(1e9, 4, 1e9, latency_s=1e-6) > \
        ring_allreduce_time(1e9, 4, 1e9)
    # 0 cannot mean both "no link" and "ideal link" — reject it loudly
    with pytest.raises(ValueError):
        ring_allreduce_time(1e9, 2, 0.0)
    with pytest.raises(ValueError):
        SweepEngine([], ici_bandwidth=0.0)
    with pytest.raises(ValueError):
        SweepEngine([], gpu_counts=(0, 2))


def test_analysis_cache_refreshes_when_trace_grows():
    """emit() after a sweep must not serve the stale stream (the process
    cache keys on op count, not just trace identity)."""
    from repro.core.sweep import analysis_for

    tr = Trace("grow")
    tr.emit("op0", 1e6, writes=[("t0", 10 * MB)])
    assert analysis_for(tr).stream.n_ops == 1
    tr.emit("op1", 1e6, reads=[("t0", 10 * MB)], writes=[("t1", 10 * MB)])
    assert analysis_for(tr).stream.n_ops == 2


def test_scaleout_workload_wraps_plain_callable():
    t = mlperf.training_trace("ncf", "small")
    w = ScaleOutWorkload(name="ncf-family", trace_for=lambda n: t)
    grid = SweepEngine([w], configs=[copa.GPU_N_BASE]).run()
    (row,) = grid.rows
    assert row.trace == "ncf-family"
    assert row.n_gpus == 1 and row.scaling_efficiency == 1.0


# --- registry -----------------------------------------------------------------

def test_registry_enumerates_all_families():
    names = registry.scenarios()
    assert len([n for n in names if n.startswith("mlperf.train.")]) == 14
    assert len([n for n in names if n.startswith("mlperf.infer.")]) == 10
    assert len([n for n in names if n.startswith("lm.")]) == 40
    assert len([n for n in names if n.startswith("hpc.")]) == 130


def test_registry_scenario_factories_cache():
    a = registry.scenario("mlperf.train.resnet.large")
    b = registry.scenario("mlperf.train.resnet.large")
    assert a is b  # lru-cached underneath
    assert a.name == "resnet.train.large"
    with pytest.raises(KeyError):
        registry.scenario("nope.nothing")


def test_registry_suites_cover_figures():
    assert set(registry.suite("mlperf.train.large")) <= set(registry.scenarios())
    assert len(registry.suite("hpc")) == 130
    lm = registry.suite("lm.decode_32k")
    assert all(n.endswith(".decode_32k") for n in lm)


def test_registry_serve_scenarios_batch_grid():
    names = registry.scenarios("serve.mlperf.")
    # grid points above a benchmark's calibrated (Table-III large) batch are
    # not registered — e.g. ssd-large tops out at 6, so no b16/b64 cells
    want = sum(sum(b <= large for b in registry.SERVE_BATCHES)
               for _, large in mlperf.INFER_BATCHES.values())
    assert len(names) == want
    assert "serve.mlperf.ssd-large.b16" not in names
    assert "serve.mlperf.ssd-large.b4" in names
    # every cell is a real trace at its batch, with a distinct row key
    t4 = registry.scenario("serve.mlperf.resnet.b4")
    t64 = registry.scenario("serve.mlperf.resnet.b64")
    assert t4.batch_size == 4 and t64.batch_size == 64
    assert t4.name != t64.name
    assert t4.kind == "inference"
    assert set(registry.suite("serve.b4")) <= set(names)


def test_registry_scaleout_families_resolve():
    names = registry.scaleout_names()
    assert len(registry.scaleout_names("scaleout.mlperf.train.")) == \
        len(mlperf.TRAIN_BATCHES)
    assert len(registry.scaleout_names("scaleout.serve.")) == \
        len(mlperf.INFER_BATCHES)
    w = registry.resolve("scaleout.mlperf.train.resnet")
    assert isinstance(w, ScaleOutWorkload)
    # n=1 is the plain large-batch scenario object (shared lru cache)...
    assert w.trace_for(1) is registry.scenario("mlperf.train.resnet.large")
    # ...and n>1 splits the fixed global batch across instances
    lb = mlperf.TRAIN_BATCHES["resnet"][1]
    assert w.trace_for(2).batch_size == lb // 2
    assert w.trace_for(10_000).batch_size == 1  # never below one sample
    with pytest.raises(KeyError):
        registry.scaleout("scaleout.nope")
    # plain names still resolve to traces
    assert isinstance(registry.resolve("mlperf.train.resnet.large"), Trace)


def test_serve_grid_sweeps_per_msm():
    """Latency/throughput grid: one engine run per serve batch, per-MSM
    latency ordering — a bigger on-package L3 never hurts."""
    names = registry.suite("serve.b64")[:2]
    grid = SweepEngine(names, configs=[copa.GPU_N_BASE, copa.HBM_L3]).run()
    for n in names:
        t = registry.scenario(n).name
        assert grid.result(t, "HBM+L3").time_s <= \
            grid.result(t, "GPU-N").time_s * (1 + 1e-9)
