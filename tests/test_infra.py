"""Infrastructure tests: data determinism, checkpoint atomicity/resharding,
watchdog, elastic restart, HLO parsing."""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step, restore,
                                   save)
from repro.core.hloparse import parse_collectives, shape_bytes
from repro.data.pipeline import DataConfig, DataLoader, _batch_at
from repro.ft import StepWatchdog, StragglerStats


# --- data ---------------------------------------------------------------------

def test_data_deterministic_across_restarts():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    l1 = DataLoader(cfg, start_step=0, process_index=0, process_count=1)
    first = [next(l1) for _ in range(5)]
    l1.close()
    l2 = DataLoader(cfg, start_step=3, process_index=0, process_count=1)
    resumed = [next(l2) for _ in range(2)]
    l2.close()
    for (s1, b1), (s2, b2) in zip(first[3:], resumed):
        assert s1 == s2
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_data_host_sharding_disjoint():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=0)
    b0 = _batch_at(cfg, 0, slice(0, 4))
    b1 = _batch_at(cfg, 0, slice(4, 8))
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # labels shift tokens by one
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


# --- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
            "b": jnp.ones((4,), jnp.bfloat16)}
    save(str(tmp_path), 7, tree, extra={"note": "hi"})
    step, out, extra = restore(str(tmp_path))
    assert step == 7 and extra["note"] == "hi"
    np.testing.assert_array_equal(out["a"]["w"], np.arange(6.0).reshape(2, 3))
    assert out["b"].dtype.name == "bfloat16"


def test_checkpoint_latest_pointer_atomic(tmp_path):
    tree = {"w": jnp.zeros(3)}
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 2, tree)
    assert latest_step(str(tmp_path)) == 2
    # partially-written garbage directory must not confuse restore
    os.makedirs(tmp_path / "step_000000099")
    assert latest_step(str(tmp_path)) == 2


def test_checkpoint_async_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, {"w": jnp.full((2,), float(s))})
    ck.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_000000004"
    _, out, _ = restore(str(tmp_path))
    np.testing.assert_array_equal(out["w"], [4.0, 4.0])


def test_checkpoint_reshard_on_restore(tmp_path):
    """Restore onto a different sharding than saved (elastic contract)."""
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.launch.mesh import make_compat_mesh

    mesh = make_compat_mesh((1,), ("data",))
    save(str(tmp_path), 1, {"w": jnp.arange(8.0)})
    sh = {"w": NamedSharding(mesh, PartitionSpec("data"))}
    _, out, _ = restore(str(tmp_path), shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))


# --- fault tolerance ------------------------------------------------------------

def test_watchdog_detects_hang():
    wd = StepWatchdog(deadline_s=0.2, poll_s=0.05)
    with wd:
        wd.step_started()
        time.sleep(0.5)
        with pytest.raises(TimeoutError):
            wd.check()


def test_watchdog_clean_steps_no_hang():
    wd = StepWatchdog(deadline_s=0.5, poll_s=0.05)
    with wd:
        for _ in range(5):
            wd.step_started()
            time.sleep(0.02)
            wd.step_finished()
            wd.check()


def test_straggler_detection():
    st = StragglerStats(threshold=2.0, streak_to_flag=3)
    flagged = False
    for _ in range(10):
        flagged |= st.observe(1.0)
    assert not flagged
    for _ in range(3):
        flagged |= st.observe(5.0)
    assert flagged


def test_elastic_restart_resumes_from_checkpoint(tmp_path):
    """A segment that crashes mid-run restarts and completes from the last
    checkpoint, preserving step monotonicity."""
    from repro.ft import ElasticRunner, RunState

    crashes = {"n": 0}

    def mesh_factory():
        return None

    def build_state(mesh, restore_step):
        if restore_step is not None:
            _, tree, extra = restore(str(tmp_path))
            return RunState(params=tree["params"], opt_state=tree["opt"],
                            step=int(extra["step"]))
        return RunState(params={"w": jnp.zeros(2)}, opt_state={"n": 0},
                        step=0)

    def train_segment(runner, st, max_steps):
        while st.step < max_steps:
            st.params = {"w": st.params["w"] + 1.0}
            st.step += 1
            runner.maybe_save(st)
            if st.step == 5 and crashes["n"] == 0:
                crashes["n"] += 1
                runner.maybe_save(st, force=True)
                runner.ckpt.wait()
                raise RuntimeError("injected node failure")
        runner.maybe_save(st, force=True)
        runner.ckpt.wait()
        return st

    runner = ElasticRunner(str(tmp_path), mesh_factory, build_state,
                           train_segment, save_every=2)
    st = runner.run(10)
    assert st.step == 10
    assert crashes["n"] == 1
    # params reflect resumed progress (>= 10 increments minus lost tail)
    assert float(st.params["w"][0]) >= 9.0


# --- HLO parsing ------------------------------------------------------------------

def test_parse_collectives_counts_bytes():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[8,8]{1,0} all-reduce(%x), to_apply=%add
  %rs = (f32[4,4]{1,0}, f32[4,4]{1,0}) reduce-scatter(%y, %z)
  %cp-start = bf16[2,2]{1,0} collective-permute-start(%w)
  %cp-done = bf16[2,2]{1,0} collective-permute-done(%cp-start)
"""
    stats = parse_collectives(hlo)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.bytes_by_kind["all-gather"] == 16 * 1024 * 2
    assert stats.bytes_by_kind["all-reduce"] == 8 * 8 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 2 * 4 * 4 * 4
    assert stats.count_by_kind["collective-permute"] == 1  # start only


def test_shape_bytes():
    assert shape_bytes("bf16", "4,4") == 32
    assert shape_bytes("f32", "") == 4
