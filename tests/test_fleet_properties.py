"""Hypothesis property tests for the vectorized fleet core: over randomized
cost grids, arrival processes, routers, fleet sizes, and KV capacities, the
batched engine (`repro.serve.fleetbatch`) must reproduce the per-instance
heap oracle bit for bit — request timings, step logs, and scale events.

Fixed-seed deterministic variants of the same invariant run without
hypothesis in tests/test_fleet_batch.py.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.sweep import CostGrid
from repro.ft.elastic import QueueDepthAutoscaler
from repro.serve.fleet import FleetSim
from repro.serve.sim import ArrivalSpec, LengthDist
from test_fleet_batch import assert_same_result


@st.composite
def fleet_case(draw):
    n_batches = draw(st.integers(min_value=1, max_value=3))
    batches = tuple(2 ** k for k in range(n_batches))
    base = draw(st.floats(min_value=1e-4, max_value=5e-3))
    tab = np.asarray([[base * (1 + 0.1 * bi + 0.05 * j) for j in range(3)]
                      for bi in range(n_batches)])
    grid = CostGrid("prop", batches, (16.0, 128.0, float("inf")), tab,
                    prefill_s_per_token=draw(st.sampled_from([0.0, 1e-4])))

    kind = draw(st.sampled_from(["poisson", "bursty"]))
    spec_kw = {}
    if kind == "bursty":
        spec_kw = dict(burst_factor=draw(st.floats(min_value=1.5,
                                                   max_value=6.0)),
                       burst_fraction=0.3, period_s=0.2)
    spec = ArrivalSpec(
        kind, rate=draw(st.floats(min_value=50.0, max_value=1500.0)),
        n_requests=draw(st.integers(min_value=1, max_value=200)),
        prompt=LengthDist("uniform", low=1, high=40),
        output=LengthDist("uniform", low=1,
                          high=draw(st.integers(min_value=1, max_value=12))),
        **spec_kw)

    kw = dict(
        n_instances=draw(st.integers(min_value=1, max_value=5)),
        router=draw(st.sampled_from(["least_loaded", "round_robin"])),
        max_batch=batches[-1],
        kv_capacity_tokens=draw(st.sampled_from([64.0, 400.0,
                                                 float("inf")])),
    )
    return grid, kw, spec, draw(st.integers(min_value=0, max_value=2**31 - 1))


@settings(max_examples=30, deadline=None)
@given(case=fleet_case())
def test_batched_matches_oracle(case):
    grid, kw, spec, seed = case
    rb = FleetSim(grid, **kw).run(spec, seed=seed)
    ro = FleetSim(grid, **kw).run(spec, seed=seed, batched=False)
    assert_same_result(rb, ro)


@settings(max_examples=15, deadline=None)
@given(rate=st.floats(min_value=100.0, max_value=2000.0),
       n0=st.integers(min_value=1, max_value=6),
       interval=st.sampled_from([0.02, 0.05, 0.2]),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_batched_matches_oracle_autoscaled(rate, n0, interval, seed):
    spec = ArrivalSpec("as", rate, 300, prompt=LengthDist("fixed", 8),
                       output=LengthDist("uniform", low=1, high=6))
    tab = np.full((2, 3), 1e-3)
    grid = CostGrid("as", (1, 4), (16.0, 128.0, float("inf")), tab)

    def sim():
        return FleetSim(grid, n0, max_batch=4, kv_capacity_tokens=4096.0,
                        autoscaler=QueueDepthAutoscaler(min_instances=1,
                                                        max_instances=8),
                        autoscale_interval_s=interval)

    assert_same_result(sim().run(spec, seed=seed),
                       sim().run(spec, seed=seed, batched=False))
