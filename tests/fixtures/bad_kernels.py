"""Deliberately-broken Pallas kernels: one per analyzer rule.

Each wrapper below violates exactly ONE of R1-R5 (and nothing else), so
``tests/test_check.py`` can assert the rule engine fires precisely its
intended finding per fixture. These kernels are only ever abstract-traced
(``repro.check.facts.trace_kernel``) — they never run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _misaligned_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[:100, :100] * 2.0


def bad_tile(x):
    """R1: (100, 100) output blocks — neither lane (128) nor sublane (8 for
    f32) aligned, and not covering the full array dim. The input stays a
    full-array (aligned-by-exemption) block so only the output trips."""
    return pl.pallas_call(
        _misaligned_kernel,
        grid=(3, 3),
        in_specs=[pl.BlockSpec((256, 256), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((100, 100), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((256, 256), jnp.float32),
    )(x)


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_index_map(x):
    """R2: the output index_map places block (i+1, j) — grid step i=1 lands
    outside cdiv(256, 128) = 2 blocks (and block row 0 is never written,
    but OOB placements suppress the coverage check so exactly one finding
    fires)."""
    return pl.pallas_call(
        _copy_kernel,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((128, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((128, 128), lambda i, j: (i + 1, j)),
        out_shape=jax.ShapeDtypeStruct((256, 256), jnp.float32),
    )(x)


def _unguarded_kernel(x_ref, o_ref):
    # Race: this store runs on EVERY grid step, but the output block only
    # changes with the outer axis — the revisited block needs the guarded
    # init/accumulate idiom (pl.when + scratch).
    o_ref[...] = x_ref[...] * 2.0


def bad_write_hazard(x):
    """R3: output block (t, 0) is revisited across all 4 inner grid steps
    with an unguarded store on each."""
    return pl.pallas_call(
        _unguarded_kernel,
        grid=(2, 4),
        in_specs=[pl.BlockSpec((128, 128), lambda t, f: (t, f))],
        out_specs=pl.BlockSpec((128, 128), lambda t, f: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((256, 128), jnp.float32),
    )(x)


def _bf16_dot_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...])


def bad_accumulator(x, w):
    """R4: a bf16 x bf16 matmul with no preferred_element_type accumulates
    in bf16. Full-array blocks and a single grid step keep R1/R3 quiet."""
    return pl.pallas_call(
        _bf16_dot_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((128, 256), lambda i: (0, 0)),
            pl.BlockSpec((256, 128), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((128, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((128, 128), jnp.bfloat16),
    )(x, w)


def _big_scratch_kernel(x_ref, o_ref, scr):
    scr[:256, :256] = x_ref[...]
    o_ref[...] = scr[:256, :256]


def bad_footprint(x):
    """R5: a (8192, 8192) f32 VMEM scratch is 256MB — double the per-core
    VMEM budget on its own."""
    return pl.pallas_call(
        _big_scratch_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((256, 256), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((256, 256), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((256, 256), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8192, 8192), jnp.float32)],
    )(x)


# rule -> (wrapper, input ShapeDtypeStructs)
FIXTURES = {
    "R1": (bad_tile,
           (jax.ShapeDtypeStruct((256, 256), jnp.float32),)),
    "R2": (bad_index_map,
           (jax.ShapeDtypeStruct((256, 256), jnp.float32),)),
    "R3": (bad_write_hazard,
           (jax.ShapeDtypeStruct((256, 512), jnp.float32),)),
    "R4": (bad_accumulator,
           (jax.ShapeDtypeStruct((128, 256), jnp.bfloat16),
            jax.ShapeDtypeStruct((256, 128), jnp.bfloat16))),
    "R5": (bad_footprint,
           (jax.ShapeDtypeStruct((256, 256), jnp.float32),)),
}
