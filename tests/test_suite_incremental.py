"""Incremental suite builds: append/invalidate parity and the fast flatten.

The PR-10 contract, asserted field for field:

* appending scenarios one at a time — in any order — produces a
  `SuiteAnalysis` bit-identical to the cold full build over the same list
  (static vectors, every cached traffic plane, l2 touch, totals, the full
  time model, component matrices, attribution grids);
* `invalidate` gathers cached planes down to the survivors, equal to a
  cold build of the survivors;
* the array-based `_flatten_trace` (closed-form dense ids + birth-only
  recycler) equals the dict-based `_reference_flatten` oracle exactly;
* the bounded stream LRU exposes accurate hit/miss/eviction counters.

A hypothesis program over random append/evict sequences rides along,
importorskip-guarded like the other property suites.
"""
import numpy as np
import pytest

from repro.core import copa
from repro.core import sweep as sweep_mod
from repro.core.cachesim import (
    _flatten_trace,
    _reference_flatten,
    build_streams,
    set_stream_cache_limit,
    stream_cache_clear,
    stream_cache_stats,
)
from repro.core.hw import MB
from repro.core.sweep import (
    SuiteAnalysis,
    _as_spec,
    kv_sweep_times,
    suite_analysis_for,
    suite_append,
    suite_invalidate,
)
from repro.workloads import registry
from test_suite_batch import _random_suite

CAPS = [float(c) * MB for c in (7, 60, 960)] + [float(1 << 50)]
SPECS = [_as_spec(c) for c in copa.TABLE_V[:3]]


def _snapshot(suite):
    """Every externally observable plane of a SuiteAnalysis, materialized.
    The model evaluations run FIRST so `_levels_cat` holds every capacity
    they materialize before the planes are copied."""
    suite.prefetch(CAPS)
    time = suite.time_batch(SPECS)
    components = suite.component_batch(SPECS)
    attribution = suite.attribution_grid(SPECS)
    return {
        "flops": suite.flops.copy(),
        "parallelism": suite.parallelism.copy(),
        "is_tc": suite.is_tc.copy(),
        "l2_touch": suite.l2_touch.copy(),
        "levels": {c: (f.copy(), w.copy())
                   for c, (f, w) in suite._levels_cat.items()},
        "totals": {c: suite.totals_below(c).copy() for c in CAPS},
        "time": time,
        "components": components,
        "attribution": attribution,
        "op_slices": [suite.op_slice(i) for i in range(suite.n_traces)],
    }


def _assert_identical(a, b):
    assert a["op_slices"] == b["op_slices"]
    for k in ("flops", "parallelism", "is_tc", "l2_touch", "time",
              "components"):
        assert a[k].dtype == b[k].dtype, k
        assert np.array_equal(a[k], b[k]), k
    assert a["levels"].keys() == b["levels"].keys()
    for c in a["levels"]:
        for u, v in zip(a["levels"][c], b["levels"][c]):
            assert np.array_equal(u, v), ("levels", c)
    for c in a["totals"]:
        assert np.array_equal(a["totals"][c], b["totals"][c]), ("totals", c)
    for ra, rb in zip(a["attribution"], b["attribution"]):
        assert ra == rb


def _fresh_suite(traces, **kw):
    """A SuiteAnalysis over private TraceAnalysis objects: cleared stream
    cache so member analyses share nothing with other suites in the test."""
    stream_cache_clear()
    return SuiteAnalysis(traces, **kw)


@pytest.fixture
def suite_traces():
    rng = np.random.default_rng(42)
    return _random_suite(rng, 8, max_ops=60)


# --- flatten parity -----------------------------------------------------------

def test_flatten_matches_reference_oracle():
    """Array flatten == dict oracle: exact arrays, dtypes, scalar fields."""
    rng = np.random.default_rng(5)
    traces = _random_suite(rng, 10, max_ops=70)
    traces += [registry.scenario(n) for n in registry.scenarios()[:20]]
    for tr in traces:
        for cyclic in (True, False):
            for reuse in (True, False):
                got = _flatten_trace(tr, cyclic, reuse)
                want = _reference_flatten(tr, cyclic, reuse)
                assert got[4] == want[4] and got[5] == want[5], tr.name
                for g, w in zip(got[:4], want[:4]):
                    assert g.dtype == w.dtype, tr.name
                    assert np.array_equal(g, w), tr.name


def test_flatten_falls_back_on_buf_named_tensors():
    """A real tensor named like a recycled buffer would collide with the
    closed-form id scheme — such traces must take the oracle path."""
    tr = registry.scenario(registry.scenarios()[0])
    from repro.core.trace import Trace
    weird = Trace("weird")
    weird.emit("k", 1e6, reads=[("__buf0.x", 8 * MB)],
               writes=[("y", 4 * MB)])
    assert weird.touch_table().has_buf_names
    assert not tr.touch_table().has_buf_names
    got = _flatten_trace(weird, True, True)
    want = _reference_flatten(weird, True, True)
    for g, w in zip(got[:4], want[:4]):
        assert np.array_equal(g, w)


# --- append / invalidate parity ----------------------------------------------

def test_append_one_at_a_time_matches_cold_build(suite_traces):
    cold = _fresh_suite(suite_traces)
    want = _snapshot(cold)
    for order in (range(len(suite_traces)),
                  reversed(range(len(suite_traces))),
                  (3, 0, 6, 1, 7, 2, 5, 4)):
        order = list(order)
        inc = _fresh_suite([suite_traces[order[0]]])
        # Warm every cache class early so appends must extend them all.
        inc.prefetch(CAPS)
        inc.time_batch(SPECS)
        _ = inc.l2_touch
        for i in order[1:]:
            inc.append([suite_traces[i]])
        got = _snapshot(inc)
        # Compare trace-by-trace: append order permutes rows/slices.
        for dst, src in enumerate(order):
            sl_c, sl_i = want["op_slices"][src], got["op_slices"][dst]
            for k in ("flops", "parallelism", "is_tc", "l2_touch"):
                assert np.array_equal(want[k][sl_c], got[k][sl_i]), (k, src)
            for c in want["levels"]:
                for u, v in zip(want["levels"][c], got["levels"][c]):
                    assert np.array_equal(u[sl_c], v[sl_i]), ("lv", c, src)
            assert np.array_equal(want["time"][:, src], got["time"][:, dst])
            assert np.array_equal(want["components"][:, :, sl_c],
                                  got["components"][:, :, sl_i])
            assert want["attribution"][src] == got["attribution"][dst]
        for c in want["totals"]:
            assert np.array_equal(want["totals"][c][order], got["totals"][c])

    # In-order incremental build is bit-identical INCLUDING layout.
    inc = _fresh_suite(suite_traces[:1])
    inc.prefetch(CAPS)
    inc.time_batch(SPECS)
    for t in suite_traces[1:]:
        inc.append([t])
    _assert_identical(want, _snapshot(inc))


def test_invalidate_matches_cold_build_of_survivors(suite_traces):
    inc = _fresh_suite(suite_traces)
    _snapshot(inc)  # warm every plane first
    drop = [suite_traces[1], suite_traces[4], suite_traces[6]]
    inc.invalidate(drop)
    survivors = [t for t in suite_traces if t not in drop]
    assert [id(t) for t in inc.traces] == [id(t) for t in survivors]
    cold = _fresh_suite(survivors)
    _assert_identical(_snapshot(cold), _snapshot(inc))
    # Unknown traces are a no-op.
    inc.invalidate(drop)
    assert inc.n_traces == len(survivors)


def test_interleaved_append_invalidate(suite_traces):
    inc = _fresh_suite(suite_traces[:4])
    _snapshot(inc)
    inc.invalidate([suite_traces[0], suite_traces[2]])
    inc.append(suite_traces[4:7])
    inc.invalidate(suite_traces[5])
    inc.append([suite_traces[0]])
    final = [suite_traces[1], suite_traces[3], suite_traces[4],
             suite_traces[6], suite_traces[0]]
    assert [id(t) for t in inc.traces] == [id(t) for t in final]
    cold = _fresh_suite(final)
    _assert_identical(_snapshot(cold), _snapshot(inc))


def test_appended_rows_inherit_capacity_union(suite_traces):
    """The session planner: capacities computed before an append must be
    present for the appended rows without any further prefetch call."""
    inc = _fresh_suite(suite_traces[:3])
    inc.prefetch(CAPS)
    inc.append(suite_traces[3:5])
    for c in CAPS:
        assert c in inc._levels_cat
        assert len(inc._levels_cat[c][0]) == inc.batch.n_ops_total
        for ta in inc.analyses[3:]:
            assert c in ta._levels  # installed into the member cache too


def test_suite_append_rekeys_memo_layer(suite_traces):
    sweep_mod._SUITES.clear()
    base = suite_analysis_for(suite_traces[:5])
    grown = suite_append(base, suite_traces[5:])
    assert grown is base and base.n_traces == len(suite_traces)
    # The grown membership now HITS; the old membership misses (rebuild).
    assert suite_analysis_for(suite_traces) is base
    assert suite_analysis_for(suite_traces[:5]) is not base
    # Appending traces already in the suite is a no-op.
    assert suite_append(base, suite_traces[:2]).n_traces == len(suite_traces)
    shrunk = suite_invalidate(base, suite_traces[0])
    assert shrunk is base
    assert suite_analysis_for(suite_traces[1:]) is base


# --- stream cache bounds ------------------------------------------------------

def test_stream_cache_counters_and_bounds(suite_traces):
    stream_cache_clear()
    try:
        build_streams(suite_traces)
        s = stream_cache_stats()
        assert s["misses"] == len(suite_traces) and s["hits"] == 0
        assert s["entries"] == len(suite_traces) and s["bytes"] > 0
        build_streams(suite_traces)
        s = stream_cache_stats()
        assert s["hits"] == len(suite_traces)
        assert s["misses"] == len(suite_traces)  # unchanged
        set_stream_cache_limit(max_entries=3)
        s = stream_cache_stats()
        assert s["entries"] == 3
        assert s["evictions"] == len(suite_traces) - 3
        # Byte budget: one entry's worth keeps only the newest streams.
        set_stream_cache_limit(max_bytes=0)
        assert stream_cache_stats()["entries"] == 0
    finally:
        set_stream_cache_limit(max_entries=512, max_bytes=256 * 1024 * 1024)
        stream_cache_clear()


# --- kv session ---------------------------------------------------------------

def test_kv_session_grows_not_rebuilds():
    sweep_mod._KV_SESSION.clear()
    sweep_mod._KV_SUITE = None
    sizes = [64 * MB, 256 * MB]
    first = kv_sweep_times(SPECS, sizes)
    suite = sweep_mod._KV_SUITE
    assert suite is not None and suite.n_traces == 2
    again = kv_sweep_times(SPECS, sizes + [512 * MB])
    assert sweep_mod._KV_SUITE is suite and suite.n_traces == 3
    # Old sizes reprice bit-identically from the grown session.
    assert np.array_equal(again[:2], first)
    # Parity with a standalone one-trace suite for the new size.
    solo = SuiteAnalysis([sweep_mod._kv_sweep_trace(int(512 * MB))])
    want = solo.time_batch(SPECS, ideal_occupancy=True)[:, 0]
    assert np.array_equal(again[2], want)


# --- hypothesis program -------------------------------------------------------

def test_random_append_evict_program():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           data=st.data())
    def run(seed, data):
        rng = np.random.default_rng(seed)
        pool = _random_suite(rng, 6, max_ops=40)
        live = list(pool[:2])
        suite = _fresh_suite(live)
        suite.prefetch(CAPS[:2])
        n_steps = data.draw(st.integers(min_value=1, max_value=6))
        for _ in range(n_steps):
            absent = [t for t in pool if t not in live]
            if absent and (not live or data.draw(st.booleans())):
                t = absent[data.draw(
                    st.integers(min_value=0, max_value=len(absent) - 1))]
                suite.append([t])
                live.append(t)
            elif live:
                t = live.pop(data.draw(
                    st.integers(min_value=0, max_value=len(live) - 1)))
                suite.invalidate(t)
        cold = _fresh_suite(live)
        _assert_identical(_snapshot(cold), _snapshot(suite))

    run()
