"""Paged KV residency: allocator units + engine parity + policy behavior.

The PR's contract, pinned field-for-field: with oversubscription disabled
and ``page_size=1`` the paged path reproduces the reservation path's
request timings BIT-FOR-BIT in both the heap oracle and the batched fleet
loop; every paged/policy mode is itself bit-identical batched-vs-oracle.
Randomized versions of the allocator invariants live in
tests/test_paged_properties.py (hypothesis-gated).
"""
import numpy as np
import pytest

from repro.core import copa, msm
from repro.core.sweep import CostGrid, serve_cost_grids
from repro.serve.fleet import FleetSim
from repro.serve.paged import (
    PagedKv,
    PagedKvSpec,
    ReservedKv,
    SchedPolicy,
    make_allocator,
    pages_for,
)
from repro.serve.sim import ArrivalSpec, LengthDist, Request, simulate

INF = float("inf")


def ramp_grid(batches=(1, 2, 4, 8, 64), prefill=1e-5):
    edges = (64.0, 512.0, 4096.0, INF)
    tab = np.asarray([[1e-3 + 5e-5 * b + 2e-6 * j for j in range(len(edges))]
                      for b in batches])
    return CostGrid("ramp", tuple(batches), edges, tab,
                    prefill_s_per_token=prefill)


def heavy_spec(rate=900.0, n=400):
    return ArrivalSpec("paged", rate, n,
                       prompt=LengthDist("lognormal", mean=400, floor=8),
                       output=LengthDist("uniform", low=100, high=300))


def assert_same_result(a, b, *, skip_pages=False):
    ab, bb = a.batch, b.batch
    for col in ("rid", "t_arrival", "prompt_tokens", "output_tokens",
                "t_admitted", "t_first_token", "t_done", "tokens_emitted",
                "evictions"):
        x, y = getattr(ab, col), getattr(bb, col)
        assert np.array_equal(x, y, equal_nan=(x.dtype.kind == "f")), \
            f"batch col {col} differs"
    assert len(a.step_logs) == len(b.step_logs)
    cols = ["t_start", "t_end", "batch", "queued", "admitted"]
    if not skip_pages:
        cols += ["kv_reserved", "pages"]
    for k, (la, lb) in enumerate(zip(a.step_logs, b.step_logs)):
        for col in cols:
            assert np.array_equal(getattr(la, col), getattr(lb, col)), \
                f"step log {k} col {col} differs"
    assert a.n_instances_final == b.n_instances_final
    assert a.scale_events == b.scale_events


# -- allocator units -----------------------------------------------------------

def test_paged_spec_validation():
    with pytest.raises(ValueError):
        PagedKvSpec(page_size=0)
    with pytest.raises(ValueError):
        PagedKvSpec(oversubscription=0.0)
    with pytest.raises(ValueError):
        PagedKvSpec(eviction="mru")
    with pytest.raises(ValueError):
        PagedKvSpec(oversubscription=1.5)   # > 1 needs an eviction policy
    PagedKvSpec(oversubscription=1.5, eviction="lru")
    with pytest.raises(ValueError):
        SchedPolicy(prefill_chunk=0)
    assert SchedPolicy().is_default
    assert not SchedPolicy(prefill_chunk=64).is_default


def test_pages_for():
    assert pages_for(0, 16) == 0
    assert pages_for(1, 16) == 1
    assert pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2


def test_paged_allocator_ledgers():
    a = PagedKv(160.0, PagedKvSpec(page_size=16, oversubscription=2.0,
                                   eviction="lru"))
    assert a.capacity_pages == 10 and a.commit_budget == 20.0
    assert a.fits(160) and not a.fits(161)
    a.admit(0, 100)                       # peak 7 pages committed
    assert a.committed_pages == 7 and a.pages_mapped == 0
    with pytest.raises(RuntimeError):
        a.admit(0, 100)                   # double admit
    a.ensure(0, 3)
    assert a.page_table[0] == [0, 1, 2]   # deterministic ascending ids
    a.ensure(0, 2)                        # never shrinks
    assert a.pages_mapped == 3
    a.admit(1, 160)
    assert a.can_admit(48) and not a.can_admit(49)   # commit bound: 20 pages
    a.ensure(1, 7)
    assert a.pages_mapped == 10 and a.page_table[1] == [3, 4, 5, 6, 7, 8, 9]
    with pytest.raises(RuntimeError):
        a.ensure(0, 4)                    # physical pool exhausted
    a.release(0)
    assert a.pages_mapped == 7 and a.committed_pages == 10
    a.admit(2, 48)
    a.ensure(2, 3)                        # freed pages recycled ascending
    assert a.page_table[2] == [0, 1, 2]
    a.release(1), a.release(2)
    assert a.pages_mapped == 0 and a.committed_pages == 0
    assert sorted(a._free) == list(range(10))


def test_reserved_allocator_is_the_oracle():
    r = make_allocator(100.0, None)
    assert isinstance(r, ReservedKv) and r.page_size is None
    assert isinstance(make_allocator(100.0, PagedKvSpec()), PagedKv)
    r.admit(0, 60)
    assert r.can_admit(40) and not r.can_admit(41)
    assert r.committed_tokens == 60.0 and r.pages_mapped == 0
    r.release(0, 60)
    assert r.committed_tokens == 0.0


def test_infinite_capacity_paged():
    a = PagedKv(INF, PagedKvSpec(page_size=8))
    assert a.fits(10**9) and a.can_admit(10**9)
    a.admit(0, 100)
    a.ensure(0, 5)
    assert a.page_table[0] == [0, 1, 2, 3, 4] and a.pages_mapped == 5
    a.release(0)
    assert a.pages_mapped == 0


# -- the parity contract -------------------------------------------------------

def test_oracle_paged_p1_bit_identical_to_reservation():
    reqs = heavy_spec(rate=500.0, n=250).generate(3)
    cost = ramp_grid()
    r0 = simulate([r for r in reqs], cost, kv_capacity_tokens=6000.0)
    r1 = simulate([r for r in reqs], cost, kv_capacity_tokens=6000.0,
                  paged=PagedKvSpec(page_size=1))
    for a, b in zip(r0.requests, r1.requests):
        assert a.t_admitted == b.t_admitted
        assert a.t_first_token == b.t_first_token
        assert a.t_done == b.t_done
        assert a.tokens_emitted == b.tokens_emitted and b.evictions == 0
    l0, l1 = r0.step_log, r1.step_log
    for col in ("t_start", "t_end", "batch", "kv_reserved", "queued",
                "admitted"):
        assert np.array_equal(getattr(l0, col), getattr(l1, col)), col
    # P=1 mapped pages ARE the reservation path's resident-KV sum
    assert l1.pages.sum() > 0


@pytest.mark.parametrize("router", ["least_loaded", "round_robin"])
def test_fleet_paged_p1_bit_identical_to_reservation(router):
    spec = heavy_spec()
    kw = dict(n_instances=3, router=router, kv_capacity_tokens=8000.0)
    rres = FleetSim(ramp_grid(), **kw).run(spec, seed=0)
    rpag = FleetSim(ramp_grid(), paged=PagedKvSpec(page_size=1), **kw).run(
        spec, seed=0)
    rpag_o = FleetSim(ramp_grid(), paged=PagedKvSpec(page_size=1), **kw).run(
        spec, seed=0, batched=False)
    # paged batched == paged oracle, including the pages column
    assert_same_result(rpag, rpag_o)
    # paged == reservation on every shared field (pages differ by design:
    # reservation logs 0, P=1 logs the resident sum)
    assert_same_result(rpag, rres, skip_pages=True)
    for lp, lr in zip(rpag.step_logs, rres.step_logs):
        assert np.array_equal(lp.kv_reserved, lr.kv_reserved)


@pytest.mark.parametrize("page_size", [4, 16, 64])
def test_fleet_paged_batched_matches_oracle(page_size):
    spec = heavy_spec()
    kw = dict(n_instances=3, kv_capacity_tokens=9000.0,
              paged=PagedKvSpec(page_size=page_size))
    rb = FleetSim(ramp_grid(), **kw).run(spec, seed=0)
    ro = FleetSim(ramp_grid(), **kw).run(spec, seed=0, batched=False)
    assert_same_result(rb, ro)
    assert max(lg.pages.max() for lg in rb.step_logs) > 0


@pytest.mark.parametrize("sched", [
    SchedPolicy(prefill_chunk=48),
    SchedPolicy(decode_priority=True),
    SchedPolicy(prefill_chunk=48, decode_priority=True),
])
def test_fleet_policy_variants_batched_matches_oracle(sched):
    spec = heavy_spec(rate=600.0, n=300)
    for paged in (None, PagedKvSpec(page_size=16)):
        kw = dict(n_instances=2, kv_capacity_tokens=9000.0, paged=paged,
                  sched=sched)
        rb = FleetSim(ramp_grid(), **kw).run(spec, seed=1)
        ro = FleetSim(ramp_grid(), **kw).run(spec, seed=1, batched=False)
        assert_same_result(rb, ro)


def test_fleet_oversubscription_eviction_batched_matches_oracle():
    spec = heavy_spec()
    kw = dict(n_instances=2, kv_capacity_tokens=12_000.0,
              paged=PagedKvSpec(page_size=16, oversubscription=1.5,
                                eviction="lru"))
    rb = FleetSim(ramp_grid(), **kw).run(spec, seed=0)
    ro = FleetSim(ramp_grid(), **kw).run(spec, seed=0, batched=False)
    assert_same_result(rb, ro)
    # pressure actually evicted, yet every request completed in full
    assert rb.batch.evictions.sum() > 0
    assert np.array_equal(rb.batch.tokens_emitted, rb.batch.output_tokens)
    # physical page bound respected at every logged step
    cap_pages = int(12_000 // 16)
    for lg in rb.step_logs:
        assert (lg.pages <= cap_pages).all()


def test_oversubscription_admits_more_than_physical():
    # one instance, commit budget 2x physical: committed KV in the step log
    # exceeds what full reservation could ever hold
    spec = heavy_spec(rate=2000.0, n=200)
    kw = dict(n_instances=1, kv_capacity_tokens=8_000.0)
    pg = PagedKvSpec(page_size=16, oversubscription=2.0, eviction="lru")
    r = FleetSim(ramp_grid(), paged=pg, **kw).run(spec, seed=0)
    assert max(lg.kv_reserved.max() for lg in r.step_logs) > 8_000.0


# -- scheduling policy behavior ------------------------------------------------

def test_chunked_prefill_closed_form():
    # one request, prompt 100, chunk 30: tokens stream out only after the
    # 4th iteration consumes the final 10-token chunk (prefill priced per
    # chunk, decode steps follow)
    cost = ramp_grid(prefill=1e-4)
    req = [Request(rid=0, t_arrival=0.0, prompt_tokens=100, output_tokens=3)]
    res = simulate(req, cost, sched=SchedPolicy(prefill_chunk=30))
    lg = res.step_log
    # 4 prefill iterations (30/30/30/10; the last also emits) + 2 decodes
    assert len(lg.t_start) == 6
    r = res.requests[0]
    chunks = [30, 30, 30, 10]
    t = 0.0
    kv_read = 0
    for c in chunks:
        t += cost.step_time(1, kv_read + c) + c * 1e-4
        kv_read += c
    assert r.t_first_token == pytest.approx(t)
    # unchunked run gets its first token in ONE (more expensive) iteration
    res1 = simulate([Request(rid=0, t_arrival=0.0, prompt_tokens=100,
                             output_tokens=3)], cost)
    assert len(res1.step_log.t_start) == 3
    assert res1.requests[0].t_first_token == pytest.approx(
        cost.step_time(1, 100) + 100 * 1e-4)


def test_decode_priority_admission_pattern():
    spec = heavy_spec(rate=1500.0, n=200)
    r = FleetSim(ramp_grid(), n_instances=1, kv_capacity_tokens=20_000.0,
                 sched=SchedPolicy(decode_priority=True)).run(spec, seed=0)
    lg = r.step_logs[0]
    # >1 admissions only when the batch was empty before the step (the batch
    # IS the admitted set); a non-empty batch takes at most one newcomer
    multi = lg.admitted > 1
    assert np.array_equal(lg.batch[multi], lg.admitted[multi])
    # default policy admits in bulk under the same pressure
    r0 = FleetSim(ramp_grid(), n_instances=1,
                  kv_capacity_tokens=20_000.0).run(spec, seed=0)
    lg0 = r0.step_logs[0]
    assert (lg0.admitted[lg0.batch > lg0.admitted] > 1).any()


def test_submit_rejects_never_admissible_paged():
    cost = ramp_grid()
    req = [Request(rid=0, t_arrival=0.0, prompt_tokens=500, output_tokens=4)]
    for batched in (True, False):
        with pytest.raises(ValueError, match="KV pages"):
            FleetSim(cost, 1, kv_capacity_tokens=100.0,
                     paged=PagedKvSpec(page_size=16)).run(
                         req, batched=batched)


# -- msm / sweep layers --------------------------------------------------------

def test_kv_token_capacity_derived_reserve():
    from repro.configs.base import ModelConfig

    base = copa.GPU_N_BASE.build()
    pol = msm.DECODE_MSM
    elems = 32768
    # fallback unchanged: no model config -> the historical 0.30
    assert msm.kv_reserve_frac(base) == 0.30
    c_fallback = msm.kv_token_capacity(base, pol, elems)
    assert c_fallback == int(0.7 * base.dram_capacity // (elems * 2))
    mc = ModelConfig(name="toy8b", family="dense", n_layers=32, d_model=4096,
                     n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=128256)
    rf = msm.kv_reserve_frac(base, mc)
    want = mc.n_params() * 2 / base.dram_capacity + 0.05
    assert rf == pytest.approx(want)
    assert msm.kv_token_capacity(base, pol, elems, model_config=mc) \
        == int((1.0 - rf) * base.dram_capacity // (elems * 2))
    # a model whose weights swamp DRAM cannot serve at all
    huge = ModelConfig(name="huge", family="dense", n_layers=400,
                       d_model=16384, n_heads=128, n_kv_heads=16,
                       d_ff=65536, vocab_size=128256)
    with pytest.raises(ValueError, match="no capacity left"):
        msm.kv_reserve_frac(base, huge)


def test_kv_compression_capacity_and_pages():
    base = copa.GPU_N_BASE.build()
    pol = msm.DECODE_MSM
    elems = 32768
    c = msm.kv_token_capacity(base, pol, elems)
    comp = msm.compose("msm_decode", kv_compression_ratio=2.0,
                       kv_compression_bw_tax=0.25)
    assert msm.kv_token_capacity(base, comp, elems) == 2 * c
    assert "kvcomp=2x" in comp.describe()
    assert msm.kv_page_capacity(base, pol, elems, 16) == c // 16
    with pytest.raises(ValueError):
        msm.kv_page_capacity(base, pol, elems, 0)
    with pytest.raises(ValueError):
        msm.compose("msm_decode", kv_compression_ratio=0.5)
    with pytest.raises(ValueError):
        msm.compose("msm_decode", kv_compression_bw_tax=-0.1)


def test_serve_cost_grids_page_buckets_and_bw_tax():
    configs = [copa.GPU_N_BASE, copa.HBML_L3]
    kvb = 2 * 1024 * 2.0     # bytes per resident KV token
    edges = (100.0, 1000.0, 10_000.0)
    plain = serve_cost_grids("gnmt", configs, tokens_per_pass=50,
                             kv_bytes_per_token=kvb, seq_edges=edges)
    paged = serve_cost_grids("gnmt", configs, tokens_per_pass=50,
                             kv_bytes_per_token=kvb, seq_edges=edges,
                             page_size=64)
    for g in paged.values():
        # edges snapped UP to page multiples: 100->128, 1000->1024, 10k->10048
        assert g.seq_edges == (128.0, 1024.0, 10_048.0)
        assert g.page_size == 64
    for g in plain.values():
        assert g.seq_edges == edges and g.page_size is None
    # compression bandwidth tax makes every KV-heavy bucket strictly slower
    comp = msm.compose("msm_decode", kv_compression_ratio=2.0,
                       kv_compression_bw_tax=0.25)
    taxed = serve_cost_grids("gnmt", configs, tokens_per_pass=50,
                             kv_bytes_per_token=kvb, seq_edges=edges,
                             kv_policy=comp)
    for name in plain:
        assert (taxed[name].step_time_s >= plain[name].step_time_s).all()
        assert (taxed[name].step_time_s[:, -1]
                > plain[name].step_time_s[:, -1]).all()
    # ratio-only compression (no tax) prices identically
    free = serve_cost_grids("gnmt", configs, tokens_per_pass=50,
                            kv_bytes_per_token=kvb, seq_edges=edges,
                            kv_policy=msm.compose(
                                "msm_decode", kv_compression_ratio=2.0))
    for name in plain:
        assert np.array_equal(free[name].step_time_s,
                              plain[name].step_time_s)


def test_diurnal_arrivals_registered_and_shaped():
    from repro.workloads import registry

    names = registry.arrival_names("arrivals.diurnal")
    assert len(names) >= 2
    for name in names:
        spec = registry.arrivals(name)
        reqs = spec.generate(0)
        ts = np.array([r.t_arrival for r in reqs])
        assert (np.diff(ts) > 0).all()
        # long-run mean rate preserved within sampling noise
        assert 0.8 * spec.rate <= len(ts) / ts[-1] <= 1.2 * spec.rate
        # peak-phase hours carry well over their uniform share
        prof = np.asarray(spec.profile)
        phase = np.mod(ts, spec.period_s) / spec.period_s
        idx = np.minimum((phase * len(prof)).astype(np.int64), len(prof) - 1)
        rel = np.asarray(spec.profile) / prof.mean()
        hi_share = (rel[idx] > 1.25).mean()
        hi_frac = (rel > 1.25).mean()
        assert hi_share > 1.2 * hi_frac
