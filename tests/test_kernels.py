"""Pallas kernel validation: shape/dtype sweeps against the jnp oracles,
interpret=True (the kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.fused_ffn import fused_ffn_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,s,h,kvh,d,causal,bq,bk", [
    (2, 256, 4, 2, 64, True, 128, 128),
    (1, 512, 8, 8, 64, True, 256, 128),
    (2, 256, 4, 1, 32, False, 128, 256),
    (1, 384, 4, 4, 128, True, 128, 128),
    (1, 256, 8, 2, 64, False, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(b, s, h, kvh, d, causal, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kvh, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kvh, d), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_kv=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("b,h,kvh,d,s,kv_len,bk", [
    (2, 8, 2, 64, 1024, 700, 256),
    (1, 4, 4, 128, 512, 512, 128),
    (4, 16, 2, 64, 2048, 1, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_kernel(b, h, kvh, d, s, kv_len, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kvh, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kvh, d), dtype)
    got = flash_decode_pallas(q, k, v, kv_len, block_kv=bk, interpret=True)
    want = ref.flash_decode_ref(q, k, v, kv_len)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("t,d,f,bt,bf", [
    (256, 128, 512, 128, 256),
    (512, 256, 1024, 256, 512),
    (128, 64, 256, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ffn_kernel(t, d, f, bt, bf, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = (jax.random.normal(ks[0], (t, d), dtype) * 0.5).astype(dtype)
    wg = (jax.random.normal(ks[1], (d, f), dtype) * 0.05).astype(dtype)
    wu = (jax.random.normal(ks[2], (d, f), dtype) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[3], (f, d), dtype) * 0.05).astype(dtype)
    got = fused_ffn_pallas(x, wg, wu, wd, block_t=bt, block_f=bf,
                           interpret=True)
    want = ref.fused_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32),
                               atol=5 * TOL[dtype], rtol=5 * TOL[dtype])


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 4, 32, 16, 64),
    (1, 128, 2, 64, 32, 32),
    (1, 512, 8, 16, 8, 128),
])
def test_ssd_scan_kernel(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    b_ = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.3
    c_ = jax.random.normal(ks[4], (b, s, n), jnp.float32) * 0.3
    got = ssd_scan_pallas(x, dt, A, b_, c_, chunk=chunk, interpret=True)
    want, _ = ref.ssd_chunk_ref(x, dt, A, b_, c_)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-3)


def test_ssd_jnp_chunked_matches_sequential():
    """The model-layer chunked SSD (lax.scan path used under pjit) agrees
    with the token-by-token recurrence for multiple chunk sizes."""
    from repro.models.ssm import ssd_chunked

    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    b, s, h, p, n = 2, 96, 4, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    b_ = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.3
    c_ = jax.random.normal(ks[4], (b, s, n), jnp.float32) * 0.3
    want, st_want = ref.ssd_chunk_ref(x, dt, A, b_, c_)
    for chunk in (16, 32, 96):
        got, st_got = ssd_chunked(x, dt, A, b_, c_, chunk=chunk)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(st_got, st_want, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("b,s,h,kvh,d,causal", [
    (1, 256, 4, 2, 32, True),
    (2, 128, 2, 2, 64, False),
])
def test_flash_attention_bwd_kernels(b, s, h, kvh, d, causal):
    """Pallas dq/dkv kernels vs autodiff of the naive oracle."""
    from repro.kernels.flash_attention_bwd import flash_attention_bwd_pallas
    from repro.models.attention import naive_attention

    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    dout = jax.random.normal(ks[3], (b, s, h, d), jnp.float32)

    # forward reference: out + lse
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None, None, None], sc, -1e30)
    lse = jax.nn.logsumexp(sc, axis=-1)            # (b,kvh,g,s)
    lse = lse.transpose(0, 3, 1, 2).reshape(b, s, h)
    out = naive_attention(q, k, v, causal=causal)

    dq, dk, dv = flash_attention_bwd_pallas(
        q, k, v, out, lse, dout, causal=causal, block_q=64, block_kv=64,
        interpret=True)

    def f(q, k, v):
        return (naive_attention(q, k, v, causal=causal) * dout).sum()

    dq_r, dk_r, dv_r = jax.grad(f, (0, 1, 2))(q, k, v)
    np.testing.assert_allclose(dq, dq_r, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(dk, dk_r, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(dv, dv_r, atol=2e-4, rtol=2e-4)
