"""Hypothesis property tests for suite-level batching: over randomized
traces, capacities, and padding amounts (mixed stream lengths inside one
StreamBatch), the batched scan must equal per-trace `traffic_below` /
`TraceAnalysis` bit for bit and track the per-touch reference oracle.

Fixed-seed deterministic variants of these invariants run without
hypothesis in tests/test_suite_batch.py.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import copa
from repro.core.cachesim import (
    StreamBatch,
    _reference_traffic_below,
    build_streams,
    traffic_below,
)
from repro.core.hw import MB
from repro.core.sweep import SuiteAnalysis, TraceAnalysis
from test_suite_batch import _random_suite


@st.composite
def trace_suite(draw):
    """A small suite of randomized traces with varying lengths (and hence
    varying padding amounts inside the StreamBatch)."""
    n_traces = draw(st.integers(min_value=1, max_value=6))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    max_ops = draw(st.sampled_from([4, 20, 60]))
    rng = np.random.default_rng(seed)
    return _random_suite(rng, n_traces, max_ops=max_ops)


@settings(max_examples=25, deadline=None)
@given(
    traces=trace_suite(),
    caps=st.lists(st.floats(min_value=0.5, max_value=2000.0),
                  min_size=1, max_size=5, unique=True),
)
def test_property_stream_batch_equals_per_trace(traces, caps):
    caps = [c * MB for c in caps]
    streams = build_streams(traces)
    batch = StreamBatch.pad(streams)
    got = batch.traffic_below(caps)
    for i, s in enumerate(streams):
        want = traffic_below(s, caps)
        ref = _reference_traffic_below(s, caps)
        for k in range(len(caps)):
            assert np.array_equal(got[i][k].fill, want[k].fill)
            assert np.array_equal(got[i][k].writeback, want[k].writeback)
            assert np.allclose(got[i][k].fill, ref[k].fill,
                               rtol=1e-9, atol=1e-3)
            assert np.allclose(got[i][k].writeback, ref[k].writeback,
                               rtol=1e-9, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(traces=trace_suite())
def test_property_suite_time_model_equals_per_trace(traces):
    suite = SuiteAnalysis(traces)
    specs = [copa.GPU_N_BASE.build(), copa.HBML_L3.build()]
    totals = suite.time_batch(specs)
    for i, t in enumerate(traces):
        ta = TraceAnalysis(t, stream=suite.analyses[i].stream)
        assert np.array_equal(totals[:, i], ta.time_batch(specs))
