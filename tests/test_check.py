"""repro.check: static analyzer facts, rules R1-R5, waivers, CLI, and the
kernel.* registry bridge (touch streams cross-checked against hlo_cost)."""
from __future__ import annotations

import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from fixtures.bad_kernels import FIXTURES
from repro.check import catalog, cli
from repro.check.facts import trace_kernel
from repro.check.rules import RULES, run_rules
from repro.core import copa
from repro.core.hlo_cost import analyze_hlo_cost
from repro.core.sweep import SweepEngine
from repro.kernels import ref
from repro.workloads import registry

S = jax.ShapeDtypeStruct


# --- facts extraction ---------------------------------------------------------

def test_facts_flash_attention_structure():
    facts, = catalog.trace_case("flash_attention.b2s512")
    assert facts.kernel == "_attn_kernel"
    assert facts.src_file.endswith("flash_attention.py")
    assert facts.grid == (8, 2, 2)
    assert [b.memory_space for b in facts.blocks] == ["vmem"] * 4
    # q block is refetched only when (bh, qi) changes; k/v every step
    q, k, v = facts.inputs
    assert int(q.fetch_mask().sum()) == 8 * 2
    assert int(k.fetch_mask().sum()) == facts.n_steps
    # the output store lives inside pl.when (the guarded finalize idiom)
    out, = facts.outputs
    assert (out.unguarded_stores, out.guarded_stores) == (0, 1)
    # both dots accumulate f32 with preferred_element_type set
    assert all(d.out_dtype == "float32" and
               d.preferred_element_type == "float32" for d in facts.dots)


def test_facts_flash_decode_smem_and_bwd_dual_grids():
    facts, = catalog.trace_case("flash_decode.b2s2048")
    assert facts.inputs[0].memory_space == "smem"     # the kv_len scalar
    assert facts.inputs[0].block_bytes == 4           # (1,) int32
    dq, dkv = catalog.trace_case("flash_attention_bwd.b2s512")
    assert dq.grid == (8, 2, 2) and dkv.grid == (8, 2, 2)
    # dq sweeps kv innermost, dkv sweeps q innermost: outputs revisit only
    # contiguously and every store is guarded (the R3 audit)
    for facts in (dq, dkv):
        for out in facts.outputs:
            assert out.unguarded_stores == 0
            assert out.guarded_stores >= 1


# --- rules on the deliberately-broken fixtures --------------------------------

@pytest.mark.parametrize("rule", list(FIXTURES))
def test_fixture_triggers_exactly_its_rule(rule):
    fn, avals = FIXTURES[rule]
    facts = trace_kernel(fn, *avals, case=f"fixture.{rule}")
    findings = run_rules(facts, waivers=False)
    assert [f.rule for f in findings] == [rule], \
        [f.format() for f in findings]
    assert findings[0].file.endswith("bad_kernels.py")
    assert findings[0].line > 0


def test_unknown_rule_rejected():
    fn, avals = FIXTURES["R1"]
    facts = trace_kernel(fn, *avals)
    with pytest.raises(ValueError, match="unknown rules"):
        run_rules(facts, rules=["R9"])


# --- the shipped kernels audit clean (the CI gate, as a test) -----------------

def test_shipped_kernels_have_no_unwaived_findings():
    findings = run_rules(catalog.trace_all())
    unwaived = [f for f in findings if not f.waived]
    assert unwaived == [], [f.format() for f in unwaived]


def test_ssd_row_slab_finding_is_waived_not_fixed():
    """The one real finding (ssd_scan's (1, chunk) dt slab vs R1) is
    covered by an inline '# check: waive[R1]' — present without waivers,
    marked waived with them."""
    facts = list(catalog.trace_case("ssd_scan.b2s1024"))
    raw = run_rules(facts, waivers=False)
    assert [f.rule for f in raw] == ["R1"]
    assert raw[0].file.endswith("ssd_scan.py")
    waived = run_rules(facts)
    assert len(waived) == 1 and waived[0].waived


# --- CLI ----------------------------------------------------------------------

def test_cli_exits_zero_on_shipped_kernels(capsys):
    assert cli.main([]) == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out and "1 waived" in out


def test_cli_json_rules_filter_and_waiver_toggle(capsys):
    assert cli.main(["--no-waivers", "--cases", "ssd_scan"]) == 1
    capsys.readouterr()
    assert cli.main(["--no-waivers", "--rules", "R3,R5"]) == 0
    capsys.readouterr()
    assert cli.main(["--no-waivers", "--json"]) == 1
    found = json.loads(capsys.readouterr().out)
    assert [f["rule"] for f in found] == ["R1"]
    assert found[0]["kernel"] == "_ssd_kernel"
    assert cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out


# --- kernel.* registry streams vs hlo_cost ------------------------------------

def _hlo(f, *avals):
    return analyze_hlo_cost(jax.jit(f).lower(*avals).compile().as_text())


_REF_CASES = {
    "kernel.flash_attention.b2s512": lambda: _hlo(
        functools.partial(ref.flash_attention_ref, causal=True),
        S((2, 512, 8, 128), jnp.bfloat16), S((2, 512, 4, 128), jnp.bfloat16),
        S((2, 512, 4, 128), jnp.bfloat16)),
    "kernel.flash_decode.b2s2048": lambda: _hlo(
        functools.partial(ref.flash_decode_ref, kv_len=2048),
        S((2, 8, 128), jnp.bfloat16), S((2, 2048, 4, 128), jnp.bfloat16),
        S((2, 2048, 4, 128), jnp.bfloat16)),
    "kernel.fused_ffn.t512d1024": lambda: _hlo(
        ref.fused_ffn_ref,
        S((512, 1024), jnp.bfloat16), S((1024, 2048), jnp.bfloat16),
        S((1024, 2048), jnp.bfloat16), S((2048, 1024), jnp.bfloat16)),
    "kernel.ssd_scan.b2s1024": lambda: _hlo(
        ref.ssd_chunk_ref,
        S((2, 1024, 4, 64), jnp.bfloat16), S((2, 1024, 4), jnp.bfloat16),
        S((4,), jnp.float32), S((2, 1024, 128), jnp.bfloat16),
        S((2, 1024, 128), jnp.bfloat16)),
}


@pytest.mark.parametrize("name", list(_REF_CASES))
def test_kernel_stream_matches_hlo_cost(name):
    """Byte/flop cross-check of the compiled touch streams against the
    reference computation's HLO cost: the stream's unique footprint is the
    kernel's exact HBM floor (the arrays it must move once), the HLO of
    the UNFUSED reference accesses strictly more (the traffic the kernel
    filters on package — the paper's Fig-4 reuse band), and dot flops
    agree exactly for the attention/FFN kernels."""
    tr = registry.scenario(name)
    cost = _REF_CASES[name]()
    case = catalog.get(name.removeprefix("kernel."))
    io_bytes = 0
    for facts in catalog.trace_case(case.name):
        io_bytes += sum(b.array_bytes for b in facts.blocks)
    assert tr.footprint_bytes() == io_bytes
    assert tr.footprint_bytes() <= tr.total_touch_bytes
    assert cost.bytes_accessed >= 2 * tr.footprint_bytes()
    if "ssd_scan" in name:
        # the chunked dual form trades flops for locality vs the
        # token-recurrence oracle (5x at these shapes)
        assert 1.0 <= tr.total_flops / cost.dot_flops <= 8.0
    else:
        assert tr.total_flops == pytest.approx(cost.dot_flops, rel=0.01)


def test_kernel_scenarios_sweep_through_suite_analysis():
    names = registry.match("kernel.*")
    assert len(names) >= 4
    specs = [copa.GPU_N_BASE.build(), copa.HBM_L3.build()]
    sa = registry.suite_analysis("kernel")
    times = sa.time_batch(specs)
    assert times.shape == (2, len(names))
    assert np.all(times > 0) and np.all(np.isfinite(times))
    grid = SweepEngine(["kernel.*"], configs=[copa.GPU_N_BASE,
                                             copa.HBM_L3]).run()
    assert len(grid.rows) == 2 * len(names)
    decode = grid.result("kernel.flash_decode.b2s2048", "GPU-N")
    assert decode.time_s > 0
