"""End-to-end behaviour tests for the whole system."""
import jax
import numpy as np


def test_train_then_serve_roundtrip(tmp_path):
    """Train a tiny model a few steps, checkpoint, restore, serve tokens."""
    from repro.launch.train import main as train_main
    from repro.checkpoint.ckpt import restore
    import repro.configs as C
    from repro.models import LanguageModel
    from repro.launch.serve import ServingEngine

    d = str(tmp_path / "ck")
    st = train_main(["--arch", "tinyllama-1.1b-smoke", "--steps", "6",
                     "--global-batch", "2", "--seq-len", "32",
                     "--ckpt-dir", d, "--save-every", "3",
                     "--log-every", "100"])
    assert st.step == 6
    _, tree, extra = restore(d)
    assert extra["step"] == 6

    cfg = C.get("tinyllama-1.1b-smoke")
    model = LanguageModel(cfg)
    engine = ServingEngine(model, tree["params"], batch=2, max_len=24)
    prompts = np.ones((2, 4), np.int32)
    toks = engine.generate(prompts, steps=4)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_training_reduces_loss_learnable_data():
    """On a learnable synthetic task (memorize a fixed batch), a few dozen
    steps must reduce loss materially."""
    import repro.configs as C
    from repro.models import LanguageModel
    from repro.train import OptimConfig, init_opt_state, make_train_step

    cfg = C.get("granite-3-2b").smoke()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    first = None
    for i in range(40):
        params, opt, metrics = step(params, opt, batch, jax.random.PRNGKey(i))
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert last < first - 1.0, (first, last)


def test_msm_policy_selection():
    """The software-MSM chooser composes per-domain policies (COPA SKUs)."""
    from repro.core import msm

    small = msm.recommend("train_4k", 1e9)
    big = msm.recommend("train_4k", 236e9)
    assert small.name == "msm_train" and big.name == "msm_train_large"
    assert big.optimizer_dtype == "bfloat16" and not big.master_weights
    assert msm.recommend("long_500k", 1e9).kv_shard_axis == "data"
    assert msm.recommend("decode_32k", 1e9).remat == "none"


def test_arch_traces_feed_copa_analysis():
    """Integration: assigned-arch traces run through the paper's machinery
    and the MSM analyzer quantifies on-chip filtering per cell."""
    from repro.core import hw, msm, perfmodel
    from repro.workloads.lm import arch_trace

    t = arch_trace("yi-6b", "decode_32k")
    r = perfmodel.PerfModel(t).run(hw.GPU_N)
    assert r.time_s > 0
    an = msm.analyze(t)
    caps = sorted(an.sweep)
    assert an.sweep[caps[0]] >= an.sweep[caps[-1]] - 1e-6  # monotone


def test_dryrun_cell_runnable_matrix():
    """The 40-cell grid: skips exactly the documented long_500k cells."""
    import repro.configs as C
    from repro.configs.base import cell_is_runnable

    skipped = []
    for arch, cfg in C.ARCHS.items():
        for shape in C.SHAPES.values():
            ok, reason = cell_is_runnable(cfg, shape)
            if not ok:
                skipped.append((arch, shape.name))
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == set(C.ARCHS) - {"mamba2-1.3b",
                                                      "zamba2-1.2b"}
