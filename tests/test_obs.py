"""Observability contract tests.

Three families: (1) the ObsConfig engine knob must be invisible — fleet
runs are bit-identical with it on or off, and the level-1 prefill column
is itself engine-parity (batched == oracle); (2) derivation correctness —
Chrome-trace schema/golden structure, exact-sum windowing against
aggregate SimMetrics, component attribution reproducing the sweep
engine's times bit for bit; (3) plumbing — store round-trip, CLI smoke,
the SimMetrics evictions column both engines now surface.
"""
import inspect
import json

import numpy as np
import pytest

from repro.core import copa
from repro.core.sweep import (
    LAUNCH_OVERHEAD_S,
    CostGrid,
    SweepEngine,
)
from repro.obs.attribution import explain_engine
from repro.obs.series import timeseries
from repro.obs.store import load_result, save_result
from repro.obs.timeline import (
    Timeline,
    chrome_trace,
    trace_events,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.serve.fleet import FleetSim
from repro.serve.paged import PagedKvSpec
from repro.serve.sim import (
    ArrivalSpec,
    LengthDist,
    ObsConfig,
    Request,
    SimMetrics,
    Slo,
    simulate,
)
from test_fleet_batch import assert_same_result, flat_grid, ramp_grid


def paged_grid():
    # big max_batch + KV-dependent step times: oversubscription pressure
    # actually fires the LRU evictor (the small fleet grids never would)
    batches = (1, 2, 4, 8, 64)
    edges = (64.0, 512.0, 4096.0, float("inf"))
    tab = np.asarray([[1e-3 + 5e-5 * b + 2e-6 * j for j in range(4)]
                      for b in batches])
    return CostGrid("obs-paged", batches, edges, tab,
                    prefill_s_per_token=1e-5)


def spec_poisson(n=300, rate=400.0):
    return ArrivalSpec("obs", rate, n,
                       prompt=LengthDist("uniform", low=4, high=32),
                       output=LengthDist("uniform", low=1, high=16))


def evicting_kw():
    return dict(n_instances=2, kv_capacity_tokens=12_000.0,
                paged=PagedKvSpec(page_size=16, oversubscription=1.5,
                                  eviction="lru"))


def evicting_spec():
    return ArrivalSpec("paged", 900.0, 400,
                       prompt=LengthDist("lognormal", mean=400, floor=8),
                       output=LengthDist("uniform", low=100, high=300))


def fleet_run(obs=None, spec=None, grid=None, **over):
    kw = dict(n_instances=3, max_batch=4, kv_capacity_tokens=2048.0)
    kw.update(over)
    return FleetSim(grid if grid is not None else ramp_grid(),
                    obs=obs, **kw).run(spec or spec_poisson(), seed=5)


# -- package surface -----------------------------------------------------------

def test_package_reexports_resolve_to_objects():
    # `explain` collides with its submodule name: from-import looks the name
    # up twice and the submodule import binds the MODULE over the package
    # attr between the two, unless __getattr__ pins the resolved object.
    import repro.obs as obs

    for name in obs.__all__:
        assert not inspect.ismodule(getattr(obs, name)), name
    assert callable(obs.explain)


# -- ObsConfig: the knob must not perturb the engines --------------------------

def test_obs_config_validates():
    assert ObsConfig().level == 0
    assert ObsConfig(level=1).step_phases
    assert not ObsConfig(level=0).step_phases
    with pytest.raises(ValueError):
        ObsConfig(level=2)


@pytest.mark.parametrize("paged", [False, True])
def test_obs_on_is_bit_identical_to_off(paged):
    kw = dict(evicting_kw(), grid=paged_grid()) if paged else {}
    spec = evicting_spec() if paged else None
    off = fleet_run(obs=None, spec=spec, **kw)
    on = fleet_run(obs=ObsConfig(level=1), spec=spec, **kw)
    assert_same_result(off, on)
    for sl in off.step_logs:
        assert sl.prefill_tokens is None
    for sl in on.step_logs:
        assert sl.prefill_tokens is not None


@pytest.mark.parametrize("paged", [False, True])
def test_obs_prefill_column_engine_parity(paged):
    grid = ramp_grid()
    kw = dict(n_instances=3, max_batch=4, kv_capacity_tokens=2048.0,
              obs=ObsConfig(level=1))
    spec = spec_poisson()
    if paged:
        grid = paged_grid()
        kw = dict(evicting_kw(), obs=ObsConfig(level=1))
        spec = evicting_spec()
    rb = FleetSim(grid, **kw).run(spec, seed=5)
    ro = FleetSim(grid, **kw).run(spec, seed=5, batched=False)
    assert_same_result(rb, ro)
    for la, lb in zip(rb.step_logs, ro.step_logs):
        assert np.array_equal(la.prefill_tokens, lb.prefill_tokens)
    # every admitted prompt token is consumed at least once across the run;
    # exactly once without eviction, more when KV recompute re-runs prefill
    total = sum(int(sl.prefill_tokens.sum()) for sl in rb.step_logs)
    prompts = int(rb.batch.prompt_tokens.sum())
    if paged:
        assert total >= prompts
    else:
        assert total == prompts


def test_obs_single_instance_prefill_column():
    reqs = [Request(rid=i, t_arrival=0.002 * i, prompt_tokens=10 + i,
                    output_tokens=3) for i in range(40)]
    r = simulate(reqs, flat_grid(), max_batch=4, obs=ObsConfig(level=1))
    r0 = simulate(reqs, flat_grid(), max_batch=4)
    assert r0.step_log.prefill_tokens is None
    assert int(r.step_log.prefill_tokens.sum()) \
        == sum(q.prompt_tokens for q in reqs)
    assert np.array_equal(r.step_log.t_end, r0.step_log.t_end)


# -- satellite: evictions surfaced through SimMetrics --------------------------

def test_metrics_evictions_fleet_both_engines():
    kw = evicting_kw()
    rb = FleetSim(paged_grid(), **kw).run(evicting_spec(), seed=0)
    ro = FleetSim(paged_grid(), **kw).run(evicting_spec(), seed=0,
                                          batched=False)
    for r in (rb, ro):
        m = r.metrics
        assert np.array_equal(m.evictions, r.batch.evictions)
        assert m.total_evictions == int(r.batch.evictions.sum()) > 0
        assert 0.0 < m.evicted_frac <= 1.0
        assert m.eviction_rate_rps > 0
    assert rb.metrics.total_evictions == ro.metrics.total_evictions


def test_metrics_evictions_single_instance():
    reqs = [Request(rid=i, t_arrival=0.0005 * i, prompt_tokens=200,
                    output_tokens=80) for i in range(60)]
    r = simulate(reqs, paged_grid(), kv_capacity_tokens=4096.0,
                 paged=PagedKvSpec(page_size=16, oversubscription=1.5,
                                   eviction="lru"))
    m = r.metrics
    assert np.array_equal(m.evictions,
                          np.array([q.evictions for q in r.requests]))
    assert m.total_evictions > 0


def test_metrics_evictions_default_zero():
    m = SimMetrics.from_arrays([0.0, 0.1], [0.2, 0.3], [0.4, 0.5], [3, 3])
    assert m.total_evictions == 0 and m.evicted_frac == 0.0


# -- timelines: schema + golden structure --------------------------------------

def test_chrome_trace_schema_and_structure():
    res = fleet_run(obs=ObsConfig(level=1))
    doc = chrome_trace(res)
    assert validate_chrome_trace(doc) == []
    ev = doc["traceEvents"]
    by_ph = {}
    for e in ev:
        by_ph.setdefault(e["ph"], []).append(e)
    # one X span per logged step, across every instance lane
    n_steps = sum(len(sl.t_start) for sl in res.step_logs)
    assert len(by_ph["X"]) == n_steps
    # nestable async request spans balance exactly
    assert len(by_ph["b"]) == len(by_ph["e"])
    # every request got a queue span and a prefill span
    names = [e["name"] for e in by_ph["b"]]
    assert names.count("queue") == len(res.batch)
    assert names.count("prefill") == len(res.batch)
    # counters are per-(pid,name) monotone — validator checked; spot-check
    # the fleet-size counter exists when scale events do, and kv occupancy
    # is always emitted per instance
    cnames = {(e["pid"], e["name"]) for e in by_ph["C"]}
    for i in range(len(res.step_logs)):
        assert (i + 1, "kv occupancy") in cnames
        assert (i + 1, "queue depth") in cnames
    # level-1 runs carry prefill_tokens on step spans
    assert any("prefill_tokens" in e.get("args", {}) for e in by_ph["X"])


def test_chrome_trace_eviction_marks():
    res = FleetSim(paged_grid(), **evicting_kw()).run(evicting_spec(),
                                                      seed=0)
    doc = chrome_trace(res)
    assert validate_chrome_trace(doc) == []
    marks = [e for e in doc["traceEvents"]
             if e["ph"] == "i" and e["name"] == "evicted"]
    assert len(marks) == int((res.batch.evictions > 0).sum()) > 0


def test_chrome_trace_max_requests():
    res = fleet_run()
    doc = chrome_trace(res, max_requests=10)
    assert validate_chrome_trace(doc) == []
    assert doc["otherData"]["n_requests"] == 10
    assert doc["otherData"]["dropped_requests"] == len(res.batch) - 10
    # instance lanes still cover the full run
    n_steps = sum(len(sl.t_start) for sl in res.step_logs)
    assert sum(e["ph"] == "X" for e in doc["traceEvents"]) == n_steps


def test_chrome_trace_from_single_instance_sim():
    reqs = [Request(rid=i, t_arrival=0.002 * i, prompt_tokens=8,
                    output_tokens=4) for i in range(50)]
    r = simulate(reqs, flat_grid(), max_batch=4, obs=ObsConfig(level=1))
    doc = chrome_trace(r)
    assert validate_chrome_trace(doc) == []
    assert doc["otherData"]["n_instances"] == 1


def test_validator_rejects_malformed():
    res = fleet_run()
    doc = chrome_trace(res, max_requests=5)
    assert validate_chrome_trace(doc) == []
    # unbalanced async span
    bad = json.loads(json.dumps(doc))
    bad["traceEvents"].append(
        {"ph": "b", "cat": "request", "id": 999_999, "name": "queue",
         "pid": 4, "tid": 0, "ts": 0.0})
    assert validate_chrome_trace(bad)
    # non-monotone counter
    bad2 = json.loads(json.dumps(doc))
    cs = [e for e in bad2["traceEvents"] if e["ph"] == "C"]
    last = max(cs, key=lambda e: e["ts"])
    bad2["traceEvents"].append(dict(last, ts=last["ts"] - 1.0))
    assert any("monotone" in m for m in validate_chrome_trace(bad2))
    # negative duration
    bad3 = json.loads(json.dumps(doc))
    xs = next(e for e in bad3["traceEvents"] if e["ph"] == "X")
    xs["dur"] = -1.0
    assert validate_chrome_trace(bad3)


def test_write_chrome_trace_roundtrips(tmp_path):
    res = fleet_run()
    p = tmp_path / "trace.json"
    doc = write_chrome_trace(p, res)
    loaded = json.loads(p.read_text())
    assert loaded["traceEvents"] == json.loads(json.dumps(doc))["traceEvents"]
    assert validate_chrome_trace(loaded) == []


def test_timeline_derive_views():
    res = fleet_run(obs=ObsConfig(level=1))
    tl = Timeline.derive(res)
    assert len(tl.instances) == len(res.step_logs)
    assert tl.n_requests_total == len(res.batch)
    assert tl.n_steps_total == sum(len(sl.t_start) for sl in res.step_logs)
    for tr, sl in zip(tl.instances, res.step_logs):
        assert tr.t_start is sl.t_start          # views, never copies
        assert np.array_equal(tr.is_prefill, sl.prefill_tokens > 0)
    assert tl.t1 >= tl.t0


# -- windowed metrics: exact-sum contract --------------------------------------

@pytest.mark.parametrize("window_s", [0.013, 0.05, 0.2, 10.0])
def test_timeseries_sums_exactly(window_s):
    res = fleet_run(obs=ObsConfig(level=1))
    slo = Slo(ttft_s=0.02, percentile=95)
    s = res.timeseries(window_s, slo=slo)
    m = res.metrics
    assert int(s.arrived.sum()) == len(res.batch)
    assert int(s.completed.sum()) == len(res.batch)
    assert int(s.tokens.sum()) == int(res.batch.output_tokens.sum())
    assert int(s.evictions.sum()) == m.total_evictions
    assert int(s.ok.sum()) == int(slo.ok_mask(m).sum())
    # busy integral == total stepped instance-seconds
    total_busy = sum(float((sl.t_end - sl.t_start).sum())
                     for sl in res.step_logs)
    assert np.isclose(s.busy_s.sum(), total_busy, rtol=1e-9)
    assert np.all(s.capacity_s >= 0)
    assert np.isclose(s.capacity_s.sum(),
                      s.n_instances * (s.t1 - s.t0), rtol=1e-9)


def test_timeseries_eviction_and_goodput_columns():
    res = FleetSim(paged_grid(), **evicting_kw()).run(evicting_spec(),
                                                      seed=0)
    s = res.timeseries(res.metrics.makespan_s / 8)
    assert int(s.evictions.sum()) == res.metrics.total_evictions > 0
    assert not s.has_slo and s.ok.sum() == 0
    rows = s.rows()
    assert len(rows) == len(s)
    json.dumps(s.to_json())  # JSON-safe end to end
    assert s.table()


def test_timeseries_single_instance_and_autoscale_capacity():
    reqs = [Request(rid=i, t_arrival=0.002 * i, prompt_tokens=8,
                    output_tokens=4) for i in range(50)]
    r = simulate(reqs, flat_grid(), max_batch=4)
    s = r.timeseries(0.01)
    assert int(s.completed.sum()) == 50
    assert s.n_instances == 1
    # autoscaled fleet: capacity integral follows the scale events
    from repro.ft.elastic import QueueDepthAutoscaler

    spec = ArrivalSpec("up", 900.0, 500, prompt=LengthDist("fixed", 16),
                       output=LengthDist("uniform", low=1, high=8))
    fs = FleetSim(flat_grid(), 1, max_batch=4, kv_capacity_tokens=4096.0,
                  autoscaler=QueueDepthAutoscaler(max_instances=6),
                  autoscale_interval_s=0.05)
    res = fs.run(spec, seed=1)
    assert res.scale_events and res.n_instances_initial == 1
    s = res.timeseries(res.metrics.makespan_s / 10)
    cap_flat = s.n_instances * (s.t1 - s.t0)
    assert not np.isclose(s.capacity_s.sum(), cap_flat)  # scaling happened
    assert int(s.completed.sum()) == 500


def test_timeseries_rejects_bad_window():
    res = fleet_run()
    for w in (0.0, -1.0, float("inf"), float("nan")):
        with pytest.raises(ValueError):
            timeseries(res, w)


# -- attribution: explain mirrors the sweep engine -----------------------------

def test_component_batch_reproduces_time_batch():
    eng = SweepEngine(["mlperf.train.resnet.large", "mlperf.infer.gnmt.large"],
                      configs=[copa.GPU_N_BASE, copa.HBM_L3])
    suite = eng.suite_analysis(eng.traces)
    specs = [c.build() for c in eng.configs]
    comp = suite.component_batch(specs)
    assert comp.shape == (4, len(specs), len(suite.flops))
    direct = suite.time_batch(specs, per_op=True)
    assert np.array_equal(comp.max(axis=0) + LAUNCH_OVERHEAD_S, direct)


def test_explain_matches_engine_run():
    eng = SweepEngine(["mlperf.train.resnet.large", "mlperf.infer.gnmt.large"],
                      configs=[copa.GPU_N_BASE, copa.HBM_L3])
    grid = eng.run()
    rep = explain_engine(eng)
    assert len(rep.cells) == len(grid.rows)
    for row in grid.rows:
        c = rep.cell(row.trace, row.config, row.n_gpus)
        assert np.isclose(c.time_s, row.time_s, rtol=1e-12, atol=0.0)
        assert np.isclose(sum(c.bound_s.values()), c.time_s,
                          rtol=1e-12, atol=0.0)
        assert c.bottleneck in ("math", "llc", "uhb", "dram", "ici")
        assert c.margin >= 1.0
    # the paper's headline: adding the L3 relieves DRAM on training
    gpu_n = rep.cell("resnet.train.large", "GPU-N")
    l3 = rep.cell("resnet.train.large", "HBM+L3")
    assert l3.bound_s["dram"] < gpu_n.bound_s["dram"]
    assert rep.table() and "resnet.train.large" in rep.table()


def test_explain_scaleout_ici_term():
    eng = SweepEngine(["mlperf.train.resnet.large"],
                      configs=[copa.GPU_N_BASE], gpu_counts=(1, 4),
                      ici_bandwidth=50e9, ici_latency_s=1e-6)
    grid = eng.run()
    rep = explain_engine(eng)
    for row in grid.rows:
        c = rep.cell(row.trace, row.config, row.n_gpus)
        assert np.isclose(c.time_s, row.time_s, rtol=1e-12, atol=0.0)
        assert (c.bound_s["ici"] > 0) == (row.n_gpus > 1)


def test_explain_report_json_and_roofline():
    eng = SweepEngine(["mlperf.train.resnet.large"],
                      configs=[copa.GPU_N_BASE, copa.HBM_L3])
    rep = explain_engine(eng)
    doc = rep.to_json()
    json.dumps(doc)  # inf margins must have been sanitized
    roof = doc["roofline"]
    assert set(roof["ceilings"]) == {"GPU-N", "HBM+L3"}
    for ceil in roof["ceilings"].values():
        assert ceil["knee_flop_per_byte"] > 0
    for pt in roof["points"]:
        assert pt["achieved_tflops"] > 0
        assert pt["ai_flop_per_byte"] > 0


# -- store + CLI ---------------------------------------------------------------

def test_store_roundtrip(tmp_path):
    res = fleet_run(obs=ObsConfig(level=1))
    p = tmp_path / "r.npz"
    save_result(p, res)
    back = load_result(p)
    assert_same_result(res, back)
    for la, lb in zip(res.step_logs, back.step_logs):
        assert np.array_equal(la.prefill_tokens, lb.prefill_tokens)
    assert back.n_instances_initial == res.n_instances_initial
    # derived views agree on the reloaded artifact
    a = timeseries(res, 0.05)
    b = timeseries(back, 0.05)
    assert np.array_equal(a.completed, b.completed)
    assert np.array_equal(a.busy_s, b.busy_s)
    assert trace_events(res) == trace_events(back)


def test_store_roundtrip_without_obs_column(tmp_path):
    res = fleet_run()  # level 0: no prefill_tokens saved
    p = tmp_path / "r0.npz"
    save_result(p, res)
    back = load_result(p)
    assert_same_result(res, back)
    assert all(sl.prefill_tokens is None for sl in back.step_logs)


def test_cli_end_to_end(tmp_path, capsys):
    from repro.obs.cli import main

    npz = tmp_path / "demo.npz"
    trace = tmp_path / "trace.json"
    roof = tmp_path / "roof.json"
    assert main(["run", "--demo", "2x80", "-o", str(npz)]) == 0
    assert main(["trace", str(npz), "--check", "-o", str(trace)]) == 0
    doc = json.loads(trace.read_text())
    assert validate_chrome_trace(doc) == []
    assert main(["timeseries", str(npz), "--window", "0.05"]) == 0
    assert "thru r/s" in capsys.readouterr().out
    assert main(["explain", "mlperf.infer.gnmt.large",
                 "--configs", "GPU-N", "--roofline", str(roof)]) == 0
    assert json.loads(roof.read_text())["points"]
    # demo source without a saved file
    assert main(["trace", "--demo", "2x60", "--check",
                 "-o", str(tmp_path / "t2.json")]) == 0


def test_cli_demo_matches_direct_run():
    from repro.obs.cli import _demo_result

    res = _demo_result("4x200")
    assert len(res.batch) == 200
    assert len(res.step_logs) == 4
    assert all(sl.prefill_tokens is not None for sl in res.step_logs)
