"""Pipeline parallelism: GPipe schedule over a mesh axis vs the sequential
reference, forward and backward, on 4 fake devices (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 12) - 3 / 15) < 1e-9


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import pipeline_apply
    from repro.launch.mesh import make_compat_mesh

    mesh = make_compat_mesh((4,), ("pipe",))
    S, M, MB, D = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (S, D, D)) * 0.3      # one layer per stage
    x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

    def block(w_s, xb):
        return jnp.tanh(xb @ w_s)

    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])

    from jax.sharding import NamedSharding, PartitionSpec as P
    w_sh = jax.device_put(w, NamedSharding(mesh, P("pipe")))

    def piped(w_, x_):
        return pipeline_apply(block, w_, x_, mesh=mesh, axis="pipe")

    out = jax.jit(piped)(w_sh, x)
    err = float(jnp.abs(out - ref).max())

    # gradients flow through the pipeline
    def loss_p(w_, x_):
        return (pipeline_apply(block, w_, x_, mesh=mesh, axis="pipe") ** 2).sum()
    def loss_r(w_, x_):
        y = x_
        for s in range(S):
            y = jnp.tanh(y @ w_[s])
        return (y ** 2).sum()
    g_p = jax.jit(jax.grad(loss_p))(w_sh, x)
    g_r = jax.grad(loss_r)(w, x)
    gerr = float(jnp.abs(jax.device_get(g_p) - g_r).max())
    print(json.dumps({"err": err, "gerr": gerr}))
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2500:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5, res
    assert res["gerr"] < 1e-4, res
