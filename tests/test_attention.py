"""Flash attention (custom-vjp jnp path) vs naive oracle: values + grads,
hypothesis-driven shape sweeps; MLA equivalence; decode paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (decode_attention, flash_attention,
                                    naive_attention)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    s_pow=st.integers(4, 7),
    kvh=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    qc=st.sampled_from([16, 48, 64]),
    kc=st.sampled_from([16, 32, 64]),
)
def test_flash_matches_naive_fwd(b, s_pow, kvh, g, d, causal, qc, kc):
    s = 2 ** s_pow
    h = kvh * g
    ks = jax.random.split(jax.random.PRNGKey(s + h + d), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_grads_match_naive(causal):
    b, s, h, kvh, d = 2, 128, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, causal=causal, q_chunk=32,
                                kv_chunk=64) ** 2).sum()

    def loss_naive(q, k, v):
        return (naive_attention(q, k, v, causal=causal) ** 2).sum()

    g1 = jax.grad(loss_flash, (0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(a, b_, atol=2e-4, rtol=2e-4)


def test_flash_packed_positions():
    """Packed sequences: two documents packed in one row must not attend
    across the boundary when positions restart (position-based masking)."""
    b, s, h, d = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    # positions restart at 32 — tokens 32.. have positions 0..31: with the
    # position-causal rule token 32 (pos 0) attends to every key with pos<=0:
    # i.e. keys 0 (pos 0) and 32 (pos 0). This matches the mask definition.
    pos = jnp.concatenate([jnp.arange(32), jnp.arange(32)])[None, :]
    got = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                          positions=pos, kv_positions=pos)
    # oracle: naive with explicit mask pos_k <= pos_q
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (d ** -0.5)
    mask = pos[0][None, :] <= pos[0][:, None]
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, -1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_decode_attention_matches_naive():
    b, h, kvh, d, s = 2, 8, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    kv_len = 40
    got = decode_attention(q, k, v, kv_len=kv_len)
    want = naive_attention(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_mla_attention_shapes_and_decode():
    import repro.configs as C
    from repro.models.attention import mla_attention, mla_decode, mla_specs
    from repro.models.base import init_params

    cfg = C.get("deepseek-v2-236b").smoke()
    params = init_params(mla_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    b, s = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    out = mla_attention(params, cfg, x, pos, impl="naive")
    assert out.shape == (b, s, cfg.d_model)

    # absorbed decode vs teacher-forced full attention on the last token
    ckv = jnp.zeros((b, s, cfg.kv_lora_rank), jnp.float32)
    krope = jnp.zeros((b, s, cfg.rope_head_dim), jnp.float32)
    outs = []
    for t in range(s):
        o, ckv, krope = mla_decode(params, cfg, x[:, t:t + 1], ckv, krope,
                                   t)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, out, atol=1e-3, rtol=1e-2)


def test_chunked_scan_reference_matches_naive():
    """The secondary scan-based reference (chunked_attention) stays honest
    against the naive oracle (it is kept as documentation of the non-VJP
    formulation)."""
    from repro.models.attention import chunked_attention

    b, s, h, kvh, d = 1, 96, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    for causal in (True, False):
        got = chunked_attention(q, k, v, causal=causal, q_chunk=32,
                                kv_chunk=24)
        want = naive_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)
