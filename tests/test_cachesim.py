"""Cache-model tests: Mattson distances, fractional residency vs exact LRU,
monotonicity properties (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cachesim import build_stream, dram_traffic_sweep, traffic_below
from repro.core.hw import MB
from repro.core.stackdist import BlockLRU, reuse_distances
from repro.core.trace import Trace


def test_reuse_distance_basic():
    # A B A: distance of second A = |B|
    ids = np.array([0, 1, 0])
    sizes = np.array([10.0, 7.0, 10.0])
    d = reuse_distances(ids, sizes, cyclic=False)
    assert np.isinf(d[0]) and np.isinf(d[1])
    assert d[2] == 7.0


def test_reuse_distance_cyclic_wraps():
    ids = np.array([0, 1])
    sizes = np.array([4.0, 6.0])
    d = reuse_distances(ids, sizes, cyclic=True)
    # steady state: A's previous touch is last iteration's A; between them: B
    assert d[0] == 6.0
    assert d[1] == 4.0


def _chain_trace(n_layers=6, act=8 * MB, w=4 * MB) -> Trace:
    tr = Trace("chain")
    for i in range(n_layers):
        tr.emit(f"l{i}", 1e6,
                reads=[(f"a{i}", act), (f"w{i}", w)],
                writes=[(f"a{i+1}", act)])
    return tr


def test_full_capacity_zero_traffic():
    tr = _chain_trace()
    total = tr.footprint_bytes()
    sweep = dram_traffic_sweep(tr, [total * 2])
    assert sweep[total * 2] == 0.0


def test_traffic_monotone_in_capacity():
    tr = _chain_trace()
    caps = [1 * MB, 4 * MB, 16 * MB, 64 * MB, 256 * MB]
    sweep = dram_traffic_sweep(tr, caps)
    vals = [sweep[c] for c in caps]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))


def test_streaming_inputs_never_hit():
    tr = Trace("stream")
    for i in range(4):
        tr.emit(f"l{i}", 1e6, reads=[("in.x", 8 * MB), (f"w{i}", MB)],
                writes=[(f"y{i}", MB)])
    # 'in.x' read 4x per iteration: intra-iteration reuse is real, but the
    # cross-iteration copy must always miss even with a huge cache
    sweep = dram_traffic_sweep(tr, [10_000 * MB])
    assert sweep[10_000 * MB] >= 8 * MB  # at least one cold copy per iter


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.lists(st.tuples(st.integers(0, 7), st.booleans()),
                min_size=4, max_size=40),
       st.lists(st.integers(1, 16), min_size=8, max_size=8),
       st.integers(2, 64))
def test_fractional_model_tracks_block_lru(touches, sizes, cap_mb):
    """Tensor-level fractional residency must track an exact block LRU on
    random single-tensor-per-op traces (same trace, two simulators).
    Tensors have stable sizes (as in real traces). The bound is loose by
    design: exact LRU thrash-cascades when the working set straddles the
    capacity (repeated full re-reads), where the fractional model stays
    optimal-like; the assertion pins magnitude, monotone cases are covered
    by the dedicated tests above. derandomize keeps the example set fixed."""
    tr = Trace("rand")
    for i, (tid, is_write) in enumerate(touches):
        size_mb = sizes[tid]
        if is_write:
            tr.emit(f"op{i}", 0.0, writes=[(f"t{tid}", size_mb * MB)])
        else:
            tr.emit(f"op{i}", 0.0, reads=[(f"t{tid}", size_mb * MB)],
                    writes=[(f"o{i}", MB)])
    cap = cap_mb * MB
    # like-for-like: no buffer recycling (BlockLRU keys raw tensor names)
    stream = build_stream(tr, cyclic=False, reuse_buffers=False)
    (res,) = traffic_below(stream, [cap])
    model_traffic = res.total

    lru = BlockLRU(cap, block_bytes=MB)
    for i, t, b, w in tr.touches():
        lru.touch_tensor(hash(t) % (1 << 30), b, w)
    lru_traffic = lru.fill_bytes + lru.writeback_bytes
    # Agreement bound: the fractional model is optimistic exactly at the
    # LRU-thrash knife edge (working set ~ capacity, where true LRU
    # cascades misses on cyclic re-reads); everywhere else they track
    # closely. 70% + 6 blocks covers the thrash corner while still pinning
    # the model to the right magnitude.
    hi = max(model_traffic, lru_traffic)
    lo = min(model_traffic, lru_traffic)
    assert hi - lo <= 0.80 * hi + 8 * MB


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 10), st.integers(1, 32))
def test_sweep_monotone_random_chains(n_layers, act_mb):
    tr = _chain_trace(n_layers=n_layers, act=act_mb * MB)
    caps = [MB, 8 * MB, 64 * MB, 512 * MB, 4096 * MB]
    sweep = dram_traffic_sweep(tr, caps)
    vals = [sweep[c] for c in caps]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))
    assert all(v >= 0 for v in vals)


def test_buffer_reuse_kills_dead_writebacks():
    """Inference chains: dead activations recycle buffers, so a large cache
    sees almost no writeback traffic (the Fig-4 16x mechanism)."""
    tr = Trace("infer")
    act = 16 * MB
    for i in range(10):
        tr.emit(f"l{i}", 1e6,
                reads=[(f"a{i}", act), (f"w{i}", MB)],
                writes=[(f"a{i+1}", act)])
    cap = 200 * MB  # >> working set with reuse, << sum of all acts
    sweep = dram_traffic_sweep(tr, [cap])
    assert sweep[cap] < 2 * act  # without reuse it would be ~10x act
