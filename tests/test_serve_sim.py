"""Request-level serving simulator: event core vs the single-request
oracle, closed-loop saturation vs the sweep engine (the acceptance bound),
scheduler invariants, cost-grid export, fleet/SLO sizing, the queue-depth
autoscaler, and the registry's glob + arrivals namespaces."""
import numpy as np
import pytest

from repro.core import copa, msm
from repro.core.sweep import (
    CostGrid,
    ScaleOutWorkload,
    SweepEngine,
    serve_cost_grids,
)
from repro.core.trace import Trace
from repro.ft.elastic import QueueDepthAutoscaler
from repro.serve.fleet import FleetSim, instances_to_meet_slo, scan_fleet
from repro.serve.sim import (
    ArrivalSpec,
    LengthDist,
    Request,
    Slo,
    _reference_sim,
    replay,
    simulate,
)
from repro.workloads import mlperf, registry

INF = float("inf")


def flat_grid(step=1e-3, batches=(1, 2, 4, 8), prefill=0.0):
    return CostGrid("flat", tuple(batches), (INF,),
                    np.full((len(batches), 1), step),
                    prefill_s_per_token=prefill)


def ramp_grid():
    """Batch-sublinear steps + a real KV axis + prefill: exercises every
    grid dimension."""
    batches = (1, 2, 4)
    edges = (8.0, 64.0, 512.0)
    base = np.array([1.0, 1.5, 2.25])[:, None]
    kv = np.array([0.1, 0.4, 1.6])[None, :]
    return CostGrid("ramp", batches, edges, base + kv,
                    prefill_s_per_token=0.01)


# --- cost grid ----------------------------------------------------------------

def test_cost_grid_bucket_lookup():
    g = ramp_grid()
    # batch rounds UP to the next priced bucket; KV rounds up to its edge
    assert g.step_time(1, 0) == g.step_time(1, 8)
    assert g.step_time(3, 8) == g.step_time(4, 8)
    assert g.step_time(1, 9) == 1.0 + 0.4
    assert g.step_time(1, 10_000) == 1.0 + 1.6  # past last edge: last bucket
    got = g.step_time(np.array([1, 2, 4]), np.array([1, 64, 65]))
    assert np.array_equal(got, [1.1, 1.9, 3.85])
    assert g.prefill_time(5) == pytest.approx(0.05)
    with pytest.raises(ValueError):
        g.step_time(5)
    with pytest.raises(ValueError):
        g.step_time(0)
    with pytest.raises(ValueError):
        CostGrid("bad", (4, 2), (INF,), np.zeros((2, 1)))


def test_serve_cost_grids_match_engine_rows_bit_for_bit():
    """One-shot grids ARE the engine's serve rows: every (config, batch)
    cell equals the SweepEngine time for that scenario."""
    cfgs = [copa.GPU_N_BASE, copa.HBM_L3]
    grids = serve_cost_grids("resnet", cfgs)
    names = registry.scenarios("serve.mlperf.resnet.b")
    eng = SweepEngine(names, configs=cfgs).run()
    for cfg in cfgs:
        g = grids[cfg.name]
        assert g.seq_edges == (INF,)
        for k, b in enumerate(g.batches):
            row = eng.result(f"resnet.infer.b{b}", cfg.name)
            assert g.step_time_s[k, 0] == row.per_gpu_time_s
        assert g.saturated_rps() == eng.result(
            f"resnet.infer.b{g.max_batch}", cfg.name).throughput


def test_serve_cost_grids_kv_axis_prices_llc_residency():
    """KV sweeps are priced from traced decode cells through the cache
    model: an L2-resident cache is swept at L2 bandwidth exactly, a
    COPA-L3-resident one stays on package (cheaper than spilling to DRAM),
    and a cache far past the LLC converges to the DRAM stream — the
    shorter-decode-steps mechanism, now with partial-residency credit the
    deleted closed form couldn't give."""
    kv_per_tok = 64 * 1024
    edges = (64, 4096, 1 << 20)     # 4MB / 256MB / 64GB of KV
    grids = serve_cost_grids("gnmt", [copa.GPU_N_BASE, copa.HBM_L3],
                             kv_bytes_per_token=kv_per_tok,
                             seq_edges=edges, tokens_per_pass=50)
    base = {c.name: serve_cost_grids("gnmt", [c], tokens_per_pass=50)
            [c.name].step_time(1, 1)
            for c in (copa.GPU_N_BASE, copa.HBM_L3)}
    gn, l3 = grids["GPU-N"], grids["HBM+L3"]
    spec_gn, spec_l3 = copa.GPU_N_BASE.build(), copa.HBM_L3.build()
    dt = {(name, e): grids[name].step_time(1, e) - base[name]
          for name in ("GPU-N", "HBM+L3") for e in edges}

    # 4MB fits both configs' 60MB L2: swept at L2 bandwidth exactly.
    s_small = edges[0] * kv_per_tok
    assert s_small < spec_gn.l2_capacity
    assert dt[("GPU-N", 64)] == pytest.approx(s_small / spec_gn.l2_bandwidth)
    assert dt[("HBM+L3", 64)] == pytest.approx(s_small / spec_l3.l2_bandwidth)

    # 256MB spills GPU-N's L2 to DRAM but fits the 960MB COPA L3: the COPA
    # sweep is faster, and both are bounded by their single-level ceilings
    # (partial L2 residency filters part of the stream).
    s_mid = edges[1] * kv_per_tok
    assert spec_gn.llc_capacity < s_mid < spec_l3.llc_capacity
    assert dt[("HBM+L3", 4096)] < dt[("GPU-N", 4096)]
    assert dt[("GPU-N", 4096)] <= s_mid / spec_gn.dram_bandwidth * (1 + 1e-9)
    assert dt[("GPU-N", 4096)] >= 0.5 * s_mid / spec_gn.dram_bandwidth
    assert dt[("HBM+L3", 4096)] <= s_mid / spec_l3.l3_bandwidth * (1 + 1e-9)

    # 64GB dwarfs every cache: both configs converge to the DRAM stream.
    s_big = edges[2] * kv_per_tok
    assert dt[("GPU-N", 1 << 20)] == pytest.approx(
        s_big / spec_gn.dram_bandwidth, rel=0.02)
    assert dt[("HBM+L3", 1 << 20)] == pytest.approx(
        s_big / spec_l3.dram_bandwidth, rel=0.05)

    # Monotone in resident KV per config.
    for name in ("GPU-N", "HBM+L3"):
        ts = [dt[(name, e)] for e in edges]
        assert ts == sorted(ts)


def test_kv_sweep_traced_parity_with_closed_form():
    """The traced KV pricing vs the closed form it replaced (LLC-fit ->
    on-package bandwidth, else DRAM): the closed form is an upper bound
    everywhere (it never credits partial residency or L2 filtering), is
    met EXACTLY where its assumptions hold (monolithic + L2-resident), and
    is approached asymptotically in the deep-DRAM regime. This is the
    CostGrid parity that justified deleting ``_kv_step_time``."""
    from repro.core.sweep import kv_sweep_times

    def closed_form(spec, kv_bytes):
        if kv_bytes <= spec.llc_capacity:
            bw = spec.l3_bandwidth if spec.l3_capacity else spec.l2_bandwidth
        else:
            bw = spec.dram_bandwidth
        return kv_bytes / bw

    specs = [copa.GPU_N_BASE.build(), copa.HBM_L3.build()]
    mb = 1024 * 1024
    sizes = [mb, 4 * mb, 64 * mb, 256 * mb, 1024 * mb, 64 * 1024 * mb]
    traced = kv_sweep_times(specs, sizes)
    for j, spec in enumerate(specs):
        for i, s in enumerate(sizes):
            closed = closed_form(spec, s)
            assert traced[i, j] <= closed * (1 + 1e-9), (spec.name, s)
            if not spec.l3_capacity and s <= spec.l2_capacity:
                assert traced[i, j] == pytest.approx(closed), (spec.name, s)
        # deep-DRAM regime: residency is negligible, the two models agree
        assert traced[-1, j] == pytest.approx(
            closed_form(spec, sizes[-1]), rel=0.02), spec.name
        assert list(traced[:, j]) == sorted(traced[:, j])
    # zero KV prices to zero (empty-cache decode step unchanged)
    assert np.all(kv_sweep_times(specs, [0]) == 0.0)


# --- event core vs the single-request oracle ----------------------------------

def test_single_request_matches_reference_sim():
    g = ramp_grid()
    for prompt, out in ((0, 1), (5, 1), (12, 7), (100, 3)):
        req = Request(rid=0, t_arrival=0.25, prompt_tokens=prompt,
                      output_tokens=out)
        res = simulate([Request(rid=0, t_arrival=0.25, prompt_tokens=prompt,
                                output_tokens=out)], g)
        r = res.requests[0]
        t_first, t_done = _reference_sim(req, g)
        assert r.t_first_token == t_first, (prompt, out)
        assert r.t_done == t_done, (prompt, out)
        m = res.metrics
        assert m.ttft[0] == pytest.approx(t_first - 0.25)
        assert m.e2e[0] == pytest.approx(t_done - 0.25)
        if out > 1:
            assert m.tpot[0] == pytest.approx((t_done - t_first) / (out - 1))
        else:
            assert m.tpot[0] == 0.0


def test_saturation_matches_sweep_engine_within_2pct():
    """Acceptance: arrival rate -> inf (everything at t=0) with unlimited
    admission reproduces the SweepEngine serve-row steady-state throughput
    within 2%, per config."""
    cfgs = [copa.GPU_N_BASE, copa.HBM_L3]
    grids = serve_cost_grids("resnet", cfgs)
    for cfg in cfgs:
        g = grids[cfg.name]
        row = SweepEngine([f"serve.mlperf.resnet.b{g.max_batch}"],
                          configs=[cfg]).run().rows[0]
        reqs = [Request(rid=i, t_arrival=0.0) for i in range(4 * g.max_batch)]
        m = simulate(reqs, g).metrics
        assert abs(m.throughput_rps - row.throughput) <= 0.02 * row.throughput
        # full batches every step, back to back
        log = simulate([Request(rid=i, t_arrival=0.0)
                        for i in range(4 * g.max_batch)], g).step_log
        assert (log.batch == g.max_batch).all()
        assert np.allclose(log.t_start[1:], log.t_end[:-1])


def test_conservation_and_scheduler_invariants():
    g = flat_grid(prefill=1e-4)
    spec = ArrivalSpec(name="t", rate=3000.0, n_requests=400,
                       prompt=LengthDist("uniform", low=0, high=30),
                       output=LengthDist("uniform", low=1, high=6, floor=1))
    res = simulate(spec.generate(seed=7), g, max_batch=4,
                   kv_capacity_tokens=120)
    # every request completed, exactly once, in causal order
    for r in res.requests:
        assert r.tokens_emitted == r.output_tokens
        assert r.t_arrival <= r.t_admitted < r.t_first_token <= r.t_done
    log = res.step_log
    assert log.admitted.sum() == 400
    assert (log.batch >= 1).all() and (log.batch <= 4).all()
    assert (log.kv_reserved <= 120).all()
    assert (np.diff(log.t_start) >= 0).all()
    assert (log.t_end > log.t_start).all()
    assert (log.t_start[1:] >= log.t_end[:-1] - 1e-12).all()


def test_kv_admission_rejects_impossible_request():
    g = flat_grid()
    with pytest.raises(ValueError):
        simulate([Request(rid=0, t_arrival=0.0, prompt_tokens=100,
                          output_tokens=1)], g, kv_capacity_tokens=50)


def test_kv_capacity_gates_batch():
    """Two requests whose combined KV exceeds capacity serialize even though
    the batch has slots."""
    g = flat_grid()
    reqs = [Request(rid=i, t_arrival=0.0, prompt_tokens=30, output_tokens=2)
            for i in range(2)]
    res = simulate(reqs, g, kv_capacity_tokens=40)
    assert (res.step_log.batch == 1).all()
    assert res.requests[0].t_done <= res.requests[1].t_admitted


def test_request_list_reusable_across_runs():
    """Simulations copy their inputs: one replayed trace can drive many
    fleet sizes without run N-1's timing state leaking into run N."""
    g = flat_grid()
    shared = replay(np.linspace(0, 0.01, 64).tolist(), outputs=5)
    r1 = FleetSim(g, 1).run(shared)
    r2 = FleetSim(g, 2).run(shared)
    for r in shared:   # caller's objects untouched
        assert r.tokens_emitted == 0 and np.isnan(r.t_done)
    for res in (r1, r2):
        assert all(q.tokens_emitted == q.output_tokens for q in res.requests)
    # more instances genuinely re-simulate (overloaded single instance
    # queues; two don't)
    assert r2.metrics.percentile("ttft", 95) < r1.metrics.percentile("ttft", 95)
    solo = simulate(shared, g)
    assert all(q.tokens_emitted == 5 for q in solo.requests)


def test_replay_and_empty():
    g = flat_grid()
    res = simulate(replay([0.3, 0.1, 0.2]), g)
    assert [r.t_arrival for r in res.requests] == [0.1, 0.2, 0.3]
    empty = simulate([], g)
    assert empty.metrics.throughput_rps == 0.0 and len(empty.requests) == 0


def test_msm_kv_token_capacity():
    base = copa.GPU_N_BASE.build()
    grown = copa.HBML_L3.build()   # 1.67x DRAM capacity
    pol = msm.DECODE_MSM           # bf16 KV
    elems = 32768
    c_base = msm.kv_token_capacity(base, pol, elems)
    assert c_base == int(0.7 * base.dram_capacity // (elems * 2))
    assert msm.kv_token_capacity(grown, pol, elems) > 1.5 * c_base
    int8 = msm.compose("msm_decode", kv_cache_dtype="int8")
    assert msm.kv_token_capacity(base, int8, elems) == pytest.approx(
        2 * c_base, rel=1e-9)
    with pytest.raises(ValueError):
        msm.kv_token_capacity(base, pol, 0)


# --- arrivals -----------------------------------------------------------------

def test_arrival_spec_deterministic_and_calibrated():
    spec = ArrivalSpec(name="p", rate=100.0, n_requests=2000)
    a, b = spec.generate(seed=3), spec.generate(seed=3)
    assert [r.t_arrival for r in a] == [r.t_arrival for r in b]
    mean_gap = a[-1].t_arrival / len(a)
    assert 0.9 / 100 <= mean_gap <= 1.1 / 100
    bursty = ArrivalSpec(name="b", rate=100.0, n_requests=2000,
                         burst_factor=4.0, burst_fraction=0.25, period_s=0.64)
    ts = np.array([r.t_arrival for r in bursty.generate(seed=3)])
    assert (np.diff(ts) > 0).all()
    # long-run mean rate preserved within sampling noise
    assert 0.85 * 100 <= len(ts) / ts[-1] <= 1.15 * 100
    # on-phase (first quarter of each period) carries well over its share
    phase = np.mod(ts, 0.64) / 0.64
    assert (phase < 0.25).mean() > 0.45


# --- fleet --------------------------------------------------------------------

def test_fleet_one_instance_matches_simulate():
    g = flat_grid()
    spec = ArrivalSpec(name="t", rate=5000.0, n_requests=300)
    solo = simulate(spec.generate(seed=1), g).metrics
    fleet = FleetSim(g, 1).run(spec, seed=1).metrics
    assert np.array_equal(solo.ttft, fleet.ttft)
    assert np.array_equal(solo.e2e, fleet.e2e)


def test_fleet_routers_conserve_and_scale():
    g = flat_grid()
    spec = ArrivalSpec(name="t", rate=20000.0, n_requests=1500)
    p99 = {}
    for router in ("round_robin", "least_loaded"):
        res = FleetSim(g, 3, router=router).run(spec, seed=0)
        assert sum(log.admitted.sum() for log in res.step_logs) == 1500
        p99[router] = res.metrics.percentile("ttft", 99)
    over = FleetSim(g, 1).run(spec, seed=0).metrics.percentile("ttft", 99)
    assert max(p99.values()) < over  # 3 instances beat 1 under overload
    with pytest.raises(ValueError):
        FleetSim(g, 1, router="random")


def test_instances_to_meet_slo_is_slo_boundary():
    g = flat_grid()
    spec = ArrivalSpec(name="t", rate=20000.0, n_requests=2500)
    slo = Slo(ttft_s=0.015, percentile=95)
    scanned = scan_fleet(g, spec, slo, max_instances=8)
    n = instances_to_meet_slo(g, spec, slo, max_instances=8)
    assert n == 3
    assert slo.met(scanned[n]) and not slo.met(scanned[n - 1])
    assert instances_to_meet_slo(
        g, spec, Slo(ttft_s=1e-9, percentile=95), max_instances=3) is None


def test_autoscaler_converges_to_slo_fleet_size():
    """The queue-depth policy lands within one instance of the SLO scan."""
    g = flat_grid()
    spec = ArrivalSpec(name="t", rate=20000.0, n_requests=2500)
    n_slo = instances_to_meet_slo(g, spec, Slo(ttft_s=0.015, percentile=95),
                                  max_instances=8)
    res = FleetSim(g, 1, autoscaler=QueueDepthAutoscaler(),
                   autoscale_interval_s=0.005).run(spec, seed=0)
    assert abs(res.n_instances_final - n_slo) <= 1
    assert res.n_instances_peak <= n_slo + 1
    # scale-down: an oversized fleet sheds idle instances
    down = FleetSim(g, 8, autoscaler=QueueDepthAutoscaler(),
                    autoscale_interval_s=0.005).run(spec, seed=0)
    assert n_slo <= down.n_instances_final < 8
    # every request still completes through scale events
    assert down.metrics.throughput_rps > 0
    assert len(down.requests) == 2500


# --- registry: glob resolve + arrivals namespace ------------------------------

def test_registry_glob_resolve():
    hits = registry.resolve("serve.mlperf.resnet.*")
    assert isinstance(hits, list) and len(hits) == 4
    assert all(isinstance(t, Trace) for t in hits)
    fams = registry.resolve("scaleout.mlperf.train.*")
    assert len(fams) == len(mlperf.TRAIN_BATCHES)
    assert all(isinstance(w, ScaleOutWorkload) for w in fams)
    assert registry.match("serve.mlperf.ssd-large.b?") == \
        ["serve.mlperf.ssd-large.b1", "serve.mlperf.ssd-large.b4"]
    with pytest.raises(KeyError):
        registry.resolve("serve.nothing.*")
    # non-glob names keep their exact-match semantics
    assert isinstance(registry.resolve("mlperf.train.resnet.large"), Trace)


def test_sweep_engine_accepts_glob_workloads():
    grid = SweepEngine(["serve.mlperf.ssd-large.*"],
                       configs=[copa.GPU_N_BASE]).run()
    assert sorted(grid.traces) == ["ssd-large.infer.b1", "ssd-large.infer.b4"]
    with pytest.raises(TypeError):
        SweepEngine(["arrivals.poisson.*"], configs=[copa.GPU_N_BASE])


def test_registry_arrivals_namespace():
    names = registry.arrival_names()
    assert "arrivals.poisson.r16" in names
    assert "arrivals.burst.r16.x4" in names
    spec = registry.resolve("arrivals.poisson.r16")
    assert isinstance(spec, ArrivalSpec) and spec.rate == 16.0
    pats = registry.resolve("arrivals.poisson.*")
    assert len(pats) == len(registry.ARRIVAL_RATES)
    assert set(registry.suite("arrivals.poisson")) <= set(names)
    with pytest.raises(KeyError):
        registry.arrivals("arrivals.nope")
    # traceless suite members are a loud error, not a KeyError deep inside
    with pytest.raises(TypeError):
        registry.suite_traces("arrivals.poisson")
    reqs = spec.generate(seed=0)
    assert len(reqs) == spec.n_requests
    assert all(r.output_tokens == 1 and r.prompt_tokens == 0 for r in reqs)


# --- metrics / SLO ------------------------------------------------------------

def test_slo_and_goodput():
    g = flat_grid()
    spec = ArrivalSpec(name="t", rate=4000.0, n_requests=500)
    m = simulate(spec.generate(seed=0), g).metrics
    assert Slo().met(m)  # no targets -> always met
    tight = Slo(ttft_s=1e-9, percentile=50)
    assert not tight.met(m)
    assert m.goodput_rps(tight) == 0.0
    loose = Slo(ttft_s=10.0)
    assert m.goodput_rps(loose) == pytest.approx(m.throughput_rps)
    assert m.percentile("ttft", 50) <= m.percentile("ttft", 99)
