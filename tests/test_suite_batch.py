"""Suite-level batching parity: StreamBatch / SuiteAnalysis / the batched
SweepEngine path must reproduce the per-trace pipeline BIT FOR BIT.

Layers, bottom-up:

* batched Mattson (`_mattson_pass_batch`) vs the 1D kernel and the Fenwick
  reference;
* `StreamBatch.traffic_below` vs per-trace `traffic_below` (exact) and
  `_reference_traffic_below` (per-touch oracle, approx);
* `SuiteAnalysis` time/attribution/dram vs per-trace `TraceAnalysis`;
* `SweepEngine.run()` (suite-batched) vs `run(batched=False)` (the
  pre-refactor per-trace loop) over the full default benchmark suite —
  every SweepResult field equal, which is the PR's acceptance criterion.

A fixed-seed deterministic suite always runs; the randomized-property
variant is hypothesis-gated like the other property suites.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import copa
from repro.core.cachesim import (
    StreamBatch,
    _reference_traffic_below,
    _STREAMS,
    build_stream,
    build_streams,
    dram_traffic_sweep,
    dram_traffic_sweep_suite,
    traffic_below,
)
from repro.core.hw import MB
from repro.core.stackdist import (
    PAD_ID,
    _mattson_pass,
    _mattson_pass_batch,
    _reference_mattson_pass,
)
from repro.core.sweep import (
    SuiteAnalysis,
    SweepEngine,
    TraceAnalysis,
    prefill_cost_per_token,
    serve_cost_grids,
    suite_analysis_for,
)
from repro.core.trace import Trace
from repro.workloads import registry


def _random_trace(rng, n_ops, n_tensors, streaming=0.2, name="rand") -> Trace:
    tr = Trace(name)
    for i in range(n_ops):
        reads, writes = [], []
        for _ in range(int(rng.integers(0, 3))):
            t = int(rng.integers(0, n_tensors))
            nm = f"in.t{t}" if rng.random() < streaming else f"t{t}"
            reads.append((nm, int(rng.integers(1, 20)) * MB))
        for _ in range(int(rng.integers(0, 2))):
            writes.append((f"t{int(rng.integers(0, n_tensors))}",
                           int(rng.integers(1, 20)) * MB))
        if reads or writes:
            tr.emit(f"op{i}", 1e6, reads=reads, writes=writes)
    return tr


def _random_suite(rng, n_traces, max_ops=80):
    """Mixed-length traces so padding amounts inside the batch vary."""
    return [
        _random_trace(rng, int(rng.integers(1, max_ops)),
                      int(rng.integers(2, 10)), name=f"rand{i}")
        for i in range(n_traces)
    ]


CAPS = [float(c) * MB for c in (1, 7, 33, 120, 1000)] + [float(1 << 50)]


# --- batched Mattson ----------------------------------------------------------

def test_mattson_batch_rows_bitwise_equal_1d_kernel():
    """Padded rows must get exactly the 1D kernel's floats — the property
    that makes suite batching invisible to every downstream consumer."""
    rng = np.random.default_rng(7)
    for _ in range(40):
        n_rows = int(rng.integers(1, 7))
        max_len = int(rng.integers(1, 150))
        ids2 = np.full((n_rows, max_len), PAD_ID, dtype=np.int64)
        sz2 = np.zeros((n_rows, max_len))
        rows = []
        for r in range(n_rows):
            n = int(rng.integers(0, max_len + 1))
            ids = rng.integers(0, int(rng.integers(1, 12)), n)
            sz = rng.integers(1, 60, n).astype(float)
            ids2[r, :n] = ids
            sz2[r, :n] = sz
            rows.append((n, _mattson_pass(ids, sz)))
        got = _mattson_pass_batch(ids2, sz2)
        for r, (n, want) in enumerate(rows):
            assert np.array_equal(got[r, :n], want, equal_nan=True)


def test_mattson_batch_matches_fenwick_reference():
    rng = np.random.default_rng(11)
    n_rows, max_len = 5, 90
    ids2 = np.full((n_rows, max_len), PAD_ID, dtype=np.int64)
    sz2 = np.zeros((n_rows, max_len))
    lens = []
    for r in range(n_rows):
        n = int(rng.integers(1, max_len + 1))
        ids2[r, :n] = rng.integers(0, 9, n)
        sz2[r, :n] = rng.integers(1, 40, n).astype(float)
        lens.append(n)
    got = _mattson_pass_batch(ids2, sz2)
    for r, n in enumerate(lens):
        want = _reference_mattson_pass(ids2[r, :n], sz2[r, :n])
        inf = np.isinf(want)
        assert np.array_equal(np.isinf(got[r, :n]), inf)
        assert np.allclose(got[r, :n][~inf], want[~inf], rtol=1e-9, atol=1e-6)


def test_build_streams_matches_build_stream_bitwise():
    rng = np.random.default_rng(3)
    traces = _random_suite(rng, 12) + [_random_suite(rng, 1, max_ops=400)[0]]
    streams = build_streams(traces)
    _STREAMS.clear()  # force per-trace rebuilds
    for t, s in zip(traces, streams):
        one = build_stream(t)
        assert np.array_equal(s.dist, one.dist, equal_nan=True)
        assert np.array_equal(s.tensor_idx, one.tensor_idx)
        assert np.array_equal(s.sizes, one.sizes)
        assert s.second_half == one.second_half


def test_build_stream_caches_per_trace():
    rng = np.random.default_rng(4)
    tr = _random_trace(rng, 20, 5)
    assert build_stream(tr) is build_stream(tr)
    tr.emit("grow", 1e6, writes=[("tnew", MB)])
    s2 = build_stream(tr)  # op count changed -> fresh stream
    assert s2.n_ops == len(tr.ops)


# --- StreamBatch traffic ------------------------------------------------------

def test_stream_batch_traffic_bitwise_vs_per_trace():
    rng = np.random.default_rng(42)
    traces = _random_suite(rng, 25) + _random_suite(rng, 5, max_ops=6)
    streams = build_streams(traces)
    batch = StreamBatch.pad(streams)
    got = batch.traffic_below(CAPS)
    for i, s in enumerate(streams):
        want = traffic_below(s, CAPS)
        for k in range(len(CAPS)):
            assert np.array_equal(got[i][k].fill, want[k].fill), (i, k)
            assert np.array_equal(got[i][k].writeback, want[k].writeback), (i, k)


def test_stream_batch_traffic_matches_reference_oracle():
    rng = np.random.default_rng(13)
    traces = _random_suite(rng, 10, max_ops=40)
    streams = build_streams(traces)
    batch = StreamBatch.pad(streams)
    got = batch.traffic_below(CAPS[:4])
    for i, s in enumerate(streams):
        ref = _reference_traffic_below(s, CAPS[:4])
        for k in range(4):
            assert np.allclose(got[i][k].fill, ref[k].fill,
                               rtol=1e-9, atol=1e-3)
            assert np.allclose(got[i][k].writeback, ref[k].writeback,
                               rtol=1e-9, atol=1e-3)


def test_stream_batch_padding_invariance():
    """A trace's row must not depend on WHICH other traces share its batch
    (and hence on how much padding it gets)."""
    rng = np.random.default_rng(5)
    tr = _random_trace(rng, 30, 6, name="probe")
    small = StreamBatch.pad(build_streams([tr]))
    big = StreamBatch.pad(build_streams(
        [tr] + _random_suite(rng, 8, max_ops=200)))
    a = small.traffic_below(CAPS)[0]
    b = big.traffic_below(CAPS)[0]
    for k in range(len(CAPS)):
        assert np.array_equal(a[k].fill, b[k].fill)
        assert np.array_equal(a[k].writeback, b[k].writeback)


def test_stream_batch_real_scenarios_bitwise():
    names = (registry.suite("mlperf.train.small")[:2]
             + registry.suite("mlperf.infer.small")[:2]
             + registry.suite("hpc")[:4])
    traces = [registry.scenario(n) for n in names]
    streams = build_streams(traces)
    batch = StreamBatch.pad(streams)
    got = batch.traffic_below(CAPS[:3])
    for i, s in enumerate(streams):
        want = traffic_below(s, CAPS[:3])
        for k in range(3):
            assert np.array_equal(got[i][k].fill, want[k].fill)
            assert np.array_equal(got[i][k].writeback, want[k].writeback)


# --- SuiteAnalysis ------------------------------------------------------------

@pytest.fixture(scope="module")
def mixed_traces():
    rng = np.random.default_rng(17)
    return _random_suite(rng, 8) + [
        registry.scenario("mlperf.infer.resnet.small"),
        registry.scenario("hpc.amber.0"),
    ]


def test_suite_analysis_time_batch_bitwise(mixed_traces):
    import itertools

    suite = SuiteAnalysis(mixed_traces)
    specs = [cfg.build() for cfg in copa.TABLE_V]
    for flags in itertools.product((False, True), repeat=3):
        kw = dict(zip(("ideal_dram", "ideal_mem_other", "ideal_occupancy"),
                      flags))
        totals = suite.time_batch(specs, **kw)
        assert totals.shape == (len(specs), len(mixed_traces))
        for i, t in enumerate(mixed_traces):
            ta = TraceAnalysis(t, stream=suite.analyses[i].stream)
            want = ta.time_batch(specs, **kw)
            assert np.array_equal(totals[:, i], want), (flags, t.name)


def test_suite_analysis_attribution_bitwise(mixed_traces):
    suite = SuiteAnalysis(mixed_traces)
    specs = [cfg.build() for cfg in copa.TABLE_V]
    grid = suite.attribution_grid(specs)
    for i, t in enumerate(mixed_traces):
        ta = TraceAnalysis(t, stream=suite.analyses[i].stream)
        want = ta.attribution_batch(specs)
        for j in range(len(specs)):
            assert grid[i][j][0] == want[j][0], (t.name, j)
            assert grid[i][j][1] == want[j][1], (t.name, j)


def test_suite_prefetch_batches_despite_warm_members(mixed_traces):
    """A capacity one member already has cached must still be computed in
    ONE batched scan for the rest — and the warm member keeps its object
    (batch rows are bit-identical to it)."""
    cap = 77.0 * MB
    suite = SuiteAnalysis(mixed_traces)
    warm = suite.analyses[0]
    warm.prefetch([cap])  # per-trace warm-up of one member
    pre = warm._levels[float(cap)]
    calls = []
    orig = suite.batch.traffic_matrices
    suite.batch.traffic_matrices = \
        lambda caps, **kw: calls.append(list(caps)) or orig(caps, **kw)
    suite.prefetch([cap])
    assert calls == [[cap]]  # exactly one batched scan, not N-1 per-trace
    assert warm._levels[float(cap)] is pre  # warm member untouched
    for i, ta in enumerate(suite.analyses[1:], start=1):
        want = traffic_below(ta.stream, [cap])[0]
        assert np.array_equal(ta._levels[float(cap)].fill, want.fill)
        assert np.array_equal(ta._levels[float(cap)].writeback, want.writeback)


def test_suite_analysis_dram_traffic_matches_per_trace(mixed_traces):
    suite = SuiteAnalysis(mixed_traces)
    mat = suite.dram_traffic(CAPS[:4])
    assert mat.shape == (len(mixed_traces), 4)
    for i, t in enumerate(mixed_traces):
        per = TraceAnalysis(t, stream=suite.analyses[i].stream).dram_traffic(
            CAPS[:4])
        for k, c in enumerate(CAPS[:4]):
            assert mat[i, k] == per[c]


def test_dram_traffic_sweep_suite_matches_single():
    traces = [registry.scenario(n)
              for n in registry.suite("mlperf.infer.small")[:3]]
    caps = [60 * MB, 960 * MB]
    suite_out = dram_traffic_sweep_suite(traces, caps)
    for t in traces:
        single = dram_traffic_sweep(t, caps)
        assert suite_out[t.name] == {float(c): single[c] for c in caps}


def test_msm_analyze_suite_matches_single():
    from repro.core import msm

    traces = [registry.scenario("lm.tinyllama-1.1b.decode_32k"),
              registry.scenario("lm.yi-6b.train_4k")]
    batch = msm.analyze_suite(traces)
    for t, got in zip(traces, batch):
        want = msm.analyze(t)
        assert got.trace_name == want.trace_name
        assert got.baseline_traffic == want.baseline_traffic
        assert got.sweep == want.sweep


def test_perfmodel_batch_matches_single():
    from repro.core import perfmodel

    traces = [registry.scenario(n)
              for n in registry.suite("mlperf.infer.small")[:3]]
    spec = copa.HBM_L3.build()
    models = perfmodel.PerfModel.batch(traces)
    for t, pm in zip(traces, models):
        one = perfmodel.PerfModel(t)
        r_b, r_1 = pm.run(spec), one.run(spec)
        assert r_b.time_s == r_1.time_s
        assert r_b.segments == r_1.segments
        assert r_b.dram_bytes == r_1.dram_bytes


# --- the acceptance criterion: engine suite pass == per-trace loop ------------

def _assert_grids_identical(g_bat, g_ref):
    assert len(g_bat.rows) == len(g_ref.rows)
    for rb, rr in zip(g_bat.rows, g_ref.rows):
        assert dataclasses.asdict(rb) == dataclasses.asdict(rr), \
            (rb.trace, rb.config, rb.n_gpus)
    assert g_bat.llc_traffic == g_ref.llc_traffic


def test_engine_batched_bit_identical_mixed_workloads():
    """Scale-out families, serve scenarios, HPC and LM cells, extra LLC
    capacities, a finite fabric — one suite pass, every row bit-identical
    to the per-trace loop."""
    works = (registry.suite("mlperf.train.small")[:2]
             + ["scaleout.mlperf.train.resnet", "scaleout.serve.gnmt"]
             + registry.scenarios("serve.mlperf.resnet")[:2]
             + registry.suite("hpc")[:3]
             + ["lm.tinyllama-1.1b.decode_32k"])
    kw = dict(configs=copa.TABLE_V, gpu_counts=(1, 2, 4),
              ici_bandwidth=600e9, extra_llc_capacities=[60 * MB, 960 * MB])
    _assert_grids_identical(SweepEngine(works, **kw).run(),
                            SweepEngine(works, **kw).run(batched=False))


def test_engine_batched_bit_identical_full_default_suite():
    """THE acceptance criterion: the full Fig-11 + Fig-12 + serve-grid
    default suite through one suite-batched pass equals the pre-refactor
    per-trace path bit for bit."""
    works = ([n for s in ("mlperf.train.large", "mlperf.train.small",
                          "mlperf.infer.large", "mlperf.infer.small")
              for n in registry.suite(s)]
             + registry.scaleout_names("scaleout.mlperf.train.")
             + registry.scenarios("serve.mlperf."))
    kw = dict(configs=copa.TABLE_V, gpu_counts=(1, 2, 4))
    _assert_grids_identical(SweepEngine(works, **kw).run(),
                            SweepEngine(works, **kw).run(batched=False))


def test_serve_cost_grids_still_match_engine_rows():
    """The suite-batched serve grid pricing must stay bit-identical to the
    engine's serve rows (the PR-4 acceptance, now through SuiteAnalysis)."""
    configs = [copa.GPU_N_BASE, copa.HBML_L3]
    grids = serve_cost_grids("resnet", configs)
    names = registry.scenarios("serve.mlperf.resnet.b")
    grid = SweepEngine(names, configs=configs).run()
    for name, g in grids.items():
        for b in g.batches:
            t = registry.scenario(f"serve.mlperf.resnet.b{b}").name
            assert g.step_time(b) == grid.result(t, name).time_s


# --- satellites ---------------------------------------------------------------

def test_prefill_cost_per_token_prices_from_trace():
    from repro.configs import SHAPES
    from repro.core.sweep import analysis_for

    configs = [copa.GPU_N_BASE, copa.HBML_L3]
    per_tok = prefill_cost_per_token("lm.tinyllama-1.1b.prefill_32k", configs)
    trace = registry.scenario("lm.tinyllama-1.1b.prefill_32k")
    tokens = trace.batch_size * SHAPES["prefill_32k"].seq_len
    want = analysis_for(trace).time_batch([c.build() for c in configs]) / tokens
    assert np.array_equal(per_tok, want)
    assert (per_tok > 0).all()
    with pytest.raises(KeyError):
        prefill_cost_per_token("lm.tinyllama-1.1b.decode_32k", configs)


def test_serve_cost_grids_prefill_scenario():
    configs = [copa.GPU_N_BASE, copa.HBML_L3]
    scen = "lm.tinyllama-1.1b.prefill_32k"
    grids = serve_cost_grids("gnmt", configs, tokens_per_pass=50,
                             prefill_scenario=scen)
    per_tok = prefill_cost_per_token(scen, configs)
    for ci, c in enumerate(configs):
        g = grids[c.name]
        assert g.prefill_s_per_token == float(per_tok[ci])
        # prefill_time scales linearly in prompt tokens from the real trace
        assert g.prefill_time(100) == pytest.approx(100 * float(per_tok[ci]))
    # flat-knob behaviour is unchanged when no scenario is given
    flat = serve_cost_grids("gnmt", configs, tokens_per_pass=50,
                            prefill_s_per_token=2e-7)
    assert all(g.prefill_s_per_token == 2e-7 for g in flat.values())


def test_registry_scenario_memoized_by_name():
    calls = {"n": 0}

    def factory():
        calls["n"] += 1
        tr = Trace("memo.probe")
        tr.emit("op", 1.0, writes=[("t", MB)])
        return tr

    name = "test.memo.probe"
    if name not in registry.names():
        registry.register(name, factory)
    a = registry.scenario(name)
    b = registry.scenario(name)
    c = registry.resolve(name)
    assert a is b is c
    assert calls["n"] == 1  # the factory ran exactly once


def test_registry_suite_analysis_entry():
    suite = registry.suite_analysis("mlperf.infer.small")
    assert suite.n_traces == len(registry.suite("mlperf.infer.small"))
    assert suite is suite_analysis_for(
        registry.suite_traces("mlperf.infer.small"))  # shared process cache
    glob = registry.suite_analysis("hpc.amber.*")
    assert glob.n_traces == len(registry.match("hpc.amber.*"))
    with pytest.raises(KeyError):
        registry.suite_analysis("no.such.suite")


# The randomized-property variant of this suite lives in
# tests/test_suite_properties.py (hypothesis importorskip-guarded, like the
# serving property suite); everything above runs without hypothesis.
