"""Optimizer + train-step tests: convergence, schedules, clipping, gradient
compression with error feedback, microbatch equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import OptimConfig, apply_updates, init_state, lr_at
from repro.train.step import (compress_grads, dequantize_int8, init_ef_state,
                              quantize_int8)


def test_adamw_converges_quadratic():
    cfg = OptimConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_ratio=1.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_state(params, cfg)
    target = jnp.array([1.0, 2.0])
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = apply_updates(params, grads, state, cfg)
    np.testing.assert_allclose(params["w"], target, atol=0.05)


def test_lr_schedule_shape():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert abs(float(lr_at(cfg, 10)) - 1.0) < 1e-6
    assert float(lr_at(cfg, 100)) == pytest.approx(0.1, abs=1e-6)
    assert float(lr_at(cfg, 55)) < float(lr_at(cfg, 11))


def test_grad_clip_applies():
    cfg = OptimConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = init_state(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = apply_updates(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_bf16_moment_state_dtype():
    cfg = OptimConfig(moment_dtype="bfloat16", master_weights=False)
    params = {"w": jnp.zeros(8, jnp.bfloat16)}
    state = init_state(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    assert "master" not in state


def test_int8_quantization_roundtrip():
    x = jnp.array([0.5, -1.0, 0.25, 127.0])
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    np.testing.assert_allclose(back, x, atol=float(s) + 1e-6)


def test_int8_ef_error_accumulates_to_zero_bias():
    """Error feedback: repeated compression of a constant gradient must pass
    the full magnitude through on average (EF re-injects residuals)."""
    g = {"w": jnp.full((64,), 0.003)}
    ef = init_ef_state(g, "int8_ef")
    total = jnp.zeros((64,))
    for _ in range(50):
        eff, ef = compress_grads(g, "int8_ef", ef)
        total = total + eff["w"]
    np.testing.assert_allclose(total / 50, g["w"], rtol=0.02)


def test_microbatch_equivalence():
    """microbatches=4 must produce (numerically close) identical updates to
    a single full batch — same loss gradient in expectation and value."""
    import repro.configs as C
    from repro.models import LanguageModel
    from repro.train import init_opt_state, make_train_step

    cfg = C.get("granite-3-2b").smoke()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimConfig(lr=1e-3)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    s1 = make_train_step(model, opt_cfg, microbatches=1)
    s4 = make_train_step(model, opt_cfg, microbatches=4)
    p1, o1, m1 = jax.jit(s1)(params, init_opt_state(params, opt_cfg), batch,
                             jax.random.PRNGKey(2))
    p4, o4, m4 = jax.jit(s4)(params, init_opt_state(params, opt_cfg), batch,
                             jax.random.PRNGKey(2))
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 0.02
    l1 = jax.tree.leaves(p1)[0].astype(jnp.float32)
    l4 = jax.tree.leaves(p4)[0].astype(jnp.float32)
    np.testing.assert_allclose(l1, l4, atol=0.02)
