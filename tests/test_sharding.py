"""Sharding-rule tests + multi-device integration on 8 fake CPU devices
(run in a subprocess so the main test session keeps 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from jax.sharding import PartitionSpec

from repro.launch.mesh import make_compat_mesh as _mesh
from repro.sharding.partition import resolve_spec


def test_resolve_spec_divisibility_degrades():
    mesh = _mesh((1, 1), ("data", "model"))
    # model=1 divides anything; heads shard onto model
    spec = resolve_spec((2048, 4096), ("embed", "heads"), mesh)
    assert spec == PartitionSpec("data", "model")


def test_resolve_spec_no_double_claim():
    mesh = _mesh((1, 1), ("data", "model"))
    # two ff axes: only one may claim "model"
    spec = resolve_spec((512, 512), ("ff", "ff"), mesh)
    assert list(spec).count("model") == 1


def test_resolve_spec_priority_experts_first():
    mesh = _mesh((1, 1), ("data", "model"))
    spec = resolve_spec((8, 64, 128), ("experts", "embed", "ff"), mesh)
    assert spec[0] == "model" and spec[1] == "data" and spec[2] is None


SUBPROC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    import repro.configs as C
    from repro.core import msm
    from repro.launch.mesh import make_host_mesh, set_default_mesh
    from repro.models import LanguageModel
    from repro.models.base import abstract_params
    from repro.sharding.partition import batch_spec, param_shardings
    from repro.train import OptimConfig, init_opt_state, make_train_step
    from repro.train.optim import state_shardings
    from jax.sharding import NamedSharding

    mesh = make_host_mesh(data=4, model=2)
    set_default_mesh(mesh)
    cfg = C.get("qwen3-moe-235b-a22b").smoke()
    model = LanguageModel(cfg)
    aparams = abstract_params(model.specs())
    sh = param_shardings(model.axes(), aparams, mesh)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)), sh)
    opt_cfg = OptimConfig(lr=1e-3)
    opt = jax.device_put(init_opt_state(params, opt_cfg),
                         state_shardings(sh, opt_cfg, mesh))
    step = make_train_step(model, opt_cfg, microbatches=2, grad_shardings=sh)
    jitted = jax.jit(step, donate_argnums=(0, 1))
    bsh = NamedSharding(mesh, batch_spec(mesh))
    tokens = jax.device_put(
        jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size), bsh)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for i in range(4):
        params, opt, metrics = jitted(params, opt, batch, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    # expert weights actually sharded over model axis
    we = params["layers"]["moe"]["w_gate"]
    assert len(we.sharding.device_set) == 8 or "model" in str(we.sharding.spec)
    print(json.dumps({"losses": losses}))
""")


@pytest.mark.slow
def test_multidevice_moe_train_8dev():
    """Sharded MoE training on 8 fake devices: loss finite + decreasing-ish."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    losses = json.loads(out.stdout.strip().splitlines()[-1])["losses"]
    assert all(l == l and l < 30 for l in losses)  # finite, sane
    assert losses[-1] < losses[0] + 0.5


ELASTIC_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + sys.argv[1]
    import json
    import jax, jax.numpy as jnp
    import repro.configs as C
    from repro.launch.train import main
    st = main(["--arch", "granite-3-2b-smoke", "--steps", sys.argv[2],
               "--global-batch", "4", "--seq-len", "32",
               "--ckpt-dir", sys.argv[3], "--save-every", "5",
               "--log-every", "100"])
    print(json.dumps({"step": st.step}))
""")


@pytest.mark.slow
def test_elastic_resume_across_device_counts(tmp_path):
    """Train on 4 devices, checkpoint, resume the SAME run on 2 devices —
    the restore path reshards onto the smaller mesh."""
    env = dict(os.environ, PYTHONPATH="src")
    cwd = os.path.dirname(os.path.dirname(__file__))
    d = str(tmp_path / "ck")
    out1 = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT, "4", "10", d],
                          env=env, capture_output=True, text=True,
                          timeout=560, cwd=cwd)
    assert out1.returncode == 0, out1.stderr[-2000:]
    out2 = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT, "2", "15", d],
                          env=env, capture_output=True, text=True,
                          timeout=560, cwd=cwd)
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert json.loads(out2.stdout.strip().splitlines()[-1])["step"] == 15
    assert "restored step 10" in out2.stdout
