"""Parity suite for the vectorized fleet core (`repro.serve.fleetbatch`).

The batched engine must be BIT-IDENTICAL to the per-instance heap oracle
(`FleetSim.run(..., batched=False)`): every request-timing column, every
per-instance step log, the final instance count, and the autoscaler's
scale-event trail.  The matrix below covers both routers, multi-instance
fleets, bursty arrivals, tight KV capacity, replayed simultaneous
arrivals, and autoscaling in both directions.

Randomized variants of the same invariant live in
tests/test_fleet_properties.py (hypothesis-gated).
"""
import numpy as np
import pytest

from repro.core.sweep import CostGrid
from repro.ft.elastic import QueueDepthAutoscaler
from repro.serve.fleet import FleetSim, instances_to_meet_slo, scan_fleet
from repro.serve.sim import (
    ArrivalSpec,
    LengthDist,
    Request,
    SimMetrics,
    Slo,
)


def flat_grid(step=1e-3, batches=(1, 2, 4, 8), prefill=0.0):
    tab = np.tile(np.asarray([step] * 3), (len(batches), 1))
    return CostGrid("flat", tuple(batches), (8.0, 64.0, float("inf")), tab,
                    prefill_s_per_token=prefill)


def ramp_grid():
    batches = (1, 2, 4)
    edges = (8.0, 64.0, 512.0)
    tab = np.asarray([[1e-3 + 1e-5 * b + 1e-6 * j for j in range(3)]
                      for b in batches])
    return CostGrid("ramp", batches, edges, tab, prefill_s_per_token=0.01)


def assert_same_result(a, b):
    """Bit-identity between two FleetResults (batched vs oracle)."""
    ab, bb = a.batch, b.batch
    for col in ("rid", "t_arrival", "prompt_tokens", "output_tokens",
                "t_admitted", "t_first_token", "t_done", "tokens_emitted",
                "evictions"):
        x, y = getattr(ab, col), getattr(bb, col)
        assert np.array_equal(x, y, equal_nan=(x.dtype.kind == "f")), \
            f"batch col {col} differs"
    assert len(a.step_logs) == len(b.step_logs)
    for k, (la, lb) in enumerate(zip(a.step_logs, b.step_logs)):
        for col in ("t_start", "t_end", "batch", "kv_reserved",
                    "queued", "admitted", "pages"):
            assert np.array_equal(getattr(la, col), getattr(lb, col)), \
                f"step log {k} col {col} differs"
    assert a.n_instances_final == b.n_instances_final
    assert a.scale_events == b.scale_events


def run_both(grid, kw, work, seed):
    rb = FleetSim(grid, **kw).run(work, seed=seed)
    ro = FleetSim(grid, **kw).run(work, seed=seed, batched=False)
    return rb, ro


@pytest.mark.parametrize("router", ["least_loaded", "round_robin"])
@pytest.mark.parametrize("n_instances", [1, 2, 3, 5])
def test_parity_poisson(router, n_instances):
    spec = ArrivalSpec("poisson", 400.0, 300,
                       prompt=LengthDist("fixed", 16),
                       output=LengthDist("uniform", low=1, high=8))
    kw = dict(n_instances=n_instances, router=router, max_batch=4,
              kv_capacity_tokens=4096.0)
    assert_same_result(*run_both(flat_grid(), kw, spec, seed=7))


def test_parity_bursty_with_prefill():
    spec = ArrivalSpec("bursty", 300.0, 400, burst_factor=4.0,
                       burst_fraction=0.3, period_s=0.25,
                       prompt=LengthDist("uniform", low=4, high=32),
                       output=LengthDist("uniform", low=1, high=16))
    kw = dict(n_instances=4, max_batch=4, kv_capacity_tokens=2048.0)
    assert_same_result(*run_both(ramp_grid(), kw, spec, seed=11))


def test_parity_kv_tight():
    spec = ArrivalSpec("kv", 500.0, 250,
                       prompt=LengthDist("uniform", low=16, high=64),
                       output=LengthDist("uniform", low=1, high=32))
    kw = dict(n_instances=2, max_batch=4, kv_capacity_tokens=160.0)
    assert_same_result(*run_both(ramp_grid(), kw, spec, seed=3))


def test_parity_replayed_simultaneous_arrivals():
    # 20 requests land at exactly t=0 — exercises the equal-timestamp
    # arrival ordering (arrivals before steps, FIFO within the wave).
    reqs = [Request(rid=i, t_arrival=0.0 if i < 20 else 0.001 * (i - 19),
                    prompt_tokens=3 + (i % 5), output_tokens=1 + (i % 7))
            for i in range(120)]
    kw = dict(n_instances=3, max_batch=4, kv_capacity_tokens=1e9)
    assert_same_result(*run_both(flat_grid(), kw, reqs, seed=0))


@pytest.mark.parametrize("name,rate,n0", [("up", 900.0, 1), ("down", 80.0, 6)])
def test_parity_autoscale(name, rate, n0):
    spec = ArrivalSpec(name, rate, 500, prompt=LengthDist("fixed", 16),
                       output=LengthDist("uniform", low=1, high=8))
    kw = dict(n_instances=n0, max_batch=4, kv_capacity_tokens=4096.0,
              autoscale_interval_s=0.05)
    rb = FleetSim(flat_grid(), autoscaler=QueueDepthAutoscaler(
        min_instances=1, max_instances=8), **kw).run(spec, seed=5)
    ro = FleetSim(flat_grid(), autoscaler=QueueDepthAutoscaler(
        min_instances=1, max_instances=8), **kw).run(spec, seed=5,
                                                     batched=False)
    assert_same_result(rb, ro)
    assert len(rb.scale_events) > 0
    if name == "up":
        assert rb.n_instances_final > n0
    else:
        assert rb.n_instances_final < n0


SCAN_SCENARIOS = {
    "poisson-tight": (ArrivalSpec("scan", 900.0, 400,
                                  prompt=LengthDist("fixed", 16),
                                  output=LengthDist("uniform", low=1,
                                                    high=8)),
                      Slo(ttft_s=0.05, tpot_s=0.01, e2e_s=2.0,
                          percentile=90.0)),
    "poisson-loose": (ArrivalSpec("scan", 300.0, 300,
                                  prompt=LengthDist("fixed", 16),
                                  output=LengthDist("uniform", low=1,
                                                    high=8)),
                      Slo(ttft_s=0.5, percentile=95.0)),
    "bursty": (ArrivalSpec("scan", 700.0, 400, burst_factor=3.0,
                           burst_fraction=0.25, period_s=0.2,
                           prompt=LengthDist("uniform", low=4, high=32),
                           output=LengthDist("uniform", low=1, high=12)),
               Slo(ttft_s=0.08, tpot_s=0.02, percentile=90.0)),
    "unmeetable": (ArrivalSpec("scan", 5000.0, 300,
                               prompt=LengthDist("fixed", 16),
                               output=LengthDist("fixed", 8)),
                   Slo(ttft_s=1e-4, percentile=50.0)),
}


@pytest.mark.parametrize("scenario", sorted(SCAN_SCENARIOS))
def test_scan_bisect_matches_linear(scenario):
    spec, slo = SCAN_SCENARIOS[scenario]
    kw = dict(max_batch=4, max_instances=8, seed=2)
    linear = instances_to_meet_slo(flat_grid(), spec, slo, batched=False,
                                   strategy="linear", **kw)
    bisect = instances_to_meet_slo(flat_grid(), spec, slo, batched=True,
                                   strategy="bisect", **kw)
    assert linear == bisect
    if scenario == "unmeetable":
        assert linear is None
        return

    scanned_l = scan_fleet(flat_grid(), spec, slo, strategy="linear",
                           batched=False, **kw)
    scanned_b = scan_fleet(flat_grid(), spec, slo, strategy="bisect",
                           batched=True, **kw)
    # bisection probes a subset of the linear ladder; every fleet size it
    # DID price must agree with the linear scan bit for bit
    assert scanned_b, "bisect scan probed no sizes"
    for n, m in scanned_b.items():
        if n not in scanned_l:
            continue
        ref = scanned_l[n]
        assert slo.met(m) == slo.met(ref)
        assert np.array_equal(m.ttft, ref.ttft)
        assert np.array_equal(m.tpot, ref.tpot)
        assert np.array_equal(m.e2e, ref.e2e)


def test_slo_tpot_percentile_ignores_single_token_requests():
    """Regression: a mostly-single-token workload must not dilute the TPOT
    percentile to zero.  90 single-token requests (tpot recorded as 0) plus
    10 multi-token requests each with a 1.0 s/token gap: at p50 the old
    full-population percentile saw 0.0 <= 0.5 and declared the SLO met; the
    percentile over multi-token requests only sees 1.0 > 0.5."""
    n_single, n_multi = 90, 10
    t_arr = np.zeros(n_single + n_multi)
    out = np.array([1] * n_single + [4] * n_multi)
    t_first = np.full(n_single + n_multi, 0.01)
    # multi-token requests emit their remaining 3 tokens at 1.0 s each
    t_done = np.where(out > 1, t_first + (out - 1) * 1.0, t_first)
    m = SimMetrics.from_arrays(t_arr, t_first, t_done, out)
    slo = Slo(tpot_s=0.5, percentile=50.0)
    assert not slo.met(m)
    # and the same population with fast multi-token decode passes
    t_done_fast = np.where(out > 1, t_first + (out - 1) * 0.1, t_first)
    m_fast = SimMetrics.from_arrays(t_arr, t_first, t_done_fast, out)
    assert slo.met(m_fast)
    # all-single-token population: TPOT target is vacuously met
    m_single = SimMetrics.from_arrays(t_arr[:n_single], t_first[:n_single],
                                      t_first[:n_single], out[:n_single])
    assert slo.met(m_single)


def test_batched_rejects_oversized_request():
    grid = flat_grid()
    reqs = [Request(rid=0, t_arrival=0.0, prompt_tokens=500,
                    output_tokens=4)]
    for batched in (True, False):
        with pytest.raises(ValueError, match="can never be"):
            FleetSim(grid, 2, max_batch=4, kv_capacity_tokens=100.0).run(
                reqs, batched=batched)
