"""Hypothesis property tests for the serving-simulator event core:
request conservation, a non-decreasing clock, and the batch-size /
KV-capacity admission invariants, over randomized arrival streams, grids,
and fleet shapes."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.sweep import CostGrid
from repro.serve.fleet import FleetSim
from repro.serve.sim import Request, simulate

INF = float("inf")

requests_st = st.lists(
    st.tuples(
        st.floats(min_value=0.0, max_value=0.05, allow_nan=False),
        st.integers(min_value=0, max_value=20),   # prompt tokens
        st.integers(min_value=1, max_value=5),    # output tokens
    ),
    min_size=1, max_size=40,
)

grid_st = st.tuples(
    st.floats(min_value=1e-4, max_value=1e-2),    # flat step seconds
    st.sampled_from([(1,), (1, 2, 4), (1, 8)]),   # priced batch buckets
    st.floats(min_value=0.0, max_value=1e-3),     # prefill s/token
)

# capacity always admits the largest possible single request (25 KV tokens)
kv_cap_st = st.one_of(st.just(INF), st.integers(min_value=25, max_value=120))


def _build(reqs, grid):
    step, batches, prefill = grid
    cost = CostGrid("prop", batches, (INF,),
                    np.full((len(batches), 1), step),
                    prefill_s_per_token=prefill)
    return [Request(rid=i, t_arrival=t, prompt_tokens=p, output_tokens=o)
            for i, (t, p, o) in enumerate(reqs)], cost


@settings(max_examples=60, deadline=None)
@given(reqs=requests_st, grid=grid_st, kv_cap=kv_cap_st)
def test_event_core_invariants(reqs, grid, kv_cap):
    reqs, cost = _build(reqs, grid)
    res = simulate(reqs, cost, kv_capacity_tokens=kv_cap)

    # conservation: every request completes exactly its output tokens,
    # causally ordered
    assert len(res.requests) == len(reqs)
    for r in res.requests:
        assert r.tokens_emitted == r.output_tokens
        assert r.t_arrival <= r.t_admitted
        assert r.t_admitted < r.t_first_token <= r.t_done

    log = res.step_log
    assert log.admitted.sum() == len(reqs)

    # non-decreasing clock: iterations are sequential and positive-length
    assert (log.t_end > log.t_start).all()
    assert (np.diff(log.t_start) >= 0).all()
    assert (log.t_start[1:] >= log.t_end[:-1] - 1e-12).all()

    # admission invariants: never over the batch bound, never over KV
    assert (log.batch >= 1).all()
    assert (log.batch <= cost.max_batch).all()
    assert (log.kv_reserved <= kv_cap + 1e-9).all()


@settings(max_examples=25, deadline=None)
@given(reqs=requests_st, grid=grid_st,
       n_instances=st.integers(min_value=1, max_value=4),
       router=st.sampled_from(["round_robin", "least_loaded"]))
def test_fleet_invariants(reqs, grid, n_instances, router):
    reqs, cost = _build(reqs, grid)
    res = FleetSim(cost, n_instances, router=router).run(reqs)
    for r in res.requests:
        assert r.tokens_emitted == r.output_tokens
        assert r.t_arrival <= r.t_admitted < r.t_first_token <= r.t_done
    assert sum(log.admitted.sum() for log in res.step_logs) == len(reqs)
    for log in res.step_logs:
        if len(log.batch):
            assert (log.batch <= cost.max_batch).all()
            assert (log.t_start[1:] >= log.t_end[:-1] - 1e-12).all()
