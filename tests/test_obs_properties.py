"""Hypothesis property tests for the observability derivations: for ANY
window width, the windowed rollup must re-partition the aggregate metrics
without losing a request, a token, or a second of busy time — and the
Chrome trace export must stay schema-valid over randomized fleet shapes.

Fixed-seed deterministic variants live in tests/test_obs.py.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.obs.timeline import chrome_trace, validate_chrome_trace
from repro.serve.fleet import FleetSim
from repro.serve.sim import ArrivalSpec, LengthDist, ObsConfig, Slo

from test_fleet_batch import ramp_grid


def _run(n_instances, n_requests, rate, seed):
    spec = ArrivalSpec("obs-prop", rate, n_requests,
                       prompt=LengthDist("uniform", low=1, high=40),
                       output=LengthDist("uniform", low=1, high=12))
    return FleetSim(ramp_grid(), n_instances, max_batch=4,
                    kv_capacity_tokens=2048.0,
                    obs=ObsConfig(level=1)).run(spec, seed=seed)


@settings(max_examples=25, deadline=None)
@given(n_instances=st.integers(min_value=1, max_value=4),
       n_requests=st.integers(min_value=1, max_value=150),
       rate=st.floats(min_value=50.0, max_value=1200.0),
       seed=st.integers(min_value=0, max_value=2**31 - 1),
       # spans windows-much-wider-than-run down to thousands of windows
       window_s=st.floats(min_value=1e-4, max_value=60.0))
def test_timeseries_repartitions_aggregates(n_instances, n_requests, rate,
                                            seed, window_s):
    res = _run(n_instances, n_requests, rate, seed)
    slo = Slo(ttft_s=0.02, percentile=95)
    s = res.timeseries(window_s, slo=slo)
    m = res.metrics
    assert int(s.arrived.sum()) == n_requests
    assert int(s.completed.sum()) == n_requests
    assert int(s.tokens.sum()) == int(res.batch.output_tokens.sum())
    assert int(s.ok.sum()) == int(slo.ok_mask(m).sum())
    total_busy = sum(float((sl.t_end - sl.t_start).sum())
                     for sl in res.step_logs)
    assert np.isclose(s.busy_s.sum(), total_busy, rtol=1e-9, atol=1e-12)
    # weighted integrals never exceed their bounds
    assert np.all(s.busy_s <= s.capacity_s * (1 + 1e-9) + 1e-12)
    assert np.all((s.batch_mean >= 0) & (s.queue_mean >= 0))


@settings(max_examples=15, deadline=None)
@given(n_instances=st.integers(min_value=1, max_value=4),
       n_requests=st.integers(min_value=1, max_value=120),
       rate=st.floats(min_value=50.0, max_value=1200.0),
       seed=st.integers(min_value=0, max_value=2**31 - 1),
       max_requests=st.one_of(st.none(),
                              st.integers(min_value=1, max_value=50)))
def test_chrome_trace_always_schema_valid(n_instances, n_requests, rate,
                                          seed, max_requests):
    res = _run(n_instances, n_requests, rate, seed)
    doc = chrome_trace(res, max_requests=max_requests)
    assert validate_chrome_trace(doc) == []
    kept = doc["otherData"]["n_requests"]
    assert kept == min(n_requests, max_requests or n_requests)
    assert doc["otherData"]["dropped_requests"] == n_requests - kept
