"""Fault-tolerance demo: train with injected failures and watch the elastic
runner recover from atomic checkpoints.

    PYTHONPATH=src python examples/fault_tolerance_demo.py

Injects a simulated node failure at step 12; the ElasticRunner restarts the
segment, restores the step-10 checkpoint, and completes to step 25. The
watchdog/straggler machinery is live throughout.
"""
import sys
import tempfile

sys.path.insert(0, "src")

import jax

import repro.configs as configs
from repro.checkpoint.ckpt import restore
from repro.ft import ElasticRunner, RunState, StepWatchdog
from repro.launch.mesh import make_host_mesh
from repro.models import LanguageModel
from repro.train import OptimConfig, init_opt_state, make_train_step

STEPS, FAIL_AT, SAVE_EVERY = 25, 12, 5
crashes = {"n": 0}


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="ft_demo_")
    cfg = configs.get("tinyllama-1.1b").smoke()
    model = LanguageModel(cfg)
    opt_cfg = OptimConfig(lr=1e-3)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    jitted = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))

    def build_state(mesh, restore_step):
        if restore_step is not None:
            _, tree, extra = restore(ckpt_dir)
            print(f"[demo] restored checkpoint at step {extra['step']}")
            return RunState(params=tree["params"], opt_state=tree["opt"],
                            step=int(extra["step"]))
        params = model.init(jax.random.PRNGKey(0))
        return RunState(params=params,
                        opt_state=init_opt_state(params, opt_cfg), step=0)

    def segment(runner, st, max_steps):
        with StepWatchdog(deadline_s=120) as wd:
            while st.step < max_steps:
                wd.step_started()
                st.params, st.opt_state, m = jitted(
                    st.params, st.opt_state, batch,
                    jax.random.PRNGKey(st.step))
                wd.step_finished()
                st.step += 1
                runner.maybe_save(st)
                print(f"step {st.step:3d} loss {float(m['loss']):7.4f}")
                if st.step == FAIL_AT and crashes["n"] == 0:
                    crashes["n"] += 1
                    runner.ckpt.wait()
                    raise RuntimeError("simulated node failure (ICI timeout)")
        runner.maybe_save(st, force=True)
        runner.ckpt.wait()
        return st

    runner = ElasticRunner(ckpt_dir, make_host_mesh, build_state, segment,
                           save_every=SAVE_EVERY)
    st = runner.run(STEPS)
    print(f"[demo] completed at step {st.step} after "
          f"{crashes['n']} injected failure(s)")
    assert st.step == STEPS


if __name__ == "__main__":
    main()
