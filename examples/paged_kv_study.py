"""COPA vs KV compression: two routes to serving capacity, one cost grid.

The COPA paper buys HBM capacity with hardware — a memory-system module
(MSM) like HBML+L3 adds 1.67x DRAM + bandwidth on package. Buddy
Compression (arXiv 1903.02596) buys capacity in software instead: KV pages
compress ~2x, at a bandwidth tax on every compressed access. This study
prices both routes through the SAME paged serving stack and asks where
each one wins:

1. derive the per-instance KV token budget per (config, policy) from the
   model's real weight footprint (``msm.kv_reserve_frac`` — a 29B MHA
   model eats 55 GiB of GPU-N's 100 GiB, so only ~40% is left for KV);
2. price per-step costs with the compression bandwidth tax folded into
   the KV sweep buckets (``serve_cost_grids(..., kv_policy=...)``);
3. replay one diurnal chat trace (``arrivals.diurnal.chat`` — evening-peak
   hourly profile) through paged fleets (block-table residency,
   ``PagedKvSpec``) across config x compression x oversubscription, and
   size each fleet against a TTFT SLO via :func:`instances_to_meet_slo`.

The punchline the assertions pin down: on capacity-starved GPU-N the 2x
ratio converts straight into batch occupancy and SHRINKS the SLO fleet,
while on HBML+L3 — whose MSM already bought enough DRAM that the batch
bound binds first — the same knob is pure bandwidth tax and GROWS the
fleet. Which route wins is a property of the config, not of compression.

The run also drops a Chrome-trace timeline of the most eviction-pressured
cell (open ``paged_kv_timeline.json`` in chrome://tracing or
https://ui.perfetto.dev) and prints its windowed metric rollup — the
``repro.obs`` view of where inside the diurnal profile the evictions and
the TTFT tail actually live.

    PYTHONPATH=src python examples/paged_kv_study.py [--fleet 12]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig
from repro.core import copa, msm
from repro.core.sweep import serve_cost_grids
from repro.obs.timeline import write_chrome_trace
from repro.serve.fleet import FleetSim, instances_to_meet_slo
from repro.serve.paged import PagedKvSpec
from repro.serve.sim import ObsConfig, Slo
from repro.workloads import registry

# A dense 29B MHA model: full-width K+V per layer per token, so KV is
# expensive (1.5 MiB/token bf16) and the weight footprint (55 GiB) eats
# most of a 100 GiB part — the regime where KV residency decides batch.
MODEL = ModelConfig(name="study-29b-mha", family="dense", n_layers=60,
                    d_model=6656, n_heads=52, n_kv_heads=52, d_ff=17920,
                    vocab_size=128256)
ELEMS_PER_TOKEN = 2 * MODEL.n_layers * MODEL.d_model
KV_BYTES_PER_TOKEN = ELEMS_PER_TOKEN * 2.0          # bf16

CONFIGS = [copa.GPU_N_BASE, copa.HBML_L3]           # base die vs big-DRAM MSM
POLICIES = {
    "off": msm.DECODE_MSM,
    "2x":  msm.compose("msm_decode", kv_compression_ratio=2.0,
                       kv_compression_bw_tax=0.25),
}
PAGE = 16
SEQ_EDGES = (96_000.0,)      # one resident bucket: both policies price the
                             # same sweep footprint, tax excepted
MAX_BATCH = 64
SEED = 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", type=int, default=12,
                    help="fixed fleet size for the goodput column")
    ap.add_argument("--max-instances", type=int, default=48)
    ap.add_argument("--trace-out", default="paged_kv_timeline.json",
                    help="Chrome-trace timeline of the most evicting cell "
                         "('' to skip)")
    args = ap.parse_args()

    trace = registry.arrivals("arrivals.diurnal.chat")
    slo = Slo(ttft_s=2.0, percentile=95)
    grid_kw = dict(tokens_per_pass=50, kv_bytes_per_token=KV_BYTES_PER_TOKEN,
                   seq_edges=SEQ_EDGES, page_size=PAGE,
                   prefill_s_per_token=2e-5)
    grids = {pol: serve_cost_grids("gnmt", CONFIGS, kv_policy=POLICIES[pol],
                                   **grid_kw)
             for pol in POLICIES}

    print(f"model {MODEL.name}: {MODEL.n_params() / 1e9:.1f}B params, "
          f"{KV_BYTES_PER_TOKEN / 2**20:.2f} MiB KV/token")
    print(f"trace {trace.name}: {trace.rate:.0f} r/s mean, "
          f"{trace.n_requests} requests, {len(trace.profile)}-slot profile")
    print(f"SLO: TTFT p{slo.percentile:.0f} <= {slo.ttft_s:.1f}s   "
          f"goodput at a fixed fleet of {args.fleet}\n")

    hdr = (f"{'config':10s} {'comp':4s} {'oversub':7s} {'kv cap':>9s} "
           f"{'fleet':>5s} {'goodput':>9s} {'ttft p95':>9s} {'evict':>5s}")
    print(hdr)
    print("-" * len(hdr))
    fleet_for = {}
    hot = None          # (evictions, cell label, grid, kw) — worst cell
    t0 = time.time()
    for cfg in CONFIGS:
        spec = cfg.build()
        for pol in POLICIES:
            cap = float(msm.kv_token_capacity(spec, POLICIES[pol],
                                              ELEMS_PER_TOKEN,
                                              model_config=MODEL))
            grid = grids[pol][cfg.name]
            for oversub, evict in ((1.0, "none"), (1.5, "lru")):
                paged = PagedKvSpec(page_size=PAGE, oversubscription=oversub,
                                    eviction=evict)
                kw = dict(max_batch=MAX_BATCH, kv_capacity_tokens=cap,
                          paged=paged)
                n = instances_to_meet_slo(
                    grid, trace, slo, seed=SEED,
                    max_instances=args.max_instances, **kw)
                res = FleetSim(grid, args.fleet, **kw).run(trace, seed=SEED)
                m = res.metrics
                evs = int(res.batch.evictions.sum())
                print(f"{cfg.name:10s} {pol:4s} {oversub:7.1f} {cap:9.0f} "
                      f"{str(n):>5s} {m.goodput_rps(slo):7.1f}r/s "
                      f"{m.percentile('ttft', 95):8.3f}s "
                      f"{evs:5d}")
                if hot is None or evs > hot[0]:
                    hot = (evs, f"{cfg.name}/{pol}/x{oversub}", grid, kw)
                if oversub == 1.0:
                    fleet_for[cfg.name, pol] = n
    print(f"\n[{time.time() - t0:.1f}s total]")

    if args.trace_out:
        # re-run the worst cell with the obs column on: the timeline gets
        # prefill/decode phase naming on its step spans (timing is
        # bit-identical with the knob on — asserted in tests/test_obs.py)
        _, label, grid, kw = hot
        res = FleetSim(grid, args.fleet, obs=ObsConfig(level=1),
                       **kw).run(trace, seed=SEED)
        doc = write_chrome_trace(args.trace_out, res, max_requests=2_000)
        series = res.timeseries(res.metrics.makespan_s / 12, slo=slo)
        print(f"\ntimeline of {label} -> {args.trace_out} "
              f"({len(doc['traceEvents'])} events; chrome://tracing)")
        print(series.table())

    n_base_off = fleet_for["GPU-N", "off"]
    n_base_2x = fleet_for["GPU-N", "2x"]
    n_msm_off = fleet_for["HBML+L3", "off"]
    n_msm_2x = fleet_for["HBML+L3", "2x"]
    # The study's claims, pinned: compression must change the fleet size in
    # opposite directions on the two configs.
    assert n_base_2x < n_base_off, \
        "compression should shrink the capacity-bound GPU-N fleet"
    assert n_msm_2x > n_msm_off, \
        "compression should cost the batch-bound HBML+L3 fleet instances"
    print(f"GPU-N:   compression shrinks the SLO fleet "
          f"{n_base_off} -> {n_base_2x} (capacity-bound: 2x ratio becomes "
          f"batch occupancy)")
    print(f"HBML+L3: compression grows the SLO fleet "
          f"{n_msm_off} -> {n_msm_2x} (batch-bound already: the knob is "
          f"pure bandwidth tax)")
    print(f"at a {n_base_2x}-instance budget the winning config flips: "
          f"without compression only HBML+L3 meets the SLO "
          f"(GPU-N needs {n_base_off}); with it, GPU-N does too — the "
          f"software knob substitutes for the MSM upgrade on this trace.")


if __name__ == "__main__":
    main()
