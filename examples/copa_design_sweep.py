"""The paper's design-space exploration through the public API: sweep the
COPA configurations (Table V) over the MLPerf-proxy suite AND the assigned
LM architectures, and print the Fig-11-style table plus the software-MSM
recommendation per LM cell.

    PYTHONPATH=src python examples/copa_design_sweep.py
"""
import sys

sys.path.insert(0, "src")

import repro.configs as configs
from repro.core import copa, hw, msm, perfmodel
from repro.core.hw import MB
from repro.workloads import mlperf
from repro.workloads.lm import arch_trace


def paper_suite_table():
    print("=== COPA design space (Table V / Fig 11) — MLPerf proxies ===")
    pms = {}

    def pm(t):
        return pms.setdefault(t.name, perfmodel.PerfModel(t))

    header = f"{'config':12s} {'train-lb':>9s} {'train-sb':>9s} {'infer-lb':>9s} {'infer-sb':>9s}"
    print(header)
    for cfg in copa.TABLE_V:
        spec = cfg.build()
        cells = []
        for suite in (mlperf.training_suite("large"),
                      mlperf.training_suite("small"),
                      mlperf.inference_suite("large"),
                      mlperf.inference_suite("small")):
            sp = perfmodel.geomean(
                pm(t).time(hw.GPU_N) / pm(t).time(spec) for t in suite)
            cells.append(f"{sp:9.3f}")
        print(f"{cfg.name:12s} " + " ".join(cells))


def arch_msm_table():
    print("\n=== Assigned architectures: COPA analysis + software-MSM ===")
    for arch in configs.ARCHS:
        for shape in ("train_4k", "decode_32k"):
            t = arch_trace(arch, shape)
            an = msm.analyze(t)
            red = min(an.baseline_traffic / max(an.sweep[960 * MB], 1e-9), 999)
            policy = msm.recommend(shape, configs.get(arch).n_params())
            print(f"{arch:24s} {shape:10s} 960MB-filter={red:6.1f}x  "
                  f"msm={policy.name:16s} ({policy.describe()})")


if __name__ == "__main__":
    paper_suite_table()
    arch_msm_table()
