"""The paper's design-space exploration through the public API: one
SweepEngine grid over the COPA configurations (Table V) x the MLPerf-proxy
suites AND the assigned LM architectures, printing the Fig-11-style table
plus the software-MSM recommendation per LM cell.

    PYTHONPATH=src python examples/copa_design_sweep.py
"""
import sys

sys.path.insert(0, "src")

import repro.configs as configs
from repro.core import copa, msm
from repro.core.hw import MB
from repro.core.sweep import SweepEngine
from repro.workloads import registry

SUITES = ("mlperf.train.large", "mlperf.train.small",
          "mlperf.infer.large", "mlperf.infer.small")


def paper_suite_table():
    print("=== COPA design space (Table V / Fig 11) — MLPerf proxies ===")
    names = [n for s in SUITES for n in registry.suite(s)]
    grid = SweepEngine(names, configs=copa.TABLE_V).run()
    header = f"{'config':12s} {'train-lb':>9s} {'train-sb':>9s} {'infer-lb':>9s} {'infer-sb':>9s}"
    print(header)
    for cfg in copa.TABLE_V:
        cells = []
        for s in SUITES:
            traces = [registry.scenario(n).name for n in registry.suite(s)]
            cells.append(f"{grid.geomean_speedup(cfg.name, traces):9.3f}")
        print(f"{cfg.name:12s} " + " ".join(cells))


def arch_msm_table():
    print("\n=== Assigned architectures: COPA analysis + software-MSM ===")
    for arch in configs.ARCHS:
        for shape in ("train_4k", "decode_32k"):
            t = registry.scenario(f"lm.{arch}.{shape}")
            an = msm.analyze(t)
            red = min(an.baseline_traffic / max(an.sweep[960 * MB], 1e-9), 999)
            policy = msm.recommend(shape, configs.get(arch).n_params())
            print(f"{arch:24s} {shape:10s} 960MB-filter={red:6.1f}x  "
                  f"msm={policy.name:16s} ({policy.describe()})")


if __name__ == "__main__":
    paper_suite_table()
    arch_msm_table()
