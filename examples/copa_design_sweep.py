"""The paper's design-space exploration through the public API: one
SweepEngine grid over the COPA configurations (Table V) x the MLPerf-proxy
suites AND the assigned LM architectures, printing the Fig-11-style table,
the Fig-12-style scale-out projection (instances x ICI fabric), the serving
latency/throughput grid per MSM, the software-MSM recommendation per LM
cell, and the one-call FULL-REGISTRY sweep (every scenario namespace x
Table V through a single suite-batched pass).

    PYTHONPATH=src python examples/copa_design_sweep.py
"""
import sys
import time

sys.path.insert(0, "src")

import repro.configs as configs
from repro.core import copa, msm
from repro.core.hw import MB
from repro.core.sweep import SweepEngine, geomean
from repro.workloads import registry

SUITES = ("mlperf.train.large", "mlperf.train.small",
          "mlperf.infer.large", "mlperf.infer.small")


def paper_suite_table():
    print("=== COPA design space (Table V / Fig 11) — MLPerf proxies ===")
    names = [n for s in SUITES for n in registry.suite(s)]
    grid = SweepEngine(names, configs=copa.TABLE_V).run()
    header = f"{'config':12s} {'train-lb':>9s} {'train-sb':>9s} {'infer-lb':>9s} {'infer-sb':>9s}"
    print(header)
    for cfg in copa.TABLE_V:
        cells = []
        for s in SUITES:
            traces = [registry.scenario(n).name for n in registry.suite(s)]
            cells.append(f"{grid.geomean_speedup(cfg.name, traces):9.3f}")
        print(f"{cfg.name:12s} " + " ".join(cells))


def scale_out_table():
    """Fig-12-style projection: fixed-global-batch DP training across 1/2/4
    GPU instances, ideal fabric vs a 600 GB/s ring all-reduce."""
    print("\n=== Scale-out projection (Fig 12): instances x ICI fabric ===")
    works = registry.scaleout_names("scaleout.mlperf.train.")
    names = [registry.scaleout(w).name for w in works]
    for label, ici in (("ideal fabric", float("inf")),
                       ("600GB/s ring", 600e9)):
        grid = SweepEngine(works, configs=[copa.GPU_N_BASE, copa.HBML_L3],
                           gpu_counts=(1, 2, 4), ici_bandwidth=ici).run()
        copa1 = grid.geomean_speedup("HBML+L3", names)
        n2 = geomean(grid.speedups("GPU-N", names, n_gpus=2))
        n4 = geomean(grid.speedups("GPU-N", names, n_gpus=4))
        eff2 = geomean(grid.result(t, "GPU-N", 2).scaling_efficiency
                       for t in names)
        reached = [n for n in
                   grid.instances_to_match("GPU-N", "HBML+L3", names).values()
                   if n is not None]
        inst = sum(reached) / len(reached) if reached else float("nan")
        print(f"{label:14s} HBML+L3@1={copa1:5.3f}  GPU-Nx2={n2:5.3f} "
              f"(eff {eff2:4.2f})  GPU-Nx4={n4:5.3f}  "
              f"GPU-N instances/COPA={inst:.2f} "
              f"({len(reached)}/{len(names)} matchable)")


def serve_grid_table():
    """Serving latency/throughput grid: batched decode per MSM config."""
    print("\n=== Serving grid: batch x MSM (per-request latency, ms) ===")
    configs_ = [copa.GPU_N_BASE, copa.HBM_L3, copa.HBML_L3]
    header = f"{'batch':>6s}" + "".join(f" {c.name:>10s}" for c in configs_)
    print(header)
    for b in registry.SERVE_BATCHES:
        names = registry.suite(f"serve.b{b}")
        grid = SweepEngine(names, configs=configs_).run()
        cells = []
        for c in configs_:
            lat = geomean(grid.result(registry.scenario(n).name, c.name).time_s
                          for n in names) * 1e3
            cells.append(f" {lat:10.3f}")
        print(f"{b:6d}" + "".join(cells))


def arch_msm_table():
    print("\n=== Assigned architectures: COPA analysis + software-MSM ===")
    cells = [(arch, shape) for arch in configs.ARCHS
             for shape in ("train_4k", "decode_32k")]
    # One suite-batched Fig-4 pass over all 20 cells (msm.analyze_suite),
    # instead of one trace walk per cell.
    traces = [registry.scenario(f"lm.{a}.{s}") for a, s in cells]
    for (arch, shape), an in zip(cells, msm.analyze_suite(traces)):
        red = min(an.baseline_traffic / max(an.sweep[960 * MB], 1e-9), 999)
        policy = msm.recommend(shape, configs.get(arch).n_params())
        print(f"{arch:24s} {shape:10s} 960MB-filter={red:6.1f}x  "
              f"msm={policy.name:16s} ({policy.describe()})")


def full_registry_sweep():
    """Every registered scenario x Table V in ONE suite-batched pass —
    the design-space product the per-trace loop made impractical."""
    print("\n=== Full-registry sweep: one StreamBatch pass ===")
    names = registry.scenarios()
    t0 = time.time()
    grid = SweepEngine(names, configs=copa.TABLE_V).run()
    dt = time.time() - t0
    print(f"{len(names)} scenarios x {len(copa.TABLE_V)} configs -> "
          f"{len(grid.rows)} rows in {dt * 1e3:.0f}ms")
    by_ns = {"mlperf.train": "mlperf.train.", "mlperf.infer": "mlperf.infer.",
             "serve": "serve.", "lm": "lm.", "hpc": "hpc."}
    import math

    for label, prefix in by_ns.items():
        traces = [registry.scenario(n).name for n in names
                  if n.startswith(prefix)]
        sp = [s for s in grid.speedups("HBML+L3", traces)
              if math.isfinite(s) and s > 0]
        geo = geomean(sp)
        note = "" if len(sp) == len(traces) else \
            f" ({len(traces) - len(sp)} degenerate cells skipped)"
        print(f"  {label:14s} {len(traces):4d} scenarios  "
              f"HBML+L3 geomean speedup {geo:.3f}{note}")


if __name__ == "__main__":
    paper_suite_table()
    scale_out_table()
    serve_grid_table()
    arch_msm_table()
    full_registry_sweep()
